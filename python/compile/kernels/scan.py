"""L1 Pallas kernel: Gantt feasibility scan (earliest-hole finding).

OAR's meta-scheduler walks its Gantt structure per job to find the first
hole wide enough (duration) and tall enough (resource count).  Batched, that
walk is a consecutive-run scan over a (jobs x time-slots) free-resource-count
matrix: run[j,t] = length of the streak of slots ending at t with
freecount >= req; the earliest start is the first t where the streak reaches
the job's duration.

The kernel tiles over jobs only — each program holds a full (Jt, T) timeline
slab in VMEM (64 x 96 f32 = 24 KB) and performs the T-step sequential scan
with a fori_loop; the scan is inherently sequential in t but fully vector
(8x128-lane) across jobs, which is the layout the VPU wants.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_tile(fc_ref, req_ref, dur_ref, out_ref):
    fc = fc_ref[...]            # [Jt, T]
    req = req_ref[...]          # [Jt, 1]
    dur = dur_ref[...]          # [Jt, 1]
    Jt, T = fc.shape
    ok = fc >= req              # [Jt, T]

    def body(t, carry):
        run, earliest = carry
        ok_t = ok[:, t]
        run = jnp.where(ok_t, run + 1.0, 0.0)
        start = jnp.float32(t) - dur[:, 0] + 1.0
        hit = (run >= dur[:, 0]) & (earliest < 0.0)
        earliest = jnp.where(hit, start, earliest)
        return run, earliest

    run0 = jnp.zeros((Jt,), jnp.float32)
    e0 = jnp.full((Jt,), -1.0, jnp.float32)
    _, earliest = jax.lax.fori_loop(0, T, body, (run0, e0))
    out_ref[...] = earliest[:, None]


@functools.partial(jax.jit, static_argnames=("block_j",))
def scan_pallas(freecount, req, dur, *, block_j=64):
    """Earliest feasible start slot f32[J] (-1 when nothing fits)."""
    J, T = freecount.shape
    bj = min(block_j, J)
    assert J % bj == 0, "pad J to a block multiple"
    out = pl.pallas_call(
        _scan_tile,
        grid=(J // bj,),
        in_specs=[
            pl.BlockSpec((bj, T), lambda i: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bj, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((J, 1), jnp.float32),
        interpret=True,
    )(freecount, req[:, None], dur[:, None])
    return out[:, 0]
