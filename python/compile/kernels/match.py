"""L1 Pallas kernel: job-on-node eligibility (resource matching).

OAR matched resources with a per-job SQL WHERE clause evaluated row-by-row
against the nodes table.  Here the predicate set is normalized to interval
constraints and the whole (jobs x nodes) matrix is computed in one tiled
kernel: the grid walks (J/Jt, N/Nt) tiles, each program holds a (Jt, P) job
slab and an (Nt, P) node slab in VMEM and emits a (Jt, Nt) eligibility tile.

TPU sizing (see DESIGN.md §Hardware-Adaptation): with Jt=64, Nt=128, P=8 the
operands are 64*8 + 128*8 floats (6 KB) and the broadcast intermediate is
64*128*8 f32 = 256 KB — comfortably inside one core's ~16 MB VMEM, with the
output tile (Jt, Nt) laid out (8-sublane, 128-lane) friendly.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls, and lowering under interpret produces plain HLO that the Rust
runtime executes directly.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _match_tile(lo_ref, hi_ref, props_ref, out_ref):
    """One (Jt, Nt) tile: reduce-AND of interval tests over the P axis."""
    lo = lo_ref[...]          # [Jt, P]
    hi = hi_ref[...]          # [Jt, P]
    props = props_ref[...]    # [Nt, P]
    ok = (props[None, :, :] >= lo[:, None, :]) & (
        props[None, :, :] <= hi[:, None, :]
    )  # [Jt, Nt, P]
    out_ref[...] = jnp.all(ok, axis=-1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_j", "block_n"))
def match_pallas(job_lo, job_hi, node_props, *, block_j=64, block_n=128):
    """Eligibility matrix f32[J, N]; J % block_j == 0 and N % block_n == 0
    are not required — pl handles ragged edges via masking in interpret mode
    only when shapes divide, so we require divisibility and let callers pad."""
    J, P = job_lo.shape
    N, _ = node_props.shape
    bj = min(block_j, J)
    bn = min(block_n, N)
    assert J % bj == 0 and N % bn == 0, "pad J and N to block multiples"
    grid = (J // bj, N // bn)
    return pl.pallas_call(
        _match_tile,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bj, P), lambda i, j: (i, 0)),
            pl.BlockSpec((bj, P), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, P), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bj, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((J, N), jnp.float32),
        interpret=True,
    )(job_lo, job_hi, node_props)
