"""L1: Pallas kernels for the scheduler's compute hot-spot.

``match_pallas`` — jobs-x-nodes eligibility (resource matching).
``scan_pallas``  — Gantt feasibility scan (earliest-hole finding).
``ref``          — pure-jnp oracle both are tested against.
"""
from .match import match_pallas
from .scan import scan_pallas

__all__ = ["match_pallas", "scan_pallas"]
