"""Pure-jnp reference oracle for the L1 Pallas kernels.

These functions define the *semantics* of the scheduling compute hot-spot;
the Pallas kernels in ``match.py`` / ``scan.py`` must agree bit-for-bit (up
to float tolerance) with them.  They are also what the Rust fallback in
``rust/src/matching/reference.rs`` mirrors.

Semantics
---------
``match_ref(job_lo, job_hi, node_props) -> elig``
    ``elig[j, n] = 1.0`` iff for every property ``p``:
    ``job_lo[j, p] <= node_props[n, p] <= job_hi[j, p]``.
    This is OAR's SQL ``properties`` WHERE-clause matching, vectorized: every
    property constraint is normalized to an interval (equality ``= v`` becomes
    ``[v, v]``, ``>= v`` becomes ``[v, +inf]``, an absent constraint becomes
    ``[-inf, +inf]``).

``scan_ref(freecount, req, dur) -> earliest``
    ``earliest[j]`` = smallest slot ``s`` such that ``s + dur[j] <= T`` and
    ``freecount[j, t] >= req[j]`` for every ``t`` in ``[s, s + dur[j])``;
    ``-1.0`` when no such window exists in the horizon.  This is the Gantt
    hole-finding walk of OAR's meta-scheduler, batched over jobs.
"""
import jax
import jax.numpy as jnp


def match_ref(job_lo, job_hi, node_props):
    """Eligibility matrix: jobs x nodes interval containment over properties.

    job_lo, job_hi: f32[J, P]; node_props: f32[N, P] -> f32[J, N] in {0, 1}.
    """
    props = node_props[None, :, :]  # [1, N, P]
    ok = (props >= job_lo[:, None, :]) & (props <= job_hi[:, None, :])
    return jnp.all(ok, axis=-1).astype(jnp.float32)


def scan_ref(freecount, req, dur):
    """Earliest feasible start slot per job, -1 if none fits the horizon.

    freecount: f32[J, T]; req: f32[J]; dur: f32[J] (slots, >= 1) -> f32[J].
    """
    J, T = freecount.shape
    ok = freecount >= req[:, None]  # [J, T]

    # run[j, t] = length of the consecutive-ok run ending at t (inclusive).
    def step(run_prev, ok_t):
        run = jnp.where(ok_t, run_prev + 1.0, 0.0)
        return run, run

    _, runs = jax.lax.scan(step, jnp.zeros((J,), jnp.float32), ok.T)
    runs = runs.T  # [J, T]
    feasible = runs >= dur[:, None]  # window ending at t of length dur is ok
    start = jnp.arange(T, dtype=jnp.float32)[None, :] - dur[:, None] + 1.0
    cand = jnp.where(feasible, start, jnp.inf)
    earliest = jnp.min(cand, axis=1)
    return jnp.where(jnp.isinf(earliest), -1.0, earliest)


def schedule_step_ref(job_lo, job_hi, node_props, node_free, req, dur,
                      job_feats, weights):
    """Full L2 reference: (elig, freecount, earliest, scores)."""
    elig = match_ref(job_lo, job_hi, node_props)
    freecount = elig @ node_free  # [J, N] @ [N, T] -> [J, T]
    earliest = scan_ref(freecount, req, dur)
    scores = job_feats @ weights  # [J, F] @ [F] -> [J]
    return elig, freecount, earliest, scores
