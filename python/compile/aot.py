"""AOT entry point: lower ``schedule_step`` to HLO *text* for the Rust runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --out ../artifacts/schedule_step.hlo.txt
Also writes a JSON manifest with the compile shapes next to the artifact so
the Rust side can assert it pads to the right dimensions.
"""
import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/schedule_step.hlo.txt")
    args = ap.parse_args()

    lowered = jax.jit(model.schedule_step).lower(*model.example_args())
    text = to_hlo_text(lowered)

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)

    manifest = {
        "entry": "schedule_step",
        "J": model.J, "N": model.N, "P": model.P,
        "T": model.T, "F": model.F,
        "inputs": [
            {"name": "job_lo", "shape": [model.J, model.P]},
            {"name": "job_hi", "shape": [model.J, model.P]},
            {"name": "node_props", "shape": [model.N, model.P]},
            {"name": "node_free", "shape": [model.N, model.T]},
            {"name": "req", "shape": [model.J]},
            {"name": "dur", "shape": [model.J]},
            {"name": "job_feats", "shape": [model.J, model.F]},
            {"name": "weights", "shape": [model.F]},
        ],
        "outputs": ["elig", "freecount", "earliest", "scores"],
    }
    man_path = os.path.join(os.path.dirname(os.path.abspath(args.out)),
                            "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(text)} chars to {args.out} (+ manifest.json)")


if __name__ == "__main__":
    main()
