"""L2: the JAX compute graph for one scheduling round (``schedule_step``).

This is the analogue, for a batch-scheduler paper, of a model forward pass:
the meta-scheduler's per-round dense computation.  It composes the two L1
Pallas kernels with a single MXU-friendly matmul:

    elig      = match_pallas(job_lo, job_hi, node_props)     # L1, [J, N]
    freecount = elig @ node_free                              # XLA dot, [J, T]
    earliest  = scan_pallas(freecount, req, dur)              # L1, [J]
    scores    = job_feats @ weights                           # XLA dot, [J]

The Rust coordinator (L3) pads the live jobs/nodes into the fixed compile
shapes below, executes the AOT artifact through PJRT, and reads back the
four outputs.  Python never runs at request time.

Fixed compile shapes (see ``aot.py`` manifest):
    J = 64  jobs per round (the meta-scheduler chunks larger queues)
    N = 128 nodes   (covers both paper platforms: 17-node Xeon, 119-node
                     Icluster)
    P = 8   matchable properties per node
    T = 96  Gantt horizon slots
    F = 6   priority features per job
"""
import jax
import jax.numpy as jnp

from .kernels import match_pallas, scan_pallas

# Canonical AOT shapes — keep in sync with rust/src/matching/shapes.rs.
J, N, P, T, F = 64, 128, 8, 96, 6


def schedule_step(job_lo, job_hi, node_props, node_free, req, dur,
                  job_feats, weights):
    """One scheduling round's dense compute.

    Args:
      job_lo, job_hi: f32[J, P] per-property interval constraints.
      node_props:     f32[N, P] node property values.
      node_free:      f32[N, T] free-resource count of node n at slot t.
      req:            f32[J]    resources required by each job.
      dur:            f32[J]    duration of each job in slots (>= 1).
      job_feats:      f32[J, F] priority features (wait time, queue prio...).
      weights:        f32[F]    priority weight vector.

    Returns (elig[J,N], freecount[J,T], earliest[J], scores[J]).
    """
    elig = match_pallas(job_lo, job_hi, node_props)
    freecount = jnp.dot(elig, node_free, preferred_element_type=jnp.float32)
    earliest = scan_pallas(freecount, req, dur)
    scores = jnp.dot(job_feats, weights, preferred_element_type=jnp.float32)
    return elig, freecount, earliest, scores


def example_args(j=J, n=N, p=P, t=T, f=F):
    """ShapeDtypeStructs used both by aot.py lowering and the tests."""
    s = jax.ShapeDtypeStruct
    return (
        s((j, p), jnp.float32),  # job_lo
        s((j, p), jnp.float32),  # job_hi
        s((n, p), jnp.float32),  # node_props
        s((n, t), jnp.float32),  # node_free
        s((j,), jnp.float32),    # req
        s((j,), jnp.float32),    # dur
        s((j, f), jnp.float32),  # job_feats
        s((f,), jnp.float32),    # weights
    )
