"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compute path — hypothesis sweeps
shapes and values; fixed seeds keep runs reproducible.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import match_pallas, scan_pallas
from compile.kernels.ref import match_ref, scan_ref


def rand_match_inputs(rng, j, n, p):
    lo = rng.uniform(-2.0, 1.0, size=(j, p)).astype(np.float32)
    hi = lo + rng.uniform(0.0, 2.5, size=(j, p)).astype(np.float32)
    props = rng.uniform(-2.0, 2.0, size=(n, p)).astype(np.float32)
    return lo, hi, props


# ---------------------------------------------------------------- match ----

class TestMatch:
    def test_basic_agreement(self):
        rng = np.random.default_rng(0)
        lo, hi, props = rand_match_inputs(rng, 64, 128, 8)
        got = np.asarray(match_pallas(lo, hi, props))
        want = np.asarray(match_ref(lo, hi, props))
        np.testing.assert_array_equal(got, want)

    def test_all_eligible(self):
        j, n, p = 8, 16, 4
        lo = np.full((j, p), -1e30, np.float32)
        hi = np.full((j, p), 1e30, np.float32)
        props = np.zeros((n, p), np.float32)
        got = np.asarray(match_pallas(lo, hi, props, block_j=8, block_n=16))
        assert got.sum() == j * n

    def test_none_eligible(self):
        j, n, p = 8, 16, 4
        lo = np.full((j, p), 2.0, np.float32)
        hi = np.full((j, p), 3.0, np.float32)
        props = np.zeros((n, p), np.float32)
        got = np.asarray(match_pallas(lo, hi, props, block_j=8, block_n=16))
        assert got.sum() == 0

    def test_equality_constraint_is_closed_interval(self):
        # '= v' is encoded as [v, v]; boundary must match.
        lo = np.array([[1.5]], np.float32)
        hi = np.array([[1.5]], np.float32)
        props = np.array([[1.5], [1.4999]], np.float32)
        got = np.asarray(match_pallas(lo, hi, props, block_j=1, block_n=2))
        np.testing.assert_array_equal(got, [[1.0, 0.0]])

    def test_single_property_violation_disqualifies(self):
        p = 6
        lo = np.full((1, p), -1.0, np.float32)
        hi = np.full((1, p), 1.0, np.float32)
        props = np.zeros((1, p), np.float32)
        props[0, 3] = 5.0  # one property out of range
        got = np.asarray(match_pallas(lo, hi, props, block_j=1, block_n=1))
        assert got[0, 0] == 0.0

    @settings(max_examples=25, deadline=None)
    @given(
        j=st.sampled_from([1, 2, 4, 8, 16, 64]),
        n=st.sampled_from([1, 4, 16, 128]),
        p=st.sampled_from([1, 2, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, j, n, p, seed):
        rng = np.random.default_rng(seed)
        lo, hi, props = rand_match_inputs(rng, j, n, p)
        got = np.asarray(match_pallas(lo, hi, props, block_j=j, block_n=n))
        want = np.asarray(match_ref(lo, hi, props))
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=10, deadline=None)
    @given(bj=st.sampled_from([8, 16, 32, 64]), bn=st.sampled_from([16, 32, 64, 128]))
    def test_block_shape_invariance(self, bj, bn):
        # Result must not depend on the tiling.
        rng = np.random.default_rng(7)
        lo, hi, props = rand_match_inputs(rng, 64, 128, 8)
        got = np.asarray(match_pallas(lo, hi, props, block_j=bj, block_n=bn))
        want = np.asarray(match_ref(lo, hi, props))
        np.testing.assert_array_equal(got, want)


# ----------------------------------------------------------------- scan ----

def rand_scan_inputs(rng, j, t, max_req=8.0):
    fc = rng.integers(0, 9, size=(j, t)).astype(np.float32)
    req = rng.integers(0, int(max_req) + 1, size=(j,)).astype(np.float32)
    dur = rng.integers(1, t + 1, size=(j,)).astype(np.float32)
    return fc, req, dur


def scan_oracle_py(fc, req, dur):
    """Plain-python oracle, independent of jax, for double-checking ref.py."""
    j, t = fc.shape
    out = np.full((j,), -1.0, np.float32)
    for a in range(j):
        d = int(dur[a])
        for s in range(0, t - d + 1):
            if np.all(fc[a, s:s + d] >= req[a]):
                out[a] = float(s)
                break
    return out


class TestScan:
    def test_basic_agreement(self):
        rng = np.random.default_rng(1)
        fc, req, dur = rand_scan_inputs(rng, 64, 96)
        got = np.asarray(scan_pallas(fc, req, dur))
        want = np.asarray(scan_ref(fc, req, dur))
        np.testing.assert_array_equal(got, want)

    def test_ref_matches_python_oracle(self):
        rng = np.random.default_rng(2)
        fc, req, dur = rand_scan_inputs(rng, 32, 40)
        want = scan_oracle_py(fc, req, dur)
        got = np.asarray(scan_ref(fc, req, dur))
        np.testing.assert_array_equal(got, want)

    def test_immediate_fit(self):
        fc = np.full((4, 8), 10.0, np.float32)
        req = np.full((4,), 3.0, np.float32)
        dur = np.array([1.0, 2.0, 4.0, 8.0], np.float32)
        got = np.asarray(scan_pallas(fc, req, dur, block_j=4))
        np.testing.assert_array_equal(got, np.zeros(4, np.float32))

    def test_no_fit_returns_minus_one(self):
        fc = np.zeros((2, 8), np.float32)
        req = np.array([1.0, 5.0], np.float32)
        dur = np.array([1.0, 2.0], np.float32)
        got = np.asarray(scan_pallas(fc, req, dur, block_j=2))
        np.testing.assert_array_equal(got, [-1.0, -1.0])

    def test_hole_in_middle(self):
        # free only during slots [3, 6); job needs 3 consecutive slots.
        fc = np.zeros((1, 10), np.float32)
        fc[0, 3:6] = 4.0
        got = np.asarray(scan_pallas(fc, np.array([2.0], np.float32),
                                     np.array([3.0], np.float32), block_j=1))
        np.testing.assert_array_equal(got, [3.0])

    def test_duration_longer_than_hole(self):
        fc = np.zeros((1, 10), np.float32)
        fc[0, 3:6] = 4.0
        got = np.asarray(scan_pallas(fc, np.array([2.0], np.float32),
                                     np.array([4.0], np.float32), block_j=1))
        np.testing.assert_array_equal(got, [-1.0])

    def test_window_must_fit_horizon(self):
        # streak at the very end shorter than dur must not match
        fc = np.zeros((1, 6), np.float32)
        fc[0, 4:] = 9.0
        got = np.asarray(scan_pallas(fc, np.array([1.0], np.float32),
                                     np.array([3.0], np.float32), block_j=1))
        np.testing.assert_array_equal(got, [-1.0])

    def test_zero_req_always_fits(self):
        fc = np.zeros((1, 5), np.float32)
        got = np.asarray(scan_pallas(fc, np.array([0.0], np.float32),
                                     np.array([5.0], np.float32), block_j=1))
        np.testing.assert_array_equal(got, [0.0])

    @settings(max_examples=25, deadline=None)
    @given(
        j=st.sampled_from([1, 2, 8, 64]),
        t=st.sampled_from([1, 4, 24, 96]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, j, t, seed):
        rng = np.random.default_rng(seed)
        fc, req, dur = rand_scan_inputs(rng, j, t)
        got = np.asarray(scan_pallas(fc, req, dur, block_j=j))
        want = scan_oracle_py(fc, req, dur)
        np.testing.assert_array_equal(got, want)

    @settings(max_examples=10, deadline=None)
    @given(bj=st.sampled_from([8, 16, 32, 64]))
    def test_block_shape_invariance(self, bj):
        rng = np.random.default_rng(11)
        fc, req, dur = rand_scan_inputs(rng, 64, 96)
        got = np.asarray(scan_pallas(fc, req, dur, block_j=bj))
        want = np.asarray(scan_ref(fc, req, dur))
        np.testing.assert_array_equal(got, want)
