"""L2 correctness: schedule_step (with Pallas kernels) vs the full jnp ref,
plus shape/lowering checks for the AOT artifact."""
import numpy as np

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels.ref import schedule_step_ref


def rand_inputs(seed=0):
    rng = np.random.default_rng(seed)
    J, N, P, T, F = model.J, model.N, model.P, model.T, model.F
    lo = rng.uniform(-2.0, 1.0, size=(J, P)).astype(np.float32)
    hi = lo + rng.uniform(0.0, 3.0, size=(J, P)).astype(np.float32)
    props = rng.uniform(-2.0, 2.0, size=(N, P)).astype(np.float32)
    free = rng.integers(0, 3, size=(N, T)).astype(np.float32)
    req = rng.integers(1, 8, size=(J,)).astype(np.float32)
    dur = rng.integers(1, T, size=(J,)).astype(np.float32)
    feats = rng.uniform(0.0, 10.0, size=(J, F)).astype(np.float32)
    w = rng.uniform(0.0, 1.0, size=(F,)).astype(np.float32)
    return lo, hi, props, free, req, dur, feats, w


class TestScheduleStep:
    def test_matches_reference(self):
        args = rand_inputs(0)
        got = jax.jit(model.schedule_step)(*args)
        want = schedule_step_ref(*args)
        for g, w, name in zip(got, want, ["elig", "freecount", "earliest", "scores"]):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-6, atol=1e-5, err_msg=name)

    def test_output_shapes(self):
        args = rand_inputs(1)
        elig, fc, earliest, scores = jax.jit(model.schedule_step)(*args)
        assert elig.shape == (model.J, model.N)
        assert fc.shape == (model.J, model.T)
        assert earliest.shape == (model.J,)
        assert scores.shape == (model.F,) or scores.shape == (model.J,)
        assert scores.shape == (model.J,)

    def test_earliest_consistent_with_elig(self):
        # A job eligible on zero nodes can never start (unless req == 0).
        args = list(rand_inputs(2))
        lo, hi = args[0], args[1]
        lo[0, :] = 100.0  # job 0 matches nothing
        hi[0, :] = 101.0
        args[4][0] = 1.0  # req >= 1
        elig, fc, earliest, _ = jax.jit(model.schedule_step)(*args)
        assert np.asarray(elig)[0].sum() == 0
        assert np.asarray(earliest)[0] == -1.0

    def test_lowering_to_hlo_text(self):
        from compile.aot import to_hlo_text
        lowered = jax.jit(model.schedule_step).lower(*model.example_args())
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        # 8 parameters, tuple-rooted output
        assert text.count("parameter(") >= 8

    def test_hlo_text_has_no_custom_call(self):
        # interpret=True must lower to plain HLO the CPU PJRT client can run.
        from compile.aot import to_hlo_text
        lowered = jax.jit(model.schedule_step).lower(*model.example_args())
        text = to_hlo_text(lowered)
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()
