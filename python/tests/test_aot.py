"""AOT pipeline tests: lowering, HLO-text interchange invariants, and the
manifest contract with the Rust runtime (rust/src/matching/shapes.rs)."""
import json
import os
import subprocess
import sys

import jax

from compile import model
from compile.aot import to_hlo_text


def lowered():
    return jax.jit(model.schedule_step).lower(*model.example_args())


class TestLowering:
    def test_entry_layout_matches_shapes(self):
        text = to_hlo_text(lowered())
        # The Rust runtime feeds literals in this exact order and shape.
        header = text.splitlines()[0]
        assert f"f32[{model.J},{model.P}]" in header  # job_lo / job_hi
        assert f"f32[{model.N},{model.P}]" in header  # node_props
        assert f"f32[{model.N},{model.T}]" in header  # node_free
        assert f"f32[{model.J},{model.N}]" in header  # elig output
        assert f"f32[{model.J},{model.T}]" in header  # freecount output

    def test_tuple_rooted_output(self):
        # return_tuple=True: the Rust side unwraps with to_tuple().
        text = to_hlo_text(lowered())
        root_lines = [l for l in text.splitlines() if "ROOT" in l]
        assert any("tuple(" in l for l in root_lines), root_lines

    def test_no_mosaic_custom_calls(self):
        # interpret=True must keep the module executable on CPU PJRT.
        text = to_hlo_text(lowered())
        assert "mosaic" not in text.lower()

    def test_contains_dot_for_mxu_path(self):
        # the freecount matmul must lower to a dot, not an unrolled loop
        text = to_hlo_text(lowered())
        assert " dot(" in text or " dot." in text

    def test_deterministic_lowering(self):
        assert to_hlo_text(lowered()) == to_hlo_text(lowered())


class TestAotCli:
    def test_writes_artifact_and_manifest(self, tmp_path):
        out = tmp_path / "schedule_step.hlo.txt"
        env = dict(os.environ)
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(out)],
            check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            env=env,
        )
        assert out.exists()
        text = out.read_text()
        assert text.startswith("HloModule")
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["J"] == model.J
        assert manifest["N"] == model.N
        assert manifest["P"] == model.P
        assert manifest["T"] == model.T
        assert manifest["F"] == model.F
        assert [i["name"] for i in manifest["inputs"]] == [
            "job_lo", "job_hi", "node_props", "node_free",
            "req", "dur", "job_feats", "weights",
        ]
        assert manifest["outputs"] == ["elig", "freecount", "earliest", "scores"]

    def test_checked_in_artifact_is_current(self):
        """If artifacts/ exists, it must match a fresh lowering (stale
        artifacts would silently desynchronize Rust and Python)."""
        repo = os.path.dirname(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        )
        path = os.path.join(repo, "artifacts", "schedule_step.hlo.txt")
        if not os.path.exists(path):
            return  # not built yet; make artifacts handles it
        with open(path) as f:
            on_disk = f.read()
        assert on_disk == to_hlo_text(lowered())
