//! Quickstart: bring up a complete OAR system on a virtual cluster,
//! submit jobs the `oarsub` way, watch them run, read `oarstat` and the
//! accounting report.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;
use std::time::Duration;

use oar::cluster::VirtualCluster;
use oar::server::{Server, ServerConfig};
use oar::types::JobSpec;

fn main() -> oar::Result<()> {
    // A virtual 8-node cluster (2 procs each) and a full server: database,
    // central automaton, meta-scheduler, launcher, monitor.
    let cluster = Arc::new(VirtualCluster::tiny(8, 2));
    let server = Server::new(cluster, ServerConfig::fast(0.05));

    println!("submitting three jobs...");
    // 1. a plain batch job
    let a = server
        .submit(&JobSpec::batch("alice", "sleep 2", 4, 600))?
        .map_err(|e| anyhow::anyhow!(e))?;
    // 2. a job with a resource-matching constraint (fig. 2 `properties`)
    let b = server
        .submit(&JobSpec {
            properties: Some("mem >= 512".into()),
            ..JobSpec::batch("bob", "sleep 1", 2, 600)
        })?
        .map_err(|e| anyhow::anyhow!(e))?;
    // 3. a best-effort job (§3.3): uses idle nodes, evicted when needed
    let c = server
        .submit(&JobSpec {
            best_effort: true,
            ..JobSpec::batch("grid", "sleep 5", 2, 3600)
        })?
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("  jobs: alice={a} bob={b} grid(best-effort)={c}");

    println!("waiting for completion...");
    let drained = server.wait_all_terminal(Duration::from_secs(60));
    println!("  all terminal: {drained}");

    println!("\noarstat:");
    for job in server.stat(None)? {
        println!(
            "  job {:>2}  user={:<6} state={:<10} response={:?}ms",
            job.id,
            job.user,
            job.state.to_string(),
            job.response_time()
        );
    }

    println!("\naccounting:");
    let acc = server.accounting();
    for (user, usage) in &acc.by_user {
        println!(
            "  {user:<6} terminated={} cpu_ms={}",
            usage.jobs_terminated, usage.cpu_seconds
        );
    }

    let (accepted, discarded) = server.hub_stats();
    println!("\ncentral module: {accepted} notifications, {discarded} coalesced");
    Ok(())
}
