//! E4 + E5: the submission-burst evaluation (figs. 9 and 10) on the live
//! server stack — real database, central automaton, scheduler and
//! launcher; only node latencies are modeled (virtual cluster).
//!
//!     cargo run --release --example burst_benchmark              # fig 9
//!     cargo run --release --example burst_benchmark -- parallel  # fig 10
//!
//! Results land in EXPERIMENTS.md §E4/§E5.

use oar::bench::{burst, report};

fn main() -> oar::Result<()> {
    let parallel = std::env::args().any(|a| a == "parallel");
    if parallel {
        fig10()
    } else {
        fig9()
    }
}

fn fig9() -> oar::Result<()> {
    // Paper: up to 1000 simultaneous submissions of `date` jobs on the
    // Xeon platform; the claim is stability across the sweep.
    let bursts = [10usize, 30, 70, 150, 300, 600, 1000];
    // time_scale compresses the launcher's modeled ssh latencies so the
    // 1000-job point stays snappy; overhead measured is the real stack's.
    println!("fig 9: response time vs burst size (Xeon, 17 nodes)\n");
    let points = burst::fig9_sweep(&bursts, 0.001)?;
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.burst.to_string(),
                format!("{:.1}", p.response_ms.mean),
                format!("{:.1}", p.response_ms.p95),
                p.errors.to_string(),
                p.drain_ms.to_string(),
                format!("{:.1}", p.queries as f64 / p.burst as f64),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(
            &["burst", "mean(ms)", "p95(ms)", "errors", "drain(ms)", "queries/job"],
            &rows
        )
    );
    let series: Vec<(f64, f64)> = points
        .iter()
        .map(|p| (p.burst as f64, p.response_ms.mean))
        .collect();
    println!("{}", report::xy_ascii(&[("mean response (ms)", &series)], 80, 12));

    let stable = points.iter().all(|p| p.errors == 0);
    println!("stability up to 1000 simultaneous submissions: {}", if stable { "OK" } else { "FAIL" });

    report::write_csv(
        std::path::Path::new("results/fig9_burst.csv"),
        &["burst", "mean_ms", "p95_ms", "max_ms", "errors", "queries"],
        &points
            .iter()
            .map(|p| {
                vec![
                    p.burst.to_string(),
                    format!("{:.2}", p.response_ms.mean),
                    format!("{:.2}", p.response_ms.p95),
                    format!("{:.2}", p.response_ms.max),
                    p.errors.to_string(),
                    p.queries.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    )?;
    println!("CSV written to results/fig9_burst.csv");
    Ok(())
}

fn fig10() -> oar::Result<()> {
    println!("fig 10: parallel-job response vs nbNodes (Icluster, 119 nodes)\n");
    let sizes = [1u32, 2, 4, 8, 16, 32, 64, 119];
    // real scale: the deployment latency model IS the measurement here
    let series = burst::fig10_sweep(&sizes, 1.0)?;
    let mut rows = Vec::new();
    for s in &series {
        for (n, ms) in &s.points {
            rows.push(vec![s.setting.clone(), n.to_string(), format!("{ms:.0}")]);
        }
    }
    println!(
        "{}",
        report::table(&["setting", "nbNodes", "response(ms)"], &rows)
    );
    let plot: Vec<(&str, Vec<(f64, f64)>)> = series
        .iter()
        .map(|s| {
            (
                s.setting.as_str(),
                s.points.iter().map(|(n, v)| (*n as f64, *v)).collect(),
            )
        })
        .collect();
    let refs: Vec<(&str, &[(f64, f64)])> =
        plot.iter().map(|(n, v)| (*n, v.as_slice())).collect();
    println!("{}", report::xy_ascii(&refs, 80, 14));

    report::write_csv(
        std::path::Path::new("results/fig10_parallel.csv"),
        &["setting", "nb_nodes", "response_ms"],
        &rows,
    )?;
    println!("CSV written to results/fig10_parallel.csv");
    Ok(())
}
