//! Grid federation walkthrough: boot three asymmetric loopback clusters,
//! farm a 60-task campaign across them as best-effort jobs, and watch the
//! meta-scheduler probe, dispatch and reconcile — including a mid-campaign
//! cluster kill + rejoin, the scenario the grid layer exists for.
//!
//! Run with: `cargo run --release --example grid_campaign`

use std::time::Duration;

use oar::grid::{Grid, GridConfig, TestGrid};
use oar::types::CampaignSpec;

fn main() -> oar::Result<()> {
    println!("── grid federation: 3 clusters (8 + 4 + 2 processors) ──\n");
    let mut fleet = TestGrid::start(&[(4, 2), (2, 2), (1, 2)], 0.02)?;
    for i in 0..fleet.len() {
        println!("  {} listening on {}", fleet.name(i), fleet.addr(i));
    }

    let grid = Grid::start(GridConfig::fast(fleet.cluster_configs(16)))?;
    let id = grid.submit_campaign(&CampaignSpec::bag(
        "demo",
        "alice",
        "sleep 5", // 100 ms per task at the harness scale
        60,
    ))?;
    println!("\ncampaign {id}: 60 tasks, farmed as best-effort jobs\n");

    let mut killed = false;
    let mut rebooted = false;
    loop {
        std::thread::sleep(Duration::from_millis(200));
        let p = grid.campaign_progress(id)?;
        println!(
            "  pending={:<3} dispatched={:<3} done={:<3} failed={}",
            p.pending, p.dispatched, p.done, p.failed
        );
        if !killed && p.done >= 15 {
            println!("  ✂ killing cluster c1 mid-campaign");
            fleet.kill(1);
            killed = true;
        }
        if killed && !rebooted && grid.counters().blacklists >= 1 {
            println!("  ⟳ c1 blacklisted; rebooting it on the same address");
            fleet.reboot(1)?;
            rebooted = true;
        }
        if p.drained() {
            break;
        }
    }

    let p = grid.campaign_progress(id)?;
    let c = grid.counters();
    println!("\n── drained: {} done, {} failed ──", p.done, p.failed);
    println!(
        "   dispatched={} retried={} orphaned={} blacklists={} rejoins={}",
        c.dispatched, c.retried, c.orphaned, c.blacklists, c.rejoins
    );
    for s in grid.clusters() {
        println!(
            "   {}: completed {} task(s), {} dispatched",
            s.name, s.completed_total, s.dispatched_total
        );
    }
    let _ = grid.shutdown();
    Ok(())
}
