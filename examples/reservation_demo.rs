//! Reservations (§2.3, fig. 1 negotiation): plan a time slot — the
//! paper's motivating example is reserving nodes "to plan a
//! demonstration" — and watch the negotiation (`toSchedule` →
//! `Scheduled`, `toAckReservation` round-trip), conservative backfilling
//! around the reserved slot, and the rejection path for a conflicting
//! reservation.
//!
//!     cargo run --release --example reservation_demo

use std::sync::Arc;
use std::time::Duration;

use oar::cluster::VirtualCluster;
use oar::server::{Server, ServerConfig};
use oar::types::{JobSpec, ReservationField};

fn main() -> oar::Result<()> {
    let cluster = Arc::new(VirtualCluster::tiny(4, 1));
    let server = Server::new(cluster, ServerConfig::fast(1.0));

    println!("reserving all 4 nodes at t+2s for a 1s demo...");
    let demo = server
        .submit(&JobSpec {
            reservation_start: Some(2),
            ..JobSpec::batch("organizer", "sleep 1", 4, 2)
        })?
        .map_err(|e| anyhow::anyhow!(e))?;

    // a second reservation for the same slot must be refused
    let clash = server
        .submit(&JobSpec {
            reservation_start: Some(2),
            ..JobSpec::batch("rival", "date", 4, 2)
        })?
        .map_err(|e| anyhow::anyhow!(e))?;

    // a short job backfills before the reservation; a long one must wait
    let short = server
        .submit(&JobSpec::batch("quick", "sleep 1", 2, 1))?
        .map_err(|e| anyhow::anyhow!(e))?;
    let long = server
        .submit(&JobSpec::batch("slow", "sleep 1", 2, 30))?
        .map_err(|e| anyhow::anyhow!(e))?;

    std::thread::sleep(Duration::from_millis(900));
    let j = server.with_db(|db| db.job(demo)).unwrap();
    println!(
        "  negotiation: job {} is {:?} / reservation field {:?}",
        demo, j.state, j.reservation
    );
    assert_eq!(j.reservation, ReservationField::Scheduled);

    let drained = server.wait_all_terminal(Duration::from_secs(60));
    println!("  drained: {drained}\n");

    for id in [demo, clash, short, long] {
        let j = server.with_db(|db| db.job(id)).unwrap();
        println!(
            "  job {:>2} {:<10} state={:<10} start={:?}ms  msg={:?}",
            id,
            j.user,
            j.state.to_string(),
            j.start_time,
            j.message
        );
    }

    let demo_start = server.with_db(|db| db.job(demo)).unwrap().start_time.unwrap();
    let short_start = server.with_db(|db| db.job(short)).unwrap().start_time.unwrap();
    println!("\nchecks:");
    println!(
        "  reservation honored its slot (start {} >= 2000ms): {}",
        demo_start,
        if demo_start >= 2000 { "OK" } else { "FAIL" }
    );
    println!(
        "  short job backfilled before the slot (start {}ms < 2000ms): {}",
        short_start,
        if short_start < 2000 { "OK" } else { "FAIL" }
    );
    let clash_state = server.with_db(|db| db.job(clash)).unwrap().state;
    println!(
        "  conflicting reservation refused: {}",
        if clash_state == oar::types::JobState::Error { "OK" } else { "FAIL" }
    );
    Ok(())
}
