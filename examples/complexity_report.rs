//! E1 (Table 1): software complexity, the paper's measurement repeated on
//! this repository and printed next to the paper's original numbers.
//!
//!     cargo run --release --example complexity_report

use oar::bench::{complexity, report};

fn main() {
    println!("Table 1 — software complexity of several resource managers\n");
    println!("paper's measurements (2005):");
    println!(
        "{}",
        report::table(
            &["system", "language", "source files", "source lines"],
            &complexity::PAPER_TABLE1
                .iter()
                .map(|(a, b, c, d)| vec![a.to_string(), b.to_string(), c.to_string(), d.to_string()])
                .collect::<Vec<_>>()
        )
    );

    println!("this repository, same procedure (operational files, tests excluded):");
    let repo = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let rows = complexity::measure_repo(repo);
    println!(
        "{}",
        report::table(
            &["component", "files", "lines", "code lines"],
            &rows
                .iter()
                .map(|l| vec![
                    l.name.clone(),
                    l.files.to_string(),
                    l.lines.to_string(),
                    l.code_lines.to_string()
                ])
                .collect::<Vec<_>>()
        )
    );
    println!("the structural claim under test: the full scheduler core stays within");
    println!("a few thousand operational lines — the paper's 'low software complexity");
    println!("through high-level components' argument, here with Rust + an embedded");
    println!("relational store playing the roles of Perl + MySQL.");
}
