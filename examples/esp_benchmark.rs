//! END-TO-END DRIVER (E3): regenerate the paper's headline evaluation —
//! Table 3 and figures 4–8 — by running the full ESP2 230-job workload
//! through every scheduler on the 34-processor Xeon shape, printing the
//! table side-by-side with the paper's numbers, rendering the utilization
//! figures, and writing CSV series under `results/`.
//!
//!     cargo run --release --example esp_benchmark
//!
//! The recorded output of this driver is EXPERIMENTS.md §E3.

use oar::bench::esp::{run_esp, PAPER_TABLE3, XEON_PROCS};
use oar::bench::report;

fn main() -> oar::Result<()> {
    println!("ESP2 throughput test: 230 jobs, 34 processors, all submitted at t=0\n");
    let rows = run_esp(XEON_PROCS, 0);

    // ---- Table 3 ----
    let mut trows = Vec::new();
    for row in &rows {
        let paper = PAPER_TABLE3.iter().find(|(n, _, _)| *n == row.system);
        trows.push(vec![
            row.system.to_string(),
            row.elapsed.to_string(),
            format!("{:.4}", row.efficiency),
            paper.map(|(_, e, _)| e.to_string()).unwrap_or_default(),
            paper.map(|(_, _, f)| format!("{f:.4}")).unwrap_or_default(),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["system", "elapsed(s)", "efficiency", "paper elapsed(s)", "paper efficiency"],
            &trows
        )
    );

    // ---- shape checks the paper argues for (§3.2.1) ----
    let eff = |n: &str| rows.iter().find(|r| r.system == n).unwrap().efficiency;
    println!("shape checks against the paper:");
    println!(
        "  greedy packers beat OAR's no-famine default:  SGE {:.4} > OAR {:.4}  [{}]",
        eff("SGE"),
        eff("OAR"),
        ok(eff("SGE") > eff("OAR"))
    );
    println!(
        "  OAR and Maui are close:                       |{:.4} - {:.4}| < 0.05 [{}]",
        eff("OAR"),
        eff("TORQUE+MAUI"),
        ok((eff("OAR") - eff("TORQUE+MAUI")).abs() < 0.05)
    );
    println!(
        "  policy swap recovers SGE-level throughput:    OAR(2) {:.4} >= SGE {:.4} - 0.01 [{}]",
        eff("OAR(2)"),
        eff("SGE"),
        ok(eff("OAR(2)") >= eff("SGE") - 0.01)
    );

    // ---- figures 4-8 ----
    for row in &rows {
        println!("\n── fig: ESP2 utilization on {} ──", row.system);
        println!("{}", report::utilization_ascii(&row.result, 100, 14));
    }

    // ---- CSV ----
    let dir = std::path::Path::new("results");
    report::write_csv(
        &dir.join("table3.csv"),
        &["system", "elapsed_s", "efficiency", "max_wait_s"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    r.system.to_string(),
                    r.elapsed.to_string(),
                    format!("{:.4}", r.efficiency),
                    r.max_wait.to_string(),
                ]
            })
            .collect::<Vec<_>>(),
    )?;
    for row in &rows {
        let name = row.system.replace(['+', '(', ')'], "_").to_lowercase();
        report::write_csv(
            &dir.join(format!("fig_esp_{name}.csv")),
            &["time_s", "busy_procs"],
            &row.result
                .utilization
                .iter()
                .map(|(t, b)| vec![t.to_string(), b.to_string()])
                .collect::<Vec<_>>(),
        )?;
    }
    println!("\nCSV series written under results/");
    Ok(())
}

fn ok(b: bool) -> &'static str {
    if b {
        "OK"
    } else {
        "FAIL"
    }
}
