//! E6 (§3.3): the Global/Desktop-computing extension — a CiGri-style
//! lightweight grid: a stream of best-effort multi-parametric tasks soaks
//! up idle nodes, and regular cluster jobs reclaim their resources on
//! arrival, cancelling exactly as many best-effort jobs as needed.
//!
//!     cargo run --release --example best_effort_grid

use std::sync::Arc;
use std::time::Duration;

use oar::cluster::VirtualCluster;
use oar::server::{Server, ServerConfig};
use oar::types::{JobSpec, JobState};

fn main() -> oar::Result<()> {
    let cluster = Arc::new(VirtualCluster::xeon());
    let server = Server::new(cluster, ServerConfig::fast(0.1));

    // A multi-parametric campaign: 17 single-node best-effort sweeps (one
    // per node), long-running.
    println!("submitting a 17-task best-effort campaign (parameter sweep)...");
    let campaign: Vec<_> = (0..17)
        .map(|i| {
            server
                .submit(&JobSpec {
                    best_effort: true,
                    ..JobSpec::batch("cigri", &format!("sleep 60 # param {i}"), 1, 3600)
                })
                .unwrap()
                .unwrap()
        })
        .collect();

    std::thread::sleep(Duration::from_millis(1500));
    let running = server.stat(Some("state = 'Running'"))?.len();
    println!("  best-effort tasks running on idle cluster: {running}");

    // A regular parallel job arrives and needs 14 nodes *entirely* (both
    // processors per node, fig. 2 `weight`). The best-effort tasks packed
    // onto the first nodes exceed what can be left alone — the scheduler
    // reclaims exactly the nodes it needs (minimal preemption: it prefers
    // the idle nodes first).
    println!("\na regular 14-node (weight 2) MPI job arrives...");
    let mpi = server
        .submit(&JobSpec {
            weight: 2,
            ..JobSpec::batch("alice", "sleep 2", 14, 600)
        })?
        .map_err(|e| anyhow::anyhow!(e))?;

    std::thread::sleep(Duration::from_millis(2500));
    let killed = server
        .stat(Some("state = 'Error'"))?
        .into_iter()
        .filter(|j| j.best_effort)
        .count();
    let mpi_state = server.with_db(|db| db.job(mpi)).unwrap().state;
    println!("  best-effort tasks reclaimed: {killed}");
    println!("  regular job state: {mpi_state}");

    // The paper's §3.3 propagation chain, visible in the event log:
    // scheduler flags → cancellation module kills → resources free.
    println!("\nevent log (the §3.3 cancellation chain):");
    for e in server.with_db(|db| db.events().to_vec()) {
        if e.kind == "BESTEFFORT_KILL" || (e.kind == "SCHEDULED" && e.job == Some(mpi)) {
            println!("  t={:>6}ms {:<16} job={:?}", e.time, e.kind, e.job);
        }
    }

    let drained = server.wait_all_terminal(Duration::from_secs(120));
    println!("\nall terminal: {drained}");
    let acc = server.accounting();
    println!(
        "cigri: {} submitted, {} completed, {} reclaimed-or-failed",
        acc.by_user["cigri"].jobs_submitted,
        acc.by_user["cigri"].jobs_terminated,
        acc.by_user["cigri"].jobs_error,
    );
    let _ = campaign;
    Ok(())
}
