//! E2 (Table 2): the functionality matrix — every feature the paper marks
//! for OAR is *demonstrated end-to-end* against the live server, not just
//! claimed.
//!
//!     cargo run --release --example feature_matrix

use oar::bench::{features, report};

fn main() {
    println!("Table 2 — functionalities of several resource managers (verified)\n");
    let rows = features::verify_features();
    let mark = |b: bool| if b { "x" } else { "" }.to_string();
    println!(
        "{}",
        report::table(
            &["feature", "OpenPBS", "SGE", "Maui", "OAR(paper)", "OAR(repo)", "evidence"],
            &rows
                .iter()
                .map(|r| vec![
                    r.feature.to_string(),
                    mark(r.paper.0),
                    mark(r.paper.1),
                    mark(r.paper.2),
                    mark(r.paper.3),
                    mark(r.demonstrated),
                    r.note.clone(),
                ])
                .collect::<Vec<_>>()
        )
    );
    let all = rows.iter().all(|r| r.demonstrated == r.paper.3);
    println!(
        "matrix matches the paper: {}",
        if all { "OK" } else { "FAIL" }
    );
    std::process::exit(if all { 0 } else { 1 });
}
