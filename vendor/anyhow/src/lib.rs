//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the small subset of `anyhow` the workspace uses: the opaque
//! [`Error`] type with a blanket `From<E: std::error::Error>` conversion
//! (so `?` works on any concrete error), the [`Result`] alias, and the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros. Like the real crate,
//! `Error` deliberately does NOT implement `std::error::Error` — that is
//! what makes the blanket `From` impl coherent.

use std::fmt;

/// Opaque error: a message plus an optional boxed source.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// The underlying cause, when this error wraps a concrete one.
    pub fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_deref().map(|e| e as _)
    }

    /// A reference to the wrapped concrete error, when there is one and
    /// it is an `E` — the subset of the real crate's `downcast_ref`
    /// callers use to turn an opaque error back into a typed one (e.g.
    /// the RPC front-end mapping `DbError` variants to protocol codes).
    pub fn downcast_ref<E: std::error::Error + 'static>(&self) -> Option<&E> {
        self.source.as_deref().and_then(|s| s.downcast_ref::<E>())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            source: Some(Box::new(e)),
        }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string, a single displayable
/// expression, or format arguments (the three forms the real crate has).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Inner;
    impl fmt::Display for Inner {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("inner failure")
        }
    }
    impl std::error::Error for Inner {}

    #[test]
    fn formats_and_wraps() {
        let e = anyhow!("bad {} at {}", "thing", 3);
        assert_eq!(e.to_string(), "bad thing at 3");
        let wrapped: Error = Inner.into();
        assert_eq!(wrapped.to_string(), "inner failure");
        assert!(wrapped.source().is_some());
    }

    #[test]
    fn downcast_ref_reaches_the_wrapped_error() {
        let wrapped: Error = Inner.into();
        assert!(wrapped.downcast_ref::<Inner>().is_some());
        assert!(wrapped.downcast_ref::<std::io::Error>().is_none());
        // Message-only errors wrap nothing.
        assert!(anyhow!("plain").downcast_ref::<Inner>().is_none());
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big");
    }
}
