//! Crash-injection harness for the durability subsystem.
//!
//! The write-ahead discipline (`db::wal`) makes one strong promise: at
//! any instant, the recoverable state is exactly the prefix of
//! fully-written WAL records — which, because records are appended
//! *before* they are applied and a failed append poisons the store, is
//! also exactly the in-memory state of the crashed process. The property
//! tests here check that promise exhaustively: for randomized workloads,
//! a crash is injected at **every** record boundary (and, within the
//! boundary record, at several torn byte offsets); recovery must then
//! reproduce the crashed process's state byte-for-byte — no acknowledged
//! mutation lost, no torn record applied — with secondary indexes
//! consistent with the rows and accounting aggregates unchanged.
//!
//! The integration tests at the bottom do the same to a *live server*:
//! crash mid-workload, restart from the data directory, reconcile the
//! stranded in-flight jobs per policy, and drain to the same terminal
//! job-state multiset as an uninterrupted run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use oar::cluster::VirtualCluster;
use oar::db::{Db, Value};
use oar::server::{Server, ServerConfig};
use oar::types::{Job, JobSpec, JobState, Node, Queue, QueuePolicyKind, RecoveryPolicy};
use oar::util::Rng;

// ------------------------------------------------- workload generator ----

/// One logical operation of a randomized workload. Ops address jobs by
/// *index* into the submitted-so-far list, so the sequence is meaningful
/// on any database replaying it.
#[derive(Debug, Clone)]
enum Op {
    Submit { user: String, nodes: u32 },
    Transition { job: usize, to: JobState },
    Message { job: usize },
    AddNode { id: u32 },
    Assign { job: usize, node: u32 },
    Unassign { job: usize },
    Event,
    AddQueue { name: String },
    QueueActive { name: String, active: bool },
    BulkMessage,
    Rule { prio: i32 },
}

fn gen_ops(seed: u64) -> Vec<Op> {
    let mut rng = Rng::new(seed);
    let mut ops = vec![
        Op::AddQueue {
            name: "default".into(),
        },
        Op::AddNode { id: 1 },
        Op::AddNode { id: 2 },
    ];
    for i in 0..40u64 {
        let op = match rng.below(12) {
            0..=3 => Op::Submit {
                user: format!("u{}", rng.below(4)),
                nodes: rng.range_i64(1, 4) as u32,
            },
            4..=6 => Op::Transition {
                job: rng.below(16) as usize,
                to: *rng.pick(&JobState::ALL),
            },
            7 => Op::Message {
                job: rng.below(16) as usize,
            },
            8 => Op::Assign {
                job: rng.below(16) as usize,
                node: rng.range_i64(1, 3) as u32,
            },
            9 => Op::Unassign {
                job: rng.below(16) as usize,
            },
            10 => Op::Event,
            _ => match rng.below(4) {
                0 => Op::AddNode { id: 10 + i as u32 },
                1 => Op::AddQueue {
                    name: format!("q{i}"),
                },
                2 => Op::QueueActive {
                    name: "default".into(),
                    active: rng.chance(0.5),
                },
                _ => {
                    if rng.chance(0.5) {
                        Op::BulkMessage
                    } else {
                        Op::Rule {
                            prio: rng.range_i64(1, 9) as i32,
                        }
                    }
                }
            },
        };
        ops.push(op);
    }
    ops
}

fn apply_op(db: &mut Db, op: &Op, jobs: &mut Vec<u64>) {
    let pick = |jobs: &[u64], i: usize| -> Option<u64> {
        if jobs.is_empty() {
            None
        } else {
            Some(jobs[i % jobs.len()])
        }
    };
    match op {
        Op::Submit { user, nodes } => {
            let spec = JobSpec::batch(user, "date", *nodes, 60);
            let id = db.insert_job(Job::from_spec(&spec, jobs.len() as i64));
            db.log_event(jobs.len() as i64, "SUBMISSION", Some(id), user);
            jobs.push(id);
        }
        Op::Transition { job, to } => {
            if let Some(id) = pick(jobs, *job) {
                // Illegal transitions are rejected without a mutation —
                // exactly as in production.
                let _ = db.set_job_state(id, *to, 5);
            }
        }
        Op::Message { job } => {
            if let Some(id) = pick(jobs, *job) {
                let _ = db.set_job_message(id, "touched");
            }
        }
        Op::AddNode { id } => {
            db.add_node(Node::new(*id, &format!("n{id}"), 2).with_prop("mem", Value::Int(512)));
        }
        Op::Assign { job, node } => {
            if let Some(id) = pick(jobs, *job) {
                db.assign_nodes(id, &[*node], 1);
            }
        }
        Op::Unassign { job } => {
            if let Some(id) = pick(jobs, *job) {
                db.remove_assignments(id);
            }
        }
        Op::Event => db.log_event(7, "TEST_EVENT", None, "detail"),
        Op::AddQueue { name } => {
            db.add_queue(Queue::new(name, 10, QueuePolicyKind::FifoConservative));
        }
        Op::QueueActive { name, active } => {
            let _ = db.set_queue_active(name, *active);
        }
        Op::BulkMessage => {
            let bulk = Value::Text("bulk".into());
            let _ = db.update_jobs_where("state = 'Waiting'", "message", bulk);
        }
        Op::Rule { prio } => {
            db.add_admission_rule(*prio, "IF nb_nodes > 64 THEN REJECT 'too big'");
        }
    }
}

/// Run ops until completion or until the WAL reports the process dead;
/// returns how many ops were *acknowledged* (completed before the crash).
fn drive(db: &mut Db, ops: &[Op]) -> usize {
    let mut jobs = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        apply_op(db, op, &mut jobs);
        if db.wal_crashed() {
            return i;
        }
    }
    ops.len()
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("oar_crash_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn seeds() -> Vec<u64> {
    match std::env::var("OAR_CRASH_SEED") {
        Ok(s) => vec![s.parse().expect("OAR_CRASH_SEED must be a u64")],
        Err(_) => vec![11, 42],
    }
}

/// The recovered database must match the crashed process's in-memory
/// state exactly, with coherent indexes and unchanged aggregates.
fn assert_recovered_matches(dir: &Path, crashed: &mut Db, ctx: &str) {
    let mem = crashed.dump();
    let mem_accounting = format!("{:?}", crashed.accounting());
    let (mut rec, _) = Db::recover(dir).expect(ctx);
    assert_eq!(rec.dump(), mem, "{ctx}: state diverged");
    assert!(rec.verify_indexes(), "{ctx}: indexes inconsistent");
    assert!(rec.verify_views(), "{ctx}: views diverged from recompute");
    assert_eq!(
        format!("{:?}", rec.accounting()),
        mem_accounting,
        "{ctx}: accounting diverged"
    );
}

// -------------------------------------------------- property: boundaries ----

#[test]
fn crash_at_every_wal_boundary_recovers_exactly() {
    for seed in seeds() {
        let ops = gen_ops(seed);

        // Reference run: count WAL records and prove clean recovery.
        let dir = fresh_dir(&format!("ref_{seed}"));
        let (mut db, _) = Db::recover(&dir).unwrap();
        assert_eq!(drive(&mut db, &ops), ops.len());
        let total = db.wal_records();
        assert!(total > ops.len() as u64 / 2, "workload too thin: {total}");
        assert_recovered_matches(&dir, &mut db, &format!("seed {seed} clean"));
        drop(db);
        let _ = std::fs::remove_dir_all(&dir);

        // Crash at every record boundary; at each boundary, tear the
        // record at several byte offsets (0 = crash exactly at the
        // boundary, 7 = inside the frame header, MAX = one byte short of
        // a complete record).
        for boundary in 0..total {
            for partial in [0usize, 7, usize::MAX] {
                let dir = fresh_dir(&format!("b_{seed}_{boundary}_{partial:x}"));
                let (mut db, _) = Db::recover(&dir).unwrap();
                db.wal_inject_failure(boundary, partial);
                let acked = drive(&mut db, &ops);
                assert!(db.wal_crashed(), "seed {seed} b{boundary}: no crash fired");
                assert!(acked < ops.len());
                assert_recovered_matches(
                    &dir,
                    &mut db,
                    &format!("seed {seed} boundary {boundary} partial {partial}"),
                );
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
}

#[test]
fn crash_with_checkpointing_recovers_exactly() {
    // Same property across snapshot generations: auto-checkpoint every 7
    // records, so crashes land before, between and after compactions.
    let seed = seeds()[0];
    let ops = gen_ops(seed);
    let dir = fresh_dir("ckpt_ref");
    let (mut db, _) = Db::recover(&dir).unwrap();
    db.set_checkpoint_every(7);
    drive(&mut db, &ops);
    let total = db.wal_records();
    drop(db);
    let _ = std::fs::remove_dir_all(&dir);

    for boundary in 0..total {
        for partial in [0usize, usize::MAX] {
            let dir = fresh_dir(&format!("ckpt_{boundary}_{partial:x}"));
            let (mut db, _) = Db::recover(&dir).unwrap();
            db.set_checkpoint_every(7);
            db.wal_inject_failure(boundary, partial);
            drive(&mut db, &ops);
            assert!(db.wal_crashed());
            assert_recovered_matches(
                &dir,
                &mut db,
                &format!("ckpt boundary {boundary} partial {partial}"),
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

// ----------------------------------------------------- atomic snapshots ----

#[test]
fn torn_snapshot_never_corrupts_previous_generation() {
    let dir = fresh_dir("snapfail");
    let (mut db, _) = Db::recover(&dir).unwrap();
    for q in Queue::standard_set() {
        db.add_queue(q);
    }
    let a = db.insert_job(Job::from_spec(&JobSpec::batch("alice", "date", 1, 60), 0));
    db.checkpoint().unwrap(); // generation 1 snapshot exists
    let b = db.insert_job(Job::from_spec(&JobSpec::batch("bob", "date", 2, 60), 1));

    // The next checkpoint dies mid-snapshot-write: the temp file is left
    // partial, nothing is renamed, the WAL keeps growing.
    db.inject_snapshot_failure(Some(40));
    assert!(db.checkpoint().is_err());
    db.inject_snapshot_failure(None);
    let c = db.insert_job(Job::from_spec(&JobSpec::batch("carol", "date", 3, 60), 2));

    let mem = db.dump();
    drop(db);
    let (mut rec, stats) = Db::recover(&dir).unwrap();
    assert_eq!(rec.dump(), mem, "recovery must use generation 1 + WAL tail");
    assert!(stats.snapshot_loaded, "generation-1 snapshot must seed recovery");
    assert_eq!(stats.generation, 1);
    for id in [a, b, c] {
        assert!(rec.job(id).is_ok(), "job {id} lost");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn plain_snapshot_is_atomic_over_existing_file() {
    let dir = fresh_dir("snapatomic");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("snap.json");
    let mut db = Db::with_standard_queues();
    let id = db.insert_job(Job::from_spec(&JobSpec::batch("alice", "date", 1, 60), 0));
    db.snapshot(&path).unwrap();

    db.insert_job(Job::from_spec(&JobSpec::batch("bob", "date", 1, 60), 1));
    db.inject_snapshot_failure(Some(10));
    assert!(db.snapshot(&path).is_err(), "injected failure must surface");

    // The original snapshot file is untouched by the torn write.
    let mut back = Db::restore(&path).unwrap();
    assert_eq!(back.job_count(), 1);
    assert_eq!(back.job(id).unwrap().user, "alice");
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------ live-server restart ----

fn durable_config(dir: &Path, policy: RecoveryPolicy, scale: f64) -> ServerConfig {
    let mut cfg = ServerConfig::fast(scale);
    cfg.sched.dense_matching = false;
    cfg.data_dir = Some(dir.to_path_buf());
    cfg.recovery = policy;
    cfg
}

/// Submit the restart-test workload: 2 × 2-node `sleep` blockers that
/// occupy the whole 4-node cluster, plus 6 quick 1-node jobs behind them.
fn submit_workload(server: &Server) -> Vec<u64> {
    let mut ids = Vec::new();
    for i in 0..2 {
        ids.push(
            server
                .submit(&JobSpec::batch(&format!("block{i}"), "sleep 10", 2, 600))
                .unwrap()
                .unwrap(),
        );
    }
    for i in 0..6 {
        ids.push(
            server
                .submit(&JobSpec::batch(&format!("u{i}"), "date", 1, 60))
                .unwrap()
                .unwrap(),
        );
    }
    ids
}

fn terminal_multiset(server: &Server, ids: &[u64]) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    for id in ids {
        let state = server.with_db(|db| db.job(*id)).unwrap().state;
        *out.entry(state.to_string()).or_insert(0) += 1;
    }
    out
}

/// Wait until at least one job is Running (a genuine in-flight victim for
/// the crash), or panic after `timeout`.
fn wait_for_running(server: &Server, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    loop {
        let running = server.with_db(|db| db.count_jobs_in_state(JobState::Running));
        if running > 0 {
            return;
        }
        assert!(Instant::now() < deadline, "no job reached Running in time");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn server_restart_requeue_drains_to_same_terminal_multiset() {
    // Baseline: the same workload, uninterrupted, on a volatile server.
    let cluster = Arc::new(VirtualCluster::tiny(4, 1));
    let mut cfg = ServerConfig::fast(0.02);
    cfg.sched.dense_matching = false;
    let baseline = Server::new(cluster, cfg);
    let base_ids = submit_workload(&baseline);
    assert!(baseline.wait_all_terminal(Duration::from_secs(60)));
    let want = terminal_multiset(&baseline, &base_ids);
    drop(baseline);

    // Crashy run: same workload, crash while the blockers are Running.
    let dir = fresh_dir("restart_requeue");
    let cluster = Arc::new(VirtualCluster::tiny(4, 1));
    let server = Server::open(
        cluster.clone(),
        durable_config(&dir, RecoveryPolicy::Requeue, 0.02),
    )
    .unwrap();
    let ids = submit_workload(&server);
    wait_for_running(&server, Duration::from_secs(20));
    server.simulate_crash();

    // Restart: recover, reconcile (requeue), drain.
    let server = Server::open(
        cluster,
        durable_config(&dir, RecoveryPolicy::Requeue, 0.02),
    )
    .unwrap();
    let report = server.recovery_report().cloned().unwrap();
    assert!(report.replayed_records > 0, "nothing replayed: {report:?}");
    assert!(
        !report.reconciled.is_empty(),
        "a Running job must have been stranded"
    );
    assert!(server.wait_all_terminal(Duration::from_secs(60)));

    // Requeued in-flight jobs run again: the drained terminal multiset
    // matches the uninterrupted run exactly.
    assert_eq!(terminal_multiset(&server, &ids), want);
    // ...and every reconciled job carries its RECOVERY_* audit event.
    for (id, _) in &report.reconciled {
        let kinds: Vec<String> = server.with_db(|db| {
            db.events_with_kind_prefix("RECOVERY_")
                .iter()
                .filter(|e| e.job == Some(*id))
                .map(|e| e.kind.clone())
                .collect()
        });
        assert!(
            kinds.contains(&"RECOVERY_REQUEUE".to_string()),
            "job {id}: {kinds:?}"
        );
    }
    let _ = server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn server_restart_fail_policy_marks_inflight_error() {
    let dir = fresh_dir("restart_fail");
    let cluster = Arc::new(VirtualCluster::tiny(4, 1));
    let server = Server::open(
        cluster.clone(),
        durable_config(&dir, RecoveryPolicy::FailInFlight, 0.02),
    )
    .unwrap();
    let ids = submit_workload(&server);
    wait_for_running(&server, Duration::from_secs(20));
    server.simulate_crash();

    let server = Server::open(
        cluster,
        durable_config(&dir, RecoveryPolicy::FailInFlight, 0.02),
    )
    .unwrap();
    let report = server.recovery_report().cloned().unwrap();
    assert!(!report.reconciled.is_empty());
    assert!(server.wait_all_terminal(Duration::from_secs(60)));

    let reconciled: Vec<u64> = report.reconciled.iter().map(|(id, _)| *id).collect();
    for id in &ids {
        let job = server.with_db(|db| db.job(*id)).unwrap();
        if reconciled.contains(id) {
            // Failed through the abnormal path, with the audit event.
            assert_eq!(job.state, JobState::Error, "job {id}");
            let has_event = server.with_db(|db| {
                db.events()
                    .iter()
                    .any(|e| e.job == Some(*id) && e.kind == "RECOVERY_FAIL")
            });
            assert!(has_event, "job {id} missing RECOVERY_FAIL event");
        } else {
            // Everything not stranded drains to normal termination.
            assert_eq!(job.state, JobState::Terminated, "job {id}");
        }
    }
    let _ = server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

// ----------------------------------------------- durable server reboots ----

#[test]
fn clean_shutdown_checkpoints_and_reboots_with_empty_tail() {
    let dir = fresh_dir("clean_reboot");
    let cluster = Arc::new(VirtualCluster::tiny(2, 1));
    let server = Server::open(
        cluster.clone(),
        durable_config(&dir, RecoveryPolicy::FailInFlight, 0.0),
    )
    .unwrap();
    let id = server
        .submit(&JobSpec::batch("alice", "date", 1, 60))
        .unwrap()
        .unwrap();
    assert!(server.wait_all_terminal(Duration::from_secs(20)));
    let _ = server.shutdown(); // checkpoints

    let server = Server::open(
        cluster,
        durable_config(&dir, RecoveryPolicy::FailInFlight, 0.0),
    )
    .unwrap();
    let report = server.recovery_report().cloned().unwrap();
    assert!(report.snapshot_loaded, "clean shutdown must leave a snapshot");
    assert_eq!(report.replayed_records, 0, "tail must be empty: {report:?}");
    assert!(report.reconciled.is_empty());
    assert_eq!(
        server.with_db(|db| db.job(id)).unwrap().state,
        JobState::Terminated
    );
    let _ = server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
