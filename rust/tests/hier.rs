//! Integration tests for the hierarchical-resources subsystem: the
//! request grammar against a fixture corpus (ReFrame/OAR-style specs), a
//! never-panics fuzz pass over junk input, moldable scheduling end to end
//! through the server (admission → scheduler → reshape → termination),
//! switch-locality placement over the Icluster resource tree, and the
//! durability story — materialized views, snapshot checkpointing, and a
//! crash at every WAL record boundary — with the `resources` table
//! populated.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use oar::cluster::VirtualCluster;
use oar::db::Db;
use oar::resources::parse_request;
use oar::sched::MetaScheduler;
use oar::server::{Server, ServerConfig};
use oar::types::{Job, JobSpec, JobState, Time};
use oar::util::Rng;

// ------------------------------------------------------ fixture corpus ----

/// Request specs in the shapes real ReFrame/OAR submissions use
/// (`-l /host={num_nodes}/core={num_tasks_per_node}`, `cpu=` for
/// sockets, `{…}` property filters, `|` moldable alternatives), with
/// the flat shape each must desugar to.
#[test]
fn fixture_corpus_parses_to_the_expected_shapes() {
    // (spec, switches, hosts, cores_per_host, walltime_secs)
    let table: &[(&str, Option<u32>, u32, u32, Option<Time>)] = &[
        ("/host=2/core=4,walltime=0:30:0", None, 2, 4, Some(1800)),
        ("/nodes=4/core=8", None, 4, 8, None),
        ("/node=1/cpu=2/core=4", None, 1, 8, None),
        ("/switch=2/host=4", Some(2), 4, 1, None),
        ("/switch=1/host=8/core=2,walltime=1:0:0", Some(1), 8, 2, Some(3600)),
        ("{mem > 2048}/host=16,walltime=12:0:0", None, 16, 1, Some(43200)),
        ("/core=64", None, 1, 64, None),
        ("/socket=1/core=16,walltime=2:30", None, 1, 16, Some(9000)),
    ];
    for (spec, switches, hosts, cores, walltime) in table {
        let req = parse_request(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
        assert_eq!(req.alternatives.len(), 1, "{spec}");
        let shape = req.alternatives[0].shape().unwrap();
        assert_eq!(shape.switches, *switches, "{spec}");
        assert_eq!(shape.hosts, *hosts, "{spec}");
        assert_eq!(shape.cores, *cores, "{spec}");
        assert_eq!(req.walltime(), *walltime, "{spec}");
    }

    // Moldable: each `|`-joined branch is one alternative, in order.
    let req = parse_request("/host=4/core=2 | /host=2/core=4").unwrap();
    assert_eq!(req.alternatives.len(), 2);
    assert_eq!(req.alternatives[0].shape().unwrap().hosts, 4);
    assert_eq!(req.alternatives[1].shape().unwrap().cores, 4);
}

// ------------------------------------------------------------- fuzzing ----

/// The parser is *total*: every input — junk included — returns either a
/// parsed request or a typed error, never a panic; and when it does
/// parse, printing is a fixed point (parse → print → parse = identity).
#[test]
fn parser_never_panics_and_roundtrips_on_junk() {
    const CHARSET: &[u8] = b"/=,|{}:.0123456789abchostwlnderwicpu >- ";
    let mut rng = Rng::new(0x6869_6572); // "hier"
    for _ in 0..4000 {
        let len = rng.below(48) as usize;
        let s: String = (0..len)
            .map(|_| CHARSET[rng.below(CHARSET.len() as u64) as usize] as char)
            .collect();
        if let Ok(req) = parse_request(&s) {
            let printed = req.to_string();
            let again = parse_request(&printed)
                .unwrap_or_else(|e| panic!("roundtrip of {s:?} → {printed:?}: {e}"));
            assert_eq!(again, req, "roundtrip of {s:?} via {printed:?}");
        }
    }
}

/// Structured generator: random *valid* specs (every level combination,
/// optional property filter, optional walltime, 1–3 moldable branches)
/// must parse, and the canonical printed form must reparse to the same
/// request — the property junk fuzzing alone can't pin down.
#[test]
fn generated_valid_specs_roundtrip_canonically() {
    let mut rng = Rng::new(0x6d6f_6c64); // "mold"
    for _ in 0..1000 {
        let branches = 1 + rng.below(3);
        let spec = (0..branches)
            .map(|_| {
                let mut s = String::new();
                if rng.below(4) == 0 {
                    s.push_str("{mem > 2048}");
                }
                if rng.below(3) == 0 {
                    s.push_str(&format!("/switch={}", 1 + rng.below(4)));
                }
                if rng.below(4) != 0 {
                    s.push_str(&format!("/host={}", 1 + rng.below(400)));
                }
                if rng.below(4) == 0 {
                    s.push_str(&format!("/cpu={}", 1 + rng.below(4)));
                }
                s.push_str(&format!("/core={}", 1 + rng.below(64)));
                if rng.below(2) == 0 {
                    s.push_str(&format!(
                        ",walltime={}:{}:{}",
                        rng.below(24),
                        rng.below(60),
                        rng.below(60)
                    ));
                }
                s
            })
            .collect::<Vec<_>>()
            .join(" | ");
        let req = parse_request(&spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
        assert_eq!(req.alternatives.len() as u64, branches, "{spec}");
        let printed = req.to_string();
        let again = parse_request(&printed).unwrap_or_else(|e| panic!("{printed}: {e}"));
        assert_eq!(again, req, "canonical form of {spec:?} must be stable");
    }
}

// ------------------------------------------- moldable end-to-end (server) ----

/// The acceptance scenario: `-l /host=4/core=2 -l /host=2/core=4` on a
/// cluster where only the second shape can exist. The job must be
/// admitted (flat fields derived from the *first* alternative), then
/// started under the *second* — the first feasible — with the reshape
/// persisted to the row before the assignment.
#[test]
fn moldable_submission_runs_under_the_first_feasible_shape() {
    let cluster = Arc::new(VirtualCluster::tiny(2, 4)); // 2 hosts × 4 cores
    let server = Arc::new(Server::new(cluster, ServerConfig::fast(0.0)));
    let spec = JobSpec {
        resources: Some("/host=4/core=2 | /host=2/core=4".into()),
        ..JobSpec::batch("alice", "date", 1, 600)
    };
    let id = server
        .submit(&spec)
        .expect("rpc")
        .expect("admission must accept the moldable request");
    // (The flat mirror admission derives from the *first* alternative is
    // asserted in the admission unit tests — reading it here would race
    // the automaton's reshape.)
    assert!(server.wait_all_terminal(Duration::from_secs(60)));
    server.read_db(|db| {
        let j = db.job(id).unwrap();
        assert_eq!(j.state, JobState::Terminated, "{}", j.message);
        // The scheduler fell through to the second alternative and the
        // reshape was persisted before launch.
        assert_eq!((j.nb_nodes, j.weight), (2, 4), "reshaped to /host=2/core=4");
        assert_eq!(
            j.resources.as_deref(),
            Some("/host=4/core=2 | /host=2/core=4"),
            "canonical request preserved on the row"
        );
        assert!(db.verify_views(), "views stay coherent through the reshape");
    });
}

/// An unparseable request is rejected at admission with a typed error —
/// it never reaches the jobs table.
#[test]
fn malformed_request_is_rejected_not_stored() {
    let cluster = Arc::new(VirtualCluster::tiny(2, 2));
    let server = Arc::new(Server::new(cluster, ServerConfig::fast(0.0)));
    let spec = JobSpec {
        resources: Some("/rack=2/host=1".into()),
        ..JobSpec::batch("mallory", "date", 1, 60)
    };
    let err = server.submit(&spec).expect("rpc").expect_err("must reject");
    assert!(err.contains("unknown resource level"), "{err}");
    assert_eq!(server.read_db(|db| db.job_count()), 0);
}

// --------------------------------------------- switch locality (Icluster) ----

/// `/switch=2/host=24/core=1` over the Icluster tree (5 switches: 24+24+
/// 24+24+23 hosts): with one sw1 host busy, the only switches that can
/// hold 24 hosts *now* are sw2..sw4; the matcher must take the first two
/// whole and skip sw1 rather than mixing switches.
#[test]
fn switch_locality_places_whole_switches() {
    let mut db = Db::with_standard_queues();
    VirtualCluster::icluster().register(&mut db);

    // A running job pins node 1 (sw1) for a long time.
    let blocker = db.insert_job(Job::from_spec(&JobSpec::batch("b", "hold", 1, 10_000), 0));
    db.assign_nodes(blocker, &[1], 1);
    db.set_job_state(blocker, JobState::ToLaunch, 0).unwrap();
    db.set_job_state(blocker, JobState::Launching, 0).unwrap();
    db.set_job_state(blocker, JobState::Running, 0).unwrap();

    let spec = JobSpec {
        nb_nodes: 48,
        weight: 1,
        resources: Some("/switch=2/host=24/core=1".into()),
        ..JobSpec::batch("alice", "mpi", 48, 600)
    };
    let id = db.insert_job(Job::from_spec(&spec, 1));

    let mut meta = MetaScheduler::sql_only();
    let d = meta.round(&db, 5).unwrap();
    let start = d
        .starts
        .iter()
        .find(|(j, _)| *j == id)
        .unwrap_or_else(|| panic!("not started: rejected={:?}", d.rejected));
    let mut chosen = start.1.clone();
    chosen.sort_unstable();
    // Icluster switch i holds nodes (i-1)*24+1 ..= i*24: sw2+sw3 whole.
    assert_eq!(chosen, (25..=72).collect::<Vec<_>>(), "two whole switches");
    assert!(d.reshapes.is_empty(), "shape matches the stored row");
}

// ----------------------------------------------------------- durability ----

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("oar_hier_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A workload exercising every resources-table write path plus the
/// moldable reshape: cluster registration (tree + derived nodes),
/// moldable submissions, a persisted reshape, an assignment and a state
/// transition.
fn drive_hier_workload(db: &mut Db) {
    VirtualCluster::tiny(4, 2).register(db);
    let mut ids = Vec::new();
    for i in 0..4i64 {
        let spec = JobSpec {
            nb_nodes: 2,
            weight: 1,
            resources: Some("/host=2/core=1 | /host=1/core=2".into()),
            ..JobSpec::batch(&format!("u{i}"), "date", 2, 60)
        };
        ids.push(db.insert_job(Job::from_spec(&spec, i)));
    }
    let _ = db.set_job_shape(ids[0], 1, 2);
    db.assign_nodes(ids[0], &[1], 2);
    let _ = db.set_job_state(ids[0], JobState::ToLaunch, 10);
    db.log_event(10, "SCHEDULED", Some(ids[0]), "[1]");
}

/// Views and indexes stay coherent with the resources table populated
/// and a reshape applied (in-memory database).
#[test]
fn views_and_indexes_hold_with_resources_and_reshapes() {
    let mut db = Db::with_standard_queues();
    drive_hier_workload(&mut db);
    assert_eq!(db.resource_count(), 1 + 1 + 4 + 4 + 8, "tiny(4,2) tree");
    assert!(db.verify_indexes());
    assert!(db.verify_views());
    let h = db.hierarchy();
    assert_eq!(h.host_count(), 4);
    assert_eq!(h.core_count(), 8);
}

/// Snapshot checkpoint + recovery round-trips the resources table.
#[test]
fn checkpoint_roundtrips_the_resource_tree() {
    let dir = fresh_dir("snap");
    let (mut db, _) = Db::recover(&dir).unwrap();
    VirtualCluster::icluster().register(&mut db);
    db.checkpoint().unwrap();
    let expect_dump = db.dump();
    let expect_hier = db.hierarchy();
    drop(db);

    let (rec, _) = Db::recover(&dir).unwrap();
    assert_eq!(rec.dump(), expect_dump);
    assert_eq!(rec.resource_count(), 1 + 5 + 119 * 3);
    assert_eq!(rec.hierarchy(), expect_hier);
    assert!(rec.verify_indexes());
    assert!(rec.verify_views());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The WAL promise, with the resources table in the workload: crash at
/// *every* record boundary (plus torn-record offsets) and recover; the
/// recovered state must equal the crashed process's memory exactly.
#[test]
fn crash_at_every_boundary_recovers_the_resource_tree() {
    // Reference run: clean recovery and the record count.
    let dir = fresh_dir("ref");
    let (mut db, _) = Db::recover(&dir).unwrap();
    drive_hier_workload(&mut db);
    let total = db.wal_records();
    assert!(total > 20, "workload too thin to sweep: {total}");
    let clean_dump = db.dump();
    drop(db);
    let (rec, _) = Db::recover(&dir).unwrap();
    assert_eq!(rec.dump(), clean_dump, "clean recovery");
    drop(rec);
    let _ = std::fs::remove_dir_all(&dir);

    for boundary in 0..total {
        for partial in [0usize, usize::MAX] {
            let dir = fresh_dir(&format!("b{boundary}_{partial:x}"));
            let (mut db, _) = Db::recover(&dir).unwrap();
            db.wal_inject_failure(boundary, partial);
            drive_hier_workload(&mut db);
            assert!(db.wal_crashed(), "boundary {boundary}: crash never fired");
            let mem = db.dump();
            let (rec, _) = Db::recover(&dir)
                .unwrap_or_else(|e| panic!("boundary {boundary} partial {partial:x}: {e}"));
            assert_eq!(rec.dump(), mem, "boundary {boundary} partial {partial:x}");
            assert!(rec.verify_indexes(), "boundary {boundary}: indexes");
            assert!(rec.verify_views(), "boundary {boundary}: views");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}
