//! The observability suite, in its own process on purpose: the obs
//! clock's manual mode and the runtime kill switch are process-global,
//! so driving them here cannot skew timings recorded by the other
//! integration binaries.
//!
//! Three halves:
//! 1. **Concurrency**: multi-thread hammers proving counter exactness,
//!    the histogram bucket-sum == count invariant under racing
//!    observes, and that the span ring stays bounded.
//! 2. **Determinism**: the frozen clock drives exact bucket placement,
//!    quantile edges, and span durations.
//! 3. **End-to-end**: a live durable server + RPC front-end, asserting
//!    the `metrics` method returns real per-phase scheduler timings,
//!    lock-wait histograms, WAL batch distributions and per-method RPC
//!    latencies, and that `events` tails the bounded log with filters.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use oar::cluster::VirtualCluster;
use oar::obs::{self, clock};
use oar::rpc::{proto, RpcClient, RpcConfig, RpcServer};
use oar::server::{Server, ServerConfig};
use oar::types::{JobSpec, JobState};

/// Everything in this binary mutates process-global state (the clock,
/// the kill switch, the span ring, the shared catalogue), so the tests
/// serialize on one lock instead of trusting the harness thread count.
static SEQ: Mutex<()> = Mutex::new(());

fn seq() -> std::sync::MutexGuard<'static, ()> {
    SEQ.lock().unwrap_or_else(|e| e.into_inner())
}

// ------------------------------------------------------- concurrency ----

#[test]
fn counters_are_exact_under_contention() {
    let _g = seq();
    static HAMMERED: obs::Counter = obs::Counter::new("test_hammered_total");
    const THREADS: usize = 8;
    const PER: u64 = 100_000;

    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            std::thread::spawn(|| {
                for _ in 0..PER {
                    HAMMERED.inc();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(HAMMERED.get(), THREADS as u64 * PER, "a relaxed inc was lost");
}

#[test]
fn histogram_invariants_hold_under_racing_observes() {
    let _g = seq();
    static RACED: obs::Histogram = obs::Histogram::new("test_raced_us", "us");
    const THREADS: u64 = 8;
    const PER: u64 = 20_000;

    // Snapshot concurrently with the observers: whatever interleaving a
    // snapshot catches, its own bucket-sum must equal its own count.
    let reader = std::thread::spawn(|| {
        let mut last = 0u64;
        for _ in 0..200 {
            let s = RACED.snapshot();
            let bucket_sum: u64 = s.buckets.iter().sum();
            assert_eq!(bucket_sum, s.count, "snapshot caught a torn histogram");
            assert!(s.count >= last, "count went backwards");
            last = s.count;
            std::thread::yield_now();
        }
    });
    let writers: Vec<_> = (0..THREADS)
        .map(|t| {
            std::thread::spawn(move || {
                for i in 0..PER {
                    // Values spread over many buckets, deterministic sum.
                    RACED.observe((t * PER + i) % 4096);
                }
            })
        })
        .collect();
    for h in writers {
        h.join().unwrap();
    }
    reader.join().unwrap();

    let s = RACED.snapshot();
    assert_eq!(s.count, THREADS * PER, "an observe was lost");
    assert_eq!(s.buckets.iter().sum::<u64>(), s.count);
    let expected_sum: u64 = (0..THREADS)
        .flat_map(|t| (0..PER).map(move |i| (t * PER + i) % 4096))
        .sum();
    assert_eq!(s.sum, expected_sum);
    assert_eq!(s.max, 4095);
}

#[test]
fn span_ring_is_bounded_and_accounts_evictions() {
    let _g = seq();
    static RING_HIST: obs::Histogram = obs::Histogram::new("test_ring_us", "us");
    obs::set_ring_capacity(64);
    let (_, _, evicted_before) = obs::ring_stats();

    let handles: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(|| {
                for _ in 0..500 {
                    let _s = obs::Span::enter("ring.hammer", &RING_HIST);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }

    let (len, cap, evicted) = obs::ring_stats();
    assert_eq!(cap, 64);
    assert!(len <= 64, "ring grew past its capacity: {len}");
    // 2000 spans into 64 slots: most were overwritten, and every
    // overwrite is tallied.
    assert!(
        evicted - evicted_before >= 2000 - 64,
        "evictions untallied: {evicted_before} -> {evicted}"
    );
    assert!(obs::recent_spans(1000).len() <= 64);
    obs::set_ring_capacity(obs::DEFAULT_RING_CAPACITY);
}

#[test]
fn kill_switch_stops_recording_without_stranding_gauges() {
    let _g = seq();
    static OFF_C: obs::Counter = obs::Counter::new("test_off_total");
    static OFF_H: obs::Histogram = obs::Histogram::new("test_off_us", "us");
    static OFF_G: obs::Gauge = obs::Gauge::new("test_off_inflight");

    OFF_G.rise(); // in flight when the switch flips
    obs::set_enabled(false);
    OFF_C.inc();
    OFF_H.observe(42);
    OFF_G.fall(); // not gated: must not strand the gauge above zero
    obs::set_enabled(true);

    assert_eq!(OFF_C.get(), 0);
    assert_eq!(OFF_H.snapshot().count, 0);
    assert_eq!(OFF_G.get(), 0);
}

// ------------------------------------------------------- determinism ----

#[test]
fn frozen_clock_places_observations_in_exact_buckets() {
    let _g = seq();
    static EDGES: obs::Histogram = obs::Histogram::new("test_edges_us", "us");
    clock::freeze_at(1_000);
    assert!(clock::is_frozen());

    // Each (advance, bucket) pair sits exactly on a log2 bucket edge.
    for (dur, bucket) in [
        (0u64, 0usize), // zero lands in the dedicated zero bucket
        (1, 1),
        (2, 2),
        (3, 2),    // still < 4
        (4, 3),
        (1023, 10), // last value of [512, 1024)
        (1024, 11), // first value of [1024, 2048)
    ] {
        let t0 = clock::now_us();
        clock::advance_us(dur);
        let before = EDGES.snapshot().buckets[bucket];
        EDGES.observe(clock::now_us() - t0);
        let after = EDGES.snapshot().buckets[bucket];
        assert_eq!(after, before + 1, "duration {dur} missed bucket {bucket}");
    }
    clock::unfreeze();
    assert!(!clock::is_frozen());
}

#[test]
fn quantiles_derive_from_buckets() {
    let _g = seq();
    static Q: obs::Histogram = obs::Histogram::new("test_quantiles_us", "us");
    for v in 1..=100u64 {
        Q.observe(v);
    }
    let s = Q.snapshot();
    assert_eq!(s.count, 100);
    assert_eq!(s.max, 100);
    assert!((s.mean() - 50.5).abs() < 1e-9);
    // Rank 50 is the value 50, whose log2 bucket covers [32, 64).
    assert_eq!(s.p50(), 63);
    // Rank 99 is the value 99, bucket [64, 128).
    assert_eq!(s.p99(), 127);
}

#[test]
fn frozen_clock_drives_exact_span_durations_and_nesting() {
    let _g = seq();
    static OUTER: obs::Histogram = obs::Histogram::new("test_span_outer_us", "us");
    static INNER: obs::Histogram = obs::Histogram::new("test_span_inner_us", "us");
    clock::freeze_at(50_000);

    let outer_id;
    {
        let outer = obs::Span::enter("det.outer", &OUTER);
        outer_id = outer.id();
        clock::advance_us(300);
        {
            let _inner = obs::Span::enter("det.inner", &INNER);
            clock::advance_us(400);
        }
        clock::advance_us(100);
    }
    clock::unfreeze();

    let spans = obs::recent_spans(8);
    let inner = spans.iter().find(|s| s.name == "det.inner").expect("inner");
    let outer = spans.iter().find(|s| s.name == "det.outer").expect("outer");
    assert_eq!(inner.dur_us, 400, "inner span must time exactly its region");
    assert_eq!(inner.start_us, 50_300);
    assert_eq!(inner.parent, outer_id, "nesting must link child to parent");
    assert_eq!(outer.dur_us, 800);
    assert_eq!(outer.start_us, 50_000);
    assert_eq!(outer.parent, 0, "outer span is a root");
    // The child finished first: ring order is completion order.
    assert_eq!(OUTER.snapshot().buckets[obs::bucket_index(800)], 1);
    assert_eq!(INNER.snapshot().buckets[obs::bucket_index(400)], 1);
}

// -------------------------------------------------------- end-to-end ----

/// The ISSUE's acceptance check: a live durable server + front-end, a
/// real workload, then the `metrics` RPC must report non-empty per-phase
/// scheduler timings, lock-wait histograms, WAL batch distributions and
/// per-method RPC latencies — and `events` must tail the bounded log.
#[test]
fn metrics_and_events_rpc_report_a_live_server() {
    let _g = seq();
    let dir = std::env::temp_dir().join(format!("oar-obs-e2e-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cluster = Arc::new(VirtualCluster::tiny(4, 1));
    let mut cfg = ServerConfig::fast(0.0);
    cfg.sched.dense_matching = false;
    cfg.data_dir = Some(dir.clone());
    let server = Arc::new(Server::open(cluster, cfg).unwrap());
    let rpc = RpcServer::start(server.clone(), RpcConfig::loopback()).unwrap();
    let addr = rpc.addr().to_string();

    let mut client = RpcClient::connect(&addr).unwrap();
    let id = client
        .sub(&JobSpec::batch("alice", "date", 2, 60))
        .unwrap()
        .unwrap();
    client
        .sub(&JobSpec::batch("bob", "date", 1, 60))
        .unwrap()
        .unwrap();
    assert!(server.wait_all_terminal(Duration::from_secs(30)));
    assert_eq!(
        server.with_db(|db| db.job(id)).unwrap().state,
        JobState::Terminated
    );
    // Mint one typed error so the per-code counters are exercised too.
    assert_eq!(
        client.hold(424_242).unwrap().unwrap_err().code,
        proto::code::NO_SUCH_JOB
    );

    let snap = client.metrics().unwrap().unwrap();
    assert_eq!(snap.version, obs::SNAPSHOT_VERSION);

    // Scheduler phases: rounds ran, plan/apply both timed.
    for hist in ["oar_sched_round_us", "oar_sched_plan_us", "oar_sched_apply_us"] {
        let h = snap.hist(hist).unwrap_or_else(|| panic!("{hist} missing"));
        assert!(h.count > 0, "{hist} recorded nothing");
        assert_eq!(h.buckets.iter().sum::<u64>(), h.count, "{hist} torn");
    }
    assert!(snap.counter("oar_sched_rounds_total").unwrap() > 0);

    // Lock waits: the workload took both guard kinds.
    assert!(snap.hist("oar_db_read_wait_us").unwrap().count > 0);
    assert!(snap.hist("oar_db_write_wait_us").unwrap().count > 0);

    // WAL: every mutation appended; group commit flushed real batches.
    assert!(snap.hist("oar_wal_append_us").unwrap().count > 0);
    let batches = snap.hist("oar_wal_batch_records").unwrap();
    assert!(batches.count > 0, "no group-commit batch was observed");
    assert!(batches.sum > 0, "batches must contain records");
    assert!(snap.hist("oar_wal_batch_bytes").unwrap().sum > 0);
    assert!(snap.hist("oar_wal_flush_us").unwrap().count >= 1);

    // RPC: per-method latencies and the request/error counters.
    assert!(snap.hist("oar_rpc_sub_us").unwrap().count >= 2);
    assert!(snap.hist("oar_rpc_hold_us").unwrap().count >= 1);
    assert!(snap.counter("oar_rpc_requests_total").unwrap() >= 4);
    assert!(snap.counter("oar_rpc_err_no_such_job_total").unwrap() >= 1);
    // The snapshot is taken inside the `metrics` dispatch itself.
    assert!(snap.gauge("oar_rpc_inflight").unwrap() >= 1);

    // Db bridge counters rode along under the read guard.
    assert!(snap.counter("oar_db_inserts_total").unwrap() >= 2);
    assert_eq!(
        snap.counter("oar_db_events_retention_cap").unwrap(),
        oar::db::DEFAULT_EVENT_RETENTION as u64
    );

    // The span ring saw the round spans, with plan nested under round.
    let spans = obs::recent_spans(obs::DEFAULT_RING_CAPACITY);
    assert!(spans.iter().any(|s| s.name == "sched.round"));
    assert!(
        spans
            .iter()
            .any(|s| s.name == "sched.plan" && s.parent != 0),
        "plan spans must nest under their round"
    );

    // `events`: full tail, then kind- and job-filtered.
    let (all, total) = client.events(10, None, None).unwrap().unwrap();
    assert!(total > 0, "a terminal workload must have logged events");
    assert!(all.len() <= 10 && !all.is_empty());
    assert!(all.windows(2).all(|w| w[0].time <= w[1].time), "oldest first");
    let kind = all[0].kind.clone();
    let (of_kind, kind_total) = client.events(100, Some(&kind), None).unwrap().unwrap();
    assert!(kind_total >= 1);
    assert!(of_kind.iter().all(|e| e.kind == kind));
    let (of_job, job_total) = client.events(100, None, Some(id)).unwrap().unwrap();
    assert!(job_total >= 1, "job {id} must have logged events");
    assert!(of_job.iter().all(|e| e.job == Some(id)));

    // The second request sees strictly more requests than the first
    // snapshot did: the metrics path meters itself.
    let snap2 = client.metrics().unwrap().unwrap();
    assert!(snap2.hist("oar_rpc_metrics_us").unwrap().count >= 1);
    assert!(
        snap2.counter("oar_rpc_requests_total").unwrap()
            > snap.counter("oar_rpc_requests_total").unwrap()
    );

    rpc.drain();
    drop(client);
    let server = Arc::try_unwrap(server).ok().expect("front-end joined");
    drop(server.shutdown());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite 1's RPC face: the event log keeps its retention cap over
/// the wire — flooding past the cap evicts oldest rows, `events` still
/// answers, and the eviction counters surface in `metrics`.
#[test]
fn bounded_event_log_reports_evictions_over_rpc() {
    let _g = seq();
    let cluster = Arc::new(VirtualCluster::tiny(2, 1));
    let mut cfg = ServerConfig::fast(0.0);
    cfg.sched.dense_matching = false;
    let server = Arc::new(Server::new(cluster, cfg));
    let rpc = RpcServer::start(server.clone(), RpcConfig::loopback()).unwrap();
    let addr = rpc.addr().to_string();

    server.with_db(|db| {
        db.set_event_retention(8);
        for i in 0..50i64 {
            db.log_event(i, "FLOOD", None, &format!("row {i}"));
        }
    });

    let mut client = RpcClient::connect(&addr).unwrap();
    let (tail, total) = client.events(100, Some("FLOOD"), None).unwrap().unwrap();
    assert_eq!(total, 8, "retention cap must bound the live log");
    assert_eq!(tail.len(), 8);
    assert_eq!(tail[0].detail, "row 42", "oldest surviving row");
    assert_eq!(tail[7].detail, "row 49", "newest row");

    let snap = client.metrics().unwrap().unwrap();
    assert_eq!(snap.counter("oar_db_events_retention_cap").unwrap(), 8);
    assert_eq!(snap.counter("oar_db_events_rows").unwrap(), 8);
    assert_eq!(snap.counter("oar_db_events_evicted_total").unwrap(), 42);

    // Mistyped params are BAD_REQUEST, not a panic or a truncation.
    let res = client
        .call("events", oar::util::Json::obj(vec![(
            "tail",
            oar::util::Json::Num(1.5),
        )]))
        .unwrap();
    assert_eq!(res.unwrap_err().code, proto::code::BAD_REQUEST);
}
