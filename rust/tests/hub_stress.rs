//! Stress/property tests of [`NotificationHub`] under concurrency: the
//! §2.2 contract is that notifications from any number of threads never
//! wedge the automaton, redundant `Schedule` notifications coalesce, and
//! job events — which carry payloads — are never lost or duplicated.
//! With the RPC front-end, every worker thread is now a notifier, so this
//! is the contention profile production actually sees.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use oar::central::{JobEvent, NotificationHub, Task, Work};
use oar::util::Rng;

/// Drain the hub the way the automaton does (poll-until-empty + bounded
/// wait), counting what was seen, until `Shutdown` arrives.
fn spawn_consumer(
    hub: Arc<NotificationHub>,
    schedules: Arc<AtomicU64>,
    events: Arc<AtomicU64>,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || loop {
        while let Some(w) = hub.poll() {
            match w {
                Work::Task(Task::Shutdown) => return,
                Work::Task(Task::Schedule) => {
                    schedules.fetch_add(1, Ordering::Relaxed);
                }
                Work::Task(_) => {}
                Work::Event(_) => {
                    events.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        hub.wait_timeout(Duration::from_millis(5));
    })
}

/// Block until `events` reaches `expected` (the wedge detector: if the
/// hub loses a wakeup or an event, this fails at the deadline instead of
/// hanging the suite).
fn await_events(events: &AtomicU64, expected: u64) {
    let deadline = Instant::now() + Duration::from_secs(60);
    while events.load(Ordering::Relaxed) < expected {
        assert!(
            Instant::now() < deadline,
            "hub wedged: {}/{} events drained",
            events.load(Ordering::Relaxed),
            expected
        );
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn notification_storm_never_wedges_and_still_coalesces() {
    const THREADS: u64 = 16;
    const PER: u64 = 500;
    let hub = Arc::new(NotificationHub::new());
    let schedules = Arc::new(AtomicU64::new(0));
    let events = Arc::new(AtomicU64::new(0));
    let consumer = spawn_consumer(hub.clone(), schedules.clone(), events.clone());

    let producers: Vec<_> = (0..THREADS)
        .map(|t| {
            let hub = hub.clone();
            std::thread::spawn(move || {
                for i in 0..PER {
                    hub.notify(Task::Schedule);
                    if i % 7 == 0 {
                        hub.push_event(JobEvent::Ended {
                            job: t * PER + i,
                            at: i as i64,
                            ok: true,
                        });
                    }
                    if i % 11 == 0 {
                        hub.notify(Task::Monitor);
                    }
                }
            })
        })
        .collect();
    for p in producers {
        p.join().unwrap();
    }

    let expected_events = THREADS * ((PER + 6) / 7);
    await_events(&events, expected_events);
    hub.notify(Task::Shutdown);
    consumer.join().unwrap();

    assert_eq!(
        events.load(Ordering::Relaxed),
        expected_events,
        "events must be delivered exactly once"
    );
    let accepted = hub.accepted.load(Ordering::Relaxed);
    let discarded = hub.discarded.load(Ordering::Relaxed);
    let total_notifies = THREADS * PER          // Schedule
        + THREADS * ((PER + 10) / 11)           // Monitor
        + 1; // Shutdown
    assert_eq!(
        accepted + discarded,
        total_notifies,
        "every notification is either accepted or coalesced, never dropped on the floor"
    );
    assert!(discarded > 0, "a {THREADS}-thread storm must coalesce");
    let seen = schedules.load(Ordering::Relaxed);
    assert!(seen >= 1, "at least one Schedule must be dispatched");
    assert!(
        seen <= accepted,
        "dispatched Schedules ({seen}) cannot exceed accepted notifications ({accepted})"
    );
}

#[test]
fn randomized_interleavings_deliver_every_event_exactly_once() {
    for seed in [1u64, 7, 42, 1337] {
        const THREADS: u64 = 8;
        const OPS: u64 = 400;
        let hub = Arc::new(NotificationHub::new());
        let schedules = Arc::new(AtomicU64::new(0));
        let events = Arc::new(AtomicU64::new(0));
        let pushed = Arc::new(AtomicU64::new(0));
        let notified = Arc::new(AtomicU64::new(0));
        let consumer = spawn_consumer(hub.clone(), schedules.clone(), events.clone());

        let producers: Vec<_> = (0..THREADS)
            .map(|t| {
                let hub = hub.clone();
                let pushed = pushed.clone();
                let notified = notified.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(seed.wrapping_mul(0x9e37).wrapping_add(t));
                    for i in 0..OPS {
                        match rng.below(5) {
                            0 => {
                                hub.push_event(JobEvent::Ended {
                                    job: t * OPS + i,
                                    at: i as i64,
                                    ok: i % 2 == 0,
                                });
                                pushed.fetch_add(1, Ordering::Relaxed);
                            }
                            1 => {
                                hub.push_event(JobEvent::LaunchFailed {
                                    job: t * OPS + i,
                                    at: i as i64,
                                });
                                pushed.fetch_add(1, Ordering::Relaxed);
                            }
                            2 => {
                                hub.notify(Task::Monitor);
                                notified.fetch_add(1, Ordering::Relaxed);
                            }
                            3 => {
                                hub.notify(Task::CheckJobs);
                                notified.fetch_add(1, Ordering::Relaxed);
                            }
                            _ => {
                                hub.notify(Task::Schedule);
                                notified.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }

        await_events(&events, pushed.load(Ordering::Relaxed));
        hub.notify(Task::Shutdown);
        consumer.join().unwrap();

        assert_eq!(
            events.load(Ordering::Relaxed),
            pushed.load(Ordering::Relaxed),
            "seed {seed}: every pushed event exactly once"
        );
        let accepted = hub.accepted.load(Ordering::Relaxed);
        let discarded = hub.discarded.load(Ordering::Relaxed);
        assert_eq!(
            accepted + discarded,
            notified.load(Ordering::Relaxed) + 1, // + Shutdown
            "seed {seed}: notification accounting must balance"
        );
    }
}
