//! Snapshot-consistency stress for the reader-writer core: reader
//! threads take consistent read guards ([`Server::read_db`]) while the
//! central automaton schedules, launches and terminates a seeded
//! workload. The write path applies every scheduling round under one
//! write guard, so no snapshot may ever observe a half-applied round:
//! the per-state counts must always partition the job table, a `Running`
//! job must always hold its node assignment, terminal states must be
//! absorbing, and the accounting aggregate — derived inside the same
//! guard — must agree with the table it was derived from. Four fixed
//! seeds vary the reader/automaton interleaving, hub_stress-style.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use oar::cluster::VirtualCluster;
use oar::server::{Server, ServerConfig};
use oar::types::{JobId, JobSpec, JobState};
use oar::util::Rng;

/// Everything that must hold in *any* snapshot, half-round or not.
/// Returns `(total, terminal)` so callers can check monotonicity across
/// successive snapshots too.
fn assert_snapshot_coherent(db: &oar::db::Db, seed: u64) -> (usize, usize) {
    let total = db.job_count();
    let by_state: Vec<usize> = JobState::ALL
        .iter()
        .map(|s| db.count_jobs_in_state(*s))
        .collect();
    let sum: usize = by_state.iter().sum();
    assert_eq!(
        sum, total,
        "seed {seed}: per-state counts must partition the job table ({by_state:?})"
    );

    // The scheduler assigns nodes and flips the state edge under one
    // write guard: a Running job without an assignment would mean a
    // reader caught the round halfway through.
    for j in db.jobs_in_state(JobState::Running) {
        assert!(
            !db.assigned_nodes(j.id).is_empty(),
            "seed {seed}: snapshot shows Running job {} with no nodes",
            j.id
        );
    }

    // Accounting is derived from the same snapshot, inside the same
    // guard — it can never disagree with the table it came from.
    let acct = db.accounting();
    let submitted: usize = acct.by_user.values().map(|u| u.jobs_submitted).sum();
    let terminated: usize = acct.by_user.values().map(|u| u.jobs_terminated).sum();
    let errored: usize = acct.by_user.values().map(|u| u.jobs_error).sum();
    assert_eq!(
        submitted, total,
        "seed {seed}: accounting must cover every job in the snapshot"
    );
    assert_eq!(
        terminated,
        db.count_jobs_in_state(JobState::Terminated),
        "seed {seed}: accounting terminated-count must match the table"
    );
    assert_eq!(
        errored,
        db.count_jobs_in_state(JobState::Error),
        "seed {seed}: accounting error-count must match the table"
    );

    let terminal: usize = JobState::ALL
        .iter()
        .filter(|s| s.is_terminal())
        .map(|s| db.count_jobs_in_state(*s))
        .sum();
    (total, terminal)
}

fn run_seed(seed: u64) {
    const READERS: u64 = 4;
    const JOBS: usize = 250;

    let cluster = Arc::new(VirtualCluster::xeon());
    let mut cfg = ServerConfig::fast(0.0);
    cfg.sched.dense_matching = false;
    let server = Arc::new(Server::new(cluster, cfg));
    let stop = Arc::new(AtomicBool::new(false));

    let readers: Vec<_> = (0..READERS)
        .map(|t| {
            let server = server.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(seed.wrapping_mul(0x9e37).wrapping_add(t));
                let mut checks = 0u64;
                let mut last_total = 0usize;
                let mut last_terminal = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let (total, terminal) =
                        server.read_db(|db| assert_snapshot_coherent(db, seed));
                    // This workload never deletes: the job table only
                    // grows, and terminal states are absorbing, so both
                    // counts are monotone across successive snapshots.
                    assert!(
                        total >= last_total,
                        "seed {seed}: job table shrank ({last_total} -> {total})"
                    );
                    assert!(
                        terminal >= last_terminal,
                        "seed {seed}: terminal set shrank ({last_terminal} -> {terminal})"
                    );
                    last_total = total;
                    last_terminal = terminal;
                    checks += 1;
                    // Vary the interleaving: sometimes re-read back to
                    // back, sometimes yield so the automaton gets a
                    // whole round in between.
                    if rng.below(3) == 0 {
                        std::thread::yield_now();
                    }
                }
                checks
            })
        })
        .collect();

    // The writer: a steady seeded submission stream from this thread
    // while the readers snapshot concurrently.
    let mut rng = Rng::new(seed);
    let mut acked: Vec<JobId> = Vec::with_capacity(JOBS);
    for i in 0..JOBS {
        let spec = JobSpec::batch(
            &format!("u{}", rng.below(5)),
            "date",
            1 + (i % 2) as u32,
            60,
        );
        let id = server
            .submit(&spec)
            .expect("transport")
            .expect("admission");
        acked.push(id);
        if rng.below(8) == 0 {
            std::thread::yield_now();
        }
    }

    assert!(
        server.wait_all_terminal(Duration::from_secs(60)),
        "seed {seed}: workload must drain to terminal states"
    );
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        let checks = r.join().expect("reader thread");
        assert!(checks > 0, "seed {seed}: reader never got a snapshot in");
    }

    // Final multiset: every acknowledged id exists exactly once and
    // reached a terminal state — nothing lost, duplicated or stuck.
    let mut unique = acked.clone();
    unique.sort_unstable();
    unique.dedup();
    assert_eq!(unique.len(), acked.len(), "seed {seed}: duplicate job ids acked");
    server.read_db(|db| {
        assert_eq!(db.job_count(), acked.len(), "seed {seed}: job multiset changed size");
        for id in &acked {
            let job = db.job(*id).expect("acked job must exist");
            assert!(
                job.state.is_terminal(),
                "seed {seed}: job {id} stuck in {:?}",
                job.state
            );
        }
        assert_snapshot_coherent(db, seed);
    });
}

#[test]
fn snapshot_reads_never_observe_half_applied_rounds() {
    for seed in [1u64, 7, 42, 1337] {
        run_seed(seed);
    }
}
