//! End-to-end tests of the network RPC front-end: envelope/versioning
//! errors, the full command set over a real loopback socket, admission
//! REJECT propagation (verbatim), the ISSUE's acceptance load test
//! (concurrent clients, racing deletions, zero lost/duplicated jobs) and
//! graceful drain + clean-shutdown checkpointing.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use oar::cluster::VirtualCluster;
use oar::rpc::{proto, signal, wire, RpcClient, RpcConfig, RpcServer};
use oar::server::{Server, ServerConfig};
use oar::types::{JobId, JobSpec, JobState};
use oar::util::Json;

/// A live server + front-end on an ephemeral loopback port.
fn rpc_server(nodes: u32, scale: f64, workers: usize) -> (Arc<Server>, RpcServer, String) {
    let cluster = Arc::new(VirtualCluster::tiny(nodes, 1));
    let mut cfg = ServerConfig::fast(scale);
    cfg.sched.dense_matching = false;
    let server = Arc::new(Server::new(cluster, cfg));
    let rpc = RpcServer::start(
        server.clone(),
        RpcConfig {
            workers,
            ..RpcConfig::loopback()
        },
    )
    .unwrap();
    let addr = rpc.addr().to_string();
    (server, rpc, addr)
}

#[test]
fn envelope_version_and_framing_errors() {
    let (_server, _rpc, addr) = rpc_server(2, 0.0, 4);
    let mut client = RpcClient::connect(&addr).unwrap();
    assert!(client.ping().unwrap().is_ok());

    // Wrong protocol version, sent raw: typed error echoing our id.
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut req = proto::request(5, "ping", Json::Null);
    if let Json::Obj(m) = &mut req {
        m.insert("v".into(), Json::Num(99.0));
    }
    wire::write_frame(&mut writer, &req).unwrap();
    let resp = wire::read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(resp.get("id").and_then(Json::as_i64), Some(5));
    let err = resp.get("err").expect("err");
    assert_eq!(
        err.get("code").and_then(Json::as_str),
        Some(proto::code::UNSUPPORTED_VERSION)
    );
    let msg = err.get("message").and_then(Json::as_str).unwrap();
    assert!(msg.contains("99") && msg.contains('1'), "{msg}");

    // Unknown method via the typed client.
    let res = client.call("warp", Json::Null).unwrap();
    assert_eq!(res.unwrap_err().code, proto::code::UNKNOWN_METHOD);

    // A frame whose payload is not JSON: best-effort error, then the
    // server cuts the (desynchronized) connection.
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    use std::io::Write;
    writer.write_all(b"00000003not").unwrap();
    writer.flush().unwrap();
    let resp = wire::read_frame(&mut reader).unwrap().unwrap();
    let err = resp.get("err").expect("err");
    assert_eq!(
        err.get("code").and_then(Json::as_str),
        Some(proto::code::BAD_REQUEST)
    );
    assert_eq!(
        wire::read_frame(&mut reader).unwrap(),
        None,
        "connection must be closed after a framing error"
    );

    // The first client is unaffected by the other connections' failures.
    assert!(client.ping().unwrap().is_ok());
}

#[test]
fn rebind_same_addr_after_kill_with_live_connection() {
    // The federation harness reboots a killed cluster on the *same*
    // address. Killing the front-end while a client connection is open
    // makes the server side close first, stranding the connection in
    // FIN_WAIT/TIME_WAIT on the port — the SO_REUSEADDR bind must shrug
    // that off instead of failing with EADDRINUSE for a minute.
    let (server, rpc, addr) = rpc_server(2, 0.0, 2);
    let mut client = RpcClient::connect(&addr).unwrap();
    assert!(client.ping().unwrap().is_ok());
    drop(rpc); // kill while `client` still holds its end open
    let rpc2 = RpcServer::start(
        server.clone(),
        RpcConfig {
            addr: addr.clone(),
            ..RpcConfig::loopback()
        },
    )
    .expect("rebinding the killed front-end's address must succeed at once");
    assert_eq!(rpc2.addr().to_string(), addr);
    let mut revived = RpcClient::connect(&addr).unwrap();
    assert!(revived.ping().unwrap().is_ok());
}

#[test]
fn hold_resume_and_load_over_the_socket() {
    // Non-zero scale: the blocker genuinely occupies the cluster, so the
    // second job is deterministically still Waiting when held.
    let (server, _rpc, addr) = rpc_server(4, 0.05, 4);
    let mut client = RpcClient::connect(&addr).unwrap();

    let idle = client.load().unwrap().unwrap();
    assert_eq!(idle.nodes_total, 4);
    assert_eq!(idle.procs_alive, 4);
    assert_eq!(idle.procs_free, 4);

    let blocker = client
        .sub(&JobSpec::batch("a", "sleep 30", 4, 60))
        .unwrap()
        .unwrap();
    let id = client
        .sub(&JobSpec::batch("b", "date", 4, 60))
        .unwrap()
        .unwrap();
    std::thread::sleep(Duration::from_millis(100));

    // hold → Hold, visible through stat; resume → Waiting, and the job
    // eventually runs to completion.
    assert_eq!(client.hold(id).unwrap().unwrap(), JobState::Hold);
    let held = client
        .stat(Some("state = 'Hold'"))
        .unwrap()
        .unwrap();
    assert_eq!(held.len(), 1);
    assert_eq!(held[0].id, id);
    // Holding a job that is not Waiting is the typed illegal_state error.
    let err = client.hold(id).unwrap().unwrap_err();
    assert_eq!(err.code, proto::code::ILLEGAL_STATE);
    assert_eq!(client.resume(id).unwrap().unwrap(), JobState::Waiting);

    // Unknown ids are no_such_job for both methods.
    assert_eq!(
        client.hold(424_242).unwrap().unwrap_err().code,
        proto::code::NO_SUCH_JOB
    );
    assert_eq!(
        client.resume(424_242).unwrap().unwrap_err().code,
        proto::code::NO_SUCH_JOB
    );

    // The load probe sees the blocker's occupancy while it runs.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let info = client.load().unwrap().unwrap();
        if info.procs_busy == 4 {
            assert_eq!(info.procs_free, 0);
            break;
        }
        assert!(Instant::now() < deadline, "blocker never became busy");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(server.wait_all_terminal(Duration::from_secs(60)));
    let job = server.with_db(|db| db.job(id)).unwrap();
    assert_eq!(job.state, JobState::Terminated);
    let _ = blocker;
}

#[test]
fn sub_stat_del_nodes_queues_roundtrip() {
    let (server, rpc, addr) = rpc_server(4, 0.0, 4);
    let mut client = RpcClient::connect(&addr).unwrap();

    let id = client
        .sub(&JobSpec::batch("alice", "date", 2, 60))
        .unwrap()
        .unwrap();
    assert!(server.wait_all_terminal(Duration::from_secs(20)));
    let jobs = client.stat(Some("state = 'Terminated'")).unwrap().unwrap();
    assert_eq!(jobs.len(), 1);
    assert_eq!(jobs[0].id, id);
    assert_eq!(jobs[0].user, "alice");
    assert!(jobs[0].response_time().is_some());

    // Campaign submission expands {i} server-side, all-or-nothing.
    let ids = client
        .sub_array(&JobSpec::batch("sweep", "date --p {i}", 1, 60), 3)
        .unwrap()
        .unwrap();
    assert_eq!(ids.len(), 3);
    assert!(server.wait_all_terminal(Duration::from_secs(20)));
    let all = client.stat(None).unwrap().unwrap();
    assert_eq!(all.len(), 4);
    assert!(all.iter().any(|j| j.command == "date --p 2"));

    // del of a terminal job reports the terminal state (nothing to do).
    let state = client.del(id).unwrap().unwrap();
    assert!(state.is_terminal());
    // Unknown id and bad filter map to their codes.
    assert_eq!(
        client.del(999_999).unwrap().unwrap_err().code,
        proto::code::NO_SUCH_JOB
    );
    assert_eq!(
        client.stat(Some("(((")).unwrap().unwrap_err().code,
        proto::code::BAD_FILTER
    );

    let nodes = client.nodes().unwrap().unwrap();
    assert_eq!(nodes.len(), 4);
    assert!(nodes.iter().all(|(_, state, procs)| state == "Alive" && *procs == 1));
    let queues = client.queues().unwrap().unwrap();
    assert_eq!(queues[0].name, "default");
    assert!(queues.iter().any(|q| q.name == "besteffort"));

    let (conns, reqs) = rpc.stats();
    assert!(conns >= 1 && reqs >= 8, "conns={conns} reqs={reqs}");
}

#[test]
fn admission_reject_message_travels_verbatim() {
    let (server, _rpc, addr) = rpc_server(2, 0.0, 4);
    server.with_db(|db| {
        db.add_admission_rule(
            5,
            "IF user = 'mallory' THEN REJECT 'mallory is banned until friday'",
        )
    });
    let mut client = RpcClient::connect(&addr).unwrap();

    let err = client
        .sub(&JobSpec::batch("mallory", "date", 1, 60))
        .unwrap()
        .unwrap_err();
    assert_eq!(err.code, proto::code::ADMISSION_REJECTED);
    assert_eq!(err.message, "mallory is banned until friday");

    // Built-in admission checks surface the same way.
    let err = client
        .sub(&JobSpec {
            queue: Some("nope".into()),
            ..JobSpec::default()
        })
        .unwrap()
        .unwrap_err();
    assert_eq!(err.code, proto::code::ADMISSION_REJECTED);
    assert!(err.message.contains("no such queue"), "{}", err.message);

    // Rejections admit nothing and other users still flow.
    assert!(client.sub(&JobSpec::batch("alice", "date", 1, 60)).unwrap().is_ok());
    assert!(server.wait_all_terminal(Duration::from_secs(20)));
    assert_eq!(server.with_db(|db| db.job_count()), 1);
}

#[test]
fn malformed_admission_rule_surfaces_as_internal_error() {
    let (server, _rpc, addr) = rpc_server(2, 0.0, 4);
    server.with_db(|db| db.add_admission_rule(1, "FROBNICATE the submission"));
    let mut client = RpcClient::connect(&addr).unwrap();
    let err = client
        .sub(&JobSpec::batch("alice", "date", 1, 60))
        .unwrap()
        .unwrap_err();
    assert_eq!(err.code, proto::code::INTERNAL);
    assert!(err.message.contains("unknown rule syntax"), "{}", err.message);
    assert_eq!(server.with_db(|db| db.job_count()), 0, "nothing admitted");
}

/// The ISSUE's acceptance criterion: ≥8 concurrent clients × ≥200
/// submissions each, with deletions racing live scheduling rounds, must
/// complete with zero lost and zero duplicated jobs — the final DB job
/// multiset equals the set of acknowledged submissions.
#[test]
fn concurrent_load_with_racing_deletions_loses_nothing() {
    const CLIENTS: usize = 8;
    // Full acceptance scale in release (the CI `rpc` job runs this suite
    // with `--release`); a same-shape smaller load in debug so the
    // tier-1 `cargo test -q` stays fast — conservative backfilling over
    // a 1600-job backlog is deliberately expensive per round.
    #[cfg(not(debug_assertions))]
    const PER: usize = 200;
    #[cfg(debug_assertions)]
    const PER: usize = 25;
    let (server, rpc, addr) = rpc_server(8, 0.0, 12);

    let acked: Arc<Mutex<Vec<JobId>>> = Arc::new(Mutex::new(Vec::new()));
    let submitters: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let addr = addr.clone();
            let acked = acked.clone();
            std::thread::spawn(move || {
                let mut client = RpcClient::connect(&addr).unwrap();
                for i in 0..PER {
                    // A few longer jobs so deletions hit live work too.
                    let cmd = if i % 50 == 0 { "sleep 0.05" } else { "date" };
                    let spec =
                        JobSpec::batch(&format!("u{c}"), cmd, 1 + (i % 2) as u32, 60);
                    let id = client.sub(&spec).unwrap().unwrap();
                    acked.lock().unwrap().push(id);
                }
            })
        })
        .collect();

    // The deleter cancels recently-acknowledged jobs while submissions
    // and scheduling rounds are in full flight; `del` must never panic
    // whatever state it races.
    let stop = Arc::new(AtomicBool::new(false));
    let deleter = {
        let addr = addr.clone();
        let acked = acked.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut client = RpcClient::connect(&addr).unwrap();
            let mut deletions = 0u64;
            loop {
                // Read the flag before deleting so the last pass (after
                // the submitters joined, acked non-empty) always deletes
                // at least once, even if this thread was starved so far.
                let stopped = stop.load(Ordering::SeqCst);
                let target = acked.lock().unwrap().last().copied();
                if let Some(id) = target {
                    client.del(id).unwrap().unwrap(); // acked ⇒ known id
                    deletions += 1;
                }
                if stopped {
                    return deletions;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };

    for h in submitters {
        h.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    let deletions = deleter.join().unwrap();
    assert!(deletions > 0, "the deleter must actually have raced");

    assert!(
        server.wait_all_terminal(Duration::from_secs(180)),
        "workload must drain to terminal states"
    );

    let acked = Arc::try_unwrap(acked).unwrap().into_inner().unwrap();
    assert_eq!(acked.len(), CLIENTS * PER, "every submission acknowledged");
    let mut unique = acked.clone();
    unique.sort_unstable();
    unique.dedup();
    assert_eq!(unique.len(), CLIENTS * PER, "an id was acknowledged twice");

    // DB job multiset == acknowledged set: same count, every id present.
    assert_eq!(server.with_db(|db| db.job_count()), CLIENTS * PER);
    for id in &unique {
        let job = server
            .with_db(|db| db.job(*id))
            .expect("acknowledged job lost from the database");
        assert!(job.state.is_terminal(), "job {id} stranded in {}", job.state);
    }

    let (_conns, reqs) = rpc.stats();
    assert!(
        reqs as usize >= CLIENTS * PER + deletions as usize,
        "front-end served fewer requests than issued"
    );
}

/// Focused mid-round cancellation: a full-cluster blocker plus a queue of
/// waiting jobs, all cancelled over RPC while scheduling rounds run.
#[test]
fn del_mid_round_never_strands_a_job() {
    let (server, _rpc, addr) = rpc_server(4, 0.02, 4);
    let mut client = RpcClient::connect(&addr).unwrap();
    let blocker = client
        .sub(&JobSpec::batch("a", "sleep 30", 4, 60))
        .unwrap()
        .unwrap();
    let queued: Vec<JobId> = (0..10)
        .map(|i| {
            client
                .sub(&JobSpec::batch(&format!("q{i}"), "date", 4, 60))
                .unwrap()
                .unwrap()
        })
        .collect();
    for id in queued.iter().rev().chain(std::iter::once(&blocker)) {
        client.del(*id).unwrap().unwrap();
    }
    assert!(server.wait_all_terminal(Duration::from_secs(60)));
    for id in queued.iter().chain(std::iter::once(&blocker)) {
        let job = server.with_db(|db| db.job(*id)).unwrap();
        assert!(job.state.is_terminal(), "job {id} stranded in {}", job.state);
    }
}

/// Satellite: graceful shutdown — drain answers in-flight requests, idle
/// connections cannot block it, and the Ctrl-C path runs the clean-
/// shutdown checkpoint so the next boot replays nothing.
#[test]
fn graceful_drain_and_clean_shutdown_checkpoint() {
    let dir = std::env::temp_dir().join(format!("oar-rpc-drain-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cluster = Arc::new(VirtualCluster::tiny(2, 1));
    let mut cfg = ServerConfig::fast(0.0);
    cfg.sched.dense_matching = false;
    cfg.data_dir = Some(dir.clone());
    let server = Arc::new(Server::open(cluster, cfg).unwrap());
    let rpc = RpcServer::start(server.clone(), RpcConfig::loopback()).unwrap();
    let addr = rpc.addr().to_string();

    let mut client = RpcClient::connect(&addr).unwrap();
    let id = client
        .sub(&JobSpec::batch("alice", "date", 1, 60))
        .unwrap()
        .unwrap();
    assert!(server.wait_all_terminal(Duration::from_secs(20)));

    // An idle keep-alive connection must not block the drain.
    let idle = RpcClient::connect(&addr).unwrap();
    let t0 = Instant::now();
    rpc.drain();
    assert!(t0.elapsed() < Duration::from_secs(10), "drain hung");
    drop(idle);

    // The listener is gone: new clients are refused, not silently queued.
    assert!(RpcClient::connect(&addr).is_err());

    // The Ctrl-C path: signal flag → drain (done above) → checkpointing
    // shutdown. The front-end has joined, so the handle is unique again.
    signal::request_shutdown();
    assert!(signal::shutdown_requested());
    let server = Arc::try_unwrap(server)
        .ok()
        .expect("front-end joined; server handle must be unique");
    let _db = server.shutdown(); // clean shutdown = WAL compaction

    let (mut db, stats) = oar::db::Db::recover(&dir).unwrap();
    assert!(stats.snapshot_loaded, "checkpoint must have published a snapshot");
    assert_eq!(stats.replayed, 0, "clean shutdown leaves no WAL tail to replay");
    assert!(!stats.torn_tail);
    assert_eq!(db.job(id).unwrap().state, JobState::Terminated);
    let _ = std::fs::remove_dir_all(&dir);
}

/// An acked `del` survives a crash: the cancellation intent is
/// WAL-logged before the ack, and recovery re-enqueues it, so the job
/// ends `Error` (cancelled) rather than silently running to completion.
#[test]
fn acked_del_survives_a_crash() {
    let dir = std::env::temp_dir().join(format!("oar-rpc-delwal-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cluster = Arc::new(VirtualCluster::tiny(2, 1));
    let mut cfg = ServerConfig::fast(0.05);
    cfg.sched.dense_matching = false;
    cfg.data_dir = Some(dir.clone());
    cfg.recovery = oar::types::RecoveryPolicy::Requeue;
    let server = Arc::new(Server::open(cluster.clone(), cfg).unwrap());
    let rpc = RpcServer::start(server.clone(), RpcConfig::loopback()).unwrap();
    let addr = rpc.addr().to_string();

    let mut client = RpcClient::connect(&addr).unwrap();
    let id = client
        .sub(&JobSpec::batch("alice", "sleep 30", 1, 60))
        .unwrap()
        .unwrap();
    // Ack the cancellation, then crash the process before (or while) the
    // automaton drains the event.
    client.del(id).unwrap().unwrap();
    rpc.drain();
    Arc::try_unwrap(server).ok().expect("unique").simulate_crash();

    // Recovery must honor the acked del even under the requeue policy.
    let mut cfg = ServerConfig::fast(0.05);
    cfg.sched.dense_matching = false;
    cfg.data_dir = Some(dir.clone());
    cfg.recovery = oar::types::RecoveryPolicy::Requeue;
    let server = Server::open(cluster, cfg).unwrap();
    assert!(server.wait_all_terminal(Duration::from_secs(30)));
    let job = server.with_db(|db| db.job(id)).unwrap();
    assert_eq!(
        job.state,
        JobState::Error,
        "acked del must not be forgotten across a crash"
    );
    drop(server);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A silent connection must not pin a worker forever: the per-connection
/// io timeout closes it, and real clients get served with the freed
/// worker.
#[test]
fn idle_connections_time_out_and_free_the_worker() {
    let cluster = Arc::new(VirtualCluster::tiny(2, 1));
    let mut cfg = ServerConfig::fast(0.0);
    cfg.sched.dense_matching = false;
    let server = Arc::new(Server::new(cluster, cfg));
    let rpc = RpcServer::start(
        server.clone(),
        RpcConfig {
            workers: 1,
            queue_depth: 1,
            io_timeout: Some(Duration::from_millis(300)),
            ..RpcConfig::loopback()
        },
    )
    .unwrap();
    let addr = rpc.addr().to_string();

    // The single worker is pinned by a client that sends nothing...
    let mut silent = std::net::TcpStream::connect(&addr).unwrap();
    // ...but only until io_timeout: a real client still gets served.
    let mut client = RpcClient::connect(&addr).unwrap();
    assert!(client.ping().unwrap().is_ok());

    // And the server closed the silent connection.
    use std::io::Read;
    silent
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut buf = [0u8; 1];
    assert_eq!(
        silent.read(&mut buf).unwrap(),
        0,
        "server must close the idle connection"
    );
}

/// Backpressure: more simultaneous connections than workers+queue slots
/// must not crash or drop requests — excess clients just wait.
#[test]
fn backpressure_queues_excess_connections() {
    let cluster = Arc::new(VirtualCluster::tiny(2, 1));
    let mut cfg = ServerConfig::fast(0.0);
    cfg.sched.dense_matching = false;
    let server = Arc::new(Server::new(cluster, cfg));
    let rpc = RpcServer::start(
        server.clone(),
        RpcConfig {
            workers: 2,
            queue_depth: 2,
            ..RpcConfig::loopback()
        },
    )
    .unwrap();
    let addr = rpc.addr().to_string();

    let handles: Vec<_> = (0..12)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = RpcClient::connect(&addr).unwrap();
                for _ in 0..5 {
                    client.ping().unwrap().unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let (conns, reqs) = rpc.stats();
    assert_eq!(conns, 12);
    assert_eq!(reqs, 60);
}
