//! Property-based tests over the coordinator's invariants (routing,
//! batching, state), using the crate's seeded PRNG as generator (the
//! proptest crate is unavailable offline — shrinkless random property
//! testing with fixed seeds and many cases serves the same role; failures
//! print the case seed for reproduction).

use oar::db::{Db, Expr, Value};
use oar::matching::encode::{Encoder, JobToMatch};
use oar::matching::reference::run_reference;
use oar::matching::SqlMatcher;
use oar::sched::baselines::{MauiLike, SgeLike, TorqueLike};
use oar::sched::policies::{FifoConservative, PolicyJob, QueuePolicy, SjfConservative};
use oar::sched::Gantt;
use oar::sim::{simulate, SimConfig, SimJob};
use oar::types::{Job, JobSpec, JobState, Node, NodeId};
use oar::util::Rng;

const CASES: u64 = 200;

// ---------------------------------------------------------------- gantt ----

/// Random occupy/release sequences never oversubscribe any node.
#[test]
fn prop_gantt_never_oversubscribes() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let n_nodes = rng.range_i64(1, 6) as u32;
        let procs = rng.range_i64(1, 4) as u32;
        let nodes: Vec<(NodeId, u32)> = (1..=n_nodes).map(|i| (i, procs)).collect();
        let mut g = Gantt::new(&nodes);
        for job in 0..rng.range_i64(1, 40) as u64 {
            let node = rng.range_i64(1, n_nodes as i64 + 1) as NodeId;
            let p = rng.range_i64(1, procs as i64 + 2) as u32; // may exceed
            let start = rng.range_i64(0, 500);
            let stop = start + rng.range_i64(1, 200);
            g.occupy(job, node, p, start, stop);
            if rng.chance(0.2) {
                g.release_job(rng.range_i64(0, job as i64 + 1) as u64);
            }
        }
        // Invariant: at every allocation edge, usage <= capacity.
        for (node, alloc) in g.allocations() {
            for t in [alloc.start, alloc.stop - 1] {
                let free = g.free_at(node, t);
                assert!(free >= 0, "seed {seed}: node {node} oversubscribed at {t}");
            }
        }
    }
}

/// find_earliest always returns a placement that occupy() accepts, and
/// there is never an earlier feasible instant among allocation edges.
#[test]
fn prop_find_earliest_is_feasible_and_minimal() {
    for seed in 0..CASES {
        let mut rng = Rng::new(1000 + seed);
        let n_nodes = rng.range_i64(2, 6) as u32;
        let nodes: Vec<(NodeId, u32)> = (1..=n_nodes).map(|i| (i, 2)).collect();
        let mut g = Gantt::new(&nodes);
        for job in 0..rng.range_i64(0, 15) as u64 {
            let node = rng.range_i64(1, n_nodes as i64 + 1) as NodeId;
            let start = rng.range_i64(0, 300);
            g.occupy(job, node, rng.range_i64(1, 3) as u32, start, start + rng.range_i64(1, 150));
        }
        let eligible: Vec<NodeId> = (1..=n_nodes).collect();
        let nb = rng.range_i64(1, n_nodes as i64 + 1) as u32;
        let weight = rng.range_i64(1, 3) as u32;
        let dur = rng.range_i64(1, 100);
        if let Some((t, chosen)) = g.find_earliest(&eligible, nb, weight, dur, 0) {
            assert_eq!(chosen.len(), nb as usize, "seed {seed}");
            // feasibility: occupy must succeed on a copy
            let mut copy = g.clone();
            for n in &chosen {
                assert!(
                    copy.occupy(999, *n, weight, t, t + dur),
                    "seed {seed}: infeasible placement at {t}"
                );
            }
            // minimality: no feasible start strictly earlier at any edge
            for cand in 0..t {
                let avail = g.available_nodes_at(&eligible, weight, cand, dur);
                assert!(
                    (avail.len() as u32) < nb,
                    "seed {seed}: earlier start {cand} < {t} was feasible"
                );
            }
        }
    }
}

// ------------------------------------------------------------- policies ----

fn random_policy_jobs(rng: &mut Rng, n_nodes: u32) -> Vec<PolicyJob> {
    let count = rng.range_i64(1, 25) as u64;
    (0..count)
        .map(|i| PolicyJob {
            id: i + 1,
            nb_nodes: rng.range_i64(1, n_nodes as i64 + 1) as u32,
            weight: 1,
            duration: rng.range_i64(1, 300),
            submission_time: rng.range_i64(0, 10),
            eligible: (1..=n_nodes).collect(),
            best_effort: false,
            score: 0.0,
            alts: vec![],
        })
        .collect()
}

/// Every policy: started jobs are mutually feasible (the gantt accepted
/// them), and no job is started twice.
#[test]
fn prop_policies_start_feasible_disjoint_sets() {
    let policies: Vec<Box<dyn QueuePolicy>> = vec![
        Box::new(FifoConservative),
        Box::new(SjfConservative),
        Box::new(TorqueLike),
        Box::new(SgeLike),
        Box::new(MauiLike),
    ];
    for seed in 0..CASES {
        let mut rng = Rng::new(2000 + seed);
        let n_nodes = rng.range_i64(1, 8) as u32;
        let jobs = random_policy_jobs(&mut rng, n_nodes);
        for policy in &policies {
            let mut g = Gantt::new(&(1..=n_nodes).map(|i| (i, 1)).collect::<Vec<_>>());
            let starts = policy.schedule(0, &jobs, &mut g);
            let mut seen = std::collections::HashSet::new();
            let mut used_now: std::collections::HashMap<NodeId, u32> = Default::default();
            for (id, nodes) in &starts {
                assert!(seen.insert(*id), "seed {seed} {}: dup start", policy.name());
                let job = jobs.iter().find(|j| j.id == *id).unwrap();
                assert_eq!(nodes.len(), job.nb_nodes as usize);
                for n in nodes {
                    *used_now.entry(*n).or_default() += job.weight;
                }
            }
            for (node, used) in used_now {
                assert!(used <= 1, "seed {seed} {}: node {node} double-started", policy.name());
            }
        }
    }
}

/// Conservative invariant (the paper's "no job delayed by later ones"):
/// adding a NEW later job never makes any earlier job's planned start
/// later under FifoConservative.
#[test]
fn prop_fifo_conservative_no_delay_by_later_submission() {
    for seed in 0..CASES {
        let mut rng = Rng::new(3000 + seed);
        let n_nodes = rng.range_i64(1, 6) as u32;
        let mut jobs = random_policy_jobs(&mut rng, n_nodes);
        jobs.sort_by_key(|j| (j.submission_time, j.id));
        let node_list: Vec<(NodeId, u32)> = (1..=n_nodes).map(|i| (i, 1)).collect();

        let planned_starts = |jobs: &[PolicyJob]| -> std::collections::HashMap<u64, i64> {
            let mut g = Gantt::new(&node_list);
            FifoConservative.schedule(0, jobs, &mut g);
            let mut firsts: std::collections::HashMap<u64, i64> = Default::default();
            for (_, a) in g.allocations() {
                firsts
                    .entry(a.job)
                    .and_modify(|s| *s = (*s).min(a.start))
                    .or_insert(a.start);
            }
            firsts
        };

        let before = planned_starts(&jobs);
        // append one more job with the latest submission time
        let mut extended = jobs.clone();
        extended.push(PolicyJob {
            id: 9999,
            nb_nodes: rng.range_i64(1, n_nodes as i64 + 1) as u32,
            weight: 1,
            duration: rng.range_i64(1, 300),
            submission_time: 100,
            eligible: (1..=n_nodes).collect(),
            best_effort: false,
            score: 0.0,
            alts: vec![],
        });
        let after = planned_starts(&extended);
        for (id, start) in &before {
            assert!(
                after.get(id).map(|s| s <= start).unwrap_or(false),
                "seed {seed}: job {id} delayed {start} -> {:?}",
                after.get(id)
            );
        }
    }
}

// ---------------------------------------------------------------- sim ----

/// Work conservation + capacity respect across all policies on random
/// workloads.
#[test]
fn prop_simulation_conserves_work_and_capacity() {
    let policies: Vec<Box<dyn QueuePolicy>> = vec![
        Box::new(FifoConservative),
        Box::new(SjfConservative),
        Box::new(TorqueLike),
        Box::new(SgeLike),
        Box::new(MauiLike),
    ];
    for seed in 0..50 {
        let mut rng = Rng::new(4000 + seed);
        let procs = rng.range_i64(2, 10) as u32;
        let nodes: Vec<(NodeId, u32)> = (1..=procs).map(|i| (i, 1)).collect();
        let jobs: Vec<SimJob> = (0..rng.range_i64(1, 60) as u64)
            .map(|i| {
                let runtime = rng.range_i64(1, 100);
                SimJob {
                    id: i + 1,
                    // range_i64 is inclusive: [1, procs] keeps jobs feasible
                    // (infeasible requests are the meta-scheduler's job to
                    // reject before a policy ever sees them)
                    nb_nodes: rng.range_i64(1, procs as i64) as u32,
                    weight: 1,
                    runtime,
                    max_time: runtime,
                    submit: rng.range_i64(0, 50),
                }
            })
            .collect();
        let want_work: i64 = jobs.iter().map(|j| j.runtime * j.total_procs() as i64).sum();
        for policy in &policies {
            let r = simulate(policy.as_ref(), &nodes, &jobs, SimConfig::default());
            assert_eq!(r.records.len(), jobs.len(), "seed {seed} {}", policy.name());
            assert_eq!(r.total_work(), want_work, "seed {seed} {}", policy.name());
            assert!(
                r.utilization.iter().all(|(_, b)| *b <= procs),
                "seed {seed} {}: capacity exceeded",
                policy.name()
            );
            for rec in &r.records {
                assert!(rec.start >= rec.submit, "seed {seed}: started before submit");
            }
        }
    }
}

/// Determinism: the same seed + workload produces byte-identical
/// [`oar::sim::JobRecord`] sequences across two independent runs, for
/// every policy. This is the assumption WAL replay rests on — recovery
/// re-derives state by re-applying a logged history, which is only sound
/// if execution is a pure function of its inputs.
#[test]
fn prop_simulation_is_deterministic_per_seed() {
    let policies: Vec<Box<dyn QueuePolicy>> = vec![
        Box::new(FifoConservative),
        Box::new(SjfConservative),
        Box::new(TorqueLike),
        Box::new(SgeLike),
        Box::new(MauiLike),
    ];
    for seed in 0..30 {
        let run = |seed: u64, policy: &dyn QueuePolicy| -> String {
            // Regenerate the workload from scratch: determinism must hold
            // through the generator, not just the simulator.
            let mut rng = Rng::new(9000 + seed);
            let procs = rng.range_i64(2, 10) as u32;
            let nodes: Vec<(NodeId, u32)> = (1..=procs).map(|i| (i, 1)).collect();
            let jobs: Vec<SimJob> = (0..rng.range_i64(1, 60) as u64)
                .map(|i| {
                    let runtime = rng.range_i64(1, 100);
                    SimJob {
                        id: i + 1,
                        nb_nodes: rng.range_i64(1, procs as i64) as u32,
                        weight: 1,
                        runtime,
                        max_time: runtime,
                        submit: rng.range_i64(0, 50),
                    }
                })
                .collect();
            let r = simulate(policy, &nodes, &jobs, SimConfig::default());
            format!("{:?}", r.records)
        };
        for policy in &policies {
            let a = run(seed, policy.as_ref());
            let b = run(seed, policy.as_ref());
            assert_eq!(a, b, "seed {seed} {}: nondeterministic records", policy.name());
        }
    }
}

// ------------------------------------------------------------- matching ----

fn random_fleet(rng: &mut Rng, n: u32) -> Vec<Node> {
    (1..=n)
        .map(|i| {
            Node::new(i, &format!("n{i}"), 2)
                .with_prop("mem", Value::Int(rng.range_i64(128, 4096)))
                .with_prop("cpu_mhz", Value::Int(rng.range_i64(500, 3000)))
                .with_prop(
                    "switch",
                    Value::Text(format!("sw{}", rng.range_i64(1, 4))),
                )
        })
        .collect()
}

fn random_interval_expr(rng: &mut Rng) -> String {
    let mut clauses = Vec::new();
    for _ in 0..rng.range_i64(0, 4) {
        let c = match rng.range_i64(0, 5) {
            0 => format!("mem >= {}", rng.range_i64(0, 4500)),
            1 => format!("mem <= {}", rng.range_i64(0, 4500)),
            2 => format!("cpu_mhz > {}", rng.range_i64(0, 3200)),
            3 => format!("switch = 'sw{}'", rng.range_i64(1, 5)),
            _ => format!(
                "mem BETWEEN {} AND {}",
                rng.range_i64(0, 2000),
                rng.range_i64(2000, 4500)
            ),
        };
        clauses.push(c);
    }
    clauses.join(" AND ")
}

/// The dense (kernel-semantics) matching path agrees exactly with SQL
/// row-at-a-time evaluation on every interval-expressible expression.
#[test]
fn prop_dense_matching_equals_sql_matching() {
    for seed in 0..CASES {
        let mut rng = Rng::new(5000 + seed);
        let fleet_size = rng.range_i64(1, 30) as u32;
        let nodes = random_fleet(&mut rng, fleet_size);
        let encoder = Encoder::from_nodes(&nodes);
        let free = vec![vec![1.0f32; oar::matching::T]; nodes.len()];
        let jobs: Vec<JobToMatch> = (0..rng.range_i64(1, 20) as u64)
            .map(|i| JobToMatch {
                id: i + 1,
                properties: random_interval_expr(&mut rng),
                total_procs: 1,
                duration: 300,
                wait_time: 0,
                queue_priority: 1,
                best_effort: false,
            })
            .collect();
        let batch = encoder.encode(&jobs, &nodes, &free, 300, [0.0; oar::matching::F]);
        let out = run_reference(&batch.input);
        for (row, job) in jobs.iter().enumerate() {
            if batch.fallback.contains(&job.id) {
                continue; // SQL path handles it by construction
            }
            let want = SqlMatcher::eligible_nodes(&job.properties, &nodes).unwrap();
            let got: Vec<NodeId> = batch
                .node_cols
                .iter()
                .enumerate()
                .filter(|(col, _)| out.elig[row * oar::matching::N + col] == 1.0)
                .map(|(_, id)| *id)
                .collect();
            assert_eq!(got, want, "seed {seed} expr {:?}", job.properties);
        }
    }
}

// ------------------------------------------------------------ expr/db ----

/// Parser totality on random well-formed comparisons + evaluation matches
/// a direct check.
#[test]
fn prop_expr_eval_matches_direct_comparison() {
    for seed in 0..CASES {
        let mut rng = Rng::new(6000 + seed);
        let threshold = rng.range_i64(-100, 100);
        let value = rng.range_i64(-100, 100);
        let ops: [(&str, fn(i64, i64) -> bool); 6] = [
            ("=", |a, b| a == b),
            ("!=", |a, b| a != b),
            ("<", |a, b| a < b),
            ("<=", |a, b| a <= b),
            (">", |a, b| a > b),
            (">=", |a, b| a >= b),
        ];
        let (op, f) = ops[rng.below(6) as usize];
        let expr = Expr::parse(&format!("x {op} {threshold}")).unwrap();
        let mut row = oar::db::Row::new();
        row.insert("x".into(), Value::Int(value));
        assert_eq!(
            expr.matches(&row),
            f(value, threshold),
            "seed {seed}: {value} {op} {threshold}"
        );
    }
}

/// State machine safety on random event sequences against a live Db: a
/// rejected transition never corrupts the stored state, and every
/// reachable state is a legal fig.-1 state.
#[test]
fn prop_state_machine_safety_under_random_transitions() {
    for seed in 0..CASES {
        let mut rng = Rng::new(7000 + seed);
        let mut db = Db::with_standard_queues();
        let id = db.insert_job(Job::from_spec(&JobSpec::default(), 0));
        for step in 0..30 {
            let target = *rng.pick(&JobState::ALL);
            let before = db.job(id).unwrap().state;
            let result = db.set_job_state(id, target, step);
            let after = db.job(id).unwrap().state;
            match result {
                Ok(()) => assert!(
                    before.can_transition_to(target) && after == target,
                    "seed {seed}: illegal accepted {before} -> {target}"
                ),
                Err(_) => assert_eq!(
                    before, after,
                    "seed {seed}: failed transition mutated state"
                ),
            }
        }
    }
}

/// Snapshot → restore is lossless for random databases.
#[test]
fn prop_snapshot_roundtrip() {
    for seed in 0..30 {
        let mut rng = Rng::new(8000 + seed);
        let mut db = Db::with_standard_queues();
        let fleet_size = rng.range_i64(1, 10) as u32;
        for n in random_fleet(&mut rng, fleet_size) {
            db.add_node(n);
        }
        let mut ids = Vec::new();
        for i in 0..rng.range_i64(0, 30) {
            let spec = JobSpec {
                properties: Some(random_interval_expr(&mut rng)),
                ..JobSpec::batch(&format!("u{}", rng.below(5)), "date", 1, 60)
            };
            ids.push(db.insert_job(Job::from_spec(&spec, i)));
        }
        db.log_event(1, "TEST", ids.first().copied(), "detail");
        let path = std::env::temp_dir().join(format!("oar_prop_snap_{seed}.json"));
        db.snapshot(&path).unwrap();
        let mut back = Db::restore(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.job_count(), ids.len(), "seed {seed}");
        for id in ids {
            let a = db.job(id).unwrap();
            let b = back.job(id).unwrap();
            assert_eq!(a.user, b.user, "seed {seed}");
            assert_eq!(a.properties, b.properties, "seed {seed}");
            assert_eq!(a.state, b.state, "seed {seed}");
        }
        assert_eq!(back.events().len(), 1);
    }
}
