//! The `oarlint` gate, in two halves:
//!
//! 1. **The real tree is clean.** `rust/src` + `rust/tests` lint with
//!    zero unsuppressed findings under the repository rule config, and
//!    the suppression inventory is pinned — adding an `allow` without
//!    updating the expected set here is a reviewable event, exactly like
//!    a snapshot-test diff.
//! 2. **Every rule actually fires.** For each of R1–R7 a positive
//!    fixture must produce that rule's findings and a negative fixture
//!    must stay silent, so a refactor of the analyzer cannot quietly
//!    lobotomize a rule while the tree stays "clean".
//!
//! The fixture corpus lives in `rust/tests/fixtures/lint/` — never
//! compiled (the directory is skipped by `analyze_paths`), only lexed.

use std::path::Path;

use oar::analysis::{analyze_paths, Analyzer, Report, RuleConfig};

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn lint_fixture(name: &str, cfg: RuleConfig) -> Report {
    let path = repo_root().join("rust/tests/fixtures/lint").join(name);
    let src = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {name}: {e}"));
    let mut analyzer = Analyzer::new(cfg);
    analyzer.add_file(name, &src);
    analyzer.finish()
}

// ------------------------------------------------- the real tree ----

#[test]
fn repository_tree_is_lint_clean() {
    let report = analyze_paths(
        repo_root(),
        &["rust/src", "rust/tests"],
        RuleConfig::repo(),
    )
    .expect("lint walk");

    assert!(
        report.findings.is_empty(),
        "oarlint found unsuppressed issues:\n{}",
        report.render_human()
    );
    // Sanity: an empty report because nothing was scanned is not clean.
    assert!(
        report.files_scanned >= 70,
        "suspiciously few files scanned: {}",
        report.files_scanned
    );
    assert!(report.functions_scanned > 300);
}

#[test]
fn repository_suppression_inventory_is_pinned() {
    let report = analyze_paths(
        repo_root(),
        &["rust/src", "rust/tests"],
        RuleConfig::repo(),
    )
    .expect("lint walk");

    let mut inventory: Vec<(String, String)> = report
        .suppressed
        .iter()
        .map(|s| (s.finding.file.clone(), s.finding.rule.clone()))
        .collect();
    inventory.sort();
    let expected = [
        ("rust/src/cli/net.rs", "R2"),       // teardown checkpoint via shared handle
        ("rust/src/rpc/server.rs", "R5"),    // acceptor spawn is startup-fatal
        ("rust/src/rpc/server.rs", "R5"),    // worker spawn is startup-fatal
        ("rust/src/server/mod.rs", "R2"),    // shutdown checkpoint under guard
        ("rust/src/server/mod.rs", "R2"),    // shutdown snapshot under guard
    ];
    let expected: Vec<(String, String)> = expected
        .iter()
        .map(|(f, r)| (f.to_string(), r.to_string()))
        .collect();
    assert_eq!(
        inventory,
        expected,
        "suppression inventory drifted:\n{}",
        report.render_human()
    );
    for s in &report.suppressed {
        assert!(!s.reason.is_empty(), "suppression without reason: {s:?}");
    }
}

// ------------------------------------------------ fixture corpus ----

#[test]
fn r1_lock_order_fires_and_stays_quiet() {
    let bad = lint_fixture("r1_bad.rs", RuleConfig::only("R1"));
    // One immediate same-class nesting + one alpha/beta cycle.
    assert_eq!(bad.of_rule("R1").count(), 2, "{}", bad.render_human());
    assert!(bad.findings.iter().any(|f| f.message.contains("cycle")));
    assert!(bad.findings.iter().any(|f| f.message.contains("nested")));

    let good = lint_fixture("r1_good.rs", RuleConfig::only("R1"));
    assert!(good.findings.is_empty(), "{}", good.render_human());
}

#[test]
fn r2_blocking_under_guard_fires_and_stays_quiet() {
    let bad = lint_fixture("r2_bad.rs", RuleConfig::only("R2"));
    assert_eq!(bad.of_rule("R2").count(), 2, "{}", bad.render_human());
    assert!(bad.findings.iter().any(|f| f.message.contains("sleep")));
    assert!(bad.findings.iter().any(|f| f.message.contains("shutdown")));

    let good = lint_fixture("r2_good.rs", RuleConfig::only("R2"));
    assert!(good.findings.is_empty(), "{}", good.render_human());
}

#[test]
fn r3_commit_before_ack_fires_and_stays_quiet() {
    let bad = lint_fixture("r3_bad.rs", RuleConfig::only("R3"));
    // Ack-before-commit, ack-under-guard, dispatch-without-intent.
    assert_eq!(bad.of_rule("R3").count(), 3, "{}", bad.render_human());
    assert!(bad.findings.iter().any(|f| f.message.contains("intent")));

    let good = lint_fixture("r3_good.rs", RuleConfig::only("R3"));
    assert!(good.findings.is_empty(), "{}", good.render_human());
}

#[test]
fn r4_db_lock_regression_fires_and_stays_quiet() {
    let bad = lint_fixture("r4_bad.rs", RuleConfig::only("R4"));
    // The Mutex<Db> field and the db.lock() call site.
    assert_eq!(bad.of_rule("R4").count(), 2, "{}", bad.render_human());

    let good = lint_fixture("r4_good.rs", RuleConfig::only("R4"));
    assert!(good.findings.is_empty(), "{}", good.render_human());
}

#[test]
fn r5_panic_freedom_fires_and_stays_quiet() {
    let bad = lint_fixture("r5_bad.rs", RuleConfig::only("R5"));
    // unwrap, slice index, expect, panic! — one each.
    assert_eq!(bad.of_rule("R5").count(), 4, "{}", bad.render_human());

    let good = lint_fixture("r5_good.rs", RuleConfig::only("R5"));
    assert!(good.findings.is_empty(), "{}", good.render_human());
}

#[test]
fn r6_atomics_calibration_fires_and_stays_quiet() {
    let bad = lint_fixture("r6_bad.rs", RuleConfig::only("R6"));
    assert_eq!(bad.of_rule("R6").count(), 2, "{}", bad.render_human());

    let good = lint_fixture("r6_good.rs", RuleConfig::only("R6"));
    assert!(good.findings.is_empty(), "{}", good.render_human());
}

#[test]
fn r7_telemetry_off_commit_path_fires_and_stays_quiet() {
    let bad = lint_fixture("r7_bad.rs", RuleConfig::only("R7"));
    // Observe under the write guard, inc inside write_db, span under
    // the sink lock — one each.
    assert_eq!(bad.of_rule("R7").count(), 3, "{}", bad.render_human());
    assert!(bad.findings.iter().any(|f| f.message.contains("observe")));
    assert!(bad.findings.iter().any(|f| f.message.contains("inc")));
    assert!(bad.findings.iter().any(|f| f.message.contains("enter")));

    let good = lint_fixture("r7_good.rs", RuleConfig::only("R7"));
    assert!(good.findings.is_empty(), "{}", good.render_human());
}

#[test]
fn suppressions_are_applied_and_accounted() {
    let rep = lint_fixture("suppress.rs", RuleConfig::only("R2"));

    // The checkpoint finding is silenced, with its reason preserved.
    assert_eq!(rep.suppressed.len(), 1, "{}", rep.render_human());
    assert!(rep.suppressed[0].reason.contains("atomic"));

    // The snapshot on the next line stays a hard error.
    assert_eq!(rep.errors(), 1, "{}", rep.render_human());
    assert!(rep.findings.iter().any(|f| {
        f.rule == "R2" && f.message.contains("snapshot")
    }));

    // The unused allow and the unknown-rule directive both warn.
    assert_eq!(rep.warnings(), 2, "{}", rep.render_human());
    assert!(rep.findings.iter().any(|f| f.message.contains("unused suppression")));
    assert!(rep.findings.iter().any(|f| f.message.contains("unknown rule")));
}

#[test]
fn literals_and_comments_are_inert() {
    // Lock calls, directives and panics inside string literals must not
    // produce findings (nor register suppressions).
    let src = r##"
fn log_examples(s: &Shared) {
    let msg = "s.db.lock() under load, then panic! — oarlint: allow(R9)";
    let raw = r#"db.write().unwrap() while db.checkpoint() runs"#;
    s.log(msg, raw);
}
"##;
    let mut analyzer = Analyzer::new(RuleConfig::everywhere());
    analyzer.add_file("inert.rs", src);
    let rep = analyzer.finish();
    assert!(rep.findings.is_empty(), "{}", rep.render_human());
    assert!(rep.suppressed.is_empty());
}
