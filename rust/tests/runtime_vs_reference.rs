//! The three-layer seam: the AOT-compiled JAX/Pallas artifact executed
//! through PJRT must agree exactly with the pure-Rust reference (which is
//! itself pytest-pinned to the pure-jnp oracle). Requires
//! `make artifacts`; every test skips cleanly when the artifact is absent
//! so `cargo test` stays green pre-build.

use oar::matching::encode::{Encoder, JobToMatch};
use oar::matching::{reference::run_reference, ScheduleStep, StepInput};
use oar::matching::{F, J, N, P, T};
use oar::runtime::HloStep;
use oar::util::Rng;

fn hlo() -> Option<HloStep> {
    match HloStep::load_default() {
        Ok(h) => Some(h),
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e}");
            None
        }
    }
}

fn random_input(seed: u64) -> StepInput {
    let mut rng = Rng::new(seed);
    let mut input = StepInput::zeros();
    for j in 0..J {
        for p in 0..P {
            let lo = rng.range_f64(-2.0, 1.0) as f32;
            input.job_lo[j * P + p] = lo;
            input.job_hi[j * P + p] = lo + rng.range_f64(0.0, 2.5) as f32;
        }
        input.req[j] = rng.range_i64(0, 8) as f32;
        input.dur[j] = rng.range_i64(1, T as i64) as f32;
        for f in 0..F {
            input.job_feats[j * F + f] = rng.range_f64(0.0, 10.0) as f32;
        }
    }
    for n in 0..N {
        for p in 0..P {
            input.node_props[n * P + p] = rng.range_f64(-2.0, 2.0) as f32;
        }
        for t in 0..T {
            input.node_free[n * T + t] = rng.range_i64(0, 3) as f32;
        }
    }
    for f in 0..F {
        input.weights[f] = rng.range_f64(0.0, 1.0) as f32;
    }
    input
}

#[test]
fn artifact_matches_reference_on_random_inputs() {
    let Some(mut hlo) = hlo() else { return };
    for seed in 0..10 {
        let input = random_input(seed);
        let got = hlo.run(&input).unwrap();
        let want = run_reference(&input);
        assert_eq!(got.elig, want.elig, "seed {seed}: elig");
        assert_eq!(got.earliest, want.earliest, "seed {seed}: earliest");
        for (i, (g, w)) in got.freecount.iter().zip(&want.freecount).enumerate() {
            assert!((g - w).abs() < 1e-3, "seed {seed}: freecount[{i}] {g} vs {w}");
        }
        for (i, (g, w)) in got.scores.iter().zip(&want.scores).enumerate() {
            assert!((g - w).abs() < 1e-3, "seed {seed}: scores[{i}] {g} vs {w}");
        }
    }
}

#[test]
fn artifact_matches_reference_on_encoded_cluster_batches() {
    let Some(mut hlo) = hlo() else { return };
    // Realistic inputs: the icluster fleet + SQL-derived constraints.
    let cluster = oar::cluster::VirtualCluster::icluster();
    let nodes = cluster.nodes().to_vec();
    let encoder = Encoder::from_nodes(&nodes);
    let free = vec![vec![1.0f32; T]; nodes.len()];
    let jobs: Vec<JobToMatch> = (0..40)
        .map(|i| JobToMatch {
            id: i + 1,
            properties: match i % 5 {
                0 => String::new(),
                1 => "mem >= 256".into(),
                2 => "cpu_mhz > 700".into(),
                3 => "switch = 'sw3'".into(),
                _ => "mem BETWEEN 128 AND 512 AND cpu_mhz >= 733".into(),
            },
            total_procs: 1 + (i % 6) as u32,
            duration: 300 * (1 + (i % 5) as i64),
            wait_time: i as i64 * 10,
            queue_priority: 10,
            best_effort: i % 7 == 0,
        })
        .collect();
    let batch = encoder.encode(&jobs, &nodes, &free, 300, [1.0, 10.0, 0.0, 0.0, -5.0, 0.0]);
    assert!(batch.fallback.is_empty());
    let got = hlo.run(&batch.input).unwrap();
    let want = run_reference(&batch.input);
    assert_eq!(got.elig, want.elig);
    assert_eq!(got.earliest, want.earliest);
}

#[test]
fn artifact_edge_cases() {
    let Some(mut hlo) = hlo() else { return };
    // all-zero input
    let out = hlo.run(&StepInput::zeros()).unwrap();
    assert_eq!(out, run_reference(&StepInput::zeros()));

    // unbounded intervals + sentinel padding values
    let mut input = StepInput::zeros();
    for p in 0..P {
        input.job_lo[p] = oar::matching::shapes::LO_UNBOUNDED;
        input.job_hi[p] = oar::matching::shapes::HI_UNBOUNDED;
    }
    for n in 0..N {
        for p in 0..P {
            input.node_props[n * P + p] = if n % 2 == 0 {
                oar::matching::shapes::PAD_PROP
            } else {
                0.0
            };
        }
    }
    let got = hlo.run(&input).unwrap();
    let want = run_reference(&input);
    assert_eq!(got.elig, want.elig, "sentinel handling must match");
}
