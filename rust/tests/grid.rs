//! End-to-end federation tests: bag-of-tasks campaigns farmed over
//! several in-process loopback clusters, including the ISSUE's acceptance
//! scenario — a 500-task campaign over 3 asymmetric clusters that drains
//! completely with zero lost/duplicated tasks while one cluster is killed
//! mid-campaign and later rejoins, with a grid restart mid-campaign
//! resuming from the persisted tables.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use oar::db::Db;
use oar::grid::{Grid, GridConfig, TestGrid};
use oar::types::{
    CampaignId, CampaignSpec, CampaignState, GridTask, GridTaskState, JobSpec, JobState,
};

/// Poll `cond` until it holds or `timeout` elapses; returns success.
fn wait_until(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        if cond() {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn fresh_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("oar_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Final task→cluster mapping counts of a drained campaign.
fn mapping_counts(grid: &Grid, id: CampaignId) -> BTreeMap<String, usize> {
    let mut counts = BTreeMap::new();
    for t in grid.tasks(id) {
        assert_eq!(t.state, GridTaskState::Done, "task {} not done: {t:?}", t.index);
        let cluster = t.cluster.clone().expect("done task without a cluster");
        assert!(t.job.is_some(), "done task without a job id: {t:?}");
        *counts.entry(cluster).or_insert(0) += 1;
    }
    counts
}

#[test]
fn small_campaign_farms_across_asymmetric_clusters() {
    // 8 + 4 + 2 processors; `sleep 2` at scale 0.02 = 40 ms per task.
    let fleet = TestGrid::start(&[(4, 2), (2, 2), (1, 2)], 0.02).unwrap();
    let grid = Grid::start(GridConfig::fast(fleet.cluster_configs(16))).unwrap();

    let id = grid
        .submit_campaign(&CampaignSpec::bag("smoke", "grid", "sleep 2", 80))
        .unwrap();
    assert!(
        grid.wait_campaign_drained(id, Duration::from_secs(60)),
        "campaign did not drain: {:?}",
        grid.campaign_progress(id)
    );
    let p = grid.campaign_progress(id).unwrap();
    assert_eq!(p.done, 80);
    assert_eq!(p.failed, 0);
    assert!(wait_until(Duration::from_secs(5), || {
        grid.campaign_progress(id).unwrap().state == CampaignState::Done
    }));

    // Every cluster participated (the first wave already water-fills all
    // three), and the mapping agrees with what each cluster really ran.
    let counts = mapping_counts(&grid, id);
    assert_eq!(counts.values().sum::<usize>(), 80);
    for i in 0..fleet.len() {
        let name = fleet.name(i).to_string();
        let mapped = counts.get(&name).copied().unwrap_or(0);
        assert!(mapped > 0, "cluster {name} never completed a task");
        assert_eq!(
            fleet.tagged_jobs_in_state(i, JobState::Terminated),
            mapped,
            "cluster {name}: remote terminations != grid mapping (lost or duplicated work)"
        );
    }

    // Counter coherence: every dispatch attempt is accounted for.
    let c = grid.counters();
    assert_eq!(c.completed, 80);
    assert_eq!(c.failed, 0);
    let attempts: u64 = grid.tasks(id).iter().map(|t| t.attempts as u64).sum();
    assert_eq!(attempts, 80 + c.retried + c.orphaned);
    assert!(grid.clusters().iter().all(|s| s.outstanding == 0));
    let _ = grid.shutdown();
}

/// A dispatched task whose remote job sits `Waiting` forever (here:
/// legitimately queued behind a local job that outlives the test) must
/// not pin its task — the staleness check cancels the placement and the
/// retry budget decides the task's fate. Without it this campaign would
/// never drain.
#[test]
fn stale_placement_is_cancelled_and_budget_decides() {
    let fleet = TestGrid::start(&[(1, 1)], 0.02).unwrap();
    let data_dir = fresh_dir("grid_stale");

    // Fabricate the grid's durable state offline — a task already
    // Dispatched to c0 — so no dispatch/hold race exists at all.
    let (cid, remote) = {
        let (mut db, _) = Db::recover(&data_dir).unwrap();
        let cid = db.insert_campaign(&CampaignSpec::bag("stale", "grid", "noop", 1), 0);
        let token = db.campaign(cid).unwrap().token;
        let tid = db.grid_tasks_of_campaign(cid)[0].id;
        db.mark_grid_task_dispatched(tid, "c0", 0).unwrap();

        // On the cluster: a long local blocker takes the only processor,
        // then the grid-tagged job queues deterministically behind it.
        let server = fleet.server(0);
        let blocker = server
            .submit(&JobSpec::batch("local", "sleep 10000", 1, 20000))
            .unwrap()
            .unwrap();
        assert!(wait_until(Duration::from_secs(10), || {
            server
                .with_db(|db| db.job(blocker))
                .map(|j| j.state == JobState::Running)
                .unwrap_or(false)
        }));
        let remote = server
            .submit(&JobSpec {
                user: "grid".into(),
                command: format!("noop {}", GridTask::tag(token, 0)),
                nb_nodes: 1,
                weight: 1,
                max_time: Some(600),
                best_effort: true,
                ..JobSpec::default()
            })
            .unwrap()
            .unwrap();
        db.set_grid_task_job(tid, remote).unwrap();
        db.checkpoint().unwrap();
        (cid, remote)
    };

    let grid = Grid::start(GridConfig {
        data_dir: Some(data_dir.clone()),
        retry_budget: 1,
        stale_after: Duration::from_millis(300),
        ..GridConfig::fast(fleet.cluster_configs(4))
    })
    .unwrap();

    assert!(
        grid.wait_campaign_drained(cid, Duration::from_secs(30)),
        "stale placement never resolved: {:?} {:?}",
        grid.campaign_progress(cid),
        grid.counters()
    );
    let p = grid.campaign_progress(cid).unwrap();
    assert_eq!(p.done, 0);
    assert_eq!(p.failed, 1, "budget of 1 must fail the task: {p:?}");
    let c = grid.counters();
    assert_eq!(c.failed, 1);
    assert_eq!(c.completed, 0);
    // The cancel really landed: the remote job is Error, not Waiting.
    assert!(wait_until(Duration::from_secs(5), || {
        fleet
            .server(0)
            .with_db(|db| db.job(remote))
            .map(|j| j.state == JobState::Error)
            .unwrap_or(false)
    }));
    let _ = grid.shutdown();
    let _ = std::fs::remove_dir_all(&data_dir);
}

#[test]
fn grid_config_requires_clusters_and_valid_campaigns() {
    assert!(Grid::start(GridConfig::default()).is_err());
    let fleet = TestGrid::start(&[(1, 1)], 0.0).unwrap();
    let mut dup = fleet.cluster_configs(4);
    dup.push(dup[0].clone());
    assert!(
        Grid::start(GridConfig::fast(dup)).is_err(),
        "duplicate cluster names must be rejected"
    );
    let grid = Grid::start(GridConfig::fast(fleet.cluster_configs(4))).unwrap();
    assert!(grid
        .submit_campaign(&CampaignSpec::bag("empty", "u", "date", 0))
        .is_err());
    assert!(grid
        .submit_campaign(&CampaignSpec::bag("blank", "u", "   ", 3))
        .is_err());
    assert!(grid.campaign_progress(99).is_err());
}

/// The acceptance scenario. Timeline:
///
/// 1. 500 `sleep 5` tasks (100 ms each at scale 0.02) over clusters of
///    8/4/2 processors, durable grid state;
/// 2. after ≥60 completions the grid is **cleanly restarted** — the new
///    instance must resume from the persisted tables: finished tasks keep
///    their recorded placement (and are not re-dispatched), in-flight
///    placements are re-reconciled against the clusters;
/// 3. after ≥250 completions cluster `c1` is **killed** with tasks in
///    flight; the reconciler blacklists it and resubmits its orphaned
///    tasks elsewhere;
/// 4. `c1` reboots (same address, empty state) and re-enters at
///    probation;
/// 5. the campaign drains: 500 done / 0 failed / 0 lost / 0 duplicated,
///    and the retry/blacklist counters match the observed events.
#[test]
fn federation_survives_cluster_kill_and_grid_restart() {
    let mut fleet = TestGrid::start(&[(4, 2), (2, 2), (1, 2)], 0.02).unwrap();
    let data_dir = fresh_dir("grid_e2e");
    let config = GridConfig {
        data_dir: Some(data_dir.clone()),
        retry_budget: 10,
        ..GridConfig::fast(fleet.cluster_configs(16))
    };

    let mut grid = Grid::start(config.clone()).unwrap();
    let id = grid
        .submit_campaign(&CampaignSpec::bag("e2e", "grid", "sleep 5", 500))
        .unwrap();

    // Phase 2: clean grid restart mid-campaign.
    assert!(
        wait_until(Duration::from_secs(60), || {
            grid.campaign_progress(id).unwrap().done >= 60
        }),
        "first instance never reached 60 completions"
    );
    // Freeze the first instance so its counters and tables are final.
    grid.pause();
    let c1 = grid.counters();
    assert_eq!(c1.retried, 0, "no failures expected before the kill");
    assert_eq!(c1.orphaned, 0);
    assert_eq!(c1.failed, 0);
    let done_before_restart: BTreeMap<u32, (String, u64)> = grid
        .tasks(id)
        .into_iter()
        .filter(|t| t.state == GridTaskState::Done)
        .map(|t| (t.index, (t.cluster.clone().unwrap(), t.job.unwrap())))
        .collect();
    let completed_1 = c1.completed;
    assert_eq!(
        completed_1,
        done_before_restart.len() as u64,
        "paused instance counters must agree with its tables"
    );
    let _ = grid.shutdown();

    let grid = Grid::start(config).unwrap();
    // Resumption, not re-dispatch: the persisted Done set is intact.
    let resumed: Vec<_> = grid
        .tasks(id)
        .into_iter()
        .filter(|t| t.state == GridTaskState::Done)
        .collect();
    assert!(resumed.len() >= done_before_restart.len());
    for (index, (cluster, job)) in &done_before_restart {
        let t = resumed.iter().find(|t| t.index == *index).unwrap();
        assert_eq!(t.cluster.as_deref(), Some(cluster.as_str()));
        assert_eq!(t.job, Some(*job));
    }

    // Phase 3: kill c1 with tasks in flight.
    assert!(
        wait_until(Duration::from_secs(60), || {
            grid.campaign_progress(id).unwrap().done >= 250
                && grid
                    .clusters()
                    .iter()
                    .find(|c| c.name == "c1")
                    .map(|c| c.outstanding >= 2)
                    .unwrap_or(false)
        }),
        "never reached the kill point with work outstanding on c1"
    );
    fleet.kill(1);
    assert!(
        wait_until(Duration::from_secs(30), || grid.counters().blacklists >= 1),
        "dead cluster was never blacklisted"
    );
    // Its in-flight tasks were orphan-requeued onto the survivors.
    let after_kill = grid.counters();
    assert!(after_kill.orphaned >= 1, "kill stranded no tasks: {after_kill:?}");

    // Phase 4: rejoin on the same address with fresh (empty) state.
    std::thread::sleep(Duration::from_millis(100));
    fleet.reboot(1).unwrap();
    assert!(
        wait_until(Duration::from_secs(30), || grid.counters().rejoins >= 1),
        "rebooted cluster never re-entered from probation"
    );

    // Phase 5: drain and verify.
    assert!(
        grid.wait_campaign_drained(id, Duration::from_secs(180)),
        "campaign did not drain: {:?}, counters {:?}",
        grid.campaign_progress(id),
        grid.counters()
    );
    let p = grid.campaign_progress(id).unwrap();
    assert_eq!(p.done, 500, "lost tasks: {p:?}");
    assert_eq!(p.failed, 0, "failed tasks: {p:?}");
    assert!(wait_until(Duration::from_secs(5), || {
        grid.campaign_progress(id).unwrap().state == CampaignState::Done
    }));

    let c2 = grid.counters();
    // Exactly-once completion across both grid instances.
    assert_eq!(completed_1 + c2.completed, 500, "instance1 {completed_1} + instance2 {:?}", c2);
    // Every dispatch attempt is explained by the initial placement plus
    // counted requeues (instance 1 had none, asserted above).
    let attempts: u64 = grid.tasks(id).iter().map(|t| t.attempts as u64).sum();
    assert_eq!(
        attempts,
        500 + c2.retried + c2.orphaned,
        "unaccounted dispatches: counters {c2:?}"
    );
    // The blacklist/rejoin counters match the one observed event each.
    assert_eq!(c2.blacklists, 1);
    assert_eq!(c2.rejoins, 1);
    assert_eq!(c2.failed, 0);
    assert_eq!(c2.orphan_kills, 0, "fresh rebooted cluster held no orphans");

    // Zero duplicated work: each surviving cluster's terminated tagged
    // jobs equal the tasks finally mapped to it; the rebooted cluster
    // additionally lost its pre-kill completions with its state, so its
    // remote count can only be lower than the mapping, never higher.
    let counts = mapping_counts(&grid, id);
    assert_eq!(counts.values().sum::<usize>(), 500);
    for (i, name) in [(0usize, "c0"), (2, "c2")] {
        assert_eq!(
            fleet.tagged_jobs_in_state(i, JobState::Terminated),
            counts.get(name).copied().unwrap_or(0),
            "cluster {name}: remote terminations != grid mapping"
        );
    }
    assert!(
        fleet.tagged_jobs_in_state(1, JobState::Terminated)
            <= counts.get("c1").copied().unwrap_or(0),
        "rebooted cluster ran more tagged jobs than the grid mapped to it"
    );

    let _ = grid.shutdown();
    let _ = std::fs::remove_dir_all(&data_dir);
}
