//! Property tests for the incrementally-maintained materialized views.
//!
//! The views (`db::view`) promise one invariant: after **any** sequence
//! of mutations driven through `Db::mutate`, the maintained aggregates
//! are structurally equal to a from-scratch recompute over the base
//! tables (`Db::verify_views`). The randomized workloads here exercise
//! every maintenance path — job inserts, legal and rejected state
//! transitions, hold gating, assignment add/remove, node registration
//! and state churn, and raw `UPDATE ... WHERE` cell sweeps that bypass
//! the typed accessors — checking the invariant after every single op.
//!
//! The second half extends the crash harness: after a torn-WAL crash at
//! arbitrary record boundaries, *recovery replays mutations through the
//! same `apply` path*, so the rebuilt views must again match both a
//! recompute and the crashed process's own view reads.

use std::path::PathBuf;

use oar::db::{Db, Value};
use oar::types::{Job, JobSpec, JobState, Node, NodeState, Queue, QueuePolicyKind};
use oar::util::Rng;

// ------------------------------------------------- workload generator ----

/// One randomized operation. Jobs are addressed by index into the
/// submitted-so-far list so a sequence is replayable on any database.
#[derive(Debug, Clone)]
enum Op {
    Submit { user: String, nodes: u32, queue: String },
    Transition { job: usize, to: JobState },
    Hold { job: usize },
    Assign { job: usize, node: u32, procs: u32 },
    Unassign { job: usize },
    AddNode { id: u32, procs: u32 },
    NodeState { node: u32, state: NodeState },
    BulkStateFlip { cutoff: u64 },
    BulkQueueMove { queue: String },
    Message { job: usize },
}

fn gen_ops(seed: u64, n: usize) -> Vec<Op> {
    let mut rng = Rng::new(seed);
    let mut ops = vec![
        Op::AddNode { id: 1, procs: 2 },
        Op::AddNode { id: 2, procs: 4 },
        Op::AddNode { id: 3, procs: 1 },
    ];
    let node_states = [NodeState::Alive, NodeState::Suspected, NodeState::Absent];
    for _ in 0..n {
        let op = match rng.below(14) {
            0..=3 => Op::Submit {
                user: format!("u{}", rng.below(4)),
                nodes: rng.range_i64(1, 3) as u32,
                queue: format!("q{}", rng.below(3)),
            },
            4..=6 => Op::Transition {
                job: rng.below(24) as usize,
                to: *rng.pick(&JobState::ALL),
            },
            7 => Op::Hold {
                job: rng.below(24) as usize,
            },
            8..=9 => Op::Assign {
                job: rng.below(24) as usize,
                node: rng.range_i64(1, 3) as u32,
                procs: rng.range_i64(1, 2) as u32,
            },
            10 => Op::Unassign {
                job: rng.below(24) as usize,
            },
            11 => Op::NodeState {
                node: rng.range_i64(1, 3) as u32,
                state: *rng.pick(&node_states),
            },
            12 => {
                if rng.chance(0.5) {
                    Op::BulkStateFlip {
                        cutoff: rng.range_i64(1, 12) as u64,
                    }
                } else {
                    Op::BulkQueueMove {
                        queue: format!("q{}", rng.below(3)),
                    }
                }
            }
            _ => Op::Message {
                job: rng.below(24) as usize,
            },
        };
        ops.push(op);
    }
    ops
}

fn apply_op(db: &mut Db, op: &Op, jobs: &mut Vec<u64>) {
    let pick = |jobs: &[u64], i: usize| -> Option<u64> {
        if jobs.is_empty() {
            None
        } else {
            Some(jobs[i % jobs.len()])
        }
    };
    match op {
        Op::Submit { user, nodes, queue } => {
            let mut spec = JobSpec::batch(user, "date", *nodes, 60);
            spec.queue = Some(queue.clone());
            let id = db.insert_job(Job::from_spec(&spec, jobs.len() as i64));
            jobs.push(id);
        }
        Op::Transition { job, to } => {
            if let Some(id) = pick(jobs, *job) {
                // Illegal edges are rejected without a mutation.
                let _ = db.set_job_state(id, *to, 5);
            }
        }
        Op::Hold { job } => {
            if let Some(id) = pick(jobs, *job) {
                // Gated: only Waiting -> Hold mutates.
                let _ = db.hold_job(id, 6);
            }
        }
        Op::Assign { job, node, procs } => {
            if let Some(id) = pick(jobs, *job) {
                db.assign_nodes(id, &[*node], *procs);
            }
        }
        Op::Unassign { job } => {
            if let Some(id) = pick(jobs, *job) {
                db.remove_assignments(id);
            }
        }
        Op::AddNode { id, procs } => {
            db.add_node(Node::new(*id, &format!("n{id}"), *procs));
        }
        Op::NodeState { node, state } => {
            let _ = db.set_node_state(*node, *state);
        }
        Op::BulkStateFlip { cutoff } => {
            // Raw cell sweep on the state column: bypasses the automaton
            // and the typed accessors, exercising the UpdateWhere
            // maintenance path on the most aggregate-laden column.
            let filter = format!("state = 'Waiting' AND id <= {cutoff}");
            let _ = db.update_jobs_where(&filter, "state", Value::Text("Hold".into()));
        }
        Op::BulkQueueMove { queue } => {
            let _ = db.update_jobs_where(
                "state = 'Waiting'",
                "queueName",
                Value::Text(queue.clone()),
            );
        }
        Op::Message { job } => {
            if let Some(id) = pick(jobs, *job) {
                let _ = db.set_job_message(id, "touched");
            }
        }
    }
}

fn seeds() -> Vec<u64> {
    match std::env::var("OAR_VIEW_SEED") {
        Ok(s) => vec![s.parse().expect("OAR_VIEW_SEED must be a u64")],
        Err(_) => vec![3, 17, 2026],
    }
}

// ------------------------------------ property: view ≡ recompute, always ----

#[test]
fn views_match_recompute_after_every_random_mutation() {
    for seed in seeds() {
        let ops = gen_ops(seed, 160);
        let mut db = Db::new();
        for q in Queue::standard_set() {
            db.add_queue(q);
        }
        db.add_queue(Queue::new("q0", 5, QueuePolicyKind::FifoConservative));
        db.add_queue(Queue::new("q1", 5, QueuePolicyKind::FifoConservative));
        db.add_queue(Queue::new("q2", 5, QueuePolicyKind::FifoConservative));

        let mut jobs = Vec::new();
        for (i, op) in ops.iter().enumerate() {
            apply_op(&mut db, op, &mut jobs);
            assert!(
                db.verify_views(),
                "seed {seed}: views diverged after op {i}: {op:?}"
            );
        }

        // The view reads agree with the scan-based answers they replace.
        assert_eq!(
            db.cluster_load(),
            db.cluster_load_recompute(),
            "seed {seed}: cluster load"
        );
        assert_eq!(
            db.node_occupancy(),
            db.busy_procs_by_node(),
            "seed {seed}: occupancy"
        );
        for state in JobState::ALL {
            assert_eq!(
                db.state_depth(state),
                db.count_jobs_in_state(state) as u64,
                "seed {seed}: depth of {state:?}"
            );
        }
        // ...including the group-by recomputes the views replaced.
        let by_state = db.jobs_by_state_recompute();
        for state in JobState::ALL {
            assert_eq!(
                db.state_depth(state),
                by_state.get(state.as_str()).copied().unwrap_or(0),
                "seed {seed}: grouped depth of {state:?}"
            );
        }
        let by_queue = db.queue_depths_recompute();
        for q in ["q0", "q1", "q2", "default"] {
            assert_eq!(
                db.queue_depth(q),
                by_queue.get(q).copied().unwrap_or(0),
                "seed {seed}: grouped depth of queue {q}"
            );
        }
    }
}

// --------------------------------- property: views survive torn-WAL crashes ----

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("oar_views_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn drive(db: &mut Db, ops: &[Op]) -> usize {
    let mut jobs = Vec::new();
    for (i, op) in ops.iter().enumerate() {
        apply_op(db, op, &mut jobs);
        if db.wal_crashed() {
            return i;
        }
    }
    ops.len()
}

#[test]
fn recovered_views_match_rebuilt_ones_after_wal_tear() {
    let seed = seeds()[0];
    let ops = gen_ops(seed, 60);

    // Reference run to learn the record count.
    let dir = fresh_dir("ref");
    let (mut db, _) = Db::recover(&dir).unwrap();
    assert_eq!(drive(&mut db, &ops), ops.len());
    let total = db.wal_records();
    assert!(total > 20, "workload too thin: {total}");
    drop(db);
    let _ = std::fs::remove_dir_all(&dir);

    // Tear the log at a spread of boundaries (full sweep lives in
    // crash_recovery.rs; here we only need view-specific coverage), with
    // checkpointing on so some runs recover snapshot + tail — exercising
    // the snapshot-load recompute path as well as pure replay.
    for boundary in (0..total).step_by(5) {
        for partial in [0usize, usize::MAX] {
            let dir = fresh_dir(&format!("tear_{boundary}_{partial:x}"));
            let (mut db, _) = Db::recover(&dir).unwrap();
            db.set_checkpoint_every(9);
            db.wal_inject_failure(boundary, partial);
            drive(&mut db, &ops);
            assert!(db.wal_crashed(), "boundary {boundary}: no crash fired");

            let (mut rec, _) = Db::recover(&dir).unwrap();
            let ctx = format!("boundary {boundary} partial {partial:x}");
            // Replay rebuilt the views through the same apply path...
            assert!(rec.verify_views(), "{ctx}: recovered views diverged");
            // ...and they answer exactly what the crashed process saw.
            assert_eq!(rec.cluster_load(), db.cluster_load(), "{ctx}: load");
            assert_eq!(
                rec.node_occupancy(),
                db.node_occupancy(),
                "{ctx}: occupancy"
            );
            assert_eq!(
                rec.cluster_load(),
                rec.cluster_load_recompute(),
                "{ctx}: recompute"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}
