//! R4 fixture (positive): the PR 6 regression — a `Mutex<Db>` field and
//! mutex-style `db.lock()` access, serializing readers behind writers.

struct Inner {
    db: Mutex<Db>,
}

fn stat(inner: &Inner) -> usize {
    let db = inner.db.lock().unwrap();
    db.jobs().len()
}
