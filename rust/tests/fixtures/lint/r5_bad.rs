//! R5 fixture (positive): every panic source in a request path —
//! `.unwrap()`, `.expect()`, `panic!`, and raw slice indexing.

fn handle(req: &Request, jobs: &[Job]) -> Response {
    let id = req.args.get("id").unwrap();
    let first = jobs[0];
    let state = parse_state(id).expect("bad id");
    if state.is_empty() {
        panic!("empty state for {id}");
    }
    Response::ok(first, state)
}
