//! R3 fixture (negative): commit the WAL before every acknowledgement;
//! record dispatch intent in the db before submitting over the wire.

fn commits_then_acks(inner: &Inner) {
    let mut db = inner.db.write().unwrap();
    db.set_job_state(id, JobState::Waiting, now);
    drop(db);
    inner.commit_wal();
    inner.hub.notify(Task::Schedule);
}

fn helper_region_commits(inner: &Inner) {
    inner.write_db(|db| db.log_event(now, "CANCEL", Some(id), ""));
    inner.hub.push_event(JobEvent::Cancel { job: id, at: now });
}

fn records_intent_then_dispatches(cx: &Campaign) {
    cx.write_db(|db| db.record_dispatch(cx.task, now));
    let mut client = cx.connect_cluster();
    let outcome = client.sub(&cx.spec);
    cx.record(outcome);
}
