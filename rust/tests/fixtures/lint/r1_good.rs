//! R1 fixture (negative): both call sites take `alpha` before `beta`,
//! so the acquisition graph is acyclic and no class nests on itself.

fn merge_forward(s: &Shared) {
    let a = s.alpha.lock().unwrap();
    let b = s.beta.lock().unwrap();
    a.merge(&b);
    drop(b);
    drop(a);
}

fn merge_again(s: &Shared) {
    let a = s.alpha.lock().unwrap();
    let b = s.beta.lock().unwrap();
    b.merge(&a);
}

fn sequential_same_class(s: &Shared) {
    {
        let g = s.gamma.lock().unwrap();
        g.touch();
    }
    let g = s.gamma.lock().unwrap();
    g.touch();
}
