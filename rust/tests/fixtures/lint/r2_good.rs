//! R2 fixture (negative): drop the guard before blocking; condvar waits
//! hand their own guard to the OS and are exempt.

fn drops_then_blocks(s: &Shared) {
    let q = s.queue.lock().unwrap();
    let next = q.front();
    drop(q);
    std::thread::sleep(Duration::from_millis(10));
    s.run(next);
}

fn collects_then_shuts_down(s: &Shared) {
    let streams: Vec<TcpStream> = s
        .active
        .lock()
        .unwrap()
        .iter()
        .filter_map(|(_, st)| st.try_clone().ok())
        .collect();
    for stream in streams {
        let _ = stream.shutdown(Shutdown::Read);
    }
}

fn waits_on_condvar(s: &Shared) {
    let mut q = s.queue.lock().unwrap();
    while q.is_empty() {
        q = s.cv.wait(q).unwrap();
    }
    q.pop();
}
