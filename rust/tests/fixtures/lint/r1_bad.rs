//! R1 fixture (positive): lock-order cycle + same-class double
//! acquisition. Never compiled — `oarlint` lexes it; the `fixtures`
//! directory is skipped by the real-tree scan.

fn ab(s: &Shared) {
    let a = s.alpha.lock().unwrap();
    let b = s.beta.lock().unwrap();
    a.merge(&b);
    drop(b);
    drop(a);
}

fn ba(s: &Shared) {
    let b = s.beta.lock().unwrap();
    let a = s.alpha.lock().unwrap();
    b.merge(&a);
    drop(a);
    drop(b);
}

fn double(s: &Shared) {
    let first = s.gamma.lock().unwrap();
    let second = s.gamma.lock().unwrap();
    first.merge(&second);
}
