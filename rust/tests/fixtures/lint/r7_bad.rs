//! R7 fixture (positive): telemetry recorded while commit-path guards
//! are held — under the named db write guard, inside a `write_db`
//! helper region, and under the WAL sink lock.

fn observes_under_write_guard(inner: &Inner) {
    let t0 = clock::now_us();
    let mut db = inner.db.write().unwrap();
    db.set_job_state(id, JobState::Running, now);
    metrics::DB_WRITE_WAIT_US.observe(clock::now_us() - t0);
    drop(db);
    inner.commit_wal();
}

fn counts_inside_helper_region(inner: &Inner) {
    inner.write_db(|db| {
        db.log_event(now, "START", Some(id), "");
        metrics::SCHED_ROUNDS.inc();
    });
}

fn spans_under_sink_lock(wal: &Wal) {
    let mut s = wal.sink.lock().unwrap();
    let _flush = Span::enter("wal.flush", &metrics::WAL_FLUSH_US);
    s.push(frame);
    drop(s);
}
