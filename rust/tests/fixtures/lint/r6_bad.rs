//! R6 fixture (positive): atomics drifting out of calibration — a
//! SeqCst read-modify-write on a pure tally, and a SeqCst store on an
//! atomic that is not one of the blessed shutdown/drain flags.

fn telemetry(s: &Shared) {
    s.served.fetch_add(1, Ordering::SeqCst);
    s.peak.store(7, Ordering::SeqCst);
}
