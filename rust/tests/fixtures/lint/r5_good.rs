//! R5 fixture (negative): the same request path written panic-free
//! (`let .. else`, `match`, `.get()`), plus a `#[test]` function where
//! unwrap/indexing are fine — tests may panic on broken expectations.

fn handle(req: &Request, jobs: &[Job]) -> Response {
    let Some(id) = req.args.get("id") else {
        return Response::err("missing id");
    };
    let Some(first) = jobs.first() else {
        return Response::err("no jobs");
    };
    let state = match parse_state(id) {
        Ok(s) => s,
        Err(e) => return Response::err(&e.to_string()),
    };
    Response::ok(first, state)
}

#[test]
fn tests_may_panic_freely() {
    let v = parse_state("Waiting").unwrap();
    let first = FIXTURE_JOBS[0];
    assert_eq!(v, first.state);
}
