//! R2 fixture (positive): blocking calls with a guard live — a named
//! guard, and the PR 4 bug shape: a `for`-header temporary that Rust
//! keeps alive through the whole loop body.

fn sleeps_under_guard(s: &Shared) {
    let q = s.queue.lock().unwrap();
    std::thread::sleep(Duration::from_millis(10));
    q.push(1);
}

fn iterates_while_calling_out(s: &Shared) {
    for (_, stream) in s.active.lock().unwrap().iter() {
        let _ = stream.shutdown(Shutdown::Read);
    }
}
