//! R6 fixture (negative): counters stay Relaxed, SeqCst only on the
//! blessed flag (`running` in the fixture config), hand-over-hand state
//! uses AcqRel — and a non-atomic `.load()` with no Ordering argument
//! is not mistaken for an atomic op.

fn telemetry(s: &Shared) {
    s.served.fetch_add(1, Ordering::Relaxed);
    s.discarded.fetch_add(1, Ordering::Relaxed);
    s.running.store(false, Ordering::SeqCst);
    let prev = s.state.swap(2, Ordering::AcqRel);
    let snapshot = s.client.load();
    s.record(prev, snapshot);
}
