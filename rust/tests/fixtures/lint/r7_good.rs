//! R7 fixture (negative): the repository's instrumentation discipline.
//! Timestamps are captured under the guard; every metric record happens
//! after release, and spans open before the guard so drop order releases
//! the lock first.

fn observes_after_release(inner: &Inner) {
    let t0 = clock::now_us();
    let (out, wait_us) = {
        let mut db = inner.db.write().unwrap();
        let wait = clock::now_us().saturating_sub(t0);
        (db.touch(), wait)
    };
    inner.commit_wal();
    metrics::DB_WRITE_WAIT_US.observe(wait_us);
    report(out);
}

fn span_opens_before_the_guard(inner: &Inner) {
    let _apply = Span::enter("sched.apply", &metrics::SCHED_APPLY_US);
    let mut db = inner.db.write().unwrap();
    db.touch();
    drop(db);
    inner.commit_wal();
}

fn unguarded_counters_are_fine() {
    metrics::RPC_REQUESTS.inc();
    metrics::RPC_INFLIGHT.rise();
    metrics::RPC_INFLIGHT.fall();
}
