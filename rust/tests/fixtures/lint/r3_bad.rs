//! R3 fixture (positive): acknowledgements that outrun durability —
//! notify after the write-guard release but before the WAL commit,
//! an ack issued with the write guard still held, and a grid dispatch
//! with no prior intent write.

fn acks_before_commit(inner: &Inner) {
    let mut db = inner.db.write().unwrap();
    db.set_job_state(id, JobState::Waiting, now);
    drop(db);
    inner.hub.notify(Task::Schedule);
    inner.commit_wal();
}

fn acks_under_guard(inner: &Inner) {
    let mut db = inner.db.write().unwrap();
    db.log_event(now, "CANCEL", Some(id), "");
    inner.hub.push_event(JobEvent::Cancel { job: id, at: now });
    drop(db);
    inner.commit_wal();
}

fn dispatches_without_intent(cx: &Campaign) {
    let mut client = cx.connect_cluster();
    let outcome = client.sub(&cx.spec);
    cx.record(outcome);
}
