//! R4 fixture (negative): the database behind `RwLock<Db>`, read guards
//! for queries and a write guard for mutations.

struct Inner {
    db: RwLock<Db>,
}

fn stat(inner: &Inner) -> usize {
    let db = inner.db.read().unwrap();
    db.jobs().len()
}

fn mutate(inner: &Inner) {
    let mut db = inner.db.write().unwrap();
    db.log_event(now, "NOTE", None, "");
}
