//! Suppression-accounting fixture: one used allow (own-line, targeting
//! the next code line), one real finding left unsuppressed, one unused
//! allow, and one directive naming a rule that does not exist.

fn teardown(s: &Shared) {
    let mut db = s.db.write().unwrap();
    // oarlint: allow(R2) teardown: the final checkpoint must be atomic with the guard
    db.checkpoint();
    db.snapshot(&s.path);
    drop(db);
}

fn stray() {
    // oarlint: allow(R2) nothing on the next line blocks
    let x = 1;
    let _ = x;
}

fn bogus() {
    // oarlint: allow(R9) not a rule that exists
    let y = 2;
    let _ = y;
}
