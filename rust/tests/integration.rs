//! Integration tests: whole-system scenarios across db + central +
//! scheduler + launcher + monitor, exercising the paper's §2 mechanisms
//! end to end on the live server.

use std::sync::Arc;
use std::time::Duration;

use oar::cluster::VirtualCluster;
use oar::db::Db;
use oar::server::{Server, ServerConfig};
use oar::types::{JobSpec, JobState, Queue, QueuePolicyKind};

fn server_on(nodes: u32, procs: u32, scale: f64) -> Server {
    let cluster = Arc::new(VirtualCluster::tiny(nodes, procs));
    let mut cfg = ServerConfig::fast(scale);
    cfg.sched.dense_matching = false;
    Server::new(cluster, cfg)
}

#[test]
fn full_lifecycle_of_100_mixed_jobs() {
    let server = server_on(8, 2, 0.0);
    let mut ids = Vec::new();
    for i in 0..100 {
        let spec = JobSpec {
            weight: 1 + (i % 2) as u32,
            ..JobSpec::batch(&format!("u{}", i % 7), "date", 1 + (i % 4) as u32, 300)
        };
        ids.push(server.submit(&spec).unwrap().unwrap());
    }
    assert!(server.wait_all_terminal(Duration::from_secs(60)));
    let jobs = server.stat(None).unwrap();
    assert_eq!(jobs.len(), 100);
    assert!(jobs.iter().all(|j| j.state == JobState::Terminated), "all must terminate");
    // Every terminated job has coherent timestamps.
    for j in &jobs {
        let (start, stop) = (j.start_time.unwrap(), j.stop_time.unwrap());
        assert!(j.submission_time <= start, "job {}", j.id);
        assert!(start <= stop, "job {}", j.id);
    }
}

#[test]
fn node_failure_suspends_and_scheduling_avoids_it() {
    let cluster = Arc::new(VirtualCluster::tiny(3, 1));
    let mut cfg = ServerConfig::fast(0.0);
    cfg.sched.dense_matching = false;
    cfg.monitor_every = Duration::from_millis(50);
    let server = Server::new(cluster.clone(), cfg);

    cluster.inject_failure(2);
    std::thread::sleep(Duration::from_millis(400));
    let suspected: Vec<_> = server
        .nodes()
        .into_iter()
        .filter(|(_, s, _)| s == "Suspected")
        .collect();
    assert_eq!(suspected.len(), 1, "{suspected:?}");

    // A 3-node job needs the suspected node: it *waits* (a transient
    // failure is not unsatisfiability); a 2-node job runs around it.
    let blocked = server.submit(&JobSpec::batch("a", "date", 3, 60)).unwrap().unwrap();
    let fits = server.submit(&JobSpec::batch("b", "date", 2, 60)).unwrap().unwrap();
    std::thread::sleep(Duration::from_millis(600));
    let fits_job = server.with_db(|db| db.job(fits)).unwrap();
    assert_eq!(fits_job.state, JobState::Terminated);
    let assigned = server.with_db(|db| db.assigned_nodes(fits));
    assert!(!assigned.contains(&2), "must avoid the suspected node: {assigned:?}");
    assert_eq!(
        server.with_db(|db| db.job(blocked)).unwrap().state,
        JobState::Waiting,
        "transiently-blocked job must keep waiting"
    );

    // A 4-node job exceeds the registered fleet: genuinely unsatisfiable.
    let too_big = server.submit(&JobSpec::batch("x", "date", 4, 60)).unwrap().unwrap();
    std::thread::sleep(Duration::from_millis(600));
    assert_eq!(server.with_db(|db| db.job(too_big)).unwrap().state, JobState::Error);

    // Node recovers: the monitor re-alives it and the blocked job runs.
    cluster.repair(2);
    assert!(server.wait_all_terminal(Duration::from_secs(20)));
    assert_eq!(server.with_db(|db| db.job(blocked)).unwrap().state, JobState::Terminated);
}

#[test]
fn queue_priorities_across_queues() {
    let server = server_on(2, 1, 0.2);
    server.with_db(|db| {
        db.add_queue(Queue::new("urgent", 100, QueuePolicyKind::FifoConservative))
    });
    // Occupy the machine briefly, then race a default and an urgent job.
    let _fill = server.submit(&JobSpec::batch("x", "sleep 5", 2, 60)).unwrap().unwrap();
    std::thread::sleep(Duration::from_millis(200));
    let normal = server.submit(&JobSpec::batch("n", "date", 2, 60)).unwrap().unwrap();
    let urgent = server
        .submit(&JobSpec {
            queue: Some("urgent".into()),
            ..JobSpec::batch("u", "date", 2, 60)
        })
        .unwrap()
        .unwrap();
    assert!(server.wait_all_terminal(Duration::from_secs(30)));
    let (ns, us) = server.with_db(|db| {
        (
            db.job(normal).unwrap().start_time.unwrap(),
            db.job(urgent).unwrap().start_time.unwrap(),
        )
    });
    assert!(us <= ns, "urgent {us} must start before default {ns}");
}

#[test]
fn best_effort_eviction_chain() {
    let server = server_on(4, 1, 0.2);
    // Best-effort job soaks the whole machine.
    let be = server
        .submit(&JobSpec {
            best_effort: true,
            ..JobSpec::batch("grid", "sleep 60", 4, 600)
        })
        .unwrap()
        .unwrap();
    std::thread::sleep(Duration::from_millis(600));
    assert_eq!(
        server.with_db(|db| db.job(be)).unwrap().state,
        JobState::Running
    );
    // Regular work arrives: the best-effort job must die, the work runs.
    let mpi = server.submit(&JobSpec::batch("a", "sleep 1", 4, 60)).unwrap().unwrap();
    assert!(server.wait_all_terminal(Duration::from_secs(30)));
    let be_job = server.with_db(|db| db.job(be)).unwrap();
    assert_eq!(be_job.state, JobState::Error);
    assert!(be_job.message.contains("reclaimed"), "{}", be_job.message);
    assert_eq!(
        server.with_db(|db| db.job(mpi)).unwrap().state,
        JobState::Terminated
    );
    // The §3.3 chain is visible in the event log.
    let kinds: Vec<String> =
        server.with_db(|db| db.events().iter().map(|e| e.kind.clone()).collect());
    assert!(kinds.iter().any(|k| k == "BESTEFFORT_KILL"));
}

#[test]
fn reservation_lifecycle_end_to_end() {
    let server = server_on(2, 1, 1.0);
    let resa = server
        .submit(&JobSpec {
            reservation_start: Some(1),
            ..JobSpec::batch("org", "date", 2, 5)
        })
        .unwrap()
        .unwrap();
    assert!(server.wait_all_terminal(Duration::from_secs(30)));
    let job = server.with_db(|db| db.job(resa)).unwrap();
    assert_eq!(job.state, JobState::Terminated);
    assert!(
        job.start_time.unwrap() >= 1000,
        "reserved slot honored: {:?}",
        job.start_time
    );
    let kinds: Vec<String> =
        server.with_db(|db| db.events().iter().map(|e| e.kind.clone()).collect());
    assert!(kinds.iter().any(|k| k == "RESERVATION_CONFIRMED"));
}

#[test]
fn queries_per_job_matches_paper_order_of_magnitude() {
    // §3.2.2: "the database receives 350 SQL queries for the processing of
    // 10 jobs" — 35 queries/job. Our per-job statement count must be in
    // the same order of magnitude (a handful to ~100).
    let server = server_on(4, 1, 0.0);
    server.with_db(|db| db.reset_stats());
    for i in 0..10 {
        server
            .submit(&JobSpec::batch(&format!("u{i}"), "date", 1, 60))
            .unwrap()
            .unwrap();
    }
    assert!(server.wait_all_terminal(Duration::from_secs(20)));
    let total = server.with_db(|db| db.stats().total());
    let per_job = total as f64 / 10.0;
    assert!(
        (3.0..500.0).contains(&per_job),
        "queries/job = {per_job} (total {total})"
    );
}

#[test]
fn snapshot_restore_preserves_system_state() {
    let server = server_on(4, 1, 0.0);
    for i in 0..20 {
        server
            .submit(&JobSpec::batch(&format!("u{i}"), "date", 1, 60))
            .unwrap()
            .unwrap();
    }
    assert!(server.wait_all_terminal(Duration::from_secs(20)));
    let db = server.shutdown();
    let path = std::env::temp_dir().join("oar_integration_snapshot.json");
    db.snapshot(&path).unwrap();
    let mut restored = Db::restore(&path).unwrap();
    assert_eq!(restored.jobs_in_state(JobState::Terminated).len(), 20);
    assert_eq!(restored.queues_by_priority().len(), 2);
    assert!(!restored.events().is_empty());
    std::fs::remove_file(path).ok();
}

#[test]
fn crashed_module_recovery_via_periodic_redundancy() {
    // The paper's robustness argument (§2.2): even when notifications are
    // lost, periodic re-execution drives the system forward. Simulate a
    // lost notification by writing a job *directly* into the database
    // (bypassing submit's notify) — the periodic Schedule tick must pick
    // it up.
    let server = server_on(2, 1, 0.0);
    let id = server.with_db(|db| {
        let job = oar::types::Job::from_spec(&JobSpec::batch("ghost", "date", 1, 60), 0);
        db.insert_job(job)
    });
    // no kick(), no notify — rely on the Planner's periodic Schedule
    assert!(server.wait_all_terminal(Duration::from_secs(20)));
    assert_eq!(
        server.with_db(|db| db.job(id)).unwrap().state,
        JobState::Terminated
    );
}

#[test]
fn interactive_and_hold_paths() {
    let server = server_on(2, 1, 0.2);
    let _fill = server.submit(&JobSpec::batch("x", "sleep 2", 2, 60)).unwrap().unwrap();
    std::thread::sleep(Duration::from_millis(200));
    let held = server.submit(&JobSpec::batch("h", "date", 1, 60)).unwrap().unwrap();
    server.hold(held).unwrap();
    std::thread::sleep(Duration::from_millis(300));
    assert_eq!(server.with_db(|db| db.job(held)).unwrap().state, JobState::Hold);
    server.resume(held).unwrap();
    assert!(server.wait_all_terminal(Duration::from_secs(30)));
    assert_eq!(
        server.with_db(|db| db.job(held)).unwrap().state,
        JobState::Terminated
    );
}

#[test]
fn accounting_report_over_live_run() {
    let server = server_on(4, 2, 0.0);
    for user in ["alice", "alice", "bob"] {
        server.submit(&JobSpec::batch(user, "date", 2, 60)).unwrap().unwrap();
    }
    assert!(server.wait_all_terminal(Duration::from_secs(20)));
    let acc = server.accounting();
    assert_eq!(acc.by_user["alice"].jobs_submitted, 2);
    assert_eq!(acc.by_user["bob"].jobs_submitted, 1);
    assert_eq!(acc.by_queue["default"], 3);
}
