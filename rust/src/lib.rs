//! # OAR — a batch scheduler with high level components
//!
//! Reproduction of Capit et al., *"A batch scheduler with high level
//! components"* (CS.DC 2005), as a three-layer Rust + JAX + Pallas system.
//!
//! The paper's thesis is architectural: a complete, efficient batch
//! scheduler can be built from two high-level components — a central
//! relational database that is the *only* communication medium between
//! modules, and a set of small executive modules driven by a central
//! automaton. This crate preserves that discipline:
//!
//! * [`db`] — the embedded relational store standing in for MySQL: typed
//!   tables, a SQL `WHERE`-expression engine (the `properties` matching
//!   language of fig. 2), event log and accounting. Modules share no state
//!   except a handle to this store.
//! * [`types`] — the job model of fig. 2 and the state machine of fig. 1.
//! * [`central`] — the central module: event buffer + notification listener
//!   + periodic (redundant) task planner (§2.2).
//! * [`admission`] — admission rules stored in the database (§2.1).
//! * [`sched`] — the meta-scheduler: Gantt diagram, per-queue policies
//!   (FIFO-conservative, SJF, best-effort), reservations, backfilling
//!   (§2.3), plus the Torque-/Maui-/SGE-like baselines of §3.2.
//! * [`matching`] — the compute hot-spot: jobs×nodes eligibility and Gantt
//!   feasibility scan, either through the AOT-compiled JAX/Pallas artifact
//!   (via [`runtime`]) or a bit-identical pure-Rust reference.
//! * [`runtime`] — PJRT CPU client loading `artifacts/schedule_step.hlo.txt`.
//! * [`launcher`] — the Taktuk-like parallel launcher (§2.4): deployment
//!   tree, rsh/ssh protocol latency models, timeout failure detection.
//! * [`cluster`] — the virtual cluster substrate (Xeon / Icluster testbeds).
//! * [`sim`] — discrete-event simulation used by the ESP2 evaluation.
//! * [`bench`] — workload generators and harnesses for every table and
//!   figure of §3 (ESP2, submission bursts, complexity, features).
//! * [`monitor`] — resource monitoring through the launcher (§2.4).
//! * [`server`] — the live system: wires db + central + scheduler +
//!   launcher into a running service with a CLI (`oarsub`/`oarstat`/...).
//! * [`rpc`] — the network front-end: length-framed JSON protocol,
//!   threaded TCP server with a bounded worker pool, typed client, and
//!   the socket-speaking user commands of §2.1 (`oar sub|stat|del|...`).
//! * [`grid`] — the federation layer above it all: a CiGri-style grid
//!   meta-scheduler farming bag-of-tasks campaigns across N cluster
//!   servers over RPC as best-effort jobs (the paper's metropolitan-GRID
//!   deployment, § abstract / §3.3).
//! * [`analysis`] — `oarlint`, the zero-dependency invariant checker
//!   that machine-enforces the concurrency/durability rules the modules
//!   above rely on (lock order, guard-vs-blocking-call discipline,
//!   WAL-commit-before-ack, `RwLock<Db>` pinning, request-path
//!   panic-freedom, atomics calibration). See `docs/LINTS.md`.

//! * [`obs`] — the observability layer (§1's "logging information
//!   analysis", live): a zero-dependency metrics registry (relaxed-atomic
//!   counters/gauges + log2-bucketed latency histograms), RAII tracing
//!   spans with a bounded forensics ring, and a deterministic test
//!   clock — exposed via the `metrics`/`events` RPC methods,
//!   `oar metrics` and `oar top`. See `docs/OBSERVABILITY.md`.
//! * [`resources`] — the hierarchical resource subsystem: the
//!   cluster/switch/host/cpu/core tree (stored as the `resources` table,
//!   with the nodes table derived from its host level), the total parser
//!   for the real `-l /switch=S/host=N/core=M,walltime=H:M:S` request
//!   grammar with moldable alternatives, and the per-level
//!   interval-counting matcher that places tree shapes under
//!   conservative backfilling.

pub mod admission;
pub mod analysis;
pub mod bench;
pub mod central;
pub mod cli;
pub mod cluster;
pub mod db;
pub mod grid;
pub mod launcher;
pub mod matching;
pub mod monitor;
pub mod obs;
pub mod resources;
pub mod rpc;
pub mod runtime;
pub mod sched;
pub mod server;
pub mod sim;
pub mod types;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
