//! The central module (§2.2): "made of two interconnected parts. The main
//! part is an automaton that reads its entries from a buffer of events ...
//! The second part ... is in charge of listening for external
//! notifications, discarding the redundant ones and planing the next tasks
//! required by users."
//!
//! [`NotificationHub`] is the second part: commands and modules call
//! [`NotificationHub::notify`]; redundant notifications coalesce (a
//! notification "is taken into account only if no scheduling was already
//! planned", §2.1). [`Planner`] is the redundancy part: every task also
//! fires periodically, so lost notifications never wedge the system —
//! "even if some notifications are lost, the whole system is kept in a
//! correct behavior".

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::types::{JobId, Time};

/// The tasks the automaton dispatches to the executive modules.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    /// Run the meta-scheduler.
    Schedule,
    /// Run the monitoring module.
    Monitor,
    /// Check launched/running jobs for completion bookkeeping.
    CheckJobs,
    /// Stop the automaton.
    Shutdown,
}

/// A job-lifecycle event queued for the automaton (the "buffer of
/// events"). These carry payloads and are never coalesced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobEvent {
    Ended { job: JobId, at: Time, ok: bool },
    LaunchFailed { job: JobId, at: Time },
    /// `oardel` arriving over the network: cancellation is *routed
    /// through* the automaton instead of racing it, so a delete can never
    /// interleave with the apply phase of a scheduling round.
    Cancel { job: JobId, at: Time },
}

/// Coalescing notification listener + event buffer.
#[derive(Debug)]
pub struct NotificationHub {
    schedule: AtomicBool,
    monitor: AtomicBool,
    check_jobs: AtomicBool,
    shutdown: AtomicBool,
    events: Mutex<VecDeque<JobEvent>>,
    /// Wakeup channel: pending-signal counter + condvar.
    signal: Mutex<u64>,
    wake: Condvar,
    /// Telemetry: how many notifications were absorbed by coalescing.
    pub discarded: std::sync::atomic::AtomicU64,
    /// Telemetry: how many notifications were accepted.
    pub accepted: std::sync::atomic::AtomicU64,
}

impl Default for NotificationHub {
    fn default() -> Self {
        Self::new()
    }
}

impl NotificationHub {
    pub fn new() -> NotificationHub {
        NotificationHub {
            schedule: AtomicBool::new(false),
            monitor: AtomicBool::new(false),
            check_jobs: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            events: Mutex::new(VecDeque::new()),
            signal: Mutex::new(0),
            wake: Condvar::new(),
            discarded: std::sync::atomic::AtomicU64::new(0),
            accepted: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn flag(&self, task: Task) -> &AtomicBool {
        match task {
            Task::Schedule => &self.schedule,
            Task::Monitor => &self.monitor,
            Task::CheckJobs => &self.check_jobs,
            Task::Shutdown => &self.shutdown,
        }
    }

    /// Request a task; redundant requests (one already pending) are
    /// discarded. Returns whether the notification was accepted.
    pub fn notify(&self, task: Task) -> bool {
        let fresh = !self.flag(task).swap(true, Ordering::AcqRel);
        if fresh {
            self.accepted.fetch_add(1, Ordering::Relaxed);
            self.ring();
        } else {
            self.discarded.fetch_add(1, Ordering::Relaxed);
        }
        fresh
    }

    /// Queue a job event (never coalesced).
    pub fn push_event(&self, ev: JobEvent) {
        self.events.lock().unwrap().push_back(ev);
        self.ring();
    }

    fn ring(&self) {
        *self.signal.lock().unwrap() += 1;
        self.wake.notify_one();
    }

    /// Non-blocking: next pending work item, events first (they carry
    /// data the tasks need), then flags in fixed priority order.
    pub fn poll(&self) -> Option<Work> {
        if let Some(ev) = self.events.lock().unwrap().pop_front() {
            return Some(Work::Event(ev));
        }
        if self.shutdown.swap(false, Ordering::AcqRel) {
            return Some(Work::Task(Task::Shutdown));
        }
        if self.schedule.swap(false, Ordering::AcqRel) {
            return Some(Work::Task(Task::Schedule));
        }
        if self.check_jobs.swap(false, Ordering::AcqRel) {
            return Some(Work::Task(Task::CheckJobs));
        }
        if self.monitor.swap(false, Ordering::AcqRel) {
            return Some(Work::Task(Task::Monitor));
        }
        None
    }

    /// Block until at least one notification arrives (or `d` elapses) —
    /// the periodic planner's tick drives the redundant re-execution even
    /// when nothing notifies, so a bounded wait is always safe.
    pub fn wait_timeout(&self, d: Duration) {
        let mut pending = self.signal.lock().unwrap();
        if *pending == 0 {
            let (guard, _timeout) = self.wake.wait_timeout(pending, d).unwrap();
            pending = guard;
        }
        *pending = 0;
    }
}

/// One unit of automaton work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Work {
    Task(Task),
    Event(JobEvent),
}

/// The redundancy planner (§2.2): schedules every task on a period so the
/// system self-heals from lost notifications, crashed modules or manual
/// database edits.
#[derive(Debug, Clone)]
pub struct Planner {
    pub schedule_every: Duration,
    pub monitor_every: Duration,
    pub check_jobs_every: Duration,
    last_schedule: Option<std::time::Instant>,
    last_monitor: Option<std::time::Instant>,
    last_check: Option<std::time::Instant>,
}

impl Planner {
    pub fn new(
        schedule_every: Duration,
        monitor_every: Duration,
        check_jobs_every: Duration,
    ) -> Planner {
        Planner {
            schedule_every,
            monitor_every,
            check_jobs_every,
            last_schedule: None,
            last_monitor: None,
            last_check: None,
        }
    }

    /// Fire periodic notifications that are due at `now`.
    pub fn tick(&mut self, now: std::time::Instant, hub: &NotificationHub) {
        let due = |last: &mut Option<std::time::Instant>, every: Duration| {
            let fire = last.map(|l| now.duration_since(l) >= every).unwrap_or(true);
            if fire {
                *last = Some(now);
            }
            fire
        };
        if due(&mut self.last_schedule, self.schedule_every) {
            hub.notify(Task::Schedule);
        }
        if due(&mut self.last_monitor, self.monitor_every) {
            hub.notify(Task::Monitor);
        }
        if due(&mut self.last_check, self.check_jobs_every) {
            hub.notify(Task::CheckJobs);
        }
    }

    /// The shortest period (the automaton's idle wait bound).
    pub fn min_period(&self) -> Duration {
        self.schedule_every
            .min(self.monitor_every)
            .min(self.check_jobs_every)
    }
}

/// Shared handle used across modules and commands.
pub type HubHandle = Arc<NotificationHub>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn redundant_notifications_are_discarded() {
        let hub = NotificationHub::new();
        assert!(hub.notify(Task::Schedule));
        assert!(!hub.notify(Task::Schedule), "second is redundant");
        assert!(!hub.notify(Task::Schedule));
        assert_eq!(hub.discarded.load(Ordering::Relaxed), 2);
        assert_eq!(hub.poll(), Some(Work::Task(Task::Schedule)));
        assert_eq!(hub.poll(), None);
        // after draining, a new notification is accepted again
        assert!(hub.notify(Task::Schedule));
    }

    #[test]
    fn events_are_never_coalesced_and_come_first() {
        let hub = NotificationHub::new();
        hub.notify(Task::Schedule);
        hub.push_event(JobEvent::Ended { job: 1, at: 10, ok: true });
        hub.push_event(JobEvent::Ended { job: 2, at: 11, ok: false });
        assert_eq!(
            hub.poll(),
            Some(Work::Event(JobEvent::Ended { job: 1, at: 10, ok: true }))
        );
        assert_eq!(
            hub.poll(),
            Some(Work::Event(JobEvent::Ended { job: 2, at: 11, ok: false }))
        );
        assert_eq!(hub.poll(), Some(Work::Task(Task::Schedule)));
    }

    #[test]
    fn shutdown_preempts_other_tasks() {
        let hub = NotificationHub::new();
        hub.notify(Task::Monitor);
        hub.notify(Task::Shutdown);
        assert_eq!(hub.poll(), Some(Work::Task(Task::Shutdown)));
    }

    #[test]
    fn planner_fires_every_task_initially_then_respects_periods() {
        let hub = NotificationHub::new();
        let mut planner = Planner::new(
            Duration::from_secs(60),
            Duration::from_secs(120),
            Duration::from_secs(60),
        );
        let t0 = std::time::Instant::now();
        planner.tick(t0, &hub);
        let mut tasks = Vec::new();
        while let Some(w) = hub.poll() {
            tasks.push(w);
        }
        assert_eq!(tasks.len(), 3, "all tasks fire on first tick");
        // immediately after, nothing is due
        planner.tick(t0 + Duration::from_secs(1), &hub);
        assert_eq!(hub.poll(), None);
        // after the schedule period, schedule (and check) fire again
        planner.tick(t0 + Duration::from_secs(61), &hub);
        let mut again = Vec::new();
        while let Some(w) = hub.poll() {
            again.push(w);
        }
        assert!(again.contains(&Work::Task(Task::Schedule)));
        assert!(!again.contains(&Work::Task(Task::Monitor)));
    }

    #[test]
    fn wait_wakes_on_notify() {
        let hub = Arc::new(NotificationHub::new());
        let h2 = hub.clone();
        let waiter = std::thread::spawn(move || {
            h2.wait_timeout(Duration::from_secs(5));
            h2.poll()
        });
        std::thread::sleep(Duration::from_millis(20));
        hub.notify(Task::Schedule);
        let got = waiter.join().unwrap();
        assert_eq!(got, Some(Work::Task(Task::Schedule)));
    }

    #[test]
    fn wait_timeout_returns_without_signal() {
        let hub = NotificationHub::new();
        let t0 = std::time::Instant::now();
        hub.wait_timeout(Duration::from_millis(30));
        assert!(t0.elapsed() >= Duration::from_millis(25));
        // a pre-rung signal makes the wait return immediately
        hub.notify(Task::Monitor);
        let t0 = std::time::Instant::now();
        hub.wait_timeout(Duration::from_secs(10));
        assert!(t0.elapsed() < Duration::from_secs(1));
    }
}
