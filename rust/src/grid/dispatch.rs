//! Wave sizing: greedy water-filling of pending tasks across per-cluster
//! headrooms.
//!
//! Each cluster contributes a *headroom* — how many more tasks it can
//! absorb right now, computed by the scheduler as
//! `min(concurrency cap − outstanding, probed free capacity)`. The wave
//! planner pours tasks one at a time into the cluster with the most
//! remaining headroom (ties to the lower index), the classic
//! water-filling shape: the emptiest back-end fills first, and over a
//! long campaign each cluster's share tracks its drain rate — the
//! feedback-driven placement idea of Libra applied to best-effort
//! farming. The function is pure and deterministic, so fairness is
//! testable and benchmarkable in isolation.

/// Plan one dispatch wave: distribute up to `pending` tasks over
/// `headrooms`, returning how many tasks each entry receives (aligned
/// with the input slice). The total never exceeds `pending` nor the sum
/// of headrooms, and no entry exceeds its own headroom.
pub fn plan_wave(pending: usize, headrooms: &[u32]) -> Vec<u32> {
    let mut counts = vec![0u32; headrooms.len()];
    let mut remaining: Vec<u32> = headrooms.to_vec();
    for _ in 0..pending {
        // Argmax over remaining headroom; strict `>` keeps ties on the
        // lowest index, making the plan deterministic.
        let Some((best, _)) = remaining
            .iter()
            .enumerate()
            .filter(|(_, r)| **r > 0)
            .reduce(|a, b| if b.1 > a.1 { b } else { a })
        else {
            break; // every cluster is full
        };
        counts[best] += 1;
        remaining[best] -= 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_headrooms_and_pending() {
        let counts = plan_wave(100, &[16, 4, 2]);
        assert_eq!(counts, vec![16, 4, 2], "saturates every cluster");
        let counts = plan_wave(0, &[16, 4, 2]);
        assert_eq!(counts, vec![0, 0, 0]);
        let counts = plan_wave(5, &[0, 0, 0]);
        assert_eq!(counts, vec![0, 0, 0]);
        assert!(plan_wave(7, &[]).is_empty());
    }

    #[test]
    fn fills_the_emptiest_cluster_first() {
        // Water-filling: remaining headrooms equalize.
        let counts = plan_wave(12, &[16, 4, 2]);
        assert_eq!(counts.iter().sum::<u32>(), 12);
        assert_eq!(counts, vec![12, 0, 0], "largest headroom absorbs first");
        let counts = plan_wave(14, &[16, 4, 2]);
        assert_eq!(counts, vec![13, 1, 0]);
        let counts = plan_wave(20, &[16, 4, 2]);
        // Remaining after the wave: [0, 1, 1] — levels within 1 of each
        // other wherever capacity allows.
        assert_eq!(counts, vec![16, 3, 1]);
    }

    #[test]
    fn ties_break_deterministically_to_the_lower_index() {
        assert_eq!(plan_wave(1, &[4, 4, 4]), vec![1, 0, 0]);
        assert_eq!(plan_wave(4, &[2, 2, 2]), vec![2, 1, 1]);
        // Same inputs, same plan.
        assert_eq!(plan_wave(9, &[5, 7, 3]), plan_wave(9, &[5, 7, 3]));
    }
}
