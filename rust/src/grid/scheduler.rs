//! The grid meta-scheduler: farms bag-of-tasks campaigns across N
//! independent cluster servers over the RPC protocol, CiGri-style.
//!
//! Structure mirrors the paper's §2.2 discipline one level up: the grid
//! keeps **all** its state in its own embedded database (`campaigns` /
//! `grid_tasks` tables, WAL-logged when a `data_dir` is configured), and
//! one round thread runs the executive loop:
//!
//! 1. **probe** — ask every cluster's `load` RPC for free capacity;
//!    consecutive transport failures blacklist a cluster for a probation
//!    period, after which one probe decides re-entry;
//! 2. **reconcile** — `stat` each reachable cluster (bounded to what is
//!    in flight), complete tasks whose remote job terminated, requeue
//!    preempted/failed/lost tasks within a retry budget, cancel + requeue
//!    placements whose remote job never starts (`stale_after`), adopt
//!    acknowledged-but-unrecorded placements by tag, and (on rejoin)
//!    kill orphaned remote duplicates before they can double-count;
//! 3. **dispatch** — size one best-effort submission wave per cluster
//!    (greedy water-filling under per-cluster concurrency caps,
//!    [`super::dispatch::plan_wave`]) and record every placement intent
//!    *before* the remote submission goes out, so a crash between intent
//!    and ack is recoverable by tag instead of double-dispatching.
//!
//! Tasks are submitted as **best-effort** jobs (§3.3): clusters may
//! reclaim their resources at any time, and the reconciler treats the
//! resulting `Error` exactly like a lost job — requeue elsewhere.
//!
//! **Exactly-once caveat.** Zero lost / zero duplicated holds for
//! cluster *crashes* (state-wiping restarts — the acceptance scenario).
//! A pure network partition is indistinguishable from a crash from the
//! grid's side: after `blacklist_after` failed probes the partitioned
//! cluster's tasks are re-placed, and if its original jobs kept running
//! and *finished* before the rejoin sweep can kill them, that work ran
//! twice. CiGri makes the same trade — campaign tasks must be
//! idempotent or uniquely-named per attempt; true fencing would need
//! cluster-side lease support the paper's protocol does not have.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::db::Db;
use crate::rpc::RpcClient;
use crate::server::LoadInfo;
use crate::types::{
    Campaign, CampaignId, CampaignSpec, CampaignState, GridTask, GridTaskState, JobId, JobSpec,
    JobState, Time,
};
use crate::Result;

use super::dispatch::plan_wave;

/// One federated cluster, as the grid sees it.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Stable name the task→placement mapping records.
    pub name: String,
    /// RPC front-end address (`host:port`).
    pub addr: String,
    /// Concurrency cap: max tasks this grid keeps outstanding there.
    pub max_outstanding: u32,
}

/// Grid meta-scheduler configuration.
#[derive(Debug, Clone)]
pub struct GridConfig {
    pub clusters: Vec<ClusterConfig>,
    /// Durable state directory (WAL + snapshots); `None` = volatile.
    pub data_dir: Option<PathBuf>,
    /// Cadence of the probe/reconcile/dispatch round.
    pub round_every: Duration,
    /// Max dispatch attempts per task before it is marked `Failed`.
    pub retry_budget: u32,
    /// Consecutive transport failures before a cluster is blacklisted.
    pub blacklist_after: u32,
    /// How long a blacklisted cluster sits out before a probation probe.
    pub probation: Duration,
    /// Per-call socket timeout on cluster RPC connections.
    pub rpc_timeout: Duration,
    /// A dispatched task whose remote job still has not *started* after
    /// this long is cancelled remotely and re-placed (within the retry
    /// budget). This is what keeps a campaign draining when a task's
    /// shape can never fit a cluster that admitted it, or a remote admin
    /// holds a grid job — without it such a placement would pin its task
    /// forever.
    pub stale_after: Duration,
    /// WAL records between automatic checkpoints (durable grids).
    pub checkpoint_every: u64,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            clusters: Vec::new(),
            data_dir: None,
            round_every: Duration::from_millis(500),
            retry_budget: 5,
            blacklist_after: 3,
            probation: Duration::from_secs(10),
            rpc_timeout: Duration::from_secs(5),
            stale_after: Duration::from_secs(600),
            checkpoint_every: 4096,
        }
    }
}

impl GridConfig {
    /// Fast cadence for tests and benches.
    pub fn fast(clusters: Vec<ClusterConfig>) -> GridConfig {
        GridConfig {
            clusters,
            round_every: Duration::from_millis(10),
            blacklist_after: 2,
            probation: Duration::from_millis(150),
            rpc_timeout: Duration::from_secs(2),
            stale_after: Duration::from_secs(5),
            ..GridConfig::default()
        }
    }
}

/// Event counters of one grid process (in-memory; the durable audit
/// trail is the grid database's event log).
#[derive(Debug, Default)]
pub struct GridCounters {
    /// Remote submissions acknowledged (including tag adoptions).
    pub dispatched: AtomicU64,
    /// Tasks completed (remote job terminated normally).
    pub completed: AtomicU64,
    /// Tasks that exhausted their retry budget.
    pub failed: AtomicU64,
    /// Requeues after a remote error / lost job / lost ack / stale
    /// never-started placement.
    pub retried: AtomicU64,
    /// Requeues because the task's cluster was blacklisted.
    pub orphaned: AtomicU64,
    /// Times a cluster entered the blacklist.
    pub blacklists: AtomicU64,
    /// Probation probes that brought a cluster back.
    pub rejoins: AtomicU64,
    /// Remote duplicate jobs killed by the rejoin sweep.
    pub orphan_kills: AtomicU64,
    /// Individual transport failures (connect/probe/stat/sub).
    pub transport_errors: AtomicU64,
    /// Rounds executed.
    pub rounds: AtomicU64,
}

/// A coherent copy of [`GridCounters`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GridCountersSnapshot {
    pub dispatched: u64,
    pub completed: u64,
    pub failed: u64,
    pub retried: u64,
    pub orphaned: u64,
    pub blacklists: u64,
    pub rejoins: u64,
    pub orphan_kills: u64,
    pub transport_errors: u64,
    pub rounds: u64,
}

/// Public view of one cluster's federation state.
#[derive(Debug, Clone)]
pub struct ClusterStatus {
    pub name: String,
    pub addr: String,
    /// Last probe answered.
    pub alive: bool,
    pub blacklisted: bool,
    pub consecutive_errors: u32,
    /// Free capacity (procs minus waiting backlog) at the last probe.
    pub last_free: u32,
    /// Tasks currently mapped to this cluster.
    pub outstanding: u32,
    pub dispatched_total: u64,
    pub completed_total: u64,
}

/// Per-campaign progress summary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CampaignProgress {
    pub total: u32,
    pub pending: u32,
    pub dispatched: u32,
    pub done: u32,
    pub failed: u32,
    pub state: CampaignState,
}

impl CampaignProgress {
    /// No task will ever move again.
    pub fn drained(&self) -> bool {
        self.pending == 0 && self.dispatched == 0
    }
}

#[derive(Clone)]
struct ClusterState {
    name: String,
    addr: String,
    cap: u32,
    alive: bool,
    consecutive_errors: u32,
    /// Grid-clock instant (ms) after which a probation probe may run.
    blacklisted_until: Option<Time>,
    /// Run the orphan sweep on the next reconcile (set at rejoin).
    sweep_on_rejoin: bool,
    last_free: u32,
    dispatched_total: u64,
    completed_total: u64,
}

struct GridInner {
    /// Reader-writer core, same discipline as the cluster server: status
    /// APIs (`campaigns`, `tasks`, `campaign_progress`, `clusters`,
    /// drain polls) take read guards and run concurrently with each
    /// other; only the round thread's reconcile/dispatch mutations and
    /// `submit_campaign` take the write guard.
    db: RwLock<Db>,
    clusters: Mutex<Vec<ClusterState>>,
    counters: GridCounters,
    running: AtomicBool,
    epoch: Instant,
    round_every: Duration,
    retry_budget: u32,
    blacklist_after: u32,
    probation: Duration,
    rpc_timeout: Duration,
    /// Grid-clock ms after which a never-started placement is stale.
    stale_ms: Time,
}

impl GridInner {
    fn now(&self) -> Time {
        self.epoch.elapsed().as_millis() as Time
    }
}

/// The grid meta-scheduler handle. Dropping it stops the round thread.
pub struct Grid {
    inner: Arc<GridInner>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// Why a task is going back to `Pending` — decides which counter ticks.
#[derive(Clone, Copy, PartialEq, Eq)]
enum RequeueKind {
    /// Remote error / lost job / lost ack.
    Retry,
    /// The task's cluster was blacklisted from under it.
    Orphan,
}

impl Grid {
    /// Boot the meta-scheduler: recover (or create) the grid database,
    /// then start the round thread. With a `data_dir`, a restart resumes
    /// mid-campaign from the persisted tables — finished tasks stay
    /// finished, in-flight placements are re-reconciled against their
    /// clusters, and the ack window is resolved by tag.
    pub fn start(config: GridConfig) -> Result<Grid> {
        anyhow::ensure!(
            !config.clusters.is_empty(),
            "GridConfig.clusters must name at least one cluster"
        );
        // Placements and reconciliation key on the cluster *name*: two
        // entries sharing one would each reconcile the other's tasks as
        // "lost" and re-run them forever.
        let mut names: Vec<&str> = config.clusters.iter().map(|c| c.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        anyhow::ensure!(
            names.len() == config.clusters.len(),
            "GridConfig.clusters contains duplicate names"
        );
        let db = match &config.data_dir {
            Some(dir) => {
                let (mut db, _stats) = Db::recover(dir)?;
                db.set_checkpoint_every(config.checkpoint_every);
                // A crash can cut a campaign's task-row inserts short:
                // the bag is derivable from its header, so re-insert the
                // missing indices. Dispatch instants from the previous
                // process's clock are meaningless on ours — reset them so
                // every in-flight task's staleness timer restarts at 0.
                let repaired = db.repair_campaigns();
                if repaired > 0 {
                    db.log_event(
                        0,
                        "GRID_REPAIR",
                        None,
                        &format!("re-inserted {repaired} truncated task rows"),
                    );
                }
                db.reset_grid_dispatch_clocks();
                db
            }
            None => Db::new(),
        };
        let clusters = config
            .clusters
            .iter()
            .map(|c| ClusterState {
                name: c.name.clone(),
                addr: c.addr.clone(),
                cap: c.max_outstanding.max(1),
                alive: false,
                consecutive_errors: 0,
                blacklisted_until: None,
                sweep_on_rejoin: false,
                last_free: 0,
                dispatched_total: 0,
                completed_total: 0,
            })
            .collect();
        let inner = Arc::new(GridInner {
            db: RwLock::new(db),
            clusters: Mutex::new(clusters),
            counters: GridCounters::default(),
            running: AtomicBool::new(true),
            epoch: Instant::now(),
            round_every: config.round_every,
            retry_budget: config.retry_budget.max(1),
            blacklist_after: config.blacklist_after.max(1),
            probation: config.probation,
            rpc_timeout: config.rpc_timeout,
            stale_ms: (config.stale_after.as_millis() as Time).max(1),
        });
        let thread = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("oar-grid".into())
                .spawn(move || {
                    while inner.running.load(Ordering::SeqCst) {
                        round(&inner);
                        std::thread::sleep(inner.round_every);
                    }
                })
                .expect("spawn grid round thread")
        };
        Ok(Grid {
            inner,
            thread: Some(thread),
        })
    }

    /// Milliseconds since grid start (the grid's clock).
    pub fn now(&self) -> Time {
        self.inner.now()
    }

    /// Submit a campaign: insert the header plus one `Pending` task row
    /// per task (all WAL-logged on a durable grid before the call
    /// returns — an acknowledged campaign survives a grid crash).
    pub fn submit_campaign(&self, spec: &CampaignSpec) -> Result<CampaignId> {
        anyhow::ensure!(spec.tasks >= 1, "campaign needs at least one task");
        anyhow::ensure!(spec.tasks <= 1_000_000, "campaign too large (max 1e6 tasks)");
        anyhow::ensure!(!spec.command.trim().is_empty(), "campaign command is empty");
        anyhow::ensure!(
            spec.nb_nodes >= 1 && spec.weight >= 1,
            "nbNodes and weight must be positive"
        );
        anyhow::ensure!(spec.max_time > 0, "maxTime must be positive");
        let now = self.inner.now();
        let mut db = self.inner.db.write().unwrap();
        let id = db.insert_campaign(spec, now);
        db.log_event(
            now,
            "CAMPAIGN",
            None,
            &format!("campaign {id} ({}) x{} tasks", spec.name, spec.tasks),
        );
        Ok(id)
    }

    pub fn campaigns(&self) -> Vec<Campaign> {
        self.inner.db.read().unwrap().campaigns()
    }

    pub fn tasks(&self, campaign: CampaignId) -> Vec<GridTask> {
        self.inner.db.read().unwrap().grid_tasks_of_campaign(campaign)
    }

    pub fn campaign_progress(&self, id: CampaignId) -> Result<CampaignProgress> {
        let db = self.inner.db.read().unwrap();
        let campaign = db.campaign(id)?;
        // Index-walk counts, no row materialization: progress is polled
        // in tight loops and must not scale with campaign size.
        let [pending, dispatched, done, failed] = db.count_campaign_tasks(id);
        Ok(CampaignProgress {
            total: campaign.tasks,
            pending: pending as u32,
            dispatched: dispatched as u32,
            done: done as u32,
            failed: failed as u32,
            state: campaign.state,
        })
    }

    /// Per-cluster federation status (for `oar grid clusters` and tests).
    pub fn clusters(&self) -> Vec<ClusterStatus> {
        let outstanding = {
            let db = self.inner.db.read().unwrap();
            let mut by_cluster: BTreeMap<String, u32> = BTreeMap::new();
            for t in db.grid_tasks_in_state(GridTaskState::Dispatched) {
                if let Some(c) = t.cluster {
                    *by_cluster.entry(c).or_insert(0) += 1;
                }
            }
            by_cluster
        };
        let now = self.inner.now();
        self.inner
            .clusters
            .lock()
            .unwrap()
            .iter()
            .map(|c| ClusterStatus {
                name: c.name.clone(),
                addr: c.addr.clone(),
                alive: c.alive,
                blacklisted: c.blacklisted_until.map(|t| now < t).unwrap_or(false),
                consecutive_errors: c.consecutive_errors,
                last_free: c.last_free,
                outstanding: outstanding.get(&c.name).copied().unwrap_or(0),
                dispatched_total: c.dispatched_total,
                completed_total: c.completed_total,
            })
            .collect()
    }

    pub fn counters(&self) -> GridCountersSnapshot {
        let c = &self.inner.counters;
        GridCountersSnapshot {
            dispatched: c.dispatched.load(Ordering::Relaxed),
            completed: c.completed.load(Ordering::Relaxed),
            failed: c.failed.load(Ordering::Relaxed),
            retried: c.retried.load(Ordering::Relaxed),
            orphaned: c.orphaned.load(Ordering::Relaxed),
            blacklists: c.blacklists.load(Ordering::Relaxed),
            rejoins: c.rejoins.load(Ordering::Relaxed),
            orphan_kills: c.orphan_kills.load(Ordering::Relaxed),
            transport_errors: c.transport_errors.load(Ordering::Relaxed),
            rounds: c.rounds.load(Ordering::Relaxed),
        }
    }

    /// Block until every task of `id` is terminal (or `timeout`).
    pub fn wait_campaign_drained(&self, id: CampaignId, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            match self.campaign_progress(id) {
                Ok(p) if p.drained() => return true,
                _ => {}
            }
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// Mutating inspection hook (tests, `oar grid stat`). Takes the
    /// write guard; prefer [`Grid::read_db`] for pure queries.
    pub fn with_db<T>(&self, f: impl FnOnce(&mut Db) -> T) -> T {
        f(&mut self.inner.db.write().unwrap())
    }

    /// Read-only inspection hook: runs against a consistent snapshot
    /// without blocking (or being blocked by) an in-progress round.
    pub fn read_db<T>(&self, f: impl FnOnce(&Db) -> T) -> T {
        f(&self.inner.db.read().unwrap())
    }

    /// Stop the round thread without giving up the handle (idempotent).
    /// Once this returns, no further state transitions happen: counters
    /// and tables are final and can be read race-free before
    /// [`Grid::shutdown`].
    pub fn pause(&mut self) {
        self.inner.running.store(false, Ordering::SeqCst);
        if let Some(h) = self.thread.take() {
            let _ = h.join();
        }
    }

    /// Stop the round thread and return the final database. A clean
    /// shutdown of a durable grid checkpoints, so the next boot replays
    /// nothing.
    pub fn shutdown(mut self) -> Db {
        self.pause();
        let inner = self.inner.clone();
        drop(self);
        // The joined round thread held the only other Arc clone, and no
        // API hands the Arc out, so unwrap cannot fail here.
        let Ok(i) = Arc::try_unwrap(inner) else {
            unreachable!("round thread is joined; no other GridInner holders exist");
        };
        let mut db = i.db.into_inner().unwrap();
        if db.is_durable() {
            let _ = db.checkpoint();
        }
        db
    }
}

impl Drop for Grid {
    fn drop(&mut self) {
        self.pause();
    }
}

// ------------------------------------------------------------ rounds ----

/// Connect and probe one cluster. Any failure — transport or a protocol
/// refusal (e.g. the cluster is draining) — means "unusable this round".
/// The connect itself is bounded by the same timeout as the calls: a
/// black-holed host (powered off, packets silently dropped) must cost
/// one `rpc_timeout`, not the OS connect default of minutes, or every
/// round would stall behind it.
fn probe(addr: &str, timeout: Duration) -> Result<(RpcClient, LoadInfo)> {
    let mut client = RpcClient::connect_timeout(addr, timeout)?;
    client.set_timeout(Some(timeout))?;
    match client.load()? {
        Ok(info) => Ok((client, info)),
        Err(e) => anyhow::bail!("load refused: {e}"),
    }
}

/// Count one transport failure against a cluster; when `blacklist_after`
/// consecutive failures accumulate — across the probe, reconcile and
/// dispatch phases, so a cluster whose `load` answers but whose
/// `stat`/`sub` persistently fail still trips it — the cluster is
/// blacklisted until probation and its in-flight tasks are requeued onto
/// the survivors. Returns whether the cluster was just blacklisted.
fn note_transport_failure(inner: &GridInner, cs: &mut ClusterState) -> bool {
    let now = inner.now();
    inner.counters.transport_errors.fetch_add(1, Ordering::Relaxed);
    cs.alive = false;
    cs.last_free = 0;
    cs.consecutive_errors += 1;
    if cs.consecutive_errors < inner.blacklist_after {
        return false;
    }
    cs.blacklisted_until = Some(now + inner.probation.as_millis() as Time);
    cs.consecutive_errors = 0;
    inner.counters.blacklists.fetch_add(1, Ordering::Relaxed);
    let mut db = inner.db.write().unwrap();
    db.log_event(now, "GRID_BLACKLIST", None, &cs.name);
    let placed: Vec<GridTask> = db
        .grid_tasks_in_state(GridTaskState::Dispatched)
        .into_iter()
        .filter(|t| t.cluster.as_deref() == Some(cs.name.as_str()))
        .collect();
    for task in placed {
        requeue_or_fail(inner, &mut db, &task, "cluster blacklisted", RequeueKind::Orphan);
    }
    true
}

/// Free capacity usable for new best-effort tasks: free processors minus
/// the waiting backlog (each waiting job will claim at least one proc).
///
/// `procs_free` comes from the cluster's `load` probe, which is answered
/// from materialized views and counts a dead node's claimed processors
/// as busy until the stranded jobs are failed or requeued — so a node
/// death shrinks the budget immediately instead of inviting a dispatch
/// wave against capacity that no longer exists.
fn wave_budget(info: &LoadInfo) -> u32 {
    info.procs_free.saturating_sub(info.waiting_jobs)
}

/// The `stat` filter of one reconcile pass: all non-terminal grid-tagged
/// jobs, plus the placed job ids (whatever state they reached), plus the
/// exact tags of any ack-window placements. Bounded by the cluster's
/// live queue + the grid's own outstanding count — never by how many
/// tasks have finished over the campaign's lifetime.
fn reconcile_filter(placed: &[GridTask], ack_tags: &[String]) -> String {
    use std::fmt::Write as _;
    let mut filter = String::from("command LIKE '%#grid:%' AND (state IN (");
    let mut first = true;
    for s in JobState::ALL {
        if !s.is_terminal() {
            if !first {
                filter.push(',');
            }
            let _ = write!(filter, "'{}'", s.as_str());
            first = false;
        }
    }
    filter.push(')');
    let ids: Vec<String> = placed
        .iter()
        .filter_map(|t| t.job)
        .map(|j| j.to_string())
        .collect();
    if !ids.is_empty() {
        let _ = write!(filter, " OR id IN ({})", ids.join(","));
    }
    for tag in ack_tags {
        let _ = write!(filter, " OR command LIKE '%{tag}'");
    }
    filter.push(')');
    filter
}

/// Requeue within budget, fail beyond it. The *only* place a task goes
/// back to `Pending`, so `sum(attempts) == initial dispatches + retried
/// + orphaned` holds exactly (the e2e suite asserts it).
fn requeue_or_fail(inner: &GridInner, db: &mut Db, task: &GridTask, why: &str, kind: RequeueKind) {
    let now = inner.now();
    if task.attempts >= inner.retry_budget {
        if db.fail_grid_task(task.id, why).is_ok() {
            inner.counters.failed.fetch_add(1, Ordering::Relaxed);
            db.log_event(
                now,
                "GRID_TASK_FAILED",
                None,
                &format!("task {}:{} after {} attempts: {why}", task.campaign, task.index, task.attempts),
            );
        }
    } else if db.requeue_grid_task(task.id, why).is_ok() {
        let (counter, kind_s) = match kind {
            RequeueKind::Retry => (&inner.counters.retried, "GRID_REQUEUE"),
            RequeueKind::Orphan => (&inner.counters.orphaned, "GRID_ORPHAN"),
        };
        counter.fetch_add(1, Ordering::Relaxed);
        db.log_event(
            now,
            kind_s,
            None,
            &format!("task {}:{}: {why}", task.campaign, task.index),
        );
    }
}

/// One executive round: probe → reconcile → dispatch → campaign close.
///
/// The round works on a **private copy** of the cluster table and writes
/// it back at the end: a round does per-cluster network I/O (worst case
/// `clusters × rpc_timeout` against black-holed hosts), and holding the
/// lock across that would stall every [`Grid::clusters`] status read.
/// The round thread is the only writer, so copy-out/write-back is
/// race-free; readers just see the previous round's snapshot.
fn round(inner: &Arc<GridInner>) {
    // Declared first: every guard the round takes below is scoped inside
    // the function, so the span records only after all are released.
    let _round = crate::obs::Span::enter("grid.round", &crate::obs::metrics::GRID_ROUND_US);
    inner.counters.rounds.fetch_add(1, Ordering::Relaxed);
    let mut clusters: Vec<ClusterState> = inner.clusters.lock().unwrap().clone();
    let n = clusters.len();
    let mut sessions: Vec<Option<RpcClient>> = Vec::with_capacity(n);

    // ------------------------------------------------------- probe ----
    let t_probe = crate::obs::clock::now_us();
    for cs in clusters.iter_mut() {
        let now = inner.now();
        if let Some(until) = cs.blacklisted_until {
            if now < until {
                sessions.push(None);
                continue;
            }
            // Probation probe: one success re-enters, one failure extends.
            match probe(&cs.addr, inner.rpc_timeout) {
                Ok((client, info)) => {
                    cs.blacklisted_until = None;
                    cs.consecutive_errors = 0;
                    cs.alive = true;
                    cs.sweep_on_rejoin = true;
                    cs.last_free = wave_budget(&info);
                    inner.counters.rejoins.fetch_add(1, Ordering::Relaxed);
                    let mut db = inner.db.write().unwrap();
                    db.log_event(now, "GRID_REJOIN", None, &cs.name);
                    sessions.push(Some(client));
                }
                Err(_) => {
                    inner.counters.transport_errors.fetch_add(1, Ordering::Relaxed);
                    cs.blacklisted_until = Some(now + inner.probation.as_millis() as Time);
                    sessions.push(None);
                }
            }
            continue;
        }
        match probe(&cs.addr, inner.rpc_timeout) {
            Ok((client, info)) => {
                cs.alive = true;
                cs.consecutive_errors = 0;
                cs.last_free = wave_budget(&info);
                sessions.push(Some(client));
            }
            Err(_) => {
                note_transport_failure(inner, cs);
                sessions.push(None);
            }
        }
    }

    // Phase boundaries are guard-free points (each phase takes and
    // releases its guards internally), so recording here never overlaps
    // a held lock.
    crate::obs::metrics::GRID_PROBE_US
        .observe(crate::obs::clock::now_us().saturating_sub(t_probe));

    // --------------------------------------------------- reconcile ----
    let t_reconcile = crate::obs::clock::now_us();
    for i in 0..n {
        if sessions[i].is_none() {
            continue;
        }
        let name = clusters[i].name.clone();
        let (placed, ack_tags): (Vec<GridTask>, Vec<String>) = {
            let db = inner.db.read().unwrap();
            let placed: Vec<GridTask> = db
                .grid_tasks_in_state(GridTaskState::Dispatched)
                .into_iter()
                .filter(|t| t.cluster.as_deref() == Some(name.as_str()))
                .collect();
            let ack_tags = placed
                .iter()
                .filter(|t| t.job.is_none())
                .filter_map(|t| {
                    db.campaign(t.campaign)
                        .ok()
                        .map(|c| GridTask::tag(c.token, t.index))
                })
                .collect();
            (placed, ack_tags)
        };
        if placed.is_empty() && !clusters[i].sweep_on_rejoin {
            continue;
        }
        // One bounded stat per cluster: every *non-terminal* grid-tagged
        // job (what the rejoin sweep must see), plus — by id — the placed
        // jobs whose terminal fate decides completion vs. retry, plus —
        // by tag — any ack-window submission that may have landed in any
        // state. Terminated jobs of past waves are excluded, so the
        // transfer stays proportional to what is in flight, not to how
        // much the campaign has already finished.
        let filter = reconcile_filter(&placed, &ack_tags);
        let jobs = match sessions[i].as_mut().unwrap().stat(Some(&filter)) {
            Ok(Ok(jobs)) => jobs,
            Ok(Err(e)) => {
                // Protocol refusal (draining, or — if the generated
                // filter ever stopped parsing — bad_filter): retried
                // next round, but logged so a persistent refusal leaves
                // a trail instead of a silent stall.
                let now = inner.now();
                let mut db = inner.db.write().unwrap();
                db.log_event(now, "GRID_STAT_REFUSED", None, &format!("{name}: {e}"));
                continue;
            }
            Err(_) => {
                note_transport_failure(inner, &mut clusters[i]);
                sessions[i] = None;
                continue;
            }
        };
        let by_id: BTreeMap<JobId, &crate::types::Job> =
            jobs.iter().map(|j| (j.id, j)).collect();
        let now = inner.now();
        // Cancels decided under the db lock are issued after it drops: a
        // `del` is a blocking RPC, and pinning the grid database for up
        // to rpc_timeout per call would stall every status read.
        let mut to_cancel: Vec<JobId> = Vec::new();
        let mut db = inner.db.write().unwrap();
        for task in &placed {
            match task.job {
                Some(jid) => {
                    // Identity check, not just the id: a cluster that
                    // crashed and rebooted between rounds (without a
                    // probe failure in between) re-issues job ids from
                    // 1, so a bare id can alias a *different* task's
                    // fresh job — trusting it would complete the wrong
                    // task. The command tag is the placement's identity.
                    let tag = db
                        .campaign(task.campaign)
                        .ok()
                        .map(|c| GridTask::tag(c.token, task.index));
                    let remote = by_id.get(&jid).copied().filter(|j| {
                        tag.as_deref()
                            .map(|t| j.command.ends_with(t))
                            .unwrap_or(false)
                    });
                    match remote {
                        Some(job) if job.state == JobState::Terminated => {
                            if db.complete_grid_task(task.id).is_ok() {
                                inner.counters.completed.fetch_add(1, Ordering::Relaxed);
                                clusters[i].completed_total += 1;
                            }
                        }
                        Some(job) if job.state == JobState::Error => {
                            let why = format!("remote error: {}", job.message);
                            requeue_or_fail(inner, &mut db, task, &why, RequeueKind::Retry);
                        }
                        Some(job)
                            if matches!(job.state, JobState::Waiting | JobState::Hold)
                                && now.saturating_sub(task.dispatched_at) > inner.stale_ms =>
                        {
                            // The placement never started (a shape the
                            // cluster admitted but can never fit, or a
                            // remote hold): nudge a cancellation, but do
                            // NOT requeue yet — the del ack only confirms
                            // the Cancel *event* was enqueued, not that
                            // it beat a concurrent launch, so releasing
                            // the task here could run it twice. The task
                            // stays Dispatched until a later stat shows
                            // the job terminal: Error (the cancel won)
                            // requeues it, Terminated (the job slipped
                            // through and finished) completes it.
                            // Re-sent each round until then; cancels are
                            // idempotent.
                            db.log_event(
                                now,
                                "GRID_STALE_CANCEL",
                                None,
                                &format!(
                                    "task {}:{} job {jid} on {name}",
                                    task.campaign, task.index
                                ),
                            );
                            to_cancel.push(jid);
                        }
                        Some(_) => {} // still waiting/running there
                        None => {
                            requeue_or_fail(
                                inner,
                                &mut db,
                                task,
                                "remote job lost",
                                RequeueKind::Retry,
                            );
                        }
                    }
                }
                None => {
                    // Ack window: the intent was recorded but the ack never
                    // made it back. The tag decides — adopt the remote job
                    // if the submission did land, requeue otherwise.
                    let Ok(campaign) = db.campaign(task.campaign) else {
                        continue;
                    };
                    let tag = GridTask::tag(campaign.token, task.index);
                    let adopted = jobs
                        .iter()
                        .filter(|j| j.command.ends_with(tag.as_str()))
                        .max_by_key(|j| j.id);
                    match adopted {
                        Some(job) => {
                            if db.set_grid_task_job(task.id, job.id).is_ok() {
                                inner.counters.dispatched.fetch_add(1, Ordering::Relaxed);
                                clusters[i].dispatched_total += 1;
                            }
                        }
                        None => {
                            requeue_or_fail(
                                inner,
                                &mut db,
                                task,
                                "submission ack lost",
                                RequeueKind::Retry,
                            );
                        }
                    }
                }
            }
        }
        // Rejoin sweep: a cluster coming back from the blacklist may
        // still hold live jobs for tasks the grid has since re-placed
        // elsewhere. Kill them before they can terminate and double-run.
        if clusters[i].sweep_on_rejoin {
            for job in &jobs {
                if job.state.is_terminal() {
                    continue;
                }
                let Some((token, index)) = GridTask::parse_tag(&job.command) else {
                    continue;
                };
                // A token not in our campaigns table is another grid's
                // job (or a past life of this one) — never ours to kill.
                let Some(campaign) = db.campaign_by_token(token) else {
                    continue;
                };
                let ours = db
                    .grid_tasks_of_campaign(campaign.id)
                    .into_iter()
                    .find(|t| t.index == index)
                    .map(|t| {
                        t.state == GridTaskState::Dispatched
                            && t.cluster.as_deref() == Some(name.as_str())
                            && t.job == Some(job.id)
                    })
                    .unwrap_or(false);
                if !ours {
                    to_cancel.push(job.id);
                    inner.counters.orphan_kills.fetch_add(1, Ordering::Relaxed);
                }
            }
            clusters[i].sweep_on_rejoin = false;
        }
        drop(db);
        for jid in to_cancel {
            let _ = sessions[i].as_mut().unwrap().del(jid);
        }
    }

    crate::obs::metrics::GRID_RECONCILE_US
        .observe(crate::obs::clock::now_us().saturating_sub(t_reconcile));

    // ---------------------------------------------------- dispatch ----
    // Headrooms first: the pending fetch is capped at what this wave can
    // actually place, so a million-task backlog costs a million-row
    // materialization exactly never.
    let t_dispatch = crate::obs::clock::now_us();
    let headrooms: Vec<u32> = {
        let db = inner.db.read().unwrap();
        let mut outstanding: BTreeMap<String, u32> = BTreeMap::new();
        for t in db.grid_tasks_in_state(GridTaskState::Dispatched) {
            if let Some(c) = t.cluster {
                *outstanding.entry(c).or_insert(0) += 1;
            }
        }
        clusters
            .iter()
            .enumerate()
            .map(|(i, cs)| {
                if sessions[i].is_none() {
                    return 0;
                }
                let out = outstanding.get(&cs.name).copied().unwrap_or(0);
                cs.cap.saturating_sub(out).min(cs.last_free)
            })
            .collect()
    };
    let wave_cap: u32 = headrooms.iter().sum();
    let (pending, campaigns_by_id) = if wave_cap > 0 {
        let db = inner.db.read().unwrap();
        let pending = db.grid_tasks_in_state_capped(GridTaskState::Pending, wave_cap as usize);
        let campaigns: BTreeMap<CampaignId, Campaign> =
            db.campaigns().into_iter().map(|c| (c.id, c)).collect();
        (pending, campaigns)
    } else {
        (Vec::new(), BTreeMap::new())
    };
    if !pending.is_empty() {
        let counts = plan_wave(pending.len(), &headrooms);
        let mut tasks = pending.into_iter();
        for i in 0..n {
            for _ in 0..counts[i] {
                let Some(task) = tasks.next() else { break };
                let Some(campaign) = campaigns_by_id.get(&task.campaign) else {
                    continue;
                };
                let name = clusters[i].name.clone();
                // Placement intent first (write-ahead at the grid level).
                {
                    let mut db = inner.db.write().unwrap();
                    if db
                        .mark_grid_task_dispatched(task.id, &name, inner.now())
                        .is_err()
                    {
                        continue;
                    }
                }
                let spec = JobSpec {
                    user: campaign.user.clone(),
                    command: format!(
                        "{} {}",
                        campaign.command.replace("{i}", &task.index.to_string()),
                        GridTask::tag(campaign.token, task.index)
                    ),
                    nb_nodes: campaign.nb_nodes,
                    weight: campaign.weight,
                    max_time: Some(campaign.max_time),
                    best_effort: true,
                    ..JobSpec::default()
                };
                match sessions[i].as_mut().unwrap().sub(&spec) {
                    Ok(Ok(job)) => {
                        let mut db = inner.db.write().unwrap();
                        if db.set_grid_task_job(task.id, job).is_ok() {
                            inner.counters.dispatched.fetch_add(1, Ordering::Relaxed);
                            clusters[i].dispatched_total += 1;
                        }
                    }
                    Ok(Err(reject)) => {
                        // Admission refused: the submission definitively
                        // did not land, so the task can move on at once.
                        let mut db = inner.db.write().unwrap();
                        if let Ok(t) = db.grid_task(task.id) {
                            let why = format!("admission rejected: {reject}");
                            requeue_or_fail(inner, &mut db, &t, &why, RequeueKind::Retry);
                        }
                    }
                    Err(_) => {
                        // Transport failure mid-sub: the outcome is
                        // unknown — leave the intent recorded (the tag
                        // resolves it next round) and stop talking to
                        // this cluster for the rest of the round.
                        note_transport_failure(inner, &mut clusters[i]);
                        sessions[i] = None;
                        break;
                    }
                }
            }
        }
    }

    crate::obs::metrics::GRID_DISPATCH_US
        .observe(crate::obs::clock::now_us().saturating_sub(t_dispatch));

    // ------------------------------------------------ close campaigns ----
    let now = inner.now();
    let mut db = inner.db.write().unwrap();
    let open: Vec<CampaignId> = db
        .campaigns()
        .into_iter()
        .filter(|c| c.state == CampaignState::Active)
        .map(|c| c.id)
        .collect();
    for id in open {
        if db.campaign_tasks_all_terminal(id) {
            let _ = db.set_campaign_state(id, CampaignState::Done);
            db.log_event(now, "CAMPAIGN_DONE", None, &format!("campaign {id}"));
        }
    }
    drop(db);

    // Publish this round's cluster state (see the fn doc: the round ran
    // on a private copy so status reads never wait on network I/O).
    *inner.clusters.lock().unwrap() = clusters;
}
