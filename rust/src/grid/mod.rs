//! Grid federation: a CiGri-style meta-scheduler that farms bag-of-tasks
//! campaigns across multiple independent cluster servers over the RPC
//! protocol.
//!
//! The paper's headline deployment is not one cluster but a metropolitan
//! GRID of ~700 nodes with global-computing support (§ abstract, §3.3):
//! many autonomous OAR clusters, plus a grid layer that feeds them
//! best-effort work. This module is that layer for the reproduction:
//!
//! * [`scheduler`] — [`Grid`], the meta-scheduler: campaigns persisted in
//!   the `campaigns`/`grid_tasks` tables of its own embedded (optionally
//!   WAL-durable) database, a probe/reconcile/dispatch round over
//!   [`crate::rpc::RpcClient`] connections, per-cluster blacklisting
//!   with timed probation, and a retry budget per task.
//! * [`dispatch`] — the pure wave planner: greedy water-filling of
//!   pending tasks across per-cluster headrooms.
//! * [`harness`] — [`TestGrid`], which boots several in-process cluster
//!   servers on loopback so federation scenarios (including killing and
//!   rebooting a cluster mid-campaign) run in one test process.
//!
//! The grid only speaks the public client protocol (`load`, `sub`,
//! `stat`, `del`) — clusters need no grid-specific state and keep serving
//! their local users; grid tasks arrive as ordinary best-effort jobs that
//! the clusters may preempt at will, and the reconciler re-places
//! preempted work elsewhere.

pub mod dispatch;
pub mod harness;
pub mod scheduler;

pub use dispatch::plan_wave;
pub use harness::{TestCluster, TestGrid};
pub use scheduler::{
    CampaignProgress, ClusterConfig, ClusterStatus, Grid, GridConfig, GridCounters,
    GridCountersSnapshot,
};
