//! [`TestGrid`]: boots several in-process cluster servers, each behind
//! its own loopback RPC front-end, so federation tests, benches and
//! examples can drive a real multi-cluster deployment in one process —
//! including killing a cluster mid-campaign and rebooting it on the same
//! address (the front-end binds with `SO_REUSEADDR`, so the port is
//! immediately reusable despite TIME_WAIT remnants of killed
//! connections).

use std::sync::Arc;
use std::time::Duration;

use crate::cluster::VirtualCluster;
use crate::rpc::{RpcConfig, RpcServer};
use crate::server::{Server, ServerConfig};
use crate::types::{GridTask, JobState};
use crate::Result;

use super::scheduler::ClusterConfig;

/// One loopback cluster of the harness.
pub struct TestCluster {
    pub name: String,
    /// Bound RPC address (stable across [`TestGrid::reboot`]).
    pub addr: String,
    nodes: u32,
    procs: u32,
    scale: f64,
    server: Option<Arc<Server>>,
    rpc: Option<RpcServer>,
}

impl TestCluster {
    fn boot(&mut self, addr: &str) -> Result<()> {
        let cluster = Arc::new(VirtualCluster::tiny(self.nodes, self.procs));
        let mut cfg = ServerConfig::fast(self.scale);
        cfg.sched.dense_matching = false; // keep the harness artifact-free
        let server = Arc::new(Server::new(cluster, cfg));
        let rpc = RpcServer::start(
            server.clone(),
            RpcConfig {
                addr: addr.into(),
                workers: 4,
                queue_depth: 16,
                io_timeout: Some(Duration::from_secs(30)),
            },
        )?;
        self.addr = rpc.addr().to_string();
        self.server = Some(server);
        self.rpc = Some(rpc);
        Ok(())
    }
}

/// A fleet of in-process clusters for federation tests.
pub struct TestGrid {
    clusters: Vec<TestCluster>,
}

impl TestGrid {
    /// Boot one cluster per `(nodes, procs_per_node)` shape, named
    /// `c0`, `c1`, ... — asymmetric shapes make dispatch fairness
    /// observable. `scale` compresses modeled latencies and simulated
    /// runtimes exactly as [`ServerConfig::fast`] does.
    pub fn start(shapes: &[(u32, u32)], scale: f64) -> Result<TestGrid> {
        let mut clusters = Vec::with_capacity(shapes.len());
        for (i, (nodes, procs)) in shapes.iter().enumerate() {
            let mut c = TestCluster {
                name: format!("c{i}"),
                addr: String::new(),
                nodes: *nodes,
                procs: *procs,
                scale,
                server: None,
                rpc: None,
            };
            c.boot("127.0.0.1:0")?;
            clusters.push(c);
        }
        Ok(TestGrid { clusters })
    }

    /// The grid-side view of this fleet, with one shared concurrency cap.
    pub fn cluster_configs(&self, max_outstanding: u32) -> Vec<ClusterConfig> {
        self.clusters
            .iter()
            .map(|c| ClusterConfig {
                name: c.name.clone(),
                addr: c.addr.clone(),
                max_outstanding,
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.clusters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.clusters.is_empty()
    }

    pub fn addr(&self, i: usize) -> &str {
        &self.clusters[i].addr
    }

    pub fn name(&self, i: usize) -> &str {
        &self.clusters[i].name
    }

    /// The live server behind cluster `i` (panics if it is killed).
    pub fn server(&self, i: usize) -> &Arc<Server> {
        self.clusters[i].server.as_ref().expect("cluster is down")
    }

    /// Kill cluster `i`: the front-end and server are torn down; further
    /// connections to its address are refused. From the grid's point of
    /// view the cluster died — its in-flight jobs are gone with it.
    pub fn kill(&mut self, i: usize) {
        self.clusters[i].rpc.take();
        self.clusters[i].server.take();
    }

    pub fn is_up(&self, i: usize) -> bool {
        self.clusters[i].rpc.is_some()
    }

    /// Reboot cluster `i` from scratch (fresh database — a crashed
    /// cluster that lost its volatile state) on the *same* address, so a
    /// blacklisted grid entry re-enters at probation time.
    pub fn reboot(&mut self, i: usize) -> Result<()> {
        let addr = self.clusters[i].addr.clone();
        self.clusters[i].boot(&addr)
    }

    /// Count grid-tagged jobs of cluster `i` currently in `state`
    /// (duplicate-detection helper for tests and benches).
    pub fn tagged_jobs_in_state(&self, i: usize, state: JobState) -> usize {
        self.server(i).with_db(|db| {
            db.jobs_in_state(state)
                .iter()
                .filter(|j| GridTask::parse_tag(&j.command).is_some())
                .count()
        })
    }
}
