//! Hierarchical resources: the request grammar and placement model of
//! the real OAR (`-l /switch=S/host=N/core=M,walltime=H:M:S`).
//!
//! The paper's resource model is a *tree* — cluster / switch / host /
//! cpu / core — and a submission asks for a shape inside that tree, not
//! a flat node count. This module provides the three pieces the rest of
//! the system composes:
//!
//! * **Model** — [`Level`] / [`Resource`]: rows of the `resources`
//!   table (WAL-durable, indexed by `level` and `parent`, snapshotted
//!   like every other table). The nodes table is a *derived view* of the
//!   host level: [`crate::cluster::VirtualCluster::register`] writes the
//!   tree first and materializes one node row per host.
//! * **Grammar** — [`parse_request`]: a *total* parser for the request
//!   language, including property filters (`{mem > 1024}/host=2`) and
//!   moldable alternatives (`/host=4/core=2 | /host=2/core=4`, from
//!   repeated `-l` flags). Every input returns either a
//!   [`ResourceRequest`] or a typed [`ParseError`] — never a panic —
//!   and `parse → print → parse` is the identity on the printed form.
//! * **Matcher** — [`find_earliest_tree`]: conservative-backfilling
//!   placement of a tree shape by per-level interval counting. Each
//!   host contributes the time ranges where it can start the per-host
//!   slice ([`crate::sched::Gantt::feasible_starts`]); counting range
//!   coverage at the host level yields per-switch feasibility intervals,
//!   and counting *those* at the switch level yields the earliest
//!   instant where S switches each hold N feasible hosts.
//!
//! Flat `nbNodes`/`weight` submissions keep working untouched: they
//! desugar to `/host=N/core=weight` (see `docs/PROTOCOL.md`).

use std::collections::BTreeMap;
use std::fmt;

use crate::db::{Row, Value};
use crate::types::{Node, NodeId, Time};

// ================================================================ model ====

/// A level of the resource tree, root to leaf.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Cluster,
    Switch,
    Host,
    Cpu,
    Core,
}

impl Level {
    /// Root-to-leaf order (the canonical printing order).
    pub const ALL: [Level; 5] = [
        Level::Cluster,
        Level::Switch,
        Level::Host,
        Level::Cpu,
        Level::Core,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            Level::Cluster => "cluster",
            Level::Switch => "switch",
            Level::Host => "host",
            Level::Cpu => "cpu",
            Level::Core => "core",
        }
    }

    /// Parse a level name. Accepts the aliases the real corpus uses:
    /// `node`/`nodes` for host (flat-spec vocabulary) and `socket` for
    /// cpu (ReFrame: "number of sockets can also be specified using
    /// cpu=...").
    pub fn parse(s: &str) -> Option<Level> {
        Some(match s {
            "cluster" => Level::Cluster,
            "switch" => Level::Switch,
            "host" | "node" | "nodes" => Level::Host,
            "cpu" | "socket" => Level::Cpu,
            "core" => Level::Core,
            _ => return None,
        })
    }

    /// Depth below the cluster root (cluster = 0, core = 4).
    pub fn depth(self) -> usize {
        match self {
            Level::Cluster => 0,
            Level::Switch => 1,
            Level::Host => 2,
            Level::Cpu => 3,
            Level::Core => 4,
        }
    }
}

/// One row of the `resources` table: a vertex of the tree.
#[derive(Debug, Clone, PartialEq)]
pub struct Resource {
    /// Row id (assigned by the table; doubles as the tree vertex id).
    pub id: u64,
    pub level: Level,
    /// Parent vertex; `None` only for the cluster root.
    pub parent: Option<u64>,
    pub name: String,
    /// Host-level rows link to their derived row in the nodes table.
    pub node_id: Option<NodeId>,
}

/// Encode a resource as a table row (the `id` column is assigned by the
/// table on insert, like every other schema).
pub fn resource_to_row(r: &Resource) -> Row {
    let mut row = Row::new();
    row.insert("level".into(), Value::Text(r.level.as_str().into()));
    row.insert(
        "parent".into(),
        r.parent.map(|p| Value::Int(p as i64)).unwrap_or(Value::Null),
    );
    row.insert("name".into(), Value::Text(r.name.clone()));
    row.insert(
        "nodeId".into(),
        r.node_id
            .map(|n| Value::Int(n as i64))
            .unwrap_or(Value::Null),
    );
    row
}

/// Decode a resource row.
pub fn resource_from_row(id: u64, row: &Row) -> crate::Result<Resource> {
    let level = row
        .get("level")
        .and_then(Value::as_str)
        .and_then(Level::parse)
        .ok_or_else(|| anyhow::anyhow!("resources.{id}: bad level"))?;
    Ok(Resource {
        id,
        level,
        parent: row.get("parent").and_then(Value::as_i64).map(|p| p as u64),
        name: row
            .get("name")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        node_id: row
            .get("nodeId")
            .and_then(Value::as_i64)
            .map(|n| n as NodeId),
    })
}

// ============================================================== grammar ====

/// Every way a request string can fail to parse. The parser is *total*:
/// any input yields a [`ResourceRequest`] or one of these — admission
/// and the RPC front-end surface them as `bad_request` with the
/// rendered message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ParseError {
    /// Empty request (or an empty alternative between `|`s).
    Empty,
    /// A `{...}` property filter with no closing brace.
    UnclosedProperties,
    /// The spec must be `/level=count(/level=count)*`.
    MissingSlash(String),
    /// `level` is not one of switch/host/cpu/core (or an alias).
    UnknownLevel(String),
    /// The count is not a positive integer.
    BadCount(String),
    /// The same level given twice in one alternative.
    DuplicateLevel(&'static str),
    /// Levels must go root→leaf (e.g. `/core=2/host=4` is inverted).
    OutOfOrder {
        outer: &'static str,
        inner: &'static str,
    },
    /// Walltime must be `H`, `H:M` or `H:M:S` with numeric parts.
    BadWalltime(String),
    /// An option other than `walltime` after the comma.
    UnknownOption(String),
    /// Folding `cpu=C/core=K` (or the total shape) overflows.
    Overflow,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParseError::Empty => write!(f, "empty resource request"),
            ParseError::UnclosedProperties => {
                write!(f, "unclosed '{{' in property filter")
            }
            ParseError::MissingSlash(s) => {
                write!(f, "expected '/level=count' spec, got {s:?}")
            }
            ParseError::UnknownLevel(s) => write!(
                f,
                "unknown resource level {s:?} (expected switch, host, cpu or core)"
            ),
            ParseError::BadCount(s) => {
                write!(f, "resource count must be a positive integer, got {s:?}")
            }
            ParseError::DuplicateLevel(l) => write!(f, "level {l:?} given twice"),
            ParseError::OutOfOrder { outer, inner } => {
                write!(f, "level {inner:?} cannot nest under {outer:?}")
            }
            ParseError::BadWalltime(s) => {
                write!(f, "walltime must be H:M:S, got {s:?}")
            }
            ParseError::UnknownOption(s) => write!(f, "unknown request option {s:?}"),
            ParseError::Overflow => write!(f, "resource request overflows"),
        }
    }
}

impl std::error::Error for ParseError {}

/// The canonical shape of one alternative: how many subtrees at each
/// level. Levels absent from the spec default to 1, except `switch`,
/// whose absence means "anywhere in the cluster" rather than "within 1
/// switch" (so `/host=4` can span switches, as the flat model always
/// could).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    /// `Some(s)`: s switches, each holding `hosts` feasible hosts.
    /// `None`: no switch locality constraint.
    pub switches: Option<u32>,
    /// Hosts per switch (or cluster-wide when `switches` is `None`).
    pub hosts: u32,
    /// Cores on each host (`cpu=C/core=K` folds to C·K).
    pub cores: u32,
}

impl Shape {
    /// Flat equivalent: number of distinct hosts (`nbNodes`).
    pub fn total_hosts(&self) -> Option<u32> {
        self.switches.unwrap_or(1).checked_mul(self.hosts)
    }

    /// Flat equivalent: procs per host (`weight`).
    pub fn weight(&self) -> u32 {
        self.cores
    }

    /// Total processors the shape occupies.
    pub fn total_procs(&self) -> Option<u32> {
        self.total_hosts()?.checked_mul(self.cores)
    }
}

/// One alternative of a (possibly moldable) request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Alternative {
    /// Property filter scoping this alternative (`{mem > 1024}/...`),
    /// a SQL expression in the same language as fig. 2's `properties`.
    pub properties: Option<String>,
    /// Requested levels with counts, in root→leaf order.
    pub levels: Vec<(Level, u32)>,
    /// Per-alternative walltime in seconds (`,walltime=H:M:S`).
    pub walltime: Option<Time>,
}

impl Alternative {
    /// Canonical shape (validation already guaranteed non-zero counts
    /// and root→leaf order).
    pub fn shape(&self) -> Result<Shape, ParseError> {
        let mut switches = None;
        let mut hosts = 1u32;
        let mut cores = 1u32;
        let mut cpus = 1u32;
        for (level, count) in &self.levels {
            match level {
                Level::Cluster => {}
                Level::Switch => switches = Some(*count),
                Level::Host => hosts = *count,
                Level::Cpu => cpus = *count,
                Level::Core => cores = *count,
            }
        }
        let cores = cpus.checked_mul(cores).ok_or(ParseError::Overflow)?;
        let shape = Shape {
            switches,
            hosts,
            cores,
        };
        shape.total_procs().ok_or(ParseError::Overflow)?;
        Ok(shape)
    }
}

impl fmt::Display for Alternative {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if let Some(p) = &self.properties {
            write!(f, "{{{p}}}")?;
        }
        for (level, count) in &self.levels {
            write!(f, "/{}={}", level.as_str(), count)?;
        }
        if let Some(w) = self.walltime {
            write!(f, ",walltime={}:{}:{}", w / 3600, (w % 3600) / 60, w % 60)?;
        }
        Ok(())
    }
}

/// A parsed request: one or more moldable alternatives. The scheduler
/// picks whichever alternative can start earliest (ties go to the first
/// one listed).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResourceRequest {
    pub alternatives: Vec<Alternative>,
}

impl ResourceRequest {
    /// The walltime the request implies: the longest any alternative
    /// asks for (conservative — the Gantt reservation covers whichever
    /// alternative is picked).
    pub fn walltime(&self) -> Option<Time> {
        self.alternatives.iter().filter_map(|a| a.walltime).max()
    }
}

impl fmt::Display for ResourceRequest {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, alt) in self.alternatives.iter().enumerate() {
            if i > 0 {
                write!(f, " | ")?;
            }
            write!(f, "{alt}")?;
        }
        Ok(())
    }
}

/// Split on a separator, but only outside `{...}` property filters.
fn split_outside_braces(s: &str, sep: char) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '{' => depth += 1,
            '}' => depth = depth.saturating_sub(1),
            c if c == sep && depth == 0 => {
                out.push(&s[start..i]);
                start = i + c.len_utf8();
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

/// Parse a full request: alternatives joined by `|` (how repeated `-l`
/// flags travel on the wire). Total: every input returns `Ok` or a
/// typed error.
pub fn parse_request(input: &str) -> Result<ResourceRequest, ParseError> {
    let input = input.trim();
    if input.is_empty() {
        return Err(ParseError::Empty);
    }
    let mut alternatives = Vec::new();
    for part in split_outside_braces(input, '|') {
        alternatives.push(parse_alternative(part.trim())?);
    }
    Ok(ResourceRequest { alternatives })
}

fn parse_alternative(s: &str) -> Result<Alternative, ParseError> {
    if s.is_empty() {
        return Err(ParseError::Empty);
    }
    // Optional `{properties}` prefix.
    let (properties, rest) = if let Some(inner) = s.strip_prefix('{') {
        let close = inner.find('}').ok_or(ParseError::UnclosedProperties)?;
        let props = inner[..close].trim();
        (
            (!props.is_empty()).then(|| props.to_string()),
            inner[close + 1..].trim_start(),
        )
    } else {
        (None, s)
    };
    // `,`-separated options after the level spec; only walltime exists.
    let mut pieces = split_outside_braces(rest, ',').into_iter();
    let spec = pieces.next().unwrap_or("").trim();
    let mut walltime = None;
    for opt in pieces {
        let opt = opt.trim();
        match opt.split_once('=') {
            Some((k, v)) if k.trim() == "walltime" => {
                walltime = Some(parse_walltime(v.trim())?);
            }
            _ => return Err(ParseError::UnknownOption(opt.to_string())),
        }
    }
    // The level spec proper: `/level=count` one or more times.
    if !spec.starts_with('/') {
        return Err(ParseError::MissingSlash(spec.to_string()));
    }
    let mut levels: Vec<(Level, u32)> = Vec::new();
    for seg in spec[1..].split('/') {
        let seg = seg.trim();
        let (name, count) = seg
            .split_once('=')
            .ok_or_else(|| ParseError::MissingSlash(seg.to_string()))?;
        let level =
            Level::parse(name.trim()).ok_or_else(|| ParseError::UnknownLevel(name.to_string()))?;
        if level == Level::Cluster {
            // The cluster root is implicit; requesting it is a grammar
            // error, same as any unknown level name.
            return Err(ParseError::UnknownLevel(name.to_string()));
        }
        let count: u32 = count
            .trim()
            .parse()
            .map_err(|_| ParseError::BadCount(count.to_string()))?;
        if count == 0 {
            return Err(ParseError::BadCount(count.to_string()));
        }
        if let Some((prev, _)) = levels.last() {
            if prev.depth() >= level.depth() {
                if *prev == level {
                    return Err(ParseError::DuplicateLevel(level.as_str()));
                }
                return Err(ParseError::OutOfOrder {
                    outer: level.as_str(),
                    inner: prev.as_str(),
                });
            }
        }
        levels.push((level, count));
    }
    let alt = Alternative {
        properties,
        levels,
        walltime,
    };
    // Reject shapes whose core/proc totals overflow right here, so a
    // parsed request always has a computable flat equivalent.
    alt.shape()?;
    Ok(alt)
}

/// `H`, `H:M` or `H:M:S` → seconds.
fn parse_walltime(s: &str) -> Result<Time, ParseError> {
    let bad = || ParseError::BadWalltime(s.to_string());
    let parts: Vec<&str> = s.split(':').collect();
    if parts.is_empty() || parts.len() > 3 {
        return Err(bad());
    }
    let mut nums = Vec::new();
    for p in &parts {
        let n: u32 = p.trim().parse().map_err(|_| bad())?;
        nums.push(n as i64);
    }
    Ok(match nums.as_slice() {
        [h] => h * 3600,
        [h, m] => h * 3600 + m * 60,
        [h, m, s] => h * 3600 + m * 60 + s,
        _ => return Err(bad()),
    })
}

// ============================================================ hierarchy ====

/// A host slot of the placement tree: the derived node plus its core
/// capacity.
#[derive(Debug, Clone, PartialEq)]
pub struct TreeHost {
    pub node: NodeId,
    pub procs: u32,
}

/// One switch subtree.
#[derive(Debug, Clone, PartialEq)]
pub struct TreeSwitch {
    pub name: String,
    pub hosts: Vec<TreeHost>,
}

/// The placement view of the resource tree: switches → hosts → core
/// counts. Built from the `resources` table when populated, or derived
/// from the nodes' `switch` property for databases registered before
/// the table existed (every pre-existing test fixture).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Hierarchy {
    pub switches: Vec<TreeSwitch>,
}

impl Hierarchy {
    /// Build from `resources` rows. Host capacity comes from the core
    /// rows beneath each host (via its cpus), falling back to the
    /// derived node's `nbProcs` when the tree stops at host level.
    pub fn from_resources(resources: &[Resource], nodes: &[Node]) -> Hierarchy {
        let procs_of: BTreeMap<NodeId, u32> = nodes.iter().map(|n| (n.id, n.nb_procs)).collect();
        // children[parent] = child ids, one pass.
        let mut children: BTreeMap<u64, Vec<&Resource>> = BTreeMap::new();
        for r in resources {
            if let Some(p) = r.parent {
                children.entry(p).or_default().push(r);
            }
        }
        let mut switches = Vec::new();
        let mut sw_rows: Vec<&Resource> = resources
            .iter()
            .filter(|r| r.level == Level::Switch)
            .collect();
        sw_rows.sort_by_key(|r| r.id);
        for sw in sw_rows {
            let mut hosts = Vec::new();
            for host in children.get(&sw.id).into_iter().flatten() {
                if host.level != Level::Host {
                    continue;
                }
                let Some(node) = host.node_id else { continue };
                // Count core leaves under the host (cpu rows in
                // between), else trust the derived node row.
                let mut cores = 0u32;
                for cpu in children.get(&host.id).into_iter().flatten() {
                    match cpu.level {
                        Level::Core => cores += 1,
                        Level::Cpu => {
                            cores += children
                                .get(&cpu.id)
                                .map(|cs| {
                                    cs.iter().filter(|c| c.level == Level::Core).count() as u32
                                })
                                .unwrap_or(0)
                        }
                        _ => {}
                    }
                }
                let procs = if cores > 0 {
                    cores
                } else {
                    procs_of.get(&node).copied().unwrap_or(1)
                };
                hosts.push(TreeHost { node, procs });
            }
            hosts.sort_by_key(|h| h.node);
            switches.push(TreeSwitch {
                name: sw.name.clone(),
                hosts,
            });
        }
        Hierarchy { switches }
    }

    /// Derive from plain nodes: group by the `switch` text property
    /// (one synthetic switch when absent).
    pub fn from_nodes(nodes: &[Node]) -> Hierarchy {
        let mut by_switch: BTreeMap<String, Vec<TreeHost>> = BTreeMap::new();
        for n in nodes {
            let sw = n
                .properties
                .get("switch")
                .and_then(Value::as_str)
                .unwrap_or("sw0")
                .to_string();
            by_switch.entry(sw).or_default().push(TreeHost {
                node: n.id,
                procs: n.nb_procs,
            });
        }
        let switches = by_switch
            .into_iter()
            .map(|(name, mut hosts)| {
                hosts.sort_by_key(|h| h.node);
                TreeSwitch { name, hosts }
            })
            .collect();
        Hierarchy { switches }
    }

    pub fn host_count(&self) -> usize {
        self.switches.iter().map(|s| s.hosts.len()).sum()
    }

    pub fn core_count(&self) -> u64 {
        self.switches
            .iter()
            .flat_map(|s| &s.hosts)
            .map(|h| h.procs as u64)
            .sum()
    }
}

// ============================================================== matcher ====

/// Inclusive time intervals during which at least `need` of the given
/// ranges are simultaneously open — the per-level counting primitive.
/// Each member's ranges must be pairwise disjoint (true of
/// [`crate::sched::Gantt::feasible_starts`] output), so counting open
/// ranges equals counting feasible members.
pub fn coverage_intervals(ranges: &[(Time, Time)], need: usize) -> Vec<(Time, Time)> {
    if need == 0 {
        return vec![(0, Time::MAX / 4)];
    }
    let mut events: Vec<(Time, i32)> = Vec::with_capacity(ranges.len() * 2);
    for (lo, hi) in ranges {
        if lo > hi {
            continue;
        }
        events.push((*lo, 1));
        events.push((hi.saturating_add(1), -1));
    }
    events.sort_unstable();
    let mut out = Vec::new();
    let mut count = 0i32;
    let mut open_at: Option<Time> = None;
    for (t, delta) in events {
        count += delta;
        if count >= need as i32 {
            if open_at.is_none() {
                open_at = Some(t);
            }
        } else if let Some(lo) = open_at.take() {
            if lo <= t - 1 {
                out.push((lo, t - 1));
            }
        }
    }
    out
}

/// Earliest placement of `shape` in the tree: the start instant and the
/// chosen hosts (each to be occupied with `shape.cores` procs).
///
/// `feasible(node, procs)` returns the inclusive ranges of start times
/// at which `node` can hold `procs` procs for the job's duration — the
/// per-node timeline scan the flat Gantt already does. The tree search
/// stacks two counting passes on top: host ranges → per-switch
/// intervals (≥ N hosts open) → cross-switch coverage (≥ S switches
/// open).
pub fn find_earliest_tree<F>(
    tree: &Hierarchy,
    eligible: &[NodeId],
    shape: &Shape,
    feasible: F,
) -> Option<(Time, Vec<NodeId>)>
where
    F: Fn(NodeId, u32) -> Vec<(Time, Time)>,
{
    let elig: std::collections::BTreeSet<NodeId> = eligible.iter().copied().collect();
    let weight = shape.cores;
    // Per-switch: each eligible host's feasible ranges.
    let mut per_switch: Vec<Vec<(NodeId, Vec<(Time, Time)>)>> = Vec::new();
    for sw in &tree.switches {
        let mut hosts = Vec::new();
        for h in &sw.hosts {
            if h.procs < weight || !elig.contains(&h.node) {
                continue;
            }
            let ranges = feasible(h.node, weight);
            if !ranges.is_empty() {
                hosts.push((h.node, ranges));
            }
        }
        per_switch.push(hosts);
    }

    let start = match shape.switches {
        None => {
            // No locality constraint: pool every host, count cluster-wide.
            let total_hosts = shape.total_hosts()? as usize;
            let pooled: Vec<(Time, Time)> = per_switch
                .iter()
                .flatten()
                .flat_map(|(_, rs)| rs.iter().copied())
                .collect();
            coverage_intervals(&pooled, total_hosts).first()?.0
        }
        Some(s) => {
            // Per-switch intervals where >= hosts are open, then count
            // switches the same way.
            let mut switch_ranges = Vec::new();
            for hosts in &per_switch {
                let flat: Vec<(Time, Time)> = hosts
                    .iter()
                    .flat_map(|(_, rs)| rs.iter().copied())
                    .collect();
                switch_ranges.extend(coverage_intervals(&flat, shape.hosts as usize));
            }
            coverage_intervals(&switch_ranges, s as usize).first()?.0
        }
    };

    // Materialize: pick hosts whose ranges cover `start`, respecting
    // the per-switch quota when switch locality was requested.
    let covers =
        |ranges: &[(Time, Time)]| ranges.iter().any(|(lo, hi)| *lo <= start && start <= *hi);
    let mut chosen = Vec::new();
    match shape.switches {
        None => {
            let need = shape.total_hosts()? as usize;
            for (node, ranges) in per_switch.iter().flatten() {
                if chosen.len() == need {
                    break;
                }
                if covers(ranges) {
                    chosen.push(*node);
                }
            }
            if chosen.len() < need {
                return None;
            }
        }
        Some(s) => {
            let mut switches_done = 0u32;
            for hosts in &per_switch {
                if switches_done == s {
                    break;
                }
                let open: Vec<NodeId> = hosts
                    .iter()
                    .filter(|(_, rs)| covers(rs))
                    .map(|(n, _)| *n)
                    .collect();
                if open.len() >= shape.hosts as usize {
                    chosen.extend(open.into_iter().take(shape.hosts as usize));
                    switches_done += 1;
                }
            }
            if switches_done < s {
                return None;
            }
        }
    }
    Some((start, chosen))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(s: &str) -> ResourceRequest {
        parse_request(s).unwrap_or_else(|e| panic!("{s:?}: {e}"))
    }

    #[test]
    fn parses_the_reframe_corpus_shape() {
        let r = req("/host=2/core=4,walltime=0:30:0");
        assert_eq!(r.alternatives.len(), 1);
        let shape = r.alternatives[0].shape().unwrap();
        assert_eq!(shape.switches, None);
        assert_eq!(shape.hosts, 2);
        assert_eq!(shape.cores, 4);
        assert_eq!(r.walltime(), Some(1800));
    }

    #[test]
    fn switch_and_cpu_levels_fold() {
        let r = req("/switch=2/host=3/cpu=2/core=4");
        let shape = r.alternatives[0].shape().unwrap();
        assert_eq!(shape.switches, Some(2));
        assert_eq!(shape.hosts, 3);
        assert_eq!(shape.cores, 8, "cpu=2/core=4 folds to 8 per host");
        assert_eq!(shape.total_hosts(), Some(6));
        assert_eq!(shape.total_procs(), Some(48));
    }

    #[test]
    fn property_filters_and_alternatives() {
        let r = req("{mem > 1024}/host=4/core=2 | /host=2/core=4,walltime=1:0:0");
        assert_eq!(r.alternatives.len(), 2);
        assert_eq!(r.alternatives[0].properties.as_deref(), Some("mem > 1024"));
        assert_eq!(r.alternatives[1].properties, None);
        assert_eq!(r.walltime(), Some(3600));
    }

    #[test]
    fn print_parse_roundtrip_is_identity() {
        for s in [
            "/host=2/core=4,walltime=0:30:0",
            "{mem > 1024}/switch=2/host=3/cpu=2/core=4",
            "/host=4/core=2 | /host=2/core=4",
            "/switch=1/host=16,walltime=12:0:0",
        ] {
            let printed = req(s).to_string();
            assert_eq!(req(&printed).to_string(), printed, "roundtrip of {s:?}");
        }
    }

    #[test]
    fn typed_errors_for_every_failure_mode() {
        use ParseError as E;
        assert_eq!(parse_request(""), Err(E::Empty));
        assert_eq!(parse_request("/host=2 |"), Err(E::Empty));
        assert!(matches!(parse_request("{mem > 1"), Err(E::UnclosedProperties)));
        assert!(matches!(parse_request("host=2"), Err(E::MissingSlash(_))));
        assert!(matches!(parse_request("/rack=2"), Err(E::UnknownLevel(_))));
        assert!(matches!(parse_request("/cluster=1"), Err(E::UnknownLevel(_))));
        assert!(matches!(parse_request("/host=zero"), Err(E::BadCount(_))));
        assert!(matches!(parse_request("/host=0"), Err(E::BadCount(_))));
        assert!(matches!(
            parse_request("/host=2/host=3"),
            Err(E::DuplicateLevel("host"))
        ));
        assert!(matches!(
            parse_request("/core=2/host=4"),
            Err(E::OutOfOrder { .. })
        ));
        assert!(matches!(
            parse_request("/host=2,walltime=abc"),
            Err(E::BadWalltime(_))
        ));
        assert!(matches!(
            parse_request("/host=2,fancy=1"),
            Err(E::UnknownOption(_))
        ));
        assert!(matches!(
            parse_request("/host=100000/core=100000"),
            Err(E::Overflow)
        ));
    }

    #[test]
    fn coverage_counts_members_not_ranges() {
        // Two hosts free over [0,10] and [5,20]: both open only on [5,10].
        let ranges = [(0, 10), (5, 20)];
        assert_eq!(coverage_intervals(&ranges, 2), vec![(5, 10)]);
        assert_eq!(coverage_intervals(&ranges, 1), vec![(0, 20)]);
        assert_eq!(coverage_intervals(&ranges, 3), vec![]);
    }

    fn two_switch_tree() -> Hierarchy {
        Hierarchy {
            switches: vec![
                TreeSwitch {
                    name: "sw1".into(),
                    hosts: vec![
                        TreeHost { node: 1, procs: 4 },
                        TreeHost { node: 2, procs: 4 },
                    ],
                },
                TreeSwitch {
                    name: "sw2".into(),
                    hosts: vec![
                        TreeHost { node: 3, procs: 4 },
                        TreeHost { node: 4, procs: 4 },
                    ],
                },
            ],
        }
    }

    #[test]
    fn tree_matcher_respects_switch_locality() {
        let tree = two_switch_tree();
        let elig = vec![1, 2, 3, 4];
        // Node 2 busy until t=100: /switch=1/host=2 must wait for sw1 or
        // use sw2 immediately — sw2 is free now.
        let feasible = |node: NodeId, _w: u32| -> Vec<(Time, Time)> {
            if node == 2 {
                vec![(100, Time::MAX / 4)]
            } else {
                vec![(0, Time::MAX / 4)]
            }
        };
        let shape = Shape {
            switches: Some(1),
            hosts: 2,
            cores: 2,
        };
        let (t, nodes) = find_earliest_tree(&tree, &elig, &shape, feasible).unwrap();
        assert_eq!(t, 0);
        assert_eq!(nodes, vec![3, 4], "whole sw2 is free now");
        // Both switches: must wait for node 2.
        let shape = Shape {
            switches: Some(2),
            hosts: 2,
            cores: 2,
        };
        let (t, nodes) = find_earliest_tree(&tree, &elig, &shape, feasible).unwrap();
        assert_eq!(t, 100);
        assert_eq!(nodes.len(), 4);
    }

    #[test]
    fn tree_matcher_pools_without_switch_constraint(){
        let tree = two_switch_tree();
        let shape = Shape {
            switches: None,
            hosts: 3,
            cores: 4,
        };
        let feasible = |_n: NodeId, _w: u32| vec![(7, Time::MAX / 4)];
        let (t, nodes) =
            find_earliest_tree(&tree, &[1, 2, 3, 4], &shape, feasible).unwrap();
        assert_eq!(t, 7);
        assert_eq!(nodes.len(), 3, "3 hosts drawn across switches");
        // Capacity gate: cores > host procs is never feasible.
        let shape = Shape {
            switches: None,
            hosts: 1,
            cores: 8,
        };
        assert!(find_earliest_tree(&tree, &[1, 2, 3, 4], &shape, feasible).is_none());
    }

    #[test]
    fn hierarchy_from_nodes_groups_by_switch_property() {
        let nodes = vec![
            Node::new(1, "a", 2).with_prop("switch", Value::Text("s1".into())),
            Node::new(2, "b", 2).with_prop("switch", Value::Text("s2".into())),
            Node::new(3, "c", 2).with_prop("switch", Value::Text("s1".into())),
            Node::new(4, "d", 8),
        ];
        let h = Hierarchy::from_nodes(&nodes);
        assert_eq!(h.switches.len(), 3, "s1, s2 and the sw0 fallback");
        assert_eq!(h.host_count(), 4);
        assert_eq!(h.core_count(), 14);
    }

    #[test]
    fn resource_row_roundtrip() {
        let r = Resource {
            id: 7,
            level: Level::Host,
            parent: Some(2),
            name: "node-3".into(),
            node_id: Some(3),
        };
        let back = resource_from_row(7, &resource_to_row(&r)).unwrap();
        assert_eq!(back, r);
        let root = Resource {
            id: 1,
            level: Level::Cluster,
            parent: None,
            name: "cluster".into(),
            node_id: None,
        };
        let back = resource_from_row(1, &resource_to_row(&root)).unwrap();
        assert_eq!(back, root);
    }
}
