//! The command-line interface of the `oar` binary: evaluation harnesses
//! (one subcommand per paper table/figure) and a live demo.
//!
//! Argument parsing is hand-rolled (the build is offline / zero-dep);
//! flags are `--key value`.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::bench::{burst, complexity, esp, features, report};
use crate::Result;

/// Parsed `--key value` flags + positional args. The one short flag is
/// `-l <spec>` (oarsub's resource request), which *accumulates*: each
/// occurrence is a moldable alternative.
#[derive(Debug, Default)]
pub struct Flags {
    pub values: BTreeMap<String, String>,
    pub positional: Vec<String>,
    /// Repeated `-l <spec>` hierarchical resource requests, in order.
    pub resource_specs: Vec<String>,
}

impl Flags {
    pub fn parse(args: &[String]) -> Flags {
        let mut flags = Flags::default();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if a == "-l" {
                // A trailing `-l` with no spec becomes an empty request,
                // which the server rejects with a typed bad_request —
                // never a silently different job.
                flags
                    .resource_specs
                    .push(it.next().cloned().unwrap_or_default());
            } else if let Some(key) = a.strip_prefix("--") {
                let value = it
                    .peek()
                    .filter(|v| !v.starts_with("--"))
                    .map(|v| v.to_string());
                if let Some(v) = value {
                    it.next();
                    flags.values.insert(key.to_string(), v);
                } else {
                    flags.values.insert(key.to_string(), "true".into());
                }
            } else {
                flags.positional.push(a.clone());
            }
        }
        flags
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.values
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.values
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_list(&self, key: &str, default: &[u64]) -> Vec<u64> {
        self.values
            .get(key)
            .map(|v| v.split(',').filter_map(|x| x.trim().parse().ok()).collect())
            .unwrap_or_else(|| default.to_vec())
    }

    pub fn has(&self, key: &str) -> bool {
        self.values.contains_key(key)
    }
}

pub const USAGE: &str = "\
oar — reproduction of 'A batch scheduler with high level components' (2005)

USAGE: oar <command> [flags]

Evaluation commands (one per paper artifact):
  esp         Table 3 + figs 4-8: ESP2 throughput benchmark
                [--procs 34] [--overhead 0] [--figures] [--csv results/]
  burst       Fig 9: response time vs simultaneous submissions (Xeon)
                [--bursts 10,30,70,150,300,600,1000] [--scale 0.001] [--csv results/]
  parallel    Fig 10: response time vs nbNodes (Icluster, 4 launcher settings)
                [--sizes 1,2,4,8,16,32,64,119] [--scale 0.001] [--csv results/]
  complexity  Table 1: software complexity (files/lines), paper vs this repo
  features    Table 2: functionality matrix, verified end-to-end

System commands:
  demo        Run a live server on the virtual Xeon cluster: submissions,
              reservations, best-effort, failure injection [--scale 0.01]
              [--data-dir DIR] [--policy fail|requeue] (durable: WAL +
              snapshots under DIR; re-run to exercise recovery)
  recover     Recover a durable server from --data-dir DIR, print the
              recovery/reconciliation report, drain the remaining workload
              [--policy fail|requeue] [--scale 0.01]
  snapshot    Run a short demo and write a database snapshot [--out PATH]
  serve       Run the server behind the network RPC front-end
              [--addr 127.0.0.1:6010] [--workers 16] [--queue-depth 64]
              [--scale 0.01] [--nodes N] [--procs P] [--data-dir DIR]
              [--policy fail|requeue]; Ctrl-C/SIGTERM drains in-flight
              requests and checkpoints before exit

Client commands (speak the socket protocol of docs/PROTOCOL.md; all take
[--addr HOST:PORT], default 127.0.0.1:6010):
  sub         oarsub: submit a job  --command 'sleep 60' [--user U]
              [--nodes N] [--weight W] [--maxtime SECS] [--queue Q]
              [--properties EXPR] [--reservation T] [--dir D]
              [--besteffort] [--interactive] [--array N]
              [-l /switch=S/host=N/core=M,walltime=H:M:S]... (hierarchical
              resource request; repeat -l for moldable alternatives, the
              scheduler starts the first feasible shape)
  stat        oarstat: list jobs [--filter \"state = 'Running'\"]
  del         oardel: cancel a job   oar del <jobId>
  hold        oarhold: suspend a Waiting job   oar hold <jobId>
  resume      oarresume: release a held job    oar resume <jobId>
  nodes       oarnodes: fleet state
  queues      queue table (priority, policy, limits, active)
  metrics     Prometheus-style text dump of the server's metrics registry
              (counters, gauges, latency histograms; docs/OBSERVABILITY.md)
              [--watch] [--every SECS] re-renders until interrupted
  top         one-screen dashboard: occupancy + queue depths + scheduler
              round / lock-wait / WAL / RPC latency percentiles
              [--watch] [--every SECS]
  events      tail the server's event log  [--tail N] [--kind KIND]
              [--job ID]

Grid federation (a CiGri-style meta-scheduler farming bag-of-tasks
campaigns across clusters as best-effort jobs):
  grid sub      submit + drain a campaign  --clusters H:P,H:P,...
                --command 'sim {i}' [--tasks 100] [--cap 32] [--user U]
                [--nodes N] [--weight W] [--maxtime SECS] [--name S]
                [--data-dir DIR] [--retries 5] [--round-ms 200]
                [--stale SECS] [--timeout SECS] ({i} = task index;
                --data-dir persists campaigns so an interrupted run
                resumes; --stale cancels+retries placements that never
                start)
  grid stat     inspect persisted campaigns  --data-dir DIR
  grid clusters probe each cluster's load    --clusters H:P,H:P,...

All evaluation outputs are printed as tables/ASCII figures; --csv writes
machine-readable series next to them.
";

/// Entry point used by `main.rs`.
pub fn run(args: Vec<String>) -> Result<i32> {
    let Some(cmd) = args.first().cloned() else {
        println!("{USAGE}");
        return Ok(2);
    };
    let flags = Flags::parse(&args[1..]);
    match cmd.as_str() {
        "esp" => cmd_esp(&flags),
        "burst" => cmd_burst(&flags),
        "parallel" => cmd_parallel(&flags),
        "complexity" => cmd_complexity(),
        "features" => cmd_features(),
        "demo" => crate::cli::demo::run_demo(
            flags.get_f64("scale", 0.01),
            flags.values.get("data-dir").map(PathBuf::from),
            parse_policy(&flags)?,
        ),
        "recover" => {
            let dir = flags
                .values
                .get("data-dir")
                .map(PathBuf::from)
                .ok_or_else(|| anyhow::anyhow!("recover requires --data-dir DIR"))?;
            crate::cli::demo::run_recover(dir, parse_policy(&flags)?, flags.get_f64("scale", 0.01))
        }
        "serve" => net::run_serve(&flags, parse_policy(&flags)?),
        "sub" => net::run_sub(&flags),
        "stat" => net::run_stat(&flags),
        "del" => net::run_del(&flags),
        "hold" => net::run_hold(&flags),
        "resume" => net::run_resume(&flags),
        "nodes" => net::run_nodes(&flags),
        "queues" => net::run_queues(&flags),
        "metrics" => net::run_metrics(&flags),
        "top" => net::run_top(&flags),
        "events" => net::run_events(&flags),
        "grid" => grid::run_grid(&flags),
        "snapshot" => crate::cli::demo::run_snapshot(
            flags
                .values
                .get("out")
                .map(PathBuf::from)
                .unwrap_or_else(|| PathBuf::from("results/demo_snapshot.json")),
        ),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            Ok(2)
        }
    }
}

fn parse_policy(flags: &Flags) -> Result<crate::types::RecoveryPolicy> {
    match flags.values.get("policy") {
        None => Ok(crate::types::RecoveryPolicy::default()),
        Some(s) => crate::types::RecoveryPolicy::parse(s)
            .ok_or_else(|| anyhow::anyhow!("--policy must be 'fail' or 'requeue', got {s:?}")),
    }
}

fn cmd_esp(flags: &Flags) -> Result<i32> {
    let procs = flags.get_u64("procs", esp::XEON_PROCS as u64) as u32;
    let overhead = flags.get_u64("overhead", 0) as i64;
    println!("ESP2 throughput benchmark: {procs} processors, 230 jobs, all submitted at t=0\n");
    let rows = esp::run_esp(procs, overhead);

    let mut table_rows = Vec::new();
    for row in &rows {
        let paper = esp::PAPER_TABLE3
            .iter()
            .find(|(n, _, _)| *n == row.system);
        table_rows.push(vec![
            row.system.to_string(),
            format!("{}", row.elapsed),
            format!("{:.4}", row.efficiency),
            paper.map(|(_, e, _)| e.to_string()).unwrap_or_default(),
            paper
                .map(|(_, _, eff)| format!("{eff:.4}"))
                .unwrap_or_default(),
            format!("{}", row.max_wait),
        ]);
    }
    println!(
        "{}",
        report::table(
            &[
                "system",
                "elapsed(s)",
                "efficiency",
                "paper elapsed",
                "paper eff.",
                "max wait(s)"
            ],
            &table_rows
        )
    );
    println!("(absolute numbers differ from the paper's testbed; the comparison");
    println!(" under test is the ordering and the OAR->OAR(2) recovery, §3.2.1)\n");

    if flags.has("figures") {
        for row in &rows {
            println!("── fig: ESP2 on {} ──", row.system);
            println!("{}", report::utilization_ascii(&row.result, 100, 16));
        }
    }
    if flags.has("csv") {
        let dir = PathBuf::from(flags.values.get("csv").cloned().unwrap_or_default());
        report::write_csv(
            &dir.join("table3.csv"),
            &["system", "elapsed_s", "efficiency", "max_wait_s"],
            &rows
                .iter()
                .map(|r| {
                    vec![
                        r.system.to_string(),
                        r.elapsed.to_string(),
                        format!("{:.4}", r.efficiency),
                        r.max_wait.to_string(),
                    ]
                })
                .collect::<Vec<_>>(),
        )?;
        for row in &rows {
            let name = row.system.replace(['+', '(', ')'], "_").to_lowercase();
            report::write_csv(
                &dir.join(format!("fig_esp_{name}.csv")),
                &["time_s", "busy_procs"],
                &row.result
                    .utilization
                    .iter()
                    .map(|(t, b)| vec![t.to_string(), b.to_string()])
                    .collect::<Vec<_>>(),
            )?;
        }
        println!("CSV written under {}", dir.display());
    }
    Ok(0)
}

fn cmd_burst(flags: &Flags) -> Result<i32> {
    let bursts: Vec<usize> = flags
        .get_list("bursts", &[10, 30, 70, 150, 300, 600, 1000])
        .into_iter()
        .map(|b| b as usize)
        .collect();
    let scale = flags.get_f64("scale", 0.001);
    println!("Submission burst (fig 9): Xeon platform, 17 nodes, `date` jobs, scale={scale}\n");
    let points = burst::fig9_sweep(&bursts, scale)?;
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.burst.to_string(),
                format!("{:.1}", p.response_ms.mean),
                format!("{:.1}", p.response_ms.p95),
                format!("{:.1}", p.response_ms.max),
                p.errors.to_string(),
                p.drain_ms.to_string(),
                p.queries.to_string(),
                format!("{:.1}", p.queries as f64 / p.burst as f64),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(
            &[
                "burst",
                "mean resp(ms)",
                "p95(ms)",
                "max(ms)",
                "errors",
                "drain(ms)",
                "queries",
                "queries/job"
            ],
            &rows
        )
    );
    let series: Vec<(f64, f64)> = points
        .iter()
        .map(|p| (p.burst as f64, p.response_ms.mean))
        .collect();
    println!("{}", report::xy_ascii(&[("OAR mean response (ms)", &series)], 80, 14));
    println!("paper's claim under test: stability up to 1000 simultaneous submissions");
    println!("(Torque/Maui destabilize past ~70 on the paper's testbed; our in-repo");
    println!(" baselines share OAR's substrate, so only OAR's own stability is testable)\n");

    if flags.has("csv") {
        let dir = PathBuf::from(flags.values.get("csv").cloned().unwrap_or_default());
        report::write_csv(
            &dir.join("fig9_burst.csv"),
            &["burst", "mean_ms", "p95_ms", "max_ms", "errors", "queries"],
            &points
                .iter()
                .map(|p| {
                    vec![
                        p.burst.to_string(),
                        format!("{:.2}", p.response_ms.mean),
                        format!("{:.2}", p.response_ms.p95),
                        format!("{:.2}", p.response_ms.max),
                        p.errors.to_string(),
                        p.queries.to_string(),
                    ]
                })
                .collect::<Vec<_>>(),
        )?;
        println!("CSV written under {}", dir.display());
    }
    Ok(0)
}

fn cmd_parallel(flags: &Flags) -> Result<i32> {
    let sizes: Vec<u32> = flags
        .get_list("sizes", &[1, 2, 4, 8, 16, 32, 64, 119])
        .into_iter()
        .map(|s| s as u32)
        .collect();
    let scale = flags.get_f64("scale", 0.001);
    println!("Parallel response (fig 10): Icluster platform, 119 nodes, scale={scale}\n");
    let series = burst::fig10_sweep(&sizes, scale)?;
    let mut rows = Vec::new();
    for s in &series {
        for (size, ms) in &s.points {
            rows.push(vec![s.setting.clone(), size.to_string(), format!("{ms:.1}")]);
        }
    }
    println!(
        "{}",
        report::table(&["setting", "nbNodes", "modeled response(ms)"], &rows)
    );
    let plot_series: Vec<(&str, Vec<(f64, f64)>)> = series
        .iter()
        .map(|s| {
            (
                s.setting.as_str(),
                s.points
                    .iter()
                    .map(|(n, v)| (*n as f64, *v))
                    .collect::<Vec<_>>(),
            )
        })
        .collect();
    let refs: Vec<(&str, &[(f64, f64)])> = plot_series
        .iter()
        .map(|(n, v)| (*n, v.as_slice()))
        .collect();
    println!("{}", report::xy_ascii(&refs, 80, 14));

    if flags.has("csv") {
        let dir = PathBuf::from(flags.values.get("csv").cloned().unwrap_or_default());
        let mut csv_rows = Vec::new();
        for s in &series {
            for (size, ms) in &s.points {
                csv_rows.push(vec![s.setting.clone(), size.to_string(), format!("{ms:.2}")]);
            }
        }
        report::write_csv(
            &dir.join("fig10_parallel.csv"),
            &["setting", "nb_nodes", "modeled_response_ms"],
            &csv_rows,
        )?;
        println!("CSV written under {}", dir.display());
    }
    Ok(0)
}

fn cmd_complexity() -> Result<i32> {
    println!("Software complexity (Table 1)\n");
    println!("Paper's measurements:");
    println!(
        "{}",
        report::table(
            &["system", "language", "source files", "source lines"],
            &complexity::PAPER_TABLE1
                .iter()
                .map(|(a, b, c, d)| vec![
                    a.to_string(),
                    b.to_string(),
                    c.to_string(),
                    d.to_string()
                ])
                .collect::<Vec<_>>()
        )
    );
    let repo = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    println!("This repository, measured the same way (operational files only):");
    let rows = complexity::measure_repo(&repo);
    println!(
        "{}",
        report::table(
            &["component", "files", "lines", "code lines"],
            &rows
                .iter()
                .map(|l| vec![
                    l.name.clone(),
                    l.files.to_string(),
                    l.lines.to_string(),
                    l.code_lines.to_string()
                ])
                .collect::<Vec<_>>()
        )
    );
    Ok(0)
}

fn cmd_features() -> Result<i32> {
    println!("Functionality matrix (Table 2) — each row verified end-to-end:\n");
    let rows = features::verify_features();
    let mark = |b: bool| if b { "x" } else { "" }.to_string();
    println!(
        "{}",
        report::table(
            &["feature", "OpenPBS", "SGE", "Maui", "OAR(paper)", "OAR(this repo)", "note"],
            &rows
                .iter()
                .map(|r| vec![
                    r.feature.to_string(),
                    mark(r.paper.0),
                    mark(r.paper.1),
                    mark(r.paper.2),
                    mark(r.paper.3),
                    mark(r.demonstrated),
                    r.note.clone(),
                ])
                .collect::<Vec<_>>()
        )
    );
    let all_match = rows.iter().all(|r| r.demonstrated == r.paper.3);
    println!(
        "{}",
        if all_match {
            "all paper-supported features demonstrated ✓"
        } else {
            "MISMATCH against the paper's matrix ✗"
        }
    );
    Ok(if all_match { 0 } else { 1 })
}

pub mod demo;
pub mod grid;
pub mod net;

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn repeated_dash_l_accumulates_alternatives() {
        let f = Flags::parse(&args(&[
            "--command",
            "sleep 1",
            "-l",
            "/host=4/core=2",
            "-l",
            "/host=2/core=4,walltime=0:30:0",
        ]));
        assert_eq!(f.values.get("command").map(String::as_str), Some("sleep 1"));
        assert_eq!(
            f.resource_specs,
            vec!["/host=4/core=2", "/host=2/core=4,walltime=0:30:0"]
        );
        assert!(f.positional.is_empty());
    }

    #[test]
    fn trailing_dash_l_yields_an_empty_spec_not_a_silent_drop() {
        let f = Flags::parse(&args(&["-l"]));
        assert_eq!(f.resource_specs, vec![String::new()]);
    }
}
