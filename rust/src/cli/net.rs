//! The network-facing subcommands: `oar serve` runs the system behind
//! the RPC front-end; `oar sub|stat|del|nodes|queues` are the paper's
//! user commands (`oarsub`, `oarstat`, `oardel`, `oarnodes`) as separate
//! client programs speaking the socket protocol (`docs/PROTOCOL.md`).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::bench::report;
use crate::cli::Flags;
use crate::cluster::VirtualCluster;
use crate::rpc::{signal, RpcClient, RpcConfig, RpcError, RpcServer, DEFAULT_ADDR};
use crate::server::{Server, ServerConfig};
use crate::types::{JobKind, JobSpec, RecoveryPolicy};
use crate::Result;

fn addr(flags: &Flags) -> String {
    flags
        .values
        .get("addr")
        .cloned()
        .unwrap_or_else(|| DEFAULT_ADDR.to_string())
}

fn connect(flags: &Flags) -> Result<RpcClient> {
    let addr = addr(flags);
    RpcClient::connect(&addr).map_err(|e| {
        anyhow::anyhow!("cannot reach oar server at {addr}: {e} (is `oar serve` running?)")
    })
}

/// Print a protocol error the way a user command should: code + message,
/// non-zero exit.
fn report_rpc_error(cmd: &str, e: &RpcError) -> i32 {
    eprintln!("{cmd}: [{}] {}", e.code, e.message);
    1
}

/// Strict `--flag N` parse for job-defining numbers: `--nodes 1O` (typo)
/// must error, not silently fall back to a default and submit a
/// different job than the user asked.
fn strict_u64(flags: &Flags, key: &str, default: u64) -> Result<u64> {
    match flags.values.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse::<u64>()
            .map_err(|_| anyhow::anyhow!("--{key} must be an integer, got {v:?}")),
    }
}

// -------------------------------------------------------------- serve ----

/// `oar serve`: the always-running server process. Ctrl-C / SIGTERM
/// drains the RPC front-end (in-flight requests are answered first) and
/// then runs the clean-shutdown checkpoint (WAL compaction) before exit.
pub fn run_serve(flags: &Flags, policy: RecoveryPolicy) -> Result<i32> {
    let scale = flags.get_f64("scale", 0.01);
    let data_dir = flags.values.get("data-dir").map(PathBuf::from);
    let nodes = flags.get_u64("nodes", 0);
    let cluster = Arc::new(if nodes == 0 {
        VirtualCluster::xeon()
    } else {
        VirtualCluster::tiny(nodes as u32, flags.get_u64("procs", 2) as u32)
    });

    let config = ServerConfig {
        data_dir: data_dir.clone(),
        recovery: policy,
        ..ServerConfig::fast(scale)
    };
    let server = match &data_dir {
        Some(dir) => {
            println!("• durable mode: WAL + snapshots under {}", dir.display());
            let server = Server::open(cluster, config)?;
            if let Some(report) = server.recovery_report() {
                println!(
                    "• recovered generation {} ({} WAL records replayed, {} jobs reconciled)",
                    report.generation,
                    report.replayed_records,
                    report.reconciled.len()
                );
            }
            server
        }
        None => Server::new(cluster, config),
    };
    let server = Arc::new(server);

    let rpc_config = RpcConfig {
        addr: addr(flags),
        workers: flags.get_u64("workers", 16) as usize,
        queue_depth: flags.get_u64("queue-depth", 64) as usize,
        ..RpcConfig::default()
    };
    let rpc = RpcServer::start(server.clone(), rpc_config)?;
    println!(
        "── oar serve: listening on {} ({} nodes, scale={scale}) ──",
        rpc.addr(),
        server.cluster().nodes().len()
    );
    println!("   Ctrl-C / SIGTERM = drain + clean-shutdown checkpoint");

    signal::install();
    while !signal::shutdown_requested() {
        std::thread::sleep(Duration::from_millis(100));
    }

    println!("\n• shutdown signal: draining RPC front-end (in-flight requests finish)");
    let (conns, reqs) = rpc.drain();
    println!("• served {reqs} requests over {conns} connections");
    match Arc::try_unwrap(server) {
        Ok(server) => {
            // Clean shutdown = checkpoint (WAL compaction) inside.
            let _ = server.shutdown();
            println!("• state checkpointed; bye");
        }
        Err(shared) => {
            // A clone is still live (shouldn't happen once the front-end
            // has joined): checkpoint through the shared handle instead.
            shared.with_db(|db| {
                if db.is_durable() {
                    // oarlint: allow(R2) teardown: checkpoint through the shared handle; the RPC front-end has already drained
                    let _ = db.checkpoint();
                }
            });
            println!("• state checkpointed (shared handle); bye");
        }
    }
    Ok(0)
}

// ---------------------------------------------------- client commands ----

/// `oar sub`: submit a job (`oarsub`). The command is `--command '...'`;
/// `--array N` expands a multi-parametric campaign server-side.
pub fn run_sub(flags: &Flags) -> Result<i32> {
    // Required, not defaulted: a typo'd `--comand` is silently dropped by
    // the flag parser, and submitting some other job instead of erroring
    // would defeat the wire layer's reject-unknown-fields discipline.
    let Some(command) = flags.values.get("command").cloned() else {
        anyhow::bail!("sub requires --command '...' (e.g. oar sub --command 'sleep 60')");
    };
    let nodes = strict_u64(flags, "nodes", 1)?;
    let weight = strict_u64(flags, "weight", 1)?;
    anyhow::ensure!(
        nodes <= u32::MAX as u64 && weight <= u32::MAX as u64,
        "--nodes/--weight out of range"
    );
    let mut spec = JobSpec {
        user: flags
            .values
            .get("user")
            .cloned()
            .or_else(|| std::env::var("USER").ok())
            .unwrap_or_else(|| "nobody".into()),
        command,
        nb_nodes: nodes as u32,
        weight: weight as u32,
        ..JobSpec::default()
    };
    if flags.has("maxtime") {
        spec.max_time = Some(strict_u64(flags, "maxtime", 3600)? as i64);
    }
    spec.queue = flags.values.get("queue").cloned();
    spec.properties = flags.values.get("properties").cloned();
    if flags.has("reservation") {
        spec.reservation_start = Some(strict_u64(flags, "reservation", 0)? as i64);
    }
    if let Some(dir) = flags.values.get("dir") {
        spec.launching_directory = dir.clone();
    }
    spec.best_effort = flags.has("besteffort");
    if flags.has("interactive") {
        spec.kind = JobKind::Interactive;
    }
    if !flags.resource_specs.is_empty() {
        // Each `-l` is one moldable alternative; the wire format joins
        // them with the grammar's `|` separator (docs/PROTOCOL.md).
        spec.resources = Some(flags.resource_specs.join(" | "));
    }

    // Strict parse + range: `--array 4294967296` must error, not wrap
    // to 0 and silently submit a single job (mirrors the server side).
    let array = strict_u64(flags, "array", 1)?;
    anyhow::ensure!(
        (1..=100_000).contains(&array),
        "--array must be in 1..=100000, got {array}"
    );
    let mut client = connect(flags)?;
    let outcome = if array == 1 {
        client.sub(&spec)?.map(|id| vec![id])
    } else {
        client.sub_array(&spec, array as u32)?
    };
    match outcome {
        Ok(ids) => {
            for id in ids {
                println!("OAR_JOB_ID={id}");
            }
            Ok(0)
        }
        Err(e) => Ok(report_rpc_error("sub", &e)),
    }
}

/// `oar stat`: list jobs (`oarstat`), optionally `--filter "<where>"`.
pub fn run_stat(flags: &Flags) -> Result<i32> {
    let mut client = connect(flags)?;
    match client.stat(flags.values.get("filter").map(String::as_str))? {
        Ok(mut jobs) => {
            jobs.sort_by_key(|j| j.id);
            let rows: Vec<Vec<String>> = jobs
                .iter()
                .map(|j| {
                    vec![
                        j.id.to_string(),
                        j.user.clone(),
                        j.queue_name.clone(),
                        j.state.to_string(),
                        j.submission_time.to_string(),
                        j.start_time.map(|t| t.to_string()).unwrap_or_default(),
                        j.stop_time.map(|t| t.to_string()).unwrap_or_default(),
                        j.command.clone(),
                        j.message.clone(),
                    ]
                })
                .collect();
            println!(
                "{}",
                report::table(
                    &[
                        "id", "user", "queue", "state", "submitted", "started", "stopped",
                        "command", "message"
                    ],
                    &rows
                )
            );
            println!("{} job(s)", rows.len());
            Ok(0)
        }
        Err(e) => Ok(report_rpc_error("stat", &e)),
    }
}

/// `oar del <id>`: cancel a job (`oardel`).
pub fn run_del(flags: &Flags) -> Result<i32> {
    let Some(id) = flags.positional.first().and_then(|s| s.parse::<u64>().ok()) else {
        anyhow::bail!("usage: oar del <jobId> [--addr HOST:PORT]");
    };
    let mut client = connect(flags)?;
    match client.del(id)? {
        Ok(state) if state.is_terminal() => {
            println!("job {id} already {state}; nothing to cancel");
            Ok(0)
        }
        Ok(state) => {
            println!("job {id} ({state}) cancellation enqueued");
            Ok(0)
        }
        Err(e) => Ok(report_rpc_error("del", &e)),
    }
}

/// `oar hold <id>`: suspend a Waiting job (`oarhold`).
pub fn run_hold(flags: &Flags) -> Result<i32> {
    hold_resume(flags, true)
}

/// `oar resume <id>`: release a held job (`oarresume`).
pub fn run_resume(flags: &Flags) -> Result<i32> {
    hold_resume(flags, false)
}

fn hold_resume(flags: &Flags, hold: bool) -> Result<i32> {
    let cmd = if hold { "hold" } else { "resume" };
    let Some(id) = flags.positional.first().and_then(|s| s.parse::<u64>().ok()) else {
        anyhow::bail!("usage: oar {cmd} <jobId> [--addr HOST:PORT]");
    };
    let mut client = connect(flags)?;
    let outcome = if hold { client.hold(id)? } else { client.resume(id)? };
    match outcome {
        Ok(state) => {
            println!("job {id} now {state}");
            Ok(0)
        }
        Err(e) => Ok(report_rpc_error(cmd, &e)),
    }
}

/// `oar nodes`: fleet state (`oarnodes`).
pub fn run_nodes(flags: &Flags) -> Result<i32> {
    let mut client = connect(flags)?;
    match client.nodes()? {
        Ok(nodes) => {
            let rows: Vec<Vec<String>> = nodes
                .iter()
                .map(|(host, state, procs)| {
                    vec![host.clone(), state.clone(), procs.to_string()]
                })
                .collect();
            println!("{}", report::table(&["hostname", "state", "procs"], &rows));
            Ok(0)
        }
        Err(e) => Ok(report_rpc_error("nodes", &e)),
    }
}

// ------------------------------------------------------ observability ----

/// Shared `--watch` loop: render once, or every `--every SECS` (default
/// 2) until interrupted. Reconnects each round so a server restart
/// doesn't strand the watcher on a dead socket.
fn watch_loop(flags: &Flags, mut render: impl FnMut(&mut RpcClient) -> Result<i32>) -> Result<i32> {
    if !flags.has("watch") {
        let mut client = connect(flags)?;
        return render(&mut client);
    }
    let every = Duration::from_secs(flags.get_u64("every", 2).max(1));
    loop {
        match connect(flags) {
            Ok(mut client) => {
                if let Err(e) = render(&mut client) {
                    eprintln!("watch: {e}");
                }
            }
            Err(e) => eprintln!("watch: {e}"),
        }
        std::thread::sleep(every);
    }
}

/// `oar metrics [--watch]`: Prometheus-style text exposition of the
/// server's registry (see `docs/OBSERVABILITY.md` for the name scheme).
pub fn run_metrics(flags: &Flags) -> Result<i32> {
    watch_loop(flags, |client| match client.metrics()? {
        Ok(snap) => {
            print!("{}", snap.render_text());
            Ok(0)
        }
        Err(e) => Ok(report_rpc_error("metrics", &e)),
    })
}

/// `oar top [--watch]`: one-screen dashboard merging the `load` probe,
/// the queue table and the registry's latency histograms — occupancy,
/// queue depths, and per-phase scheduler / lock-wait / WAL / RPC
/// percentiles at a glance.
pub fn run_top(flags: &Flags) -> Result<i32> {
    watch_loop(flags, |client| {
        let load = match client.load()? {
            Ok(l) => l,
            Err(e) => return Ok(report_rpc_error("top", &e)),
        };
        let snap = match client.metrics()? {
            Ok(s) => s,
            Err(e) => return Ok(report_rpc_error("top", &e)),
        };

        println!("── oar top ──");
        println!(
            "occupancy: {}/{} procs busy ({} free) on {}/{} alive nodes; {} waiting, {} running",
            load.procs_busy,
            load.procs_alive,
            load.procs_free,
            load.nodes_alive,
            load.nodes_total,
            load.waiting_jobs,
            load.running_jobs,
        );

        let counter = |name: &str| {
            snap.counters
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, v)| *v)
                .unwrap_or(0)
        };
        let gauge = |name: &str| {
            snap.gauges
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, v)| *v)
                .unwrap_or(0)
        };
        println!(
            "activity:  {} sched rounds, {} rpc requests ({} in flight), {} db events ({} evicted)",
            counter("oar_sched_rounds_total"),
            counter("oar_rpc_requests_total"),
            gauge("oar_rpc_inflight"),
            counter("oar_db_events_rows"),
            counter("oar_db_events_evicted_total"),
        );

        // Latency table: every histogram with at least one observation,
        // registry order (catalogue groups related phases together).
        let rows: Vec<Vec<String>> = snap
            .hists
            .iter()
            .filter(|h| h.count > 0)
            .map(|h| {
                vec![
                    h.name.clone(),
                    h.count.to_string(),
                    format!("{:.0}", h.mean()),
                    h.p50().to_string(),
                    h.p99().to_string(),
                    h.max.to_string(),
                    h.unit.clone(),
                ]
            })
            .collect();
        if rows.is_empty() {
            println!("no latency observations yet");
        } else {
            println!(
                "{}",
                report::table(
                    &["histogram", "count", "mean", "p50≤", "p99≤", "max", "unit"],
                    &rows
                )
            );
        }
        Ok(0)
    })
}

/// `oar events`: tail the server's bounded event log
/// (`--tail N --kind KIND --job ID`).
pub fn run_events(flags: &Flags) -> Result<i32> {
    let tail = strict_u64(flags, "tail", 20)? as usize;
    let job = if flags.has("job") {
        Some(strict_u64(flags, "job", 0)?)
    } else {
        None
    };
    let kind = flags.values.get("kind").map(String::as_str);
    let mut client = connect(flags)?;
    match client.events(tail, kind, job)? {
        Ok((records, total)) => {
            let rows: Vec<Vec<String>> = records
                .iter()
                .map(|r| {
                    vec![
                        r.time.to_string(),
                        r.kind.clone(),
                        r.job.map(|j| j.to_string()).unwrap_or_default(),
                        r.detail.clone(),
                    ]
                })
                .collect();
            println!("{}", report::table(&["time", "kind", "job", "detail"], &rows));
            println!("{} of {} matching event(s)", rows.len(), total);
            Ok(0)
        }
        Err(e) => Ok(report_rpc_error("events", &e)),
    }
}

/// `oar queues`: the queue table.
pub fn run_queues(flags: &Flags) -> Result<i32> {
    let mut client = connect(flags)?;
    match client.queues()? {
        Ok(queues) => {
            let rows: Vec<Vec<String>> = queues
                .iter()
                .map(|q| {
                    vec![
                        q.name.clone(),
                        q.priority.to_string(),
                        q.policy.as_str().to_string(),
                        q.default_max_time.to_string(),
                        if q.max_procs_per_job == u32::MAX {
                            "-".into()
                        } else {
                            q.max_procs_per_job.to_string()
                        },
                        if q.active { "yes" } else { "no" }.to_string(),
                    ]
                })
                .collect();
            println!(
                "{}",
                report::table(
                    &["queue", "priority", "policy", "default maxTime", "max procs/job", "active"],
                    &rows
                )
            );
            Ok(0)
        }
        Err(e) => Ok(report_rpc_error("queues", &e)),
    }
}
