//! `oar demo`: a narrated live run on the virtual Xeon cluster, touching
//! every §2/§3.3 mechanism: submissions, properties matching, priorities,
//! a reservation, best-effort + reclamation, node failure + recovery, and
//! the accounting report.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use crate::cluster::VirtualCluster;
use crate::server::{RecoveryReport, Server, ServerConfig};
use crate::types::{JobSpec, JobState, RecoveryPolicy};
use crate::Result;

fn print_recovery(report: &RecoveryReport) {
    println!(
        "• recovery: generation {} ({}), {} WAL records replayed{}",
        report.generation,
        if report.snapshot_loaded {
            "snapshot + WAL tail"
        } else {
            "WAL only"
        },
        report.replayed_records,
        if report.torn_tail {
            ", torn tail truncated"
        } else {
            ""
        }
    );
    for (id, state) in &report.reconciled {
        println!("    job {id}: stranded in {state}, reconciled");
    }
}

pub fn run_demo(scale: f64, data_dir: Option<PathBuf>, policy: RecoveryPolicy) -> Result<i32> {
    println!("── oar demo: virtual Xeon cluster (17 bi-Xeon nodes), scale={scale} ──\n");
    let cluster = Arc::new(VirtualCluster::xeon());
    let server = match &data_dir {
        Some(dir) => {
            println!("• durable mode: WAL + snapshots under {}\n", dir.display());
            let server = Server::open(
                cluster.clone(),
                ServerConfig {
                    data_dir: Some(dir.clone()),
                    recovery: policy,
                    ..ServerConfig::fast(scale)
                },
            )?;
            if let Some(report) = server.recovery_report() {
                print_recovery(report);
            }
            server
        }
        None => Server::new(cluster.clone(), ServerConfig::fast(scale)),
    };

    println!("• oarsub: 6 batch jobs (mixed sizes), one with a property constraint");
    let mut ids = Vec::new();
    for (user, cmd, nodes) in [
        ("alice", "sleep 2", 4),
        ("bob", "sleep 1", 2),
        ("carol", "sleep 1", 8),
        ("dave", "date", 1),
        ("erin", "sleep 1", 2),
    ] {
        let id = server
            .submit(&JobSpec::batch(user, cmd, nodes, 600))?
            .map_err(|e| anyhow::anyhow!(e))?;
        println!("    job {id}: {user} wants {nodes} nodes ({cmd})");
        ids.push(id);
    }
    let picky = server
        .submit(&JobSpec {
            properties: Some("mem >= 512 AND switch = 'sw1'".into()),
            ..JobSpec::batch("frank", "date", 2, 600)
        })?
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("    job {picky}: frank wants 2 nodes WHERE mem >= 512 AND switch = 'sw1'");

    println!("• oarsub -r: a reservation 3s from now");
    let resa = server
        .submit(&JobSpec {
            reservation_start: Some(3),
            ..JobSpec::batch("grace", "date", 4, 60)
        })?
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("    job {resa}: grace reserved 4 nodes at t+3s");

    println!("• best-effort (Global computing, §3.3): a 17-node background sweep");
    let be = server
        .submit(&JobSpec {
            best_effort: true,
            ..JobSpec::batch("grid", "sleep 30", 17, 3600)
        })?
        .map_err(|e| anyhow::anyhow!(e))?;
    println!("    job {be}: routed to the besteffort queue; will be reclaimed");

    println!("• injecting a node failure; the monitor must suspect it");
    cluster.inject_failure(9);
    std::thread::sleep(Duration::from_millis(800));
    let suspected = server
        .nodes()
        .into_iter()
        .filter(|(_, state, _)| state == "Suspected")
        .count();
    println!("    suspected nodes: {suspected}");
    cluster.repair(9);

    println!("• waiting for the system to drain...");
    let done = server.wait_all_terminal(Duration::from_secs(120));
    println!("    drained: {done}\n");

    println!("• oarstat:");
    for job in server.stat(None)? {
        println!(
            "    job {:>3}  {:<8} {:<10} resp={:?}ms  msg={:?}",
            job.id,
            job.user,
            job.state.to_string(),
            job.response_time(),
            job.message
        );
    }

    let be_job = server.with_db(|db| db.job(be))?;
    println!(
        "\n• best-effort job ended as {:?} ({})",
        be_job.state, be_job.message
    );

    println!("\n• oarstat --accounting:");
    let acc = server.accounting();
    for (user, usage) in &acc.by_user {
        println!(
            "    {user:<8} submitted={} terminated={} errors={} cpu_ms={} wait_ms={}",
            usage.jobs_submitted,
            usage.jobs_terminated,
            usage.jobs_error,
            usage.cpu_seconds,
            usage.total_wait
        );
    }
    println!("    mean response: {:.0} ms", acc.mean_response_time);

    let (accepted, discarded) = server.hub_stats();
    println!("\n• central module: {accepted} notifications accepted, {discarded} coalesced");
    let stats = server.with_db(|db| db.stats());
    println!(
        "• database: {} SQL-equivalent statements ({} selects, {} inserts, {} updates)",
        stats.total(),
        stats.selects,
        stats.inserts,
        stats.updates
    );
    println!(
        "  access paths: {} index probes, {} full scans",
        stats.index_probes, stats.full_scans
    );
    if let Some(dir) = data_dir {
        let _ = server.shutdown(); // clean shutdown checkpoints the WAL
        println!(
            "• durable state checkpointed under {} (rerun with --data-dir to recover)",
            dir.display()
        );
    }
    Ok(0)
}

/// `oar recover`: bring a durable server back from its data directory,
/// print the recovery + restart-reconciliation report, and drain whatever
/// workload survived the crash.
pub fn run_recover(dir: PathBuf, policy: RecoveryPolicy, scale: f64) -> Result<i32> {
    println!(
        "── oar recover: data dir {}, policy {} ──\n",
        dir.display(),
        policy.as_str()
    );
    let cluster = Arc::new(VirtualCluster::xeon());
    let server = Server::open(
        cluster,
        ServerConfig {
            data_dir: Some(dir),
            recovery: policy,
            ..ServerConfig::fast(scale)
        },
    )?;
    let report = server.recovery_report().cloned();
    if let Some(report) = &report {
        print_recovery(report);
    }
    println!("• draining the recovered workload...");
    let drained = server.wait_all_terminal(Duration::from_secs(120));
    println!("    drained: {drained}\n");
    println!("• oarstat:");
    for job in server.stat(None)? {
        println!(
            "    job {:>3}  {:<8} {:<10} msg={:?}",
            job.id,
            job.user,
            job.state.to_string(),
            job.message
        );
    }
    let recovery_events =
        server.with_db(|db| db.events_with_kind_prefix("RECOVERY_").len());
    println!("\n• {recovery_events} RECOVERY_* events logged");
    let _ = server.shutdown();
    Ok(0)
}

/// `oar snapshot`: run a short workload, snapshot the database, restore it
/// and verify — the paper's §2 data-safety argument, demonstrated.
pub fn run_snapshot(out: PathBuf) -> Result<i32> {
    let cluster = Arc::new(VirtualCluster::tiny(4, 1));
    let server = Server::new(cluster, ServerConfig::fast(0.0));
    for i in 0..8 {
        server
            .submit(&JobSpec::batch(&format!("u{i}"), "date", 1, 60))?
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    server.wait_all_terminal(Duration::from_secs(30));
    let db = server.shutdown();
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    db.snapshot(&out)?;
    // prove the snapshot round-trips
    let mut restored = crate::db::Db::restore(&out)?;
    let terminated = restored.jobs_in_state(JobState::Terminated).len();
    println!(
        "snapshot written to {} ({} terminated jobs round-tripped)",
        out.display(),
        terminated
    );
    Ok(0)
}
