//! The `oar grid` subcommands: drive the federation layer from the
//! command line.
//!
//! * `oar grid sub` — submit a bag-of-tasks campaign and run the grid
//!   meta-scheduler in-process until it drains (CiGri as a driver
//!   command rather than a daemon: the grid state lives in `--data-dir`
//!   when given, so an interrupted run resumes where it stopped).
//! * `oar grid stat` — inspect the persisted campaigns/tasks of a grid
//!   state directory without dispatching anything.
//! * `oar grid clusters` — probe each cluster's `load` RPC and print the
//!   federation view.

use std::path::PathBuf;
use std::time::Duration;

use crate::bench::report;
use crate::cli::Flags;
use crate::db::Db;
use crate::grid::{ClusterConfig, Grid, GridConfig};
use crate::rpc::RpcClient;
use crate::types::{CampaignSpec, CampaignState, GridTaskState};
use crate::Result;

pub fn run_grid(flags: &Flags) -> Result<i32> {
    match flags.positional.first().map(String::as_str) {
        Some("sub") => grid_sub(flags),
        Some("stat") => grid_stat(flags),
        Some("clusters") => grid_clusters(flags),
        other => {
            eprintln!(
                "unknown grid subcommand {:?}; expected sub|stat|clusters",
                other.unwrap_or("")
            );
            Ok(2)
        }
    }
}

/// Parse `--clusters host:port,host:port,...` into grid cluster configs.
/// Each cluster is *named by its address*: persisted `grid_tasks`
/// placements key on the name, so it must stay stable when a `--data-dir`
/// run is resumed with the addresses listed in a different order —
/// positional names (`c0`, `c1`, ...) would silently remap every
/// in-flight placement.
fn cluster_list(flags: &Flags, cap: u32) -> Result<Vec<ClusterConfig>> {
    let Some(raw) = flags.values.get("clusters") else {
        anyhow::bail!("requires --clusters HOST:PORT,HOST:PORT,...");
    };
    let clusters: Vec<ClusterConfig> = raw
        .split(',')
        .map(str::trim)
        .filter(|a| !a.is_empty())
        .map(|addr| ClusterConfig {
            name: addr.to_string(),
            addr: addr.to_string(),
            max_outstanding: cap,
        })
        .collect();
    anyhow::ensure!(!clusters.is_empty(), "--clusters names no addresses");
    let mut names: Vec<&str> = clusters.iter().map(|c| c.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    anyhow::ensure!(
        names.len() == clusters.len(),
        "--clusters lists the same address twice"
    );
    Ok(clusters)
}

fn grid_sub(flags: &Flags) -> Result<i32> {
    let Some(command) = flags.values.get("command").cloned() else {
        anyhow::bail!(
            "grid sub requires --command '...' (use {{i}} for the task index)"
        );
    };
    let tasks = flags.get_u64("tasks", 100);
    anyhow::ensure!(
        (1..=1_000_000).contains(&tasks),
        "--tasks must be in 1..=1000000"
    );
    let cap = flags.get_u64("cap", 32) as u32;
    let clusters = cluster_list(flags, cap)?;
    let config = GridConfig {
        clusters,
        data_dir: flags.values.get("data-dir").map(PathBuf::from),
        round_every: Duration::from_millis(flags.get_u64("round-ms", 200)),
        retry_budget: flags.get_u64("retries", 5) as u32,
        stale_after: Duration::from_secs(flags.get_u64("stale", 600)),
        ..GridConfig::default()
    };
    let spec = CampaignSpec {
        name: flags
            .values
            .get("name")
            .cloned()
            .unwrap_or_else(|| "campaign".into()),
        user: flags
            .values
            .get("user")
            .cloned()
            .or_else(|| std::env::var("USER").ok())
            .unwrap_or_else(|| "nobody".into()),
        command,
        nb_nodes: flags.get_u64("nodes", 1) as u32,
        weight: flags.get_u64("weight", 1) as u32,
        max_time: flags.get_u64("maxtime", 3600) as i64,
        tasks: tasks as u32,
    };

    let grid = Grid::start(config)?;
    // With --data-dir, an interrupted run resumes: an Active campaign
    // with the same identity is reattached instead of resubmitted (its
    // finished tasks stay finished); anything else is a new campaign.
    let resumed = grid.campaigns().into_iter().find(|c| {
        c.state == CampaignState::Active
            && c.name == spec.name
            && c.user == spec.user
            && c.command == spec.command
            && c.tasks == spec.tasks
            && c.nb_nodes == spec.nb_nodes
            && c.weight == spec.weight
            && c.max_time == spec.max_time
    });
    let id = match resumed {
        Some(c) => {
            println!("resuming campaign {} ({} tasks) from grid state", c.id, c.tasks);
            c.id
        }
        None => grid.submit_campaign(&spec)?,
    };
    println!("GRID_CAMPAIGN_ID={id} ({} tasks)", spec.tasks);

    let timeout = Duration::from_secs(flags.get_u64("timeout", 3600));
    let started = std::time::Instant::now();
    loop {
        let p = grid.campaign_progress(id)?;
        println!(
            "  pending={} dispatched={} done={} failed={}",
            p.pending, p.dispatched, p.done, p.failed
        );
        if p.drained() {
            break;
        }
        if started.elapsed() > timeout {
            eprintln!("grid sub: timeout after {timeout:?}; state kept in --data-dir");
            return Ok(1);
        }
        std::thread::sleep(Duration::from_millis(500));
    }
    let p = grid.campaign_progress(id)?;
    let c = grid.counters();
    println!("── campaign {id} drained: {} done, {} failed ──", p.done, p.failed);
    println!(
        "   dispatched={} retried={} orphaned={} blacklists={} rejoins={} transport_errors={}",
        c.dispatched, c.retried, c.orphaned, c.blacklists, c.rejoins, c.transport_errors
    );
    print_cluster_table(&grid);
    let _ = grid.shutdown();
    Ok(if p.failed == 0 { 0 } else { 1 })
}

fn print_cluster_table(grid: &Grid) {
    let rows: Vec<Vec<String>> = grid
        .clusters()
        .iter()
        .map(|c| {
            vec![
                c.name.clone(),
                c.addr.clone(),
                if c.blacklisted {
                    "blacklisted".into()
                } else if c.alive {
                    "alive".into()
                } else {
                    "unreachable".into()
                },
                c.last_free.to_string(),
                c.outstanding.to_string(),
                c.dispatched_total.to_string(),
                c.completed_total.to_string(),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(
            &["cluster", "addr", "state", "free", "outstanding", "dispatched", "completed"],
            &rows
        )
    );
}

fn grid_stat(flags: &Flags) -> Result<i32> {
    let dir = flags
        .values
        .get("data-dir")
        .map(PathBuf::from)
        .ok_or_else(|| anyhow::anyhow!("grid stat requires --data-dir DIR"))?;
    // Inspect a *copy* of the state directory: `Db::recover` is not a
    // read-only open — it truncates torn WAL tails and sweeps stale
    // generations, which against the live directory of a running
    // `grid sub` would corrupt the state this command only reads.
    let scratch = std::env::temp_dir().join(format!("oar-grid-stat-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&scratch);
    std::fs::create_dir_all(&scratch)?;
    for entry in std::fs::read_dir(&dir)? {
        let entry = entry?;
        if entry.file_type()?.is_file() {
            std::fs::copy(entry.path(), scratch.join(entry.file_name()))?;
        }
    }
    let (mut db, stats) = Db::recover(&scratch)?;
    println!(
        "grid state {} (generation {}, {} WAL records replayed)\n",
        dir.display(),
        stats.generation,
        stats.replayed
    );
    let campaigns = db.campaigns();
    let mut rows = Vec::new();
    for c in &campaigns {
        let tasks = db.grid_tasks_of_campaign(c.id);
        let count = |s: GridTaskState| tasks.iter().filter(|t| t.state == s).count();
        rows.push(vec![
            c.id.to_string(),
            c.name.clone(),
            c.user.clone(),
            c.state.as_str().to_string(),
            c.tasks.to_string(),
            count(GridTaskState::Pending).to_string(),
            count(GridTaskState::Dispatched).to_string(),
            count(GridTaskState::Done).to_string(),
            count(GridTaskState::Failed).to_string(),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["id", "name", "user", "state", "tasks", "pending", "dispatched", "done", "failed"],
            &rows
        )
    );
    println!("{} campaign(s)", campaigns.len());
    drop(db);
    let _ = std::fs::remove_dir_all(&scratch);
    Ok(0)
}

fn grid_clusters(flags: &Flags) -> Result<i32> {
    let clusters = cluster_list(flags, 0)?;
    let mut rows = Vec::new();
    for c in &clusters {
        match RpcClient::connect_timeout(&c.addr, Duration::from_secs(5)).and_then(|mut cl| {
            cl.set_timeout(Some(Duration::from_secs(5)))?;
            cl.load()
        }) {
            Ok(Ok(info)) => rows.push(vec![
                c.name.clone(),
                c.addr.clone(),
                "alive".into(),
                format!("{}/{}", info.nodes_alive, info.nodes_total),
                format!("{}/{}", info.procs_free, info.procs_alive),
                info.waiting_jobs.to_string(),
                info.running_jobs.to_string(),
            ]),
            Ok(Err(e)) => rows.push(vec![
                c.name.clone(),
                c.addr.clone(),
                format!("refused [{}]", e.code),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
            ]),
            Err(_) => rows.push(vec![
                c.name.clone(),
                c.addr.clone(),
                "unreachable".into(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
            ]),
        }
    }
    println!(
        "{}",
        report::table(
            &["cluster", "addr", "state", "nodes", "free/alive procs", "waiting", "running"],
            &rows
        )
    );
    Ok(0)
}
