//! Tracing spans: RAII timing into histograms plus a bounded ring of
//! recent span records for post-hoc round forensics.
//!
//! [`Span::enter`] stamps the obs clock, links itself under the
//! thread's current span (parent/child nesting via a thread-local), and
//! on drop records the elapsed time into its histogram and pushes a
//! [`SpanRecord`] into a global fixed-capacity ring. The ring overwrites
//! oldest-first, so memory is bounded no matter how long the server
//! runs; overwrites are tallied (`oar_obs_spans_evicted_total`).
//!
//! Lock discipline: the ring mutex (`RING`) is a leaf — record/read
//! take it for a few instructions and never acquire anything under it.
//! Instrumented code must still never *reach* a record call while
//! holding the db write guard or the WAL sink lock; that is the R7 lint
//! (docs/LINTS.md), and the RAII sites are arranged so the drop fires
//! after those guards are released.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};

use super::clock;
use super::registry::Histogram;

/// Default ring capacity (records, not bytes; a record is ~64 bytes).
pub const DEFAULT_RING_CAPACITY: usize = 1024;

/// One finished span.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    /// Unique id (process-wide, never 0).
    pub id: u64,
    /// Enclosing span's id at enter time; 0 for a root span.
    pub parent: u64,
    pub name: &'static str,
    /// Obs-clock time at enter, microseconds.
    pub start_us: u64,
    pub dur_us: u64,
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Innermost live span on this thread (0 = none).
    static CURRENT: Cell<u64> = const { Cell::new(0) };
}

struct Ring {
    buf: Vec<SpanRecord>,
    /// Next overwrite position once `buf.len() == cap`.
    head: usize,
    cap: usize,
    evicted: u64,
}

// Telemetry must survive a panicking peer (the rpc workers run handlers
// under catch_unwind): the ring holds a plain list with no cross-field
// invariant, so poison is ignored, same policy as the rpc queue locks.
static RING: Mutex<Ring> = Mutex::new(Ring {
    buf: Vec::new(),
    head: 0,
    cap: DEFAULT_RING_CAPACITY,
    evicted: 0,
});

fn ring_push(rec: SpanRecord) {
    let mut r = RING.lock().unwrap_or_else(PoisonError::into_inner);
    if r.cap == 0 {
        r.evicted += 1;
        return;
    }
    if r.buf.len() < r.cap {
        r.buf.push(rec);
    } else {
        let head = r.head;
        r.buf[head] = rec;
        r.head = (head + 1) % r.cap;
        r.evicted += 1;
    }
}

/// The most recent `n` finished spans, oldest first.
pub fn recent_spans(n: usize) -> Vec<SpanRecord> {
    let r = RING.lock().unwrap_or_else(PoisonError::into_inner);
    let len = r.buf.len();
    let take = n.min(len);
    let mut out = Vec::with_capacity(take);
    // Chronological order: the ring's oldest entry sits at `head`.
    for i in (len - take)..len {
        out.push(r.buf[(r.head + i) % len].clone());
    }
    out
}

/// `(live records, capacity, overwritten-total)`.
pub fn ring_stats() -> (usize, usize, u64) {
    let r = RING.lock().unwrap_or_else(PoisonError::into_inner);
    (r.buf.len(), r.cap, r.evicted)
}

/// Resize the ring (test hook / future config). Existing records are
/// kept newest-first up to the new capacity.
pub fn set_ring_capacity(cap: usize) {
    let mut r = RING.lock().unwrap_or_else(PoisonError::into_inner);
    let mut records: Vec<SpanRecord> = {
        let len = r.buf.len();
        let mut v = Vec::with_capacity(len);
        for i in 0..len {
            v.push(r.buf[(r.head + i) % len].clone());
        }
        v
    };
    if records.len() > cap {
        let drop_n = records.len() - cap;
        records.drain(..drop_n);
        r.evicted += drop_n as u64;
    }
    r.buf = records;
    r.head = 0;
    r.cap = cap;
}

/// An in-progress timed region. Construct with [`Span::enter`]; the
/// drop records into the histogram and the ring.
pub struct Span {
    name: &'static str,
    hist: &'static Histogram,
    id: u64,
    parent: u64,
    start_us: u64,
}

impl Span {
    pub fn enter(name: &'static str, hist: &'static Histogram) -> Span {
        let parent = CURRENT.with(Cell::get);
        let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        CURRENT.with(|c| c.set(id));
        Span { name, hist, id, parent, start_us: clock::now_us() }
    }

    /// This span's id (stable across its lifetime; useful in tests).
    pub fn id(&self) -> u64 {
        self.id
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(self.parent));
        if !super::registry::enabled() {
            return;
        }
        let dur_us = clock::now_us().saturating_sub(self.start_us);
        self.hist.observe(dur_us);
        ring_push(SpanRecord {
            id: self.id,
            parent: self.parent,
            name: self.name,
            start_us: self.start_us,
            dur_us,
        });
    }
}
