//! The observability clock: monotonic microseconds since process start,
//! with a deterministic manual mode for tests.
//!
//! Every duration the `obs` layer records flows through [`now_us`], so a
//! test that freezes the clock and advances it by hand can assert *exact*
//! histogram bucket placement instead of sleeping and hoping. The manual
//! mode is process-global on purpose: the deterministic suites live in
//! their own integration binary (`rust/tests/obs.rs`), which is a
//! separate process, so freezing there cannot skew timings observed by
//! the other test suites.
//!
//! The real mode derives from a lazily-pinned [`Instant`] epoch (the
//! first call wins), never from wall-clock time — `SystemTime` can step
//! backwards under NTP and would corrupt latency histograms.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

static MANUAL: AtomicBool = AtomicBool::new(false);
static MANUAL_US: AtomicU64 = AtomicU64::new(0);
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Current observability time in microseconds. Monotonic in real mode;
/// exactly what the test set in manual mode.
pub fn now_us() -> u64 {
    if MANUAL.load(Ordering::Relaxed) {
        return MANUAL_US.load(Ordering::Relaxed);
    }
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Switch to manual time, pinned at `us`. Subsequent [`now_us`] calls
/// return exactly the values driven by [`advance_us`].
pub fn freeze_at(us: u64) {
    MANUAL_US.store(us, Ordering::Relaxed);
    MANUAL.store(true, Ordering::Relaxed);
}

/// Advance manual time. No-op on the real clock reading, but always
/// updates the manual register so freeze→advance sequences compose.
pub fn advance_us(us: u64) {
    MANUAL_US.fetch_add(us, Ordering::Relaxed);
}

/// Return to the real monotonic clock.
pub fn unfreeze() {
    MANUAL.store(false, Ordering::Relaxed);
}

/// Whether the clock is in manual (test) mode.
pub fn is_frozen() -> bool {
    MANUAL.load(Ordering::Relaxed)
}
