//! The metrics registry: relaxed-atomic counters, gauges and
//! log-bucketed histograms, registered by `static` name.
//!
//! Registration is the catalogue in [`crate::obs::metrics`]: every metric
//! is a `static` item built with a `const` constructor, so the hot path
//! is a single relaxed atomic op on a pre-existing cell — no lazy init,
//! no map lookup, no lock, ever. Enumeration (for the `metrics` RPC and
//! the Prometheus-style exposition) walks fixed `&'static` slices.
//!
//! All orderings are `Relaxed` by calibration (docs/LINTS.md §R6): these
//! are pure tallies — nothing synchronizes *through* a metric.
//!
//! The whole layer has a kill switch: [`set_enabled`] for the runtime
//! ablation the `obs` bench measures, and the `obs_noop` cargo feature
//! for a true compiled-out baseline (the `enabled()` branch folds to
//! `false` and the record paths disappear).

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};

use crate::util::Json;

/// Number of log2 buckets per histogram. Bucket 0 holds the value 0;
/// bucket `i >= 1` holds values in `[2^(i-1), 2^i)`; the last bucket
/// absorbs everything above. 40 buckets cover > 6 days in microseconds.
pub const BUCKETS: usize = 40;

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Whether record calls do anything. With the `obs_noop` feature the
/// answer is a compile-time `false`.
#[inline]
pub fn enabled() -> bool {
    #[cfg(feature = "obs_noop")]
    {
        false
    }
    #[cfg(not(feature = "obs_noop"))]
    {
        ENABLED.load(Ordering::Relaxed)
    }
}

/// Runtime kill switch (the ablation baseline in `benches/obs.rs`).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

// ------------------------------------------------------------ counter ----

/// A monotonically increasing tally.
pub struct Counter {
    name: &'static str,
    v: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str) -> Counter {
        Counter { name, v: AtomicU64::new(0) }
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

// ------------------------------------------------------------- gauge ----

/// A value that goes up and down (in-flight requests, queue depth).
pub struct Gauge {
    name: &'static str,
    v: AtomicI64,
}

impl Gauge {
    pub const fn new(name: &'static str) -> Gauge {
        Gauge { name, v: AtomicI64::new(0) }
    }

    #[inline]
    pub fn rise(&self) {
        if enabled() {
            self.v.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Paired with [`Gauge::rise`]. Always executes (not gated on
    /// [`enabled`]) so a toggle mid-request cannot strand the gauge
    /// above zero forever; a spurious decrement clamps at the reader.
    #[inline]
    pub fn fall(&self) {
        self.v.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed).max(0)
    }

    pub fn name(&self) -> &'static str {
        self.name
    }
}

// --------------------------------------------------------- histogram ----

/// A log2-bucketed distribution. `p50`/`p99`/`max` are derived from the
/// buckets at snapshot time; recording is one index computation plus two
/// relaxed adds (three when a new max is seen).
pub struct Histogram {
    name: &'static str,
    /// Unit suffix carried into the exposition (`us`, `bytes`, ...).
    unit: &'static str,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

/// Index of the bucket holding `v`: 0 for 0, else `⌊log2 v⌋ + 1`,
/// clamped into the last bucket.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i` (`u64::MAX` for the overflow
/// bucket — rendered as `+Inf`).
pub fn bucket_le(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

impl Histogram {
    pub const fn new(name: &'static str, unit: &'static str) -> Histogram {
        // `AtomicU64` is not `Copy`; a `const` item is the standard way
        // to splat a fresh cell per array slot.
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            name,
            unit,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            buckets: [ZERO; BUCKETS],
        }
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        if !enabled() {
            return;
        }
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> = self
            .buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        // Per-bucket loads are individually relaxed, so a racing observe
        // can make the straight `count` load disagree with the bucket
        // sum by in-flight observations. The snapshot's own invariant
        // (bucket-sum == count, asserted by tests and consumers) is kept
        // by deriving the count from the buckets we actually read.
        let count: u64 = buckets.iter().sum();
        HistogramSnapshot {
            name: self.name.to_string(),
            unit: self.unit.to_string(),
            count,
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// Point-in-time copy of one histogram, with percentiles derivable.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    pub name: String,
    pub unit: String,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    /// One count per log2 bucket, index as in [`bucket_le`].
    pub buckets: Vec<u64>,
}

impl HistogramSnapshot {
    /// Upper bound of the bucket containing the q-th quantile
    /// (`0.0 < q <= 1.0`), 0 when empty. Exact for the bucket edges the
    /// deterministic-clock suite drives; an upper bound otherwise.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= rank {
                // The overflow bucket has no finite upper bound; the
                // observed max is the tightest true statement.
                return if bucket_le(i) == u64::MAX { self.max } else { bucket_le(i) };
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn to_json(&self) -> Json {
        // Sparse encoding: only non-empty buckets travel, as
        // [index, count] pairs — a fresh histogram is a few bytes.
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, c)| **c > 0)
            .map(|(i, c)| Json::Arr(vec![Json::Num(i as f64), Json::Num(*c as f64)]))
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("unit", Json::Str(self.unit.clone())),
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum as f64)),
            ("max", Json::Num(self.max as f64)),
            ("p50", Json::Num(self.p50() as f64)),
            ("p99", Json::Num(self.p99() as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<HistogramSnapshot> {
        let name = j.get("name")?.as_str()?.to_string();
        let unit = j.get("unit")?.as_str()?.to_string();
        let count = j.get("count")?.as_f64()? as u64;
        let sum = j.get("sum")?.as_f64()? as u64;
        let max = j.get("max")?.as_f64()? as u64;
        let mut buckets = vec![0u64; BUCKETS];
        for pair in j.get("buckets")?.as_arr()? {
            let p = pair.as_arr()?;
            let i = p.first()?.as_f64()? as usize;
            let c = p.get(1)?.as_f64()? as u64;
            if i < BUCKETS {
                buckets[i] = c;
            }
        }
        Some(HistogramSnapshot { name, unit, count, sum, max, buckets })
    }
}

// ---------------------------------------------------------- snapshot ----

/// Query-engine counters read under a db *read* guard at snapshot time.
///
/// These live in `QueryStats`/per-table cells that are bumped inside
/// `Db` methods — including the apply/commit path under the write guard
/// — so they are bridged into the registry here, at read time, instead
/// of being recorded inline (the R7 invariant: no telemetry call under
/// the write guard's commit path or the WAL sink lock).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DbCounters {
    pub selects: u64,
    pub inserts: u64,
    pub updates: u64,
    pub deletes: u64,
    pub index_probes: u64,
    pub full_scans: u64,
    pub view_hits: u64,
    /// Live rows in the bounded event log.
    pub events_len: u64,
    /// Rows evicted by the retention cap since this `Db` was built.
    pub events_evicted: u64,
    /// The retention cap itself.
    pub events_cap: u64,
}

impl DbCounters {
    fn pairs(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("oar_db_selects_total", self.selects),
            ("oar_db_inserts_total", self.inserts),
            ("oar_db_updates_total", self.updates),
            ("oar_db_deletes_total", self.deletes),
            ("oar_db_index_probes_total", self.index_probes),
            ("oar_db_full_scans_total", self.full_scans),
            ("oar_db_view_hits_total", self.view_hits),
            ("oar_db_events_rows", self.events_len),
            ("oar_db_events_evicted_total", self.events_evicted),
            ("oar_db_events_retention_cap", self.events_cap),
        ]
    }
}

/// The versioned, typed snapshot the `metrics` RPC ships.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub version: u64,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub hists: Vec<HistogramSnapshot>,
}

/// Snapshot wire-format version (bump on breaking shape changes).
pub const SNAPSHOT_VERSION: u64 = 1;

/// Assemble a snapshot of every registered metric, merging the
/// db-derived counters when the caller holds them.
pub fn snapshot(db: Option<&DbCounters>) -> MetricsSnapshot {
    let mut counters: Vec<(String, u64)> = super::metrics::all_counters()
        .iter()
        .map(|c| (c.name().to_string(), c.get()))
        .collect();
    if let Some(db) = db {
        counters.extend(db.pairs().into_iter().map(|(n, v)| (n.to_string(), v)));
    }
    let (ring_len, ring_cap, ring_evicted) = super::span::ring_stats();
    counters.push(("oar_obs_spans_evicted_total".to_string(), ring_evicted));
    let mut gauges: Vec<(String, i64)> = super::metrics::all_gauges()
        .iter()
        .map(|g| (g.name().to_string(), g.get()))
        .collect();
    gauges.push(("oar_obs_span_ring_rows".to_string(), ring_len as i64));
    gauges.push(("oar_obs_span_ring_cap".to_string(), ring_cap as i64));
    let hists = super::metrics::all_hists().iter().map(|h| h.snapshot()).collect();
    MetricsSnapshot { version: SNAPSHOT_VERSION, counters, gauges, hists }
}

impl MetricsSnapshot {
    pub fn hist(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.hists.iter().find(|h| h.name == name)
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn gauge(&self, name: &str) -> Option<i64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::Num(self.version as f64)),
            (
                "counters",
                Json::Arr(
                    self.counters
                        .iter()
                        .map(|(n, v)| {
                            Json::Arr(vec![Json::Str(n.clone()), Json::Num(*v as f64)])
                        })
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Arr(
                    self.gauges
                        .iter()
                        .map(|(n, v)| {
                            Json::Arr(vec![Json::Str(n.clone()), Json::Num(*v as f64)])
                        })
                        .collect(),
                ),
            ),
            ("hists", Json::Arr(self.hists.iter().map(|h| h.to_json()).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> Option<MetricsSnapshot> {
        let version = j.get("v")?.as_f64()? as u64;
        let mut counters = Vec::new();
        for pair in j.get("counters")?.as_arr()? {
            let p = pair.as_arr()?;
            counters.push((p.first()?.as_str()?.to_string(), p.get(1)?.as_f64()? as u64));
        }
        let mut gauges = Vec::new();
        for pair in j.get("gauges")?.as_arr()? {
            let p = pair.as_arr()?;
            gauges.push((p.first()?.as_str()?.to_string(), p.get(1)?.as_f64()? as i64));
        }
        let mut hists = Vec::new();
        for h in j.get("hists")?.as_arr()? {
            hists.push(HistogramSnapshot::from_json(h)?);
        }
        Some(MetricsSnapshot { version, counters, gauges, hists })
    }

    /// Prometheus-style text exposition (`oar metrics`). One line per
    /// counter/gauge, and per histogram: `_count`, `_sum`, `_max`,
    /// quantile series and cumulative `_bucket{le=...}` lines.
    pub fn render_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, v) in &self.counters {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in &self.gauges {
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        }
        for h in &self.hists {
            let _ = writeln!(out, "# TYPE {} histogram", h.name);
            let _ = writeln!(out, "{}_count {}", h.name, h.count);
            let _ = writeln!(out, "{}_sum {}", h.name, h.sum);
            let _ = writeln!(out, "{}_max {}", h.name, h.max);
            let _ = writeln!(out, "{}{{quantile=\"0.5\"}} {}", h.name, h.p50());
            let _ = writeln!(out, "{}{{quantile=\"0.99\"}} {}", h.name, h.p99());
            let mut cum = 0u64;
            for (i, c) in h.buckets.iter().enumerate() {
                if *c == 0 {
                    continue;
                }
                cum += c;
                let le = bucket_le(i);
                if le == u64::MAX {
                    let _ = writeln!(out, "{}_bucket{{le=\"+Inf\"}} {cum}", h.name);
                } else {
                    let _ = writeln!(out, "{}_bucket{{le=\"{le}\"}} {cum}", h.name);
                }
            }
        }
        out
    }
}
