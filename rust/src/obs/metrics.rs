//! The metric catalogue: every metric in the tree, registered by
//! `static` name (docs/OBSERVABILITY.md documents each one).
//!
//! Naming scheme: `oar_<subsystem>_<what>[_<unit>]`, with `_total` for
//! counters. Units are microseconds (`_us`) unless stated otherwise.
//! Adding a metric means adding the `static` *and* its entry in the
//! `all_*` slice below — the obs test suite asserts the two stay in
//! sync (every name unique, every static enumerated).

use super::registry::{Counter, Gauge, Histogram};

// ---------------------------------------------------- central server ----

/// Whole scheduling round (plan + apply + launch dispatch).
pub static SCHED_ROUND_US: Histogram = Histogram::new("oar_sched_round_us", "us");
/// Plan phase: `Scheduler::round` under the db *read* guard (includes
/// the guard acquisition wait, reported separately below).
pub static SCHED_PLAN_US: Histogram = Histogram::new("oar_sched_plan_us", "us");
/// Apply phase: `apply_decision` under the db *write* guard, through
/// the group-commit WAL flush.
pub static SCHED_APPLY_US: Histogram = Histogram::new("oar_sched_apply_us", "us");
/// Scheduling rounds run.
pub static SCHED_ROUNDS: Counter = Counter::new("oar_sched_rounds_total");
/// One monitoring round: reachability sweep + state reconciliation.
pub static MONITOR_ROUND_US: Histogram = Histogram::new("oar_monitor_round_us", "us");

// ---------------------------------------------------------- db locks ----

/// Wait to acquire a shared db read guard.
pub static DB_READ_WAIT_US: Histogram = Histogram::new("oar_db_read_wait_us", "us");
/// Wait to acquire the exclusive db write guard.
pub static DB_WRITE_WAIT_US: Histogram = Histogram::new("oar_db_write_wait_us", "us");

// --------------------------------------------------------------- wal ----

/// One `Wal::append`: frame + buffer (group mode) or flush (immediate).
pub static WAL_APPEND_US: Histogram = Histogram::new("oar_wal_append_us", "us");
/// One group-commit flush (`WalCommit::commit` with a non-empty batch).
pub static WAL_FLUSH_US: Histogram = Histogram::new("oar_wal_flush_us", "us");
/// Bytes per flushed group-commit batch.
pub static WAL_BATCH_BYTES: Histogram = Histogram::new("oar_wal_batch_bytes", "bytes");
/// Records per flushed group-commit batch.
pub static WAL_BATCH_RECORDS: Histogram = Histogram::new("oar_wal_batch_records", "records");

// --------------------------------------------------------------- rpc ----

/// Requests dispatched (any method, any outcome).
pub static RPC_REQUESTS: Counter = Counter::new("oar_rpc_requests_total");
/// Requests currently inside `dispatch`.
pub static RPC_INFLIGHT: Gauge = Gauge::new("oar_rpc_inflight");

pub static RPC_PING_US: Histogram = Histogram::new("oar_rpc_ping_us", "us");
pub static RPC_SUB_US: Histogram = Histogram::new("oar_rpc_sub_us", "us");
pub static RPC_STAT_US: Histogram = Histogram::new("oar_rpc_stat_us", "us");
pub static RPC_DEL_US: Histogram = Histogram::new("oar_rpc_del_us", "us");
pub static RPC_HOLD_US: Histogram = Histogram::new("oar_rpc_hold_us", "us");
pub static RPC_RESUME_US: Histogram = Histogram::new("oar_rpc_resume_us", "us");
pub static RPC_LOAD_US: Histogram = Histogram::new("oar_rpc_load_us", "us");
pub static RPC_NODES_US: Histogram = Histogram::new("oar_rpc_nodes_us", "us");
pub static RPC_QUEUES_US: Histogram = Histogram::new("oar_rpc_queues_us", "us");
pub static RPC_METRICS_US: Histogram = Histogram::new("oar_rpc_metrics_us", "us");
pub static RPC_EVENTS_US: Histogram = Histogram::new("oar_rpc_events_us", "us");
/// Unknown or malformed-envelope requests (no recognized method).
pub static RPC_OTHER_US: Histogram = Histogram::new("oar_rpc_other_us", "us");

/// Per-method latency histogram; unrecognized methods share `other`.
pub fn rpc_method_hist(method: &str) -> &'static Histogram {
    match method {
        "ping" => &RPC_PING_US,
        "sub" => &RPC_SUB_US,
        "stat" => &RPC_STAT_US,
        "del" => &RPC_DEL_US,
        "hold" => &RPC_HOLD_US,
        "resume" => &RPC_RESUME_US,
        "load" => &RPC_LOAD_US,
        "nodes" => &RPC_NODES_US,
        "queues" => &RPC_QUEUES_US,
        "metrics" => &RPC_METRICS_US,
        "events" => &RPC_EVENTS_US,
        _ => &RPC_OTHER_US,
    }
}

/// One counter per stable error code (`rpc::proto::code`).
pub static RPC_ERR_BAD_REQUEST: Counter = Counter::new("oar_rpc_err_bad_request_total");
pub static RPC_ERR_UNSUPPORTED_VERSION: Counter =
    Counter::new("oar_rpc_err_unsupported_version_total");
pub static RPC_ERR_UNKNOWN_METHOD: Counter = Counter::new("oar_rpc_err_unknown_method_total");
pub static RPC_ERR_ADMISSION_REJECTED: Counter =
    Counter::new("oar_rpc_err_admission_rejected_total");
pub static RPC_ERR_BAD_FILTER: Counter = Counter::new("oar_rpc_err_bad_filter_total");
pub static RPC_ERR_NO_SUCH_JOB: Counter = Counter::new("oar_rpc_err_no_such_job_total");
pub static RPC_ERR_ILLEGAL_STATE: Counter = Counter::new("oar_rpc_err_illegal_state_total");
pub static RPC_ERR_SHUTTING_DOWN: Counter = Counter::new("oar_rpc_err_shutting_down_total");
pub static RPC_ERR_INTERNAL: Counter = Counter::new("oar_rpc_err_internal_total");
/// A code outside the stable set (future servers; never minted today).
pub static RPC_ERR_OTHER: Counter = Counter::new("oar_rpc_err_other_total");

/// Per-error-code counter; codes outside the stable set share `other`.
pub fn rpc_error_counter(code: &str) -> &'static Counter {
    match code {
        "bad_request" => &RPC_ERR_BAD_REQUEST,
        "unsupported_version" => &RPC_ERR_UNSUPPORTED_VERSION,
        "unknown_method" => &RPC_ERR_UNKNOWN_METHOD,
        "admission_rejected" => &RPC_ERR_ADMISSION_REJECTED,
        "bad_filter" => &RPC_ERR_BAD_FILTER,
        "no_such_job" => &RPC_ERR_NO_SUCH_JOB,
        "illegal_state" => &RPC_ERR_ILLEGAL_STATE,
        "shutting_down" => &RPC_ERR_SHUTTING_DOWN,
        "internal" => &RPC_ERR_INTERNAL,
        _ => &RPC_ERR_OTHER,
    }
}

// -------------------------------------------------------------- grid ----

/// Whole executive round (probe → reconcile → dispatch → close).
pub static GRID_ROUND_US: Histogram = Histogram::new("oar_grid_round_us", "us");
/// Probe phase: one bounded `load` per cluster.
pub static GRID_PROBE_US: Histogram = Histogram::new("oar_grid_probe_us", "us");
/// Reconcile phase: per-cluster `stat` + task-state convergence.
pub static GRID_RECONCILE_US: Histogram = Histogram::new("oar_grid_reconcile_us", "us");
/// Dispatch phase: intent records + remote `sub` calls.
pub static GRID_DISPATCH_US: Histogram = Histogram::new("oar_grid_dispatch_us", "us");

// ------------------------------------------------------- enumeration ----

pub fn all_counters() -> &'static [&'static Counter] {
    &[
        &SCHED_ROUNDS,
        &RPC_REQUESTS,
        &RPC_ERR_BAD_REQUEST,
        &RPC_ERR_UNSUPPORTED_VERSION,
        &RPC_ERR_UNKNOWN_METHOD,
        &RPC_ERR_ADMISSION_REJECTED,
        &RPC_ERR_BAD_FILTER,
        &RPC_ERR_NO_SUCH_JOB,
        &RPC_ERR_ILLEGAL_STATE,
        &RPC_ERR_SHUTTING_DOWN,
        &RPC_ERR_INTERNAL,
        &RPC_ERR_OTHER,
    ]
}

pub fn all_gauges() -> &'static [&'static Gauge] {
    &[&RPC_INFLIGHT]
}

pub fn all_hists() -> &'static [&'static Histogram] {
    &[
        &SCHED_ROUND_US,
        &SCHED_PLAN_US,
        &SCHED_APPLY_US,
        &MONITOR_ROUND_US,
        &DB_READ_WAIT_US,
        &DB_WRITE_WAIT_US,
        &WAL_APPEND_US,
        &WAL_FLUSH_US,
        &WAL_BATCH_BYTES,
        &WAL_BATCH_RECORDS,
        &RPC_PING_US,
        &RPC_SUB_US,
        &RPC_STAT_US,
        &RPC_DEL_US,
        &RPC_HOLD_US,
        &RPC_RESUME_US,
        &RPC_LOAD_US,
        &RPC_NODES_US,
        &RPC_QUEUES_US,
        &RPC_METRICS_US,
        &RPC_EVENTS_US,
        &RPC_OTHER_US,
        &GRID_ROUND_US,
        &GRID_PROBE_US,
        &GRID_RECONCILE_US,
        &GRID_DISPATCH_US,
    ]
}
