//! Observability (`obs`): in-process telemetry for the whole scheduler.
//!
//! The paper's §1 names "user-friendly logging information analysis" as
//! a first-class need; this module is the runtime half of that — a
//! zero-dependency telemetry layer answering "where does a scheduling
//! round spend its time, how long do readers wait on the `RwLock<Db>`,
//! how big are group-commit WAL batches" *on a live server*:
//!
//! - a global, lock-free **metrics registry** ([`registry`]): relaxed
//!   atomic counters, gauges and log2-bucketed latency histograms,
//!   registered by static name in the catalogue ([`metrics`]);
//! - **tracing spans** ([`Span::enter`]): RAII timing into histograms
//!   plus a bounded ring of recent [`SpanRecord`]s with parent/child
//!   nesting, for post-hoc round forensics;
//! - a deterministic, injectable **clock** ([`clock`]) so tests assert
//!   exact bucket placement.
//!
//! Exposure: the versioned `metrics` RPC method (typed
//! [`MetricsSnapshot`]), the Prometheus-style text exposition
//! (`oar metrics [--watch]`), and the `oar top` dashboard. See
//! docs/OBSERVABILITY.md for the catalogue and the overhead numbers.
//!
//! Invariant (machine-checked, docs/LINTS.md §R7): no metric or span
//! call executes while holding the db write guard's commit path or the
//! WAL sink lock — instrumentation times *across* those regions and
//! records after release.

pub mod clock;
pub mod metrics;
pub mod registry;
pub mod span;

pub use registry::{
    bucket_index, bucket_le, enabled, set_enabled, snapshot, Counter, DbCounters, Gauge,
    Histogram, HistogramSnapshot, MetricsSnapshot, BUCKETS, SNAPSHOT_VERSION,
};
pub use span::{
    recent_spans, ring_stats, set_ring_capacity, Span, SpanRecord, DEFAULT_RING_CAPACITY,
};

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may panic on broken expectations
mod tests {
    use super::*;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn bucket_le_is_inclusive_upper_bound() {
        // Every representable value lands in a bucket whose `le` bounds it.
        for v in [0u64, 1, 2, 3, 4, 7, 8, 1000, 1 << 20] {
            let i = bucket_index(v);
            assert!(v <= bucket_le(i), "v={v} i={i} le={}", bucket_le(i));
            if i > 0 {
                assert!(v > bucket_le(i - 1), "v={v} not above lower bucket");
            }
        }
    }

    #[test]
    fn catalogue_names_are_unique_and_enumerated() {
        let mut names: Vec<&str> = metrics::all_counters().iter().map(|c| c.name()).collect();
        names.extend(metrics::all_gauges().iter().map(|g| g.name()));
        names.extend(metrics::all_hists().iter().map(|h| h.name()));
        let total = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), total, "duplicate metric name in the catalogue");
        assert!(names.iter().all(|n| n.starts_with("oar_")));
    }

    #[test]
    fn rpc_lookups_cover_the_protocol() {
        for m in [
            "ping", "sub", "stat", "del", "hold", "resume", "load", "nodes", "queues",
            "metrics", "events",
        ] {
            assert_ne!(metrics::rpc_method_hist(m).name(), "oar_rpc_other_us", "{m}");
        }
        assert_eq!(metrics::rpc_method_hist("nope").name(), "oar_rpc_other_us");
        for c in crate::rpc::proto::code::ALL {
            assert_ne!(
                metrics::rpc_error_counter(c).name(),
                "oar_rpc_err_other_total",
                "{c}"
            );
        }
        assert_eq!(
            metrics::rpc_error_counter("martian").name(),
            "oar_rpc_err_other_total"
        );
    }

    #[test]
    fn snapshot_roundtrips_through_json() {
        metrics::SCHED_ROUNDS.inc();
        metrics::SCHED_PLAN_US.observe(5);
        metrics::SCHED_PLAN_US.observe(5000);
        let snap = snapshot(Some(&DbCounters { view_hits: 7, ..DbCounters::default() }));
        let back = MetricsSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
        assert_eq!(back.counter("oar_db_view_hits_total"), Some(7));
        assert!(back.counter("oar_sched_rounds_total").unwrap() >= 1);
        let h = back.hist("oar_sched_plan_us").unwrap();
        assert_eq!(h.buckets.iter().sum::<u64>(), h.count);
    }

    #[test]
    fn text_exposition_has_one_line_per_scalar() {
        let snap = snapshot(None);
        let text = snap.render_text();
        assert!(text.contains("# TYPE oar_rpc_requests_total counter"));
        assert!(text.contains("# TYPE oar_rpc_inflight gauge"));
        assert!(text.contains("# TYPE oar_sched_plan_us histogram"));
        assert!(text.contains("oar_sched_plan_us_count"));
    }
}
