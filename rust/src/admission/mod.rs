//! Admission rules (§2.1): "a connection to the database to get the
//! appropriate admission rules. These rules are used to set the value of
//! parameters that are not provided by the user and to check the validity
//! of the submission. ... The rules are stored as Perl code in the
//! database".
//!
//! The paper stores executable rule code in a table; we store a small rule
//! DSL (conditions are the same SQL expressions the rest of the system
//! uses) in the `admission_rules` table and interpret it here:
//!
//! ```text
//! DEFAULT <field> = <literal>              # set when absent
//! IF <where-expr> THEN SET <field> = <literal>
//! IF <where-expr> THEN REJECT '<message>'
//! ```
//!
//! Conditions see the submission as a row: `user`, `command`, `nbNodes`,
//! `weight`, `maxTime` (NULL when unset), `queue` (NULL when unset),
//! `bestEffort`, `interactive`, `reservation` (requested start or NULL),
//! `resources` (the canonical hierarchical request, or NULL for flat
//! submissions — by the time rules run, `nbNodes`/`weight` already hold
//! the flat equivalent of the first alternative).
//! After the stored rules run, two built-in checks apply, mirroring the
//! paper's defaults: the target queue must exist and be active, and the
//! job must not exceed the queue's `max_procs_per_job` ("no user ask for
//! too much resources at once").

use crate::db::{Db, Expr, Row, Value};
use crate::types::{JobKind, JobSpec};
use crate::Result;

/// A parsed admission rule.
#[derive(Debug, Clone)]
pub enum Rule {
    Default { field: String, value: Value },
    Set { cond: Expr, field: String, value: Value },
    Reject { cond: Expr, message: String },
}

impl Rule {
    /// Parse one rule line (comments start with `#`).
    pub fn parse(line: &str) -> Result<Option<Rule>> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(None);
        }
        if let Some(rest) = strip_kw(line, "DEFAULT") {
            let (field, value) = parse_assignment(rest)?;
            return Ok(Some(Rule::Default { field, value }));
        }
        if let Some(rest) = strip_kw(line, "IF") {
            let Some(idx) = find_kw(rest, "THEN") else {
                anyhow::bail!("IF rule missing THEN: {line:?}");
            };
            let cond = Expr::parse(&rest[..idx])
                .map_err(|e| anyhow::anyhow!("bad condition in {line:?}: {e}"))?;
            let action = rest[idx + 4..].trim();
            if let Some(rest) = strip_kw(action, "SET") {
                let (field, value) = parse_assignment(rest)?;
                return Ok(Some(Rule::Set { cond, field, value }));
            }
            if let Some(rest) = strip_kw(action, "REJECT") {
                let message = rest.trim().trim_matches('\'').to_string();
                return Ok(Some(Rule::Reject { cond, message }));
            }
            anyhow::bail!("unknown action in {line:?}");
        }
        anyhow::bail!("unknown rule syntax: {line:?}");
    }
}

fn strip_kw<'a>(s: &'a str, kw: &str) -> Option<&'a str> {
    let s = s.trim_start();
    if s.len() >= kw.len() && s[..kw.len()].eq_ignore_ascii_case(kw) {
        Some(&s[kw.len()..])
    } else {
        None
    }
}

fn find_kw(s: &str, kw: &str) -> Option<usize> {
    let upper = s.to_ascii_uppercase();
    let pat = format!(" {kw} ");
    upper.find(&pat).map(|i| i + 1)
}

fn parse_assignment(s: &str) -> Result<(String, Value)> {
    let mut parts = s.splitn(2, '=');
    let field = parts
        .next()
        .map(str::trim)
        .filter(|f| !f.is_empty())
        .ok_or_else(|| anyhow::anyhow!("assignment missing field"))?;
    let raw = parts
        .next()
        .map(str::trim)
        .ok_or_else(|| anyhow::anyhow!("assignment missing value"))?;
    let value = match Expr::parse(raw).map_err(|e| anyhow::anyhow!("bad literal {raw:?}: {e}"))? {
        Expr::Literal(v) => v,
        _ => anyhow::bail!("assignment value must be a literal: {raw:?}"),
    };
    Ok((field.to_string(), value))
}

/// The default rule set installed into a fresh database — the behaviour
/// §2.1 describes.
pub const DEFAULT_RULES: &[(i32, &str)] = &[
    (10, "IF bestEffort = TRUE THEN SET queue = 'besteffort'"),
    (20, "DEFAULT queue = 'default'"),
    (30, "IF nbNodes <= 0 THEN REJECT 'nbNodes must be positive'"),
    (31, "IF weight <= 0 THEN REJECT 'weight must be positive'"),
    (40, "IF maxTime <= 0 THEN REJECT 'maxTime must be positive'"),
];

/// Install [`DEFAULT_RULES`] into a database.
pub fn install_default_rules(db: &mut Db) {
    for (prio, src) in DEFAULT_RULES {
        db.add_admission_rule(*prio, src);
    }
}

/// Outcome of the admission process.
#[derive(Debug, Clone, PartialEq)]
pub enum Admission {
    /// Completed spec (all parameters filled), ready for insertion.
    Accepted(JobSpec),
    Rejected(String),
}

/// Run the admission process: stored rules (priority order), then the
/// built-in queue checks. Reads the rules and queues from the database —
/// exactly the two round-trips the paper's submission makes.
pub fn admit(db: &mut Db, spec: &JobSpec) -> Result<Admission> {
    let mut spec = spec.clone();
    // Hierarchical requests first: parse with the total grammar (typed
    // errors, never a panic), derive the flat equivalent of the first
    // alternative so the stored rules and built-in checks see honest
    // `nbNodes`/`weight`, default `maxTime` from the walltime, and
    // store the canonical printed form on the job row.
    if let Some(raw) = spec.resources.clone() {
        let req = match crate::resources::parse_request(&raw) {
            Ok(r) => r,
            Err(e) => return Ok(Admission::Rejected(format!("bad resource request: {e}"))),
        };
        if let Some(first) = req.alternatives.first() {
            // The parser rejected any shape whose totals overflow, so
            // the flat equivalent is always computable here.
            let shape = first.shape().map_err(|e| anyhow::anyhow!("{e}"))?;
            spec.nb_nodes = shape.total_hosts().unwrap_or(u32::MAX);
            spec.weight = shape.weight();
        }
        if spec.max_time.is_none() {
            spec.max_time = req.walltime();
        }
        spec.resources = Some(req.to_string());
    }
    let rules = db.admission_rules();
    for (_prio, source) in rules {
        for line in source.lines() {
            let Some(rule) = Rule::parse(line)? else {
                continue;
            };
            let row = spec_row(&spec);
            match rule {
                Rule::Default { field, value } => {
                    if row.get(field.as_str()).map(Value::is_null).unwrap_or(true) {
                        apply_field(&mut spec, &field, &value)?;
                    }
                }
                Rule::Set { cond, field, value } => {
                    if cond.matches(&row) {
                        apply_field(&mut spec, &field, &value)?;
                    }
                }
                Rule::Reject { cond, message } => {
                    if cond.matches(&row) {
                        return Ok(Admission::Rejected(message));
                    }
                }
            }
        }
    }

    // Built-in: queue must exist and be active; fill queue defaults.
    let qname = spec.queue.clone().unwrap_or_else(|| "default".into());
    let queue = match db.queue(&qname) {
        Ok(q) => q,
        Err(_) => return Ok(Admission::Rejected(format!("no such queue: {qname}"))),
    };
    if !queue.active {
        return Ok(Admission::Rejected(format!("queue {qname} is closed")));
    }
    spec.queue = Some(queue.name.clone());
    if spec.max_time.is_none() {
        spec.max_time = Some(queue.default_max_time);
    }
    // `nbNodes * weight` can overflow u32 on adversarial submissions; a
    // wrapped product would sail under the queue limit, so overflow is a
    // typed rejection, never an arithmetic wrap.
    let Some(total) = spec.checked_total_procs() else {
        return Ok(Admission::Rejected(format!(
            "nbNodes {} x weight {} overflows the processor count",
            spec.nb_nodes, spec.weight
        )));
    };
    if total > queue.max_procs_per_job {
        return Ok(Admission::Rejected(format!(
            "requests {} procs > queue limit {}",
            total, queue.max_procs_per_job
        )));
    }
    // Every moldable alternative must respect the queue limit too — the
    // scheduler may pick any of them later, unsupervised.
    if let Some(r) = &spec.resources {
        if let Ok(req) = crate::resources::parse_request(r) {
            for alt in &req.alternatives {
                let procs = alt.shape().ok().and_then(|s| s.total_procs());
                if procs.map(|p| p > queue.max_procs_per_job).unwrap_or(true) {
                    return Ok(Admission::Rejected(format!(
                        "alternative {alt} exceeds queue limit {}",
                        queue.max_procs_per_job
                    )));
                }
            }
        }
    }
    Ok(Admission::Accepted(spec))
}

fn spec_row(spec: &JobSpec) -> Row {
    let mut row = Row::new();
    row.insert("user".into(), Value::Text(spec.user.clone()));
    row.insert("command".into(), Value::Text(spec.command.clone()));
    row.insert("nbNodes".into(), Value::Int(spec.nb_nodes as i64));
    row.insert("weight".into(), Value::Int(spec.weight as i64));
    row.insert(
        "maxTime".into(),
        spec.max_time.map(Value::Int).unwrap_or(Value::Null),
    );
    row.insert(
        "queue".into(),
        spec.queue.clone().map(Value::Text).unwrap_or(Value::Null),
    );
    row.insert("bestEffort".into(), Value::Bool(spec.best_effort));
    row.insert(
        "interactive".into(),
        Value::Bool(spec.kind == JobKind::Interactive),
    );
    row.insert(
        "reservation".into(),
        spec.reservation_start.map(Value::Int).unwrap_or(Value::Null),
    );
    row.insert(
        "resources".into(),
        spec.resources
            .clone()
            .map(Value::Text)
            .unwrap_or(Value::Null),
    );
    row
}

fn apply_field(spec: &mut JobSpec, field: &str, value: &Value) -> Result<()> {
    match field {
        "queue" => {
            spec.queue = value.as_str().map(str::to_string);
        }
        "maxTime" => {
            spec.max_time = value.as_i64();
        }
        "nbNodes" => {
            spec.nb_nodes = value.as_i64().unwrap_or(1) as u32;
        }
        "weight" => {
            spec.weight = value.as_i64().unwrap_or(1) as u32;
        }
        "bestEffort" => {
            spec.best_effort = value.is_truthy();
        }
        other => anyhow::bail!("admission rule sets unknown field {other:?}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Queue;

    fn db() -> Db {
        let mut db = Db::with_standard_queues();
        install_default_rules(&mut db);
        db
    }

    #[test]
    fn fills_missing_queue_and_max_time() {
        let mut db = db();
        let spec = JobSpec {
            max_time: None,
            queue: None,
            ..JobSpec::default()
        };
        match admit(&mut db, &spec).unwrap() {
            Admission::Accepted(s) => {
                assert_eq!(s.queue.as_deref(), Some("default"));
                assert_eq!(s.max_time, Some(3600), "queue default applied");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn routes_best_effort_to_besteffort_queue() {
        let mut db = db();
        let spec = JobSpec {
            best_effort: true,
            ..JobSpec::default()
        };
        match admit(&mut db, &spec).unwrap() {
            Admission::Accepted(s) => assert_eq!(s.queue.as_deref(), Some("besteffort")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_over_limit_requests() {
        let mut db = db();
        db.add_queue(Queue {
            max_procs_per_job: 8,
            ..Queue::new("small", 5, crate::types::QueuePolicyKind::FifoConservative)
        });
        let spec = JobSpec {
            nb_nodes: 16,
            queue: Some("small".into()),
            max_time: Some(60),
            ..JobSpec::default()
        };
        assert!(matches!(
            admit(&mut db, &spec).unwrap(),
            Admission::Rejected(m) if m.contains("queue limit")
        ));
    }

    #[test]
    fn rejects_overflowing_proc_requests_typed() {
        let mut db = db();
        let spec = JobSpec {
            nb_nodes: u32::MAX,
            weight: 2,
            max_time: Some(60),
            ..JobSpec::default()
        };
        assert!(matches!(
            admit(&mut db, &spec).unwrap(),
            Admission::Rejected(m) if m.contains("overflows")
        ));
    }

    #[test]
    fn hierarchical_request_derives_flat_shape_and_walltime() {
        let mut db = db();
        let spec = JobSpec {
            resources: Some("/switch=2/host=3/core=4,  walltime=0:30:0".into()),
            max_time: None,
            ..JobSpec::default()
        };
        match admit(&mut db, &spec).unwrap() {
            Admission::Accepted(s) => {
                assert_eq!(s.nb_nodes, 6, "2 switches x 3 hosts");
                assert_eq!(s.weight, 4);
                assert_eq!(s.max_time, Some(1800), "walltime fills maxTime");
                assert_eq!(
                    s.resources.as_deref(),
                    Some("/switch=2/host=3/core=4,walltime=0:30:0"),
                    "canonicalized"
                );
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_resource_request_is_a_typed_rejection() {
        let mut db = db();
        let spec = JobSpec {
            resources: Some("/rack=9".into()),
            ..JobSpec::default()
        };
        assert!(matches!(
            admit(&mut db, &spec).unwrap(),
            Admission::Rejected(m) if m.contains("unknown resource level")
        ));
    }

    #[test]
    fn every_moldable_alternative_respects_the_queue_limit() {
        let mut db = db();
        db.add_queue(Queue {
            max_procs_per_job: 8,
            ..Queue::new("small", 5, crate::types::QueuePolicyKind::FifoConservative)
        });
        // First alternative fits (8 procs), second does not (16).
        let spec = JobSpec {
            resources: Some("/host=4/core=2 | /host=4/core=4".into()),
            queue: Some("small".into()),
            max_time: Some(60),
            ..JobSpec::default()
        };
        assert!(matches!(
            admit(&mut db, &spec).unwrap(),
            Admission::Rejected(m) if m.contains("alternative")
        ));
    }

    #[test]
    fn rejects_bad_queue_and_closed_queue() {
        let mut db = db();
        let spec = JobSpec {
            queue: Some("nope".into()),
            ..JobSpec::default()
        };
        assert!(matches!(admit(&mut db, &spec).unwrap(), Admission::Rejected(_)));
        db.set_queue_active("default", false).unwrap();
        let spec = JobSpec::default();
        assert!(matches!(
            admit(&mut db, &spec).unwrap(),
            Admission::Rejected(m) if m.contains("closed")
        ));
    }

    #[test]
    fn custom_rule_reject_by_user() {
        let mut db = db();
        db.add_admission_rule(5, "IF user = 'mallory' THEN REJECT 'banned'");
        let spec = JobSpec {
            user: "mallory".into(),
            ..JobSpec::default()
        };
        assert_eq!(
            admit(&mut db, &spec).unwrap(),
            Admission::Rejected("banned".into())
        );
    }

    #[test]
    fn custom_rule_caps_interactive_time() {
        let mut db = db();
        db.add_admission_rule(
            50,
            "IF interactive = TRUE AND maxTime > 7200 THEN SET maxTime = 7200",
        );
        let spec = JobSpec {
            kind: JobKind::Interactive,
            max_time: Some(100_000),
            ..JobSpec::default()
        };
        match admit(&mut db, &spec).unwrap() {
            Admission::Accepted(s) => assert_eq!(s.max_time, Some(7200)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rule_parse_errors_are_reported() {
        assert!(Rule::parse("IF x THEN").is_err());
        assert!(Rule::parse("FOO bar").is_err());
        assert!(Rule::parse("# comment").unwrap().is_none());
        assert!(Rule::parse("").unwrap().is_none());
    }

    #[test]
    fn default_does_not_override_user_value() {
        let mut db = db();
        let spec = JobSpec {
            queue: Some("besteffort".into()),
            max_time: Some(42),
            ..JobSpec::default()
        };
        match admit(&mut db, &spec).unwrap() {
            Admission::Accepted(s) => {
                assert_eq!(s.queue.as_deref(), Some("besteffort"));
                assert_eq!(s.max_time, Some(42));
            }
            other => panic!("{other:?}"),
        }
    }
}
