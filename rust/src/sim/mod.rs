//! Discrete-event simulation of a whole scheduler run.
//!
//! The ESP2 evaluation (§3.2.1) runs 230 jobs over hours of wall time; the
//! simulator executes the same scheduler *policies* over simulated time so
//! the full benchmark takes milliseconds. The scheduling code under test
//! is exactly the production code ([`crate::sched::policies`] /
//! [`crate::sched::baselines`]): the simulator only replaces wall-clock,
//! job execution and the launcher with event bookkeeping.
//!
//! Model, mirroring the real system's behaviour:
//! * a scheduling round fires at every event (arrival or completion) —
//!   the notification-driven reactivity of §2.2;
//! * started jobs complete after their *actual* runtime (≤ `maxTime`);
//! * per-job launch overhead is charged to the start time, reproducing
//!   "the overhead of launching each individual job" that ESP measures.

use std::collections::BinaryHeap;

use crate::sched::gantt::Gantt;
use crate::sched::policies::{PolicyJob, QueuePolicy};
use crate::types::{JobId, NodeId, Time};

/// One workload job for the simulator.
#[derive(Debug, Clone)]
pub struct SimJob {
    pub id: JobId,
    /// Nodes requested (simulation treats processors as 1-proc nodes).
    pub nb_nodes: u32,
    pub weight: u32,
    /// Actual execution time.
    pub runtime: Time,
    /// Requested limit (what the scheduler plans with).
    pub max_time: Time,
    pub submit: Time,
}

impl SimJob {
    /// Saturating like [`crate::types::Job::total_procs`]: synthetic
    /// workload generators can hand in adversarial shapes.
    pub fn total_procs(&self) -> u32 {
        self.nb_nodes.saturating_mul(self.weight)
    }
}

/// Per-job outcome.
#[derive(Debug, Clone, Copy)]
pub struct JobRecord {
    pub id: JobId,
    pub submit: Time,
    pub start: Time,
    pub stop: Time,
    pub procs: u32,
}

impl JobRecord {
    pub fn response_time(&self) -> Time {
        self.stop - self.submit
    }

    pub fn wait_time(&self) -> Time {
        self.start - self.submit
    }
}

/// Result of one simulated run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub records: Vec<JobRecord>,
    /// (time, busy processors) at every change point — the plain line of
    /// figs. 4–8.
    pub utilization: Vec<(Time, u32)>,
    /// (start time, procs) per started job — the dashed markers of the
    /// figures.
    pub starts: Vec<(Time, u32)>,
    pub total_procs: u32,
}

impl SimResult {
    /// Time the last job completes (ESP's "Elapsed Time").
    pub fn elapsed(&self) -> Time {
        self.records.iter().map(|r| r.stop).max().unwrap_or(0)
    }

    /// Σ procs·runtime — the jobmix work in CPU-seconds.
    pub fn total_work(&self) -> i64 {
        self.records
            .iter()
            .map(|r| (r.stop - r.start) * r.procs as i64)
            .sum()
    }

    /// ESP efficiency: work / (procs × elapsed).
    pub fn efficiency(&self) -> f64 {
        let e = self.elapsed();
        if e == 0 {
            return 0.0;
        }
        self.total_work() as f64 / (self.total_procs as f64 * e as f64)
    }

    pub fn mean_response_time(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(JobRecord::response_time).sum::<Time>() as f64
            / self.records.len() as f64
    }

    pub fn mean_wait_time(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(JobRecord::wait_time).sum::<Time>() as f64
            / self.records.len() as f64
    }

    /// Maximum wait time — the famine indicator of §3.2.1.
    pub fn max_wait_time(&self) -> Time {
        self.records.iter().map(JobRecord::wait_time).max().unwrap_or(0)
    }
}

#[derive(Debug, PartialEq, Eq)]
enum Event {
    Arrival(usize),
    Completion(JobId),
}

/// Simulator configuration.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimConfig {
    /// Fixed overhead added to every job start (scheduler + launcher cost
    /// per job, the quantity ESP stresses).
    pub launch_overhead: Time,
}

/// Run `policy` over `jobs` on a cluster of `nodes`.
pub fn simulate(
    policy: &dyn QueuePolicy,
    nodes: &[(NodeId, u32)],
    jobs: &[SimJob],
    config: SimConfig,
) -> SimResult {
    // Event queue keyed by (time, seq) for determinism.
    let mut heap: BinaryHeap<std::cmp::Reverse<(Time, u64, usize)>> = BinaryHeap::new();
    let mut event_payload: Vec<Event> = Vec::new();
    let mut seq = 0u64;
    let push = |heap: &mut BinaryHeap<std::cmp::Reverse<(Time, u64, usize)>>,
                    payload: &mut Vec<Event>,
                    t: Time,
                    ev: Event,
                    seq: &mut u64| {
        payload.push(ev);
        heap.push(std::cmp::Reverse((t, *seq, payload.len() - 1)));
        *seq += 1;
    };

    for (i, j) in jobs.iter().enumerate() {
        push(&mut heap, &mut event_payload, j.submit, Event::Arrival(i), &mut seq);
    }

    let total_procs: u32 = nodes.iter().map(|(_, p)| p).sum();
    let mut waiting: Vec<usize> = Vec::new();
    let mut running: Vec<(JobId, Vec<NodeId>, Time, Time)> = Vec::new(); // id, nodes, start, stop
    let mut records = Vec::with_capacity(jobs.len());
    let mut utilization = vec![(0, 0u32)];
    let mut starts = Vec::new();
    let mut busy = 0u32;

    let by_id = |id: JobId| jobs.iter().position(|j| j.id == id).unwrap();

    while let Some(std::cmp::Reverse((now, _, idx))) = heap.pop() {
        match &event_payload[idx] {
            Event::Arrival(i) => waiting.push(*i),
            Event::Completion(id) => {
                let pos = running.iter().position(|(jid, ..)| jid == id).unwrap();
                let (jid, _nodes, start, stop) = running.remove(pos);
                let job = &jobs[by_id(jid)];
                busy -= job.total_procs();
                utilization.push((now, busy));
                records.push(JobRecord {
                    id: jid,
                    submit: job.submit,
                    start,
                    stop,
                    procs: job.total_procs(),
                });
            }
        }

        // Drain simultaneous events before scheduling.
        if let Some(std::cmp::Reverse((t, ..))) = heap.peek() {
            if *t == now {
                continue;
            }
        }

        if waiting.is_empty() {
            continue;
        }

        // Scheduling round: rebuild the Gantt from running jobs (the
        // meta-scheduler's behaviour — no hidden state between rounds).
        let mut gantt = Gantt::new(nodes);
        for (jid, nids, _start, stop) in &running {
            let job = &jobs[by_id(*jid)];
            for n in nids {
                gantt.occupy(*jid, *n, job.weight, now, (*stop).max(now + 1));
            }
        }
        let node_ids: Vec<NodeId> = nodes.iter().map(|(id, _)| *id).collect();
        let policy_jobs: Vec<PolicyJob> = waiting
            .iter()
            .map(|&i| {
                let j = &jobs[i];
                PolicyJob {
                    id: j.id,
                    nb_nodes: j.nb_nodes,
                    weight: j.weight,
                    duration: j.max_time.max(1),
                    submission_time: j.submit,
                    eligible: node_ids.clone(),
                    best_effort: false,
                    score: 0.0,
                    alts: vec![],
                }
            })
            .collect();
        let started = policy.schedule(now, &policy_jobs, &mut gantt);
        for (id, nids) in started {
            let i = by_id(id);
            let job = &jobs[i];
            let start = now;
            let stop = now + config.launch_overhead + job.runtime;
            running.push((id, nids, start, stop));
            waiting.retain(|&w| w != i);
            busy += job.total_procs();
            utilization.push((now, busy));
            starts.push((now, job.total_procs()));
            push(&mut heap, &mut event_payload, stop, Event::Completion(id), &mut seq);
        }
    }

    records.sort_by_key(|r| r.id);
    SimResult {
        records,
        utilization,
        starts,
        total_procs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::baselines::{MauiLike, SgeLike, TorqueLike};
    use crate::sched::policies::{FifoConservative, SjfConservative};

    fn nodes(n: u32) -> Vec<(NodeId, u32)> {
        (1..=n).map(|i| (i, 1)).collect()
    }

    fn job(id: JobId, procs: u32, runtime: Time, submit: Time) -> SimJob {
        SimJob {
            id,
            nb_nodes: procs,
            weight: 1,
            runtime,
            max_time: runtime,
            submit,
        }
    }

    #[test]
    fn single_job_runs_to_completion() {
        let r = simulate(&FifoConservative, &nodes(2), &[job(1, 2, 100, 0)], SimConfig::default());
        assert_eq!(r.records.len(), 1);
        assert_eq!(r.records[0].start, 0);
        assert_eq!(r.records[0].stop, 100);
        assert_eq!(r.elapsed(), 100);
        assert!((r.efficiency() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn serial_when_machine_too_small() {
        let js = [job(1, 2, 100, 0), job(2, 2, 100, 0)];
        let r = simulate(&FifoConservative, &nodes(2), &js, SimConfig::default());
        assert_eq!(r.elapsed(), 200);
        assert_eq!(r.records[1].start, 100);
    }

    #[test]
    fn parallel_when_room() {
        let js = [job(1, 1, 100, 0), job(2, 1, 100, 0)];
        let r = simulate(&FifoConservative, &nodes(2), &js, SimConfig::default());
        assert_eq!(r.elapsed(), 100);
    }

    #[test]
    fn launch_overhead_extends_completion() {
        let r = simulate(
            &FifoConservative,
            &nodes(1),
            &[job(1, 1, 100, 0)],
            SimConfig { launch_overhead: 5 },
        );
        assert_eq!(r.records[0].stop, 105);
    }

    #[test]
    fn all_policies_complete_all_jobs() {
        let js: Vec<SimJob> = (0..20)
            .map(|i| job(i + 1, 1 + (i % 4) as u32, 50 + 10 * (i % 3) as Time, 0))
            .collect();
        let policies: Vec<Box<dyn QueuePolicy>> = vec![
            Box::new(FifoConservative),
            Box::new(SjfConservative),
            Box::new(TorqueLike),
            Box::new(SgeLike),
            Box::new(MauiLike),
        ];
        for p in policies {
            let r = simulate(p.as_ref(), &nodes(4), &js, SimConfig::default());
            assert_eq!(r.records.len(), js.len(), "{}", p.name());
            // conservation: work is invariant across schedulers
            assert_eq!(
                r.total_work(),
                js.iter().map(|j| j.runtime * j.total_procs() as i64).sum::<i64>(),
                "{}",
                p.name()
            );
        }
    }

    #[test]
    fn fifo_avoids_famine_better_than_sge() {
        // A stream of small jobs + one big job early: greedy small-first
        // (SGE) delays the big job far longer than FIFO-conservative.
        let mut js = vec![job(1, 4, 50, 0)]; // big
        for i in 0..40 {
            js.push(job(i + 2, 1, 50, 1 + i as Time));
        }
        let fifo = simulate(&FifoConservative, &nodes(4), &js, SimConfig::default());
        let sge = simulate(&SgeLike, &nodes(4), &js, SimConfig::default());
        let fifo_big = fifo.records.iter().find(|r| r.id == 1).unwrap();
        let sge_big = sge.records.iter().find(|r| r.id == 1).unwrap();
        assert!(
            fifo_big.start <= sge_big.start,
            "fifo {} vs sge {}",
            fifo_big.start,
            sge_big.start
        );
    }

    #[test]
    fn utilization_trace_is_consistent() {
        let js = [job(1, 2, 100, 0), job(2, 1, 50, 0)];
        let r = simulate(&FifoConservative, &nodes(3), &js, SimConfig::default());
        // trace never exceeds capacity and ends at 0
        assert!(r.utilization.iter().all(|(_, b)| *b <= 3));
        assert_eq!(r.utilization.last().unwrap().1, 0);
        assert_eq!(r.starts.len(), 2);
    }
}
