//! PJRT runtime: load the AOT-compiled `schedule_step` HLO artifact and
//! execute it from the Rust hot path. Python never runs at request time —
//! `make artifacts` produced `artifacts/schedule_step.hlo.txt` once, and
//! this module compiles it on the in-process PJRT CPU client at startup.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` for why).
//!
//! The PJRT client comes from the `xla` crate, which is not available in
//! offline builds: everything touching it is behind the `pjrt` cargo
//! feature. Without the feature, [`HloStep`] is a stub whose loaders
//! always fail, and [`HloStep::best_available`] falls back to the
//! bit-identical pure-Rust [`crate::matching::ReferenceStep`].

use std::path::PathBuf;

/// Conventional artifact location relative to the crate root.
fn artifact_path() -> PathBuf {
    PathBuf::from(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/artifacts/schedule_step.hlo.txt"
    ))
}

#[cfg(feature = "pjrt")]
pub use pjrt::HloStep;

#[cfg(not(feature = "pjrt"))]
pub use stub::HloStep;

#[cfg(feature = "pjrt")]
mod pjrt {
    use std::path::{Path, PathBuf};

    use crate::matching::shapes::{F, J, N, P, T};
    use crate::matching::{ScheduleStep, StepInput, StepOutput};
    use crate::Result;

    /// The dense engine backed by the AOT artifact.
    pub struct HloStep {
        exe: xla::PjRtLoadedExecutable,
        /// Artifact path, for diagnostics.
        pub path: PathBuf,
    }

    impl HloStep {
        /// Conventional artifact location relative to the repo root.
        pub fn default_artifact() -> PathBuf {
            super::artifact_path()
        }

        /// Load + compile the artifact on the PJRT CPU client.
        pub fn load(path: &Path) -> Result<HloStep> {
            let client = xla::PjRtClient::cpu().map_err(wrap)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )
            .map_err(wrap)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(wrap)?;
            Ok(HloStep {
                exe,
                path: path.to_path_buf(),
            })
        }

        /// Load from the default location; `Err` when artifacts are not built.
        pub fn load_default() -> Result<HloStep> {
            Self::load(&Self::default_artifact())
        }

        /// Best engine available: the HLO artifact when present, otherwise the
        /// pure-Rust reference (bit-identical semantics).
        pub fn best_available() -> Box<dyn ScheduleStep> {
            match Self::load_default() {
                Ok(h) => Box::new(h),
                Err(_) => Box::new(crate::matching::ReferenceStep),
            }
        }
    }

    fn wrap(e: xla::Error) -> anyhow::Error {
        anyhow::anyhow!("xla: {e}")
    }

    fn literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let expect: i64 = dims.iter().product();
        anyhow::ensure!(
            expect as usize == data.len(),
            "shape {:?} != len {}",
            dims,
            data.len()
        );
        if dims.len() == 1 {
            return Ok(xla::Literal::vec1(data));
        }
        xla::Literal::vec1(data).reshape(dims).map_err(wrap)
    }

    impl ScheduleStep for HloStep {
        fn run(&mut self, input: &StepInput) -> Result<StepOutput> {
            let args = [
                literal(&input.job_lo, &[J as i64, P as i64])?,
                literal(&input.job_hi, &[J as i64, P as i64])?,
                literal(&input.node_props, &[N as i64, P as i64])?,
                literal(&input.node_free, &[N as i64, T as i64])?,
                literal(&input.req, &[J as i64])?,
                literal(&input.dur, &[J as i64])?,
                literal(&input.job_feats, &[J as i64, F as i64])?,
                literal(&input.weights, &[F as i64])?,
            ];
            let result = self.exe.execute::<xla::Literal>(&args).map_err(wrap)?[0][0]
                .to_literal_sync()
                .map_err(wrap)?;
            // Lowered with return_tuple=True: one tuple of 4 arrays.
            let parts = result.to_tuple().map_err(wrap)?;
            anyhow::ensure!(parts.len() == 4, "expected 4 outputs, got {}", parts.len());
            let mut it = parts.into_iter();
            let elig = it.next().unwrap().to_vec::<f32>().map_err(wrap)?;
            let freecount = it.next().unwrap().to_vec::<f32>().map_err(wrap)?;
            let earliest = it.next().unwrap().to_vec::<f32>().map_err(wrap)?;
            let scores = it.next().unwrap().to_vec::<f32>().map_err(wrap)?;
            anyhow::ensure!(elig.len() == J * N, "elig shape");
            anyhow::ensure!(freecount.len() == J * T, "freecount shape");
            anyhow::ensure!(earliest.len() == J, "earliest shape");
            anyhow::ensure!(scores.len() == J, "scores shape");
            Ok(StepOutput {
                elig,
                freecount,
                earliest,
                scores,
            })
        }

        fn engine_name(&self) -> &'static str {
            "hlo_pjrt"
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        /// Runs only when `make artifacts` has produced the HLO file; the
        /// dedicated integration test (`runtime_vs_reference`) does the full
        /// numeric comparison.
        #[test]
        fn loads_and_runs_artifact_when_present() {
            let path = HloStep::default_artifact();
            if !path.exists() {
                eprintln!("skipping: {} not built", path.display());
                return;
            }
            let mut step = HloStep::load(&path).unwrap();
            let out = step.run(&StepInput::zeros()).unwrap();
            assert_eq!(out.elig.len(), J * N);
            // zero input: padding jobs have lo=0 <= prop=0 <= hi=0 -> all
            // eligible; freecount all 0; req=0 -> earliest 0.
            assert!(out.earliest.iter().all(|&e| e == 0.0));
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::{Path, PathBuf};

    use crate::matching::{ScheduleStep, StepInput, StepOutput};
    use crate::Result;

    /// Stub standing in for the PJRT-backed engine when the crate is built
    /// without the `pjrt` feature. Loading always fails cleanly, so every
    /// caller takes its documented artifact-absent fallback path.
    pub struct HloStep {
        /// Artifact path, for diagnostics.
        pub path: PathBuf,
    }

    impl HloStep {
        /// Conventional artifact location relative to the repo root.
        pub fn default_artifact() -> PathBuf {
            super::artifact_path()
        }

        /// Always fails: the PJRT client is not compiled in.
        pub fn load(path: &Path) -> Result<HloStep> {
            anyhow::bail!(
                "built without the `pjrt` feature: cannot load {}",
                path.display()
            )
        }

        /// Always fails: the PJRT client is not compiled in.
        pub fn load_default() -> Result<HloStep> {
            Self::load(&Self::default_artifact())
        }

        /// Without PJRT the best engine is the pure-Rust reference
        /// (bit-identical semantics to the AOT artifact).
        pub fn best_available() -> Box<dyn ScheduleStep> {
            Box::new(crate::matching::ReferenceStep)
        }
    }

    impl ScheduleStep for HloStep {
        fn run(&mut self, _input: &StepInput) -> Result<StepOutput> {
            anyhow::bail!("built without the `pjrt` feature")
        }

        fn engine_name(&self) -> &'static str {
            "hlo_unavailable"
        }
    }
}
