//! The embedded relational store — the "MySQL" of the paper.
//!
//! The paper's central design choice is that the database "holds all our
//! internal data and thus is the only communication medium between
//! modules" (§2). This module reproduces that substrate: typed tables with
//! the schema of fig. 2, a SQL `WHERE`-expression engine used both for the
//! jobs' `properties` resource matching and for ad-hoc queries, an event
//! log (the paper's logging/accounting requirement), and aggregate query
//! helpers for `oarstat`-style analysis.
//!
//! Discipline enforced here, as in the paper: modules receive a
//! [`DbHandle`] and *nothing else*; every interaction between the
//! submission module, the central module, the scheduler and the launcher
//! goes through these tables. A query counter reproduces the paper's
//! "350 SQL queries for the processing of 10 jobs" measurement.
//!
//! Durability ("the database engine can handle the data safety", §2) is
//! provided by the write-ahead log: every logical mutation is logged before it is
//! applied, snapshots compact the log in atomic generations, and
//! [`Db::recover`] replays the tail deterministically after a crash.

mod accounting;
mod expr;
mod index;
mod log;
mod plan;
mod store;
mod table;
mod value;
mod view;
mod wal;

pub use accounting::{Accounting, AccountingBuilder, UserUsage};
pub use expr::{CmpOp, Columns, Expr, ParseError};
pub use index::{ColumnIndex, IndexKey};
pub use log::{EventLog, EventRecord, DEFAULT_EVENT_RETENTION};
pub use plan::{PlanKind, QueryPlan};
pub use store::{Db, DbHandle, DbError, QueryStats};
pub use table::{ColName, Row, Table};
pub use value::Value;
pub use view::{ClusterLoad, Views};
pub use wal::{AppendError, Mutation, RecoverStats, TableId, Wal, WalCommit};
