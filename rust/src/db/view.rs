//! Incrementally-maintained materialized views over the hot aggregates.
//!
//! The paper's control loop is "queries against the database" — and the
//! hottest queries (queue depth for the scheduler round, per-node
//! occupancy for launching, cluster-wide load for the grid's `load`
//! probe) are aggregates that every round used to recompute from
//! scratch. [`Views`] holds those aggregates as first-class derived
//! state, updated with an O(changed) delta for every [`Mutation`]
//! *before* it is applied to the base tables (the observer runs inside
//! `Db::apply`, the single choke point shared by live writes and WAL
//! replay — so crash recovery rebuilds the views for free).
//!
//! Views are derived state, like secondary indexes: never serialized in
//! snapshots, rebuilt by [`Views::recompute`] when a snapshot is loaded,
//! and verifiable at any time against a from-scratch recomputation
//! (`Db::verify_views`). Maintenance is deliberately *uncounted* by
//! [`super::store::QueryStats`] — the §3.2.2 logical statement counts
//! must not depend on which derived structures happen to exist. Reads
//! that are answered from a view count one `select` plus one `view_hit`.
//!
//! Maintained views:
//!
//! * **`jobs_by_state`** — row count per [`JobState`] (queue depth /
//!   occupancy by state, the scheduler round's skip test).
//! * **`queue_depth`** — `Waiting` jobs per queue name (the per-queue
//!   scheduling trigger).
//! * **`node_busy`** — processors claimed per node by the
//!   resource-holding states (`ToLaunch`/`Launching`/`Running`), a
//!   jobs⋈assignments join maintained incrementally. Deliberately
//!   independent of node liveness: a dead node's claimed processors stay
//!   claimed until the automaton fails or requeues its jobs, which is
//!   what makes the `load` probe coherent (`procsFree = procsAlive −
//!   procsBusy` never counts a dead node's capacity twice).
//! * **`fleet`** — the decoded nodes table (hostname, state, procs) plus
//!   the cluster-load scalars (`nodes_total/alive`, `procs_total/alive`).

use std::collections::BTreeMap;

use crate::types::{JobId, JobState, NodeId, NodeState};

use super::expr::Expr;
use super::table::{Row, Table};
use super::value::Value;
use super::wal::{Mutation, TableId};

/// Position of `s` in [`JobState::ALL`] — the `jobs_by_state` slot.
fn sidx(s: JobState) -> usize {
    JobState::ALL
        .iter()
        .position(|&x| x == s)
        .expect("JobState::ALL is exhaustive")
}

/// One decoded row of the nodes table, held by the fleet view. Mirrors
/// `node_from_row` validity exactly: a slot exists iff the row has a
/// numeric `nodeId` and a parseable `state`; `hostname` defaults to `""`
/// and `nbProcs` to 1.
#[derive(Debug, Clone, PartialEq, Eq)]
struct FleetSlot {
    hostname: String,
    state: NodeState,
    nb_procs: u32,
}

fn slot_of(row: &Row) -> Option<FleetSlot> {
    row.get("nodeId").and_then(Value::as_i64)?;
    let state = row
        .get("state")
        .and_then(Value::as_str)
        .and_then(NodeState::parse)?;
    Some(FleetSlot {
        hostname: row
            .get("hostname")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        state,
        nb_procs: row.get("nbProcs").and_then(Value::as_i64).unwrap_or(1) as u32,
    })
}

/// The cluster-wide load scalars, readable in O(1). `procs_busy` counts
/// *every* processor claimed by a resource-holding job, whether or not
/// its node is still `Alive` — see the module docs for why.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClusterLoad {
    pub nodes_total: u32,
    pub nodes_alive: u32,
    pub procs_total: u32,
    pub procs_alive: u32,
    pub procs_busy: u32,
}

/// The registered materialized views. Plain data (no interior
/// mutability): mutated only under the database write lock, compared
/// wholesale against [`Views::recompute`] by the invariant tests.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Views {
    /// Jobs per state, indexed by position in [`JobState::ALL`]. Rows
    /// whose `state` cell does not parse are counted nowhere.
    jobs_by_state: [u64; 9],
    /// `Waiting` jobs per `queueName`; entries are removed at zero so
    /// the map equals a from-scratch recomputation structurally.
    queue_depth: BTreeMap<String, u64>,
    /// Processors claimed per node by resource-holding jobs' assignment
    /// rows; entries are removed at zero.
    node_busy: BTreeMap<NodeId, u32>,
    /// Valid node rows keyed by row id (iteration order therefore
    /// matches `all_nodes`).
    fleet: BTreeMap<u64, FleetSlot>,
    /// The O(1) scalars, maintained alongside `fleet` / `node_busy`.
    load: ClusterLoad,
}

impl Views {
    // ---------------------------------------------------------- reads ----

    /// Jobs currently in `s` (any table size, O(1)).
    pub fn state_count(&self, s: JobState) -> u64 {
        self.jobs_by_state[sidx(s)]
    }

    /// `Waiting` jobs in `queue` (O(log queues)).
    pub fn queue_depth(&self, queue: &str) -> u64 {
        self.queue_depth.get(queue).copied().unwrap_or(0)
    }

    /// The cluster-load scalars (O(1)).
    pub fn cluster_load(&self) -> ClusterLoad {
        self.load
    }

    /// Processors claimed per node by resource-holding jobs.
    pub fn node_busy(&self) -> &BTreeMap<NodeId, u32> {
        &self.node_busy
    }

    /// The fleet in row-id order: `(hostname, state, nb_procs)` per
    /// valid node row — the shape `monitor::fleet_summary` serves.
    pub fn fleet_rows(&self) -> impl Iterator<Item = (&str, NodeState, u32)> {
        self.fleet
            .values()
            .map(|s| (s.hostname.as_str(), s.state, s.nb_procs))
    }

    /// Entry count of the named view, for `EXPLAIN` output; `None` for
    /// an unknown view name.
    pub fn entries(&self, view: &str) -> Option<usize> {
        match view {
            "jobs_by_state" => Some(JobState::ALL.len()),
            "queue_depth" => Some(self.queue_depth.len()),
            "node_busy" => Some(self.node_busy.len()),
            "cluster_load" => Some(1),
            "fleet" => Some(self.fleet.len()),
            _ => None,
        }
    }

    // ---------------------------------------------------- maintenance ----

    /// Apply the O(changed) delta for `m`. MUST be called with the base
    /// tables in their **pre-apply** state (deletes and cell writes read
    /// the outgoing row to reverse its contribution); `Db::apply` calls
    /// this first, before touching the tables.
    pub(crate) fn observe(
        &mut self,
        m: &Mutation,
        jobs: &Table,
        nodes: &Table,
        assignments: &Table,
    ) {
        match m {
            Mutation::Insert { table, row } => match table {
                TableId::Jobs => self.job_inserted(jobs.peek_next_id(), row, assignments),
                TableId::Nodes => self.node_inserted(nodes.peek_next_id(), row),
                TableId::Assignments => self.assignment_delta(row, jobs, 1),
                _ => {}
            },
            Mutation::Delete { table, id } => match table {
                TableId::Jobs => {
                    if let Some(row) = jobs.get(*id) {
                        self.job_removed(*id, row, assignments);
                    }
                }
                TableId::Nodes => self.node_removed(*id),
                TableId::Assignments => {
                    if let Some(row) = assignments.get(*id) {
                        self.assignment_delta(row, jobs, -1);
                    }
                }
                _ => {}
            },
            Mutation::SetCell {
                table,
                id,
                col,
                value,
            } => self.cell_changed(*table, *id, col, value, jobs, nodes, assignments),
            Mutation::UpdateWhere {
                table,
                filter,
                col,
                value,
            } => {
                // Mirror `Db::apply`: an unparseable filter applies to
                // nothing. The match set below (raw scan + full
                // expression) is the same one `update_where` computes
                // through the planner, without touching any counter.
                let Ok(expr) = Expr::parse(filter) else { return };
                let t = match table {
                    TableId::Jobs => jobs,
                    TableId::Nodes => nodes,
                    TableId::Assignments => assignments,
                    _ => return,
                };
                let ids: Vec<u64> = t
                    .iter()
                    .filter(|(_, row)| expr.matches(row))
                    .map(|(id, _)| *id)
                    .collect();
                for id in ids {
                    self.cell_changed(*table, id, col, value, jobs, nodes, assignments);
                }
            }
            Mutation::LogEvent { .. } => {}
        }
    }

    /// Rebuild every view from the base tables (snapshot load, and the
    /// `verify_views` oracle). Touches no query counter.
    pub(crate) fn recompute(jobs: &Table, nodes: &Table, assignments: &Table) -> Views {
        let mut v = Views::default();
        for (_, row) in jobs.iter() {
            if let Some(s) = row.get("state").and_then(Value::as_str).and_then(JobState::parse) {
                v.jobs_by_state[sidx(s)] += 1;
                if s == JobState::Waiting {
                    if let Some(q) = row.get("queueName").and_then(Value::as_str) {
                        v.queue_inc(q);
                    }
                }
            }
        }
        for (id, row) in nodes.iter() {
            if let Some(slot) = slot_of(row) {
                v.slot_add(*id, slot);
            }
        }
        for (_, row) in assignments.iter() {
            v.assignment_delta(row, jobs, 1);
        }
        v
    }

    // ------------------------------------------------------------ jobs ----

    fn job_inserted(&mut self, id: JobId, row: &Row, assignments: &Table) {
        let Some(s) = row.get("state").and_then(Value::as_str).and_then(JobState::parse) else {
            return;
        };
        self.jobs_by_state[sidx(s)] += 1;
        if s == JobState::Waiting {
            if let Some(q) = row.get("queueName").and_then(Value::as_str) {
                self.queue_inc(q);
            }
        }
        if s.holds_resources() {
            // Assignment rows may already reference the id the table is
            // about to assign (replayed out-of-order histories).
            self.busy_walk(id, assignments, 1);
        }
    }

    fn job_removed(&mut self, id: JobId, row: &Row, assignments: &Table) {
        let Some(s) = row.get("state").and_then(Value::as_str).and_then(JobState::parse) else {
            return;
        };
        self.jobs_by_state[sidx(s)] = self.jobs_by_state[sidx(s)].saturating_sub(1);
        if s == JobState::Waiting {
            if let Some(q) = row.get("queueName").and_then(Value::as_str) {
                self.queue_dec(q);
            }
        }
        if s.holds_resources() {
            self.busy_walk(id, assignments, -1);
        }
    }

    fn job_cell_changed(
        &mut self,
        id: JobId,
        col: &str,
        value: &Value,
        jobs: &Table,
        assignments: &Table,
    ) {
        let Some(row) = jobs.get(id) else { return };
        match col {
            "state" => {
                let old = row.get("state").and_then(Value::as_str).and_then(JobState::parse);
                let new = value.as_str().and_then(JobState::parse);
                if old == new {
                    return;
                }
                if let Some(s) = old {
                    self.jobs_by_state[sidx(s)] = self.jobs_by_state[sidx(s)].saturating_sub(1);
                }
                if let Some(s) = new {
                    self.jobs_by_state[sidx(s)] += 1;
                }
                let queue = row.get("queueName").and_then(Value::as_str);
                if old == Some(JobState::Waiting) {
                    if let Some(q) = queue {
                        self.queue_dec(q);
                    }
                }
                if new == Some(JobState::Waiting) {
                    if let Some(q) = queue {
                        self.queue_inc(q);
                    }
                }
                let was = old.map(JobState::holds_resources).unwrap_or(false);
                let is = new.map(JobState::holds_resources).unwrap_or(false);
                if was != is {
                    self.busy_walk(id, assignments, if is { 1 } else { -1 });
                }
            }
            "queueName" => {
                let state = row.get("state").and_then(Value::as_str).and_then(JobState::parse);
                if state == Some(JobState::Waiting) {
                    if let Some(q) = row.get("queueName").and_then(Value::as_str) {
                        self.queue_dec(q);
                    }
                    if let Some(q) = value.as_str() {
                        self.queue_inc(q);
                    }
                }
            }
            _ => {}
        }
    }

    /// Add (`sign > 0`) or remove the busy contribution of every
    /// assignment row attached to job `id`. Uses the uncounted equality
    /// walk — an index probe when `assignments.jobId` is indexed, a raw
    /// scan otherwise — so view maintenance never perturbs `QueryStats`.
    fn busy_walk(&mut self, id: JobId, assignments: &Table, sign: i32) {
        let key = Value::Int(id as i64);
        assignments.for_each_eq_raw("jobId", &key, |_, row| {
            // Same membership rule as `recompute`: numeric jobId equality.
            if row.get("jobId").and_then(Value::as_i64) != Some(id as i64) {
                return;
            }
            let node = row.get("nodeId").and_then(Value::as_i64).unwrap_or(-1) as NodeId;
            let procs = row.get("procs").and_then(Value::as_i64).unwrap_or(0) as u32;
            self.busy_adjust(node, procs, sign);
        });
    }

    // ----------------------------------------------------- assignments ----

    /// Add/remove one assignment row's busy contribution: counts iff its
    /// `jobId` resolves to a job in a resource-holding state.
    fn assignment_delta(&mut self, row: &Row, jobs: &Table, sign: i32) {
        let Some(jid) = row.get("jobId").and_then(Value::as_i64) else {
            return;
        };
        let holding = jobs
            .get(jid as u64)
            .and_then(|jr| jr.get("state").and_then(Value::as_str))
            .and_then(JobState::parse)
            .map(JobState::holds_resources)
            .unwrap_or(false);
        if !holding {
            return;
        }
        let node = row.get("nodeId").and_then(Value::as_i64).unwrap_or(-1) as NodeId;
        let procs = row.get("procs").and_then(Value::as_i64).unwrap_or(0) as u32;
        self.busy_adjust(node, procs, sign);
    }

    fn assignment_cell_changed(
        &mut self,
        id: u64,
        col: &str,
        value: &Value,
        jobs: &Table,
        assignments: &Table,
    ) {
        let Some(row) = assignments.get(id) else { return };
        if !matches!(col, "jobId" | "nodeId" | "procs") {
            return;
        }
        self.assignment_delta(row, jobs, -1);
        let mut updated = row.clone();
        updated.insert(col.to_string().into(), value.clone());
        self.assignment_delta(&updated, jobs, 1);
    }

    fn busy_adjust(&mut self, node: NodeId, procs: u32, sign: i32) {
        if sign >= 0 {
            self.load.procs_busy = self.load.procs_busy.wrapping_add(procs);
            let e = self.node_busy.entry(node).or_insert(0);
            *e = e.wrapping_add(procs);
            if *e == 0 {
                self.node_busy.remove(&node);
            }
        } else {
            self.load.procs_busy = self.load.procs_busy.wrapping_sub(procs);
            if let Some(e) = self.node_busy.get_mut(&node) {
                *e = e.wrapping_sub(procs);
                if *e == 0 {
                    self.node_busy.remove(&node);
                }
            }
        }
    }

    // ----------------------------------------------------------- nodes ----

    fn node_inserted(&mut self, rowid: u64, row: &Row) {
        if let Some(slot) = slot_of(row) {
            self.slot_add(rowid, slot);
        }
    }

    fn node_removed(&mut self, rowid: u64) {
        self.slot_remove(rowid);
    }

    fn node_cell_changed(&mut self, id: u64, col: &str, value: &Value, nodes: &Table) {
        let Some(row) = nodes.get(id) else { return };
        if !matches!(col, "nodeId" | "state" | "hostname" | "nbProcs") {
            return;
        }
        self.slot_remove(id);
        let mut updated = row.clone();
        updated.insert(col.to_string().into(), value.clone());
        if let Some(slot) = slot_of(&updated) {
            self.slot_add(id, slot);
        }
    }

    fn slot_add(&mut self, rowid: u64, slot: FleetSlot) {
        self.load.nodes_total += 1;
        self.load.procs_total = self.load.procs_total.wrapping_add(slot.nb_procs);
        if slot.state == NodeState::Alive {
            self.load.nodes_alive += 1;
            self.load.procs_alive = self.load.procs_alive.wrapping_add(slot.nb_procs);
        }
        self.fleet.insert(rowid, slot);
    }

    fn slot_remove(&mut self, rowid: u64) {
        if let Some(slot) = self.fleet.remove(&rowid) {
            self.load.nodes_total = self.load.nodes_total.saturating_sub(1);
            self.load.procs_total = self.load.procs_total.wrapping_sub(slot.nb_procs);
            if slot.state == NodeState::Alive {
                self.load.nodes_alive = self.load.nodes_alive.saturating_sub(1);
                self.load.procs_alive = self.load.procs_alive.wrapping_sub(slot.nb_procs);
            }
        }
    }

    // ---------------------------------------------------------- queues ----

    fn queue_inc(&mut self, q: &str) {
        *self.queue_depth.entry(q.to_string()).or_insert(0) += 1;
    }

    fn queue_dec(&mut self, q: &str) {
        if let Some(n) = self.queue_depth.get_mut(q) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                self.queue_depth.remove(q);
            }
        }
    }

    fn cell_changed(
        &mut self,
        table: TableId,
        id: u64,
        col: &str,
        value: &Value,
        jobs: &Table,
        nodes: &Table,
        assignments: &Table,
    ) {
        match table {
            TableId::Jobs => self.job_cell_changed(id, col, value, jobs, assignments),
            TableId::Nodes => self.node_cell_changed(id, col, value, nodes),
            TableId::Assignments => self.assignment_cell_changed(id, col, value, jobs, assignments),
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node_row(id: i64, host: &str, state: &str, procs: i64) -> Row {
        let mut r = Row::new();
        r.insert("nodeId".into(), Value::Int(id));
        r.insert("hostname".into(), Value::Text(host.into()));
        r.insert("state".into(), Value::Text(state.into()));
        r.insert("nbProcs".into(), Value::Int(procs));
        r
    }

    #[test]
    fn slot_validity_mirrors_node_from_row() {
        assert!(slot_of(&node_row(1, "n1", "Alive", 2)).is_some());
        assert!(slot_of(&node_row(1, "n1", "Zombie", 2)).is_none());
        let mut missing_id = node_row(1, "n1", "Alive", 2);
        missing_id.remove("nodeId");
        assert!(slot_of(&missing_id).is_none());
        // Defaults mirror node_from_row: hostname "", nbProcs 1.
        let mut bare = Row::new();
        bare.insert("nodeId".into(), Value::Int(7));
        bare.insert("state".into(), Value::Text("Absent".into()));
        let slot = slot_of(&bare).unwrap();
        assert_eq!(slot.hostname, "");
        assert_eq!(slot.nb_procs, 1);
        assert_eq!(slot.state, NodeState::Absent);
    }

    #[test]
    fn queue_depth_entries_vanish_at_zero() {
        let mut v = Views::default();
        v.queue_inc("default");
        v.queue_inc("default");
        v.queue_dec("default");
        assert_eq!(v.queue_depth("default"), 1);
        v.queue_dec("default");
        assert_eq!(v.queue_depth("default"), 0);
        assert!(v.queue_depth.is_empty(), "zero entries must be removed");
        // Structural equality with a fresh recompute depends on it.
        assert_eq!(v, Views::default());
    }

    #[test]
    fn busy_entries_vanish_at_zero() {
        let mut v = Views::default();
        v.busy_adjust(3, 2, 1);
        v.busy_adjust(3, 2, -1);
        assert!(v.node_busy.is_empty());
        assert_eq!(v.cluster_load().procs_busy, 0);
        assert_eq!(v, Views::default());
    }

    #[test]
    fn fleet_scalars_track_slot_churn() {
        let mut v = Views::default();
        v.node_inserted(1, &node_row(1, "n1", "Alive", 2));
        v.node_inserted(2, &node_row(2, "n2", "Suspected", 4));
        let l = v.cluster_load();
        assert_eq!((l.nodes_total, l.nodes_alive), (2, 1));
        assert_eq!((l.procs_total, l.procs_alive), (6, 2));
        v.node_removed(2);
        let l = v.cluster_load();
        assert_eq!((l.nodes_total, l.procs_total), (1, 2));
        assert_eq!(
            v.fleet_rows().map(|(h, _, _)| h.to_string()).collect::<Vec<_>>(),
            vec!["n1"]
        );
    }
}
