//! Generic row-oriented table with WHERE-expression selection — the
//! storage primitive under all OAR tables (jobs, nodes, assignments,
//! queues, admission rules, event log).

use std::collections::BTreeMap;


use super::expr::Expr;
use super::value::Value;

/// A row: column name → value. BTreeMap keeps dumps deterministic.
pub type Row = BTreeMap<String, Value>;

/// A table with an auto-increment primary key, mirroring MySQL's
/// `AUTO_INCREMENT` id columns (`idJob` is "its index number in the table
/// of the jobs", §2.1).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub name: String,
    next_id: u64,
    rows: BTreeMap<u64, Row>,
}

impl Table {
    pub fn new(name: &str) -> Table {
        Table {
            name: name.into(),
            next_id: 1,
            rows: BTreeMap::new(),
        }
    }

    /// Insert a row, assigning and returning its id (also stored in the
    /// `id` column).
    pub fn insert(&mut self, mut row: Row) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        row.insert("id".into(), Value::Int(id as i64));
        self.rows.insert(id, row);
        id
    }

    pub fn get(&self, id: u64) -> Option<&Row> {
        self.rows.get(&id)
    }

    pub fn get_mut(&mut self, id: u64) -> Option<&mut Row> {
        self.rows.get_mut(&id)
    }

    pub fn delete(&mut self, id: u64) -> bool {
        self.rows.remove(&id).is_some()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// All rows in id order.
    pub fn iter(&self) -> impl Iterator<Item = (&u64, &Row)> {
        self.rows.iter()
    }

    /// SELECT ... WHERE expr, in id order.
    pub fn select(&self, filter: &Expr) -> Vec<(u64, Row)> {
        self.rows
            .iter()
            .filter(|(_, r)| filter.matches(r))
            .map(|(id, r)| (*id, r.clone()))
            .collect()
    }

    /// SELECT COUNT(*) WHERE expr.
    pub fn count_where(&self, filter: &Expr) -> usize {
        self.rows.values().filter(|r| filter.matches(r)).count()
    }

    /// UPDATE ... SET col = value WHERE expr; returns affected row count.
    pub fn update_where(&mut self, filter: &Expr, col: &str, value: Value) -> usize {
        let mut n = 0;
        for row in self.rows.values_mut() {
            if filter.matches(row) {
                row.insert(col.to_string(), value.clone());
                n += 1;
            }
        }
        n
    }

    /// Aggregate helpers for the accounting queries (§1: "the powerfull sql
    /// language can be used for data analysis and extraction").
    pub fn sum_where(&self, filter: &Expr, col: &str) -> f64 {
        self.rows
            .values()
            .filter(|r| filter.matches(r))
            .filter_map(|r| r.get(col).and_then(Value::as_f64))
            .sum()
    }

    pub fn group_count(&self, filter: &Expr, col: &str) -> BTreeMap<String, usize> {
        let mut out = BTreeMap::new();
        for r in self.rows.values().filter(|r| filter.matches(r)) {
            let key = r
                .get(col)
                .map(|v| v.to_string())
                .unwrap_or_else(|| "NULL".into());
            *out.entry(key).or_insert(0) += 1;
        }
        out
    }

    /// Snapshot encoding.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(id, row)| {
                let cells: BTreeMap<String, Json> =
                    row.iter().map(|(k, v)| (k.clone(), v.to_json())).collect();
                Json::obj(vec![
                    ("id", Json::Num(*id as f64)),
                    ("row", Json::Obj(cells)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("next_id", Json::Num(self.next_id as f64)),
            ("rows", Json::Arr(rows)),
        ])
    }

    /// Decode the [`Table::to_json`] encoding.
    pub fn from_json(j: &crate::util::Json) -> crate::Result<Table> {
        use crate::util::Json;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("table missing name"))?
            .to_string();
        let next_id = j
            .get("next_id")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("table missing next_id"))? as u64;
        let mut rows = BTreeMap::new();
        for item in j
            .get("rows")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("table missing rows"))?
        {
            let id = item
                .get("id")
                .and_then(Json::as_i64)
                .ok_or_else(|| anyhow::anyhow!("row missing id"))? as u64;
            let cells = match item.get("row") {
                Some(Json::Obj(m)) => m,
                _ => anyhow::bail!("row missing cells"),
            };
            let mut row = Row::new();
            for (k, v) in cells {
                row.insert(k.clone(), Value::from_json(v)?);
            }
            rows.insert(id, row);
        }
        Ok(Table {
            name,
            next_id,
            rows,
        })
    }
}

/// Tiny helper to build rows inline: `rowvec![ "a" => 1i64, "b" => "x" ]`.
#[macro_export]
macro_rules! rowvec {
    ($($k:expr => $v:expr),* $(,)?) => {{
        let mut row = $crate::db::Row::new();
        $( row.insert($k.to_string(), $crate::db::Value::from($v)); )*
        row
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Table {
        let mut t = Table::new("nodes");
        t.insert(rowvec!["hostname" => "n1", "mem" => 256i64]);
        t.insert(rowvec!["hostname" => "n2", "mem" => 512i64]);
        t.insert(rowvec!["hostname" => "n3", "mem" => 1024i64]);
        t
    }

    #[test]
    fn insert_assigns_sequential_ids() {
        let t = fixture();
        assert_eq!(t.len(), 3);
        assert_eq!(t.get(1).unwrap()["hostname"], Value::Text("n1".into()));
        assert_eq!(t.get(3).unwrap()["id"], Value::Int(3));
    }

    #[test]
    fn select_where() {
        let t = fixture();
        let e = Expr::parse("mem >= 512").unwrap();
        let got = t.select(&e);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 2);
    }

    #[test]
    fn update_where() {
        let mut t = fixture();
        let e = Expr::parse("mem < 1024").unwrap();
        let n = t.update_where(&e, "state", Value::Text("old".into()));
        assert_eq!(n, 2);
        assert_eq!(t.get(1).unwrap()["state"], Value::Text("old".into()));
        assert!(t.get(3).unwrap().get("state").is_none());
    }

    #[test]
    fn delete_and_ids_not_reused() {
        let mut t = fixture();
        assert!(t.delete(2));
        assert!(!t.delete(2));
        let id = t.insert(rowvec!["hostname" => "n4"]);
        assert_eq!(id, 4, "auto-increment must not reuse ids");
    }

    #[test]
    fn aggregates() {
        let t = fixture();
        let all = Expr::parse("").unwrap();
        assert_eq!(t.sum_where(&all, "mem"), 1792.0);
        assert_eq!(t.count_where(&Expr::parse("mem = 512").unwrap()), 1);
        let g = t.group_count(&all, "hostname");
        assert_eq!(g.len(), 3);
    }
}
