//! Generic row-oriented table with WHERE-expression selection — the
//! storage primitive under all OAR tables (jobs, nodes, assignments,
//! queues, admission rules, event log).
//!
//! This is a real (if small) query engine, not a bag of rows:
//!
//! * **Secondary indexes** ([`Table::create_index`]) are maintained
//!   incrementally by every mutation path. Direct `&mut Row` access is
//!   deliberately not offered — cells change through [`Table::set_cell`]
//!   or [`Table::update_where`], which keep the indexes coherent.
//! * **Predicate pushdown**: every WHERE-driven read plans its access
//!   path ([`Table::plan`] is the `EXPLAIN` surface), probing the most
//!   selective index for the sargable part of the expression and applying
//!   the full expression as a residual filter. Probe/scan counts are kept
//!   per table and surfaced through `QueryStats`.
//! * **Zero-copy reads**: [`Table::for_each_where`], [`Table::select_map`]
//!   and [`Table::select_ids`] visit borrowed rows; only what the caller
//!   keeps is allocated. The historical cloning [`Table::select`] remains
//!   for callers that genuinely want owned rows.

use std::borrow::Cow;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

use super::expr::Expr;
use super::index::{range_empty, ColumnIndex};
use super::plan::{sargs, PlanKind, QueryPlan, Sarg};
use super::value::Value;

/// Interned column name: the fixed schema columns are `'static` borrows
/// (building a row allocates nothing per column name), dynamic ones
/// (e.g. the nodes' free-form `prop_*` columns) own their string.
pub type ColName = Cow<'static, str>;

/// A row: column name → value. BTreeMap keeps dumps deterministic.
pub type Row = BTreeMap<ColName, Value>;

/// A table with an auto-increment primary key, mirroring MySQL's
/// `AUTO_INCREMENT` id columns (`idJob` is "its index number in the table
/// of the jobs", §2.1).
#[derive(Debug)]
pub struct Table {
    pub name: String,
    next_id: u64,
    rows: BTreeMap<u64, Row>,
    indexes: BTreeMap<ColName, ColumnIndex>,
    /// Access-path telemetry: WHERE-driven statements answered via an
    /// index probe vs. by visiting every row. Atomics so reads can record
    /// their plan without `&mut` — tables are shared by concurrent
    /// readers under the store's read lock, and relaxed increments keep
    /// the counters exact without ordering cost.
    probes: AtomicU64,
    scans: AtomicU64,
}

impl Default for Table {
    /// Empty table with MySQL `AUTO_INCREMENT` semantics: ids start at 1.
    fn default() -> Table {
        Table {
            name: String::new(),
            next_id: 1,
            rows: BTreeMap::new(),
            indexes: BTreeMap::new(),
            probes: AtomicU64::new(0),
            scans: AtomicU64::new(0),
        }
    }
}

impl Clone for Table {
    /// Counter values are carried over (a cloned table continues the
    /// original's telemetry, as the derived impl did with `Cell`).
    fn clone(&self) -> Table {
        Table {
            name: self.name.clone(),
            next_id: self.next_id,
            rows: self.rows.clone(),
            indexes: self.indexes.clone(),
            probes: AtomicU64::new(self.probes.load(Ordering::Relaxed)),
            scans: AtomicU64::new(self.scans.load(Ordering::Relaxed)),
        }
    }
}

/// Candidate rows a plan will visit.
enum Candidates {
    /// No usable index: every row.
    All,
    /// Index probe result, in ascending id order.
    Ids(Vec<u64>),
}

impl Table {
    pub fn new(name: &str) -> Table {
        Table {
            name: name.into(),
            ..Table::default()
        }
    }

    /// Insert a row, assigning and returning its id (also stored in the
    /// `id` column). All indexes are updated.
    pub fn insert(&mut self, mut row: Row) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        row.insert("id".into(), Value::Int(id as i64));
        for (col, idx) in &mut self.indexes {
            if let Some(v) = row.get(col.as_ref()) {
                idx.add(v, id);
            }
        }
        self.rows.insert(id, row);
        id
    }

    pub fn get(&self, id: u64) -> Option<&Row> {
        self.rows.get(&id)
    }

    /// Write one cell, keeping the column's index (if any) coherent.
    /// Returns `false` when the row does not exist. This replaces the old
    /// raw `get_mut` escape hatch, which could silently corrupt indexes.
    pub fn set_cell(&mut self, id: u64, col: impl Into<ColName>, value: Value) -> bool {
        self.set_cell_inner(id, &col.into(), value)
    }

    /// The one index-maintenance write path (shared by [`Table::set_cell`]
    /// and [`Table::update_where`]). Clones the column name only when the
    /// row gains a new column.
    fn set_cell_inner(&mut self, id: u64, col: &ColName, value: Value) -> bool {
        let Some(row) = self.rows.get_mut(&id) else {
            return false;
        };
        if let Some(idx) = self.indexes.get_mut(col) {
            if let Some(old) = row.get(col.as_ref()) {
                idx.remove(old, id);
            }
            idx.add(&value, id);
        }
        match row.get_mut(col.as_ref()) {
            Some(slot) => *slot = value,
            None => {
                row.insert(col.clone(), value);
            }
        }
        true
    }

    /// Delete a row; all indexes are updated.
    pub fn delete(&mut self, id: u64) -> bool {
        match self.rows.remove(&id) {
            None => false,
            Some(row) => {
                for (col, idx) in &mut self.indexes {
                    if let Some(v) = row.get(col.as_ref()) {
                        idx.remove(v, id);
                    }
                }
                true
            }
        }
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// All rows in id order (raw iteration; not counted as a query).
    pub fn iter(&self) -> impl Iterator<Item = (&u64, &Row)> {
        self.rows.iter()
    }

    // ------------------------------------------------------- indexes ----

    /// Create (or rebuild) a secondary index on `col`.
    pub fn create_index(&mut self, col: impl Into<ColName>) {
        let col = col.into();
        let mut idx = ColumnIndex::default();
        for (id, row) in &self.rows {
            if let Some(v) = row.get(col.as_ref()) {
                idx.add(v, *id);
            }
        }
        self.indexes.insert(col, idx);
    }

    /// Drop the index on `col`; returns whether one existed.
    pub fn drop_index(&mut self, col: &str) -> bool {
        self.indexes.remove(col).is_some()
    }

    /// Drop every secondary index (benchmarks use this to compare the
    /// scan path against the probe path on identical data).
    pub fn drop_all_indexes(&mut self) {
        self.indexes.clear();
    }

    /// Indexed column names, in order.
    pub fn indexed_columns(&self) -> Vec<&str> {
        self.indexes.keys().map(|c| c.as_ref()).collect()
    }

    /// Every secondary index agrees with a fresh rebuild from the rows —
    /// the index-coherence invariant the crash-recovery tests assert.
    pub fn indexes_consistent(&self) -> bool {
        self.indexes.iter().all(|(col, idx)| {
            let mut fresh = ColumnIndex::default();
            for (id, row) in &self.rows {
                if let Some(v) = row.get(col.as_ref()) {
                    fresh.add(v, *id);
                }
            }
            *idx == fresh
        })
    }

    /// `(index probes, full scans)` recorded since the last reset.
    pub fn plan_counters(&self) -> (u64, u64) {
        (
            self.probes.load(Ordering::Relaxed),
            self.scans.load(Ordering::Relaxed),
        )
    }

    pub fn reset_plan_counters(&self) {
        self.probes.store(0, Ordering::Relaxed);
        self.scans.store(0, Ordering::Relaxed);
    }

    // ------------------------------------------------------ planning ----

    /// `EXPLAIN`: the access path a WHERE clause would take, without
    /// executing it or touching the counters.
    pub fn plan(&self, filter: &Expr) -> QueryPlan {
        match self.choose(filter) {
            Some((sarg, est)) => QueryPlan {
                kind: sarg.kind(),
                column: Some(sarg.column().to_string()),
                estimated_rows: est,
            },
            None => QueryPlan {
                kind: PlanKind::FullScan,
                column: None,
                estimated_rows: self.rows.len(),
            },
        }
    }

    /// Most selective sargable conjunct that has an index, with its
    /// estimated candidate count.
    fn choose(&self, filter: &Expr) -> Option<(Sarg, usize)> {
        let mut best: Option<(Sarg, usize)> = None;
        for sarg in sargs(filter) {
            let Some(idx) = self.indexes.get(sarg.column()) else {
                continue;
            };
            let est = match &sarg {
                Sarg::Eq(_, v) => idx.eq_count(v),
                Sarg::In(_, items) => items.iter().map(|v| idx.eq_count(v)).sum(),
                Sarg::Range(_, lo, hi) => idx.range_count(lo, hi),
            };
            if best.as_ref().map(|(_, b)| est < *b).unwrap_or(true) {
                best = Some((sarg, est));
            }
        }
        best
    }

    /// Execute the access-path decision for `filter`, recording it in the
    /// plan counters. One logical statement = one probe or one scan.
    fn candidates(&self, filter: &Expr) -> Candidates {
        match self.choose(filter) {
            None => {
                self.scans.fetch_add(1, Ordering::Relaxed);
                Candidates::All
            }
            Some((sarg, _)) => {
                self.probes.fetch_add(1, Ordering::Relaxed);
                let idx = &self.indexes[sarg.column()];
                let ids = match &sarg {
                    Sarg::Eq(_, v) => idx
                        .eq_ids(v)
                        .map(|s| s.iter().copied().collect())
                        .unwrap_or_default(),
                    Sarg::In(_, items) => {
                        let mut set = std::collections::BTreeSet::new();
                        for v in items {
                            if let Some(s) = idx.eq_ids(v) {
                                set.extend(s.iter().copied());
                            }
                        }
                        set.into_iter().collect()
                    }
                    Sarg::Range(_, lo, hi) => {
                        if range_empty(lo, hi) {
                            Vec::new()
                        } else {
                            idx.range_ids(lo, hi)
                        }
                    }
                };
                Candidates::Ids(ids)
            }
        }
    }

    // --------------------------------------------------------- reads ----

    /// Visit every row matching `filter`, in id order, without cloning —
    /// the zero-copy workhorse under all SELECT-shaped reads.
    pub fn for_each_where(&self, filter: &Expr, mut f: impl FnMut(u64, &Row)) {
        match self.candidates(filter) {
            Candidates::All => {
                for (id, row) in &self.rows {
                    if filter.matches(row) {
                        f(*id, row);
                    }
                }
            }
            Candidates::Ids(ids) => {
                for id in ids {
                    if let Some(row) = self.rows.get(&id) {
                        if filter.matches(row) {
                            f(id, row);
                        }
                    }
                }
            }
        }
    }

    /// Visit every row (a logical full-table SELECT; counts as one scan).
    pub fn for_each_all(&self, mut f: impl FnMut(u64, &Row)) {
        self.scans.fetch_add(1, Ordering::Relaxed);
        for (id, row) in &self.rows {
            f(*id, row);
        }
    }

    /// Rows with `col = value` (SQL equality), in id order. Probes the
    /// column's index when one exists; a residual equality check keeps
    /// the result exact either way.
    pub fn for_each_eq(&self, col: &str, value: &Value, mut f: impl FnMut(u64, &Row)) {
        let residual =
            |row: &Row| row.get(col).map(|v| v.sql_eq(value)).unwrap_or(false);
        if let Some(idx) = self.indexes.get(col) {
            self.probes.fetch_add(1, Ordering::Relaxed);
            if let Some(ids) = idx.eq_ids(value) {
                for id in ids {
                    if let Some(row) = self.rows.get(id) {
                        if residual(row) {
                            f(*id, row);
                        }
                    }
                }
            }
        } else {
            self.scans.fetch_add(1, Ordering::Relaxed);
            for (id, row) in &self.rows {
                if residual(row) {
                    f(*id, row);
                }
            }
        }
    }

    /// Uncounted equality walk for derived-state maintenance (the
    /// materialized views): identical match set to [`Table::for_each_eq`]
    /// — index candidates plus a residual equality check, raw scan when
    /// the column is unindexed — but touches no probe/scan counter, so
    /// maintaining a view never perturbs `QueryStats`.
    pub(crate) fn for_each_eq_raw(&self, col: &str, value: &Value, mut f: impl FnMut(u64, &Row)) {
        let residual =
            |row: &Row| row.get(col).map(|v| v.sql_eq(value)).unwrap_or(false);
        if let Some(idx) = self.indexes.get(col) {
            if let Some(ids) = idx.eq_ids(value) {
                for id in ids {
                    if let Some(row) = self.rows.get(id) {
                        if residual(row) {
                            f(*id, row);
                        }
                    }
                }
            }
        } else {
            for (id, row) in &self.rows {
                if residual(row) {
                    f(*id, row);
                }
            }
        }
    }

    /// The id the next [`Table::insert`] will assign. Lets a pre-apply
    /// observer attribute an `Insert` mutation to its future row id.
    pub(crate) fn peek_next_id(&self) -> u64 {
        self.next_id
    }

    /// Like [`Table::for_each_eq`], but stops as soon as `f` returns
    /// `false` — capped fetches and first-counterexample checks must not
    /// pay for the whole matching set.
    pub fn for_each_eq_while(
        &self,
        col: &str,
        value: &Value,
        mut f: impl FnMut(u64, &Row) -> bool,
    ) {
        let residual =
            |row: &Row| row.get(col).map(|v| v.sql_eq(value)).unwrap_or(false);
        if let Some(idx) = self.indexes.get(col) {
            self.probes.fetch_add(1, Ordering::Relaxed);
            if let Some(ids) = idx.eq_ids(value) {
                for id in ids {
                    if let Some(row) = self.rows.get(id) {
                        if residual(row) && !f(*id, row) {
                            return;
                        }
                    }
                }
            }
        } else {
            self.scans.fetch_add(1, Ordering::Relaxed);
            for (id, row) in &self.rows {
                if residual(row) && !f(*id, row) {
                    return;
                }
            }
        }
    }

    /// First row with `col = value`, by id order.
    pub fn find_eq(&self, col: &str, value: &Value) -> Option<(u64, &Row)> {
        let residual =
            |row: &Row| row.get(col).map(|v| v.sql_eq(value)).unwrap_or(false);
        if let Some(idx) = self.indexes.get(col) {
            self.probes.fetch_add(1, Ordering::Relaxed);
            for id in idx.eq_ids(value)? {
                if let Some(row) = self.rows.get(id) {
                    if residual(row) {
                        return Some((*id, row));
                    }
                }
            }
            None
        } else {
            self.scans.fetch_add(1, Ordering::Relaxed);
            self.rows
                .iter()
                .find(|(_, row)| residual(row))
                .map(|(id, row)| (*id, row))
        }
    }

    /// `SELECT COUNT(*) WHERE col = value` straight off the index when
    /// one exists (no row is touched at all).
    pub fn count_eq(&self, col: &str, value: &Value) -> usize {
        if let Some(idx) = self.indexes.get(col) {
            self.probes.fetch_add(1, Ordering::Relaxed);
            idx.eq_count(value)
        } else {
            self.scans.fetch_add(1, Ordering::Relaxed);
            self.rows
                .values()
                .filter(|row| row.get(col).map(|v| v.sql_eq(value)).unwrap_or(false))
                .count()
        }
    }

    /// Index-only cardinality estimate for `col = value`; `None` when the
    /// column has no index. Does not count as a statement (planning aid).
    pub fn eq_estimate(&self, col: &str, value: &Value) -> Option<usize> {
        self.indexes.get(col).map(|idx| idx.eq_count(value))
    }

    /// Ids of rows matching `filter`, in id order, without cloning rows.
    pub fn select_ids(&self, filter: &Expr) -> Vec<u64> {
        let mut out = Vec::new();
        self.for_each_where(filter, |id, _| out.push(id));
        out
    }

    /// Map over matching rows without cloning them; `None` results are
    /// dropped (typed-accessor pattern: `|_, r| job_from_row(r).ok()`).
    pub fn select_map<T>(
        &self,
        filter: &Expr,
        mut f: impl FnMut(u64, &Row) -> Option<T>,
    ) -> Vec<T> {
        let mut out = Vec::new();
        self.for_each_where(filter, |id, row| {
            if let Some(v) = f(id, row) {
                out.push(v);
            }
        });
        out
    }

    /// SELECT ... WHERE expr, in id order (clones every matched row; use
    /// the `for_each_where` / `select_map` family for zero-copy reads).
    pub fn select(&self, filter: &Expr) -> Vec<(u64, Row)> {
        let mut out = Vec::new();
        self.for_each_where(filter, |id, row| out.push((id, row.clone())));
        out
    }

    /// SELECT COUNT(*) WHERE expr.
    pub fn count_where(&self, filter: &Expr) -> usize {
        let mut n = 0;
        self.for_each_where(filter, |_, _| n += 1);
        n
    }

    /// UPDATE ... SET col = value WHERE expr; returns affected row count.
    /// Routed through the planner like any read, and through the shared
    /// `set_cell` write path so indexes stay coherent (the column name is
    /// built once, not per matched row).
    pub fn update_where(&mut self, filter: &Expr, col: &str, value: Value) -> usize {
        let ids = self.select_ids(filter);
        let col: ColName = col.to_string().into();
        for id in &ids {
            self.set_cell_inner(*id, &col, value.clone());
        }
        ids.len()
    }

    /// Aggregate helpers for the accounting queries (§1: "the powerfull sql
    /// language can be used for data analysis and extraction").
    pub fn sum_where(&self, filter: &Expr, col: &str) -> f64 {
        let mut sum = 0.0;
        self.for_each_where(filter, |_, row| {
            if let Some(x) = row.get(col).and_then(Value::as_f64) {
                sum += x;
            }
        });
        sum
    }

    pub fn group_count(&self, filter: &Expr, col: &str) -> BTreeMap<String, usize> {
        let mut out = BTreeMap::new();
        self.for_each_where(filter, |_, row| {
            let key = row
                .get(col)
                .map(|v| v.to_string())
                .unwrap_or_else(|| "NULL".into());
            *out.entry(key).or_insert(0) += 1;
        });
        out
    }

    /// `SELECT group_col, SUM(sum_col) ... GROUP BY group_col`: grouped
    /// aggregate over the matching rows (rows without a numeric
    /// `sum_col` contribute nothing; the group key is stringified like
    /// [`Table::group_count`]'s).
    pub fn group_sum(&self, filter: &Expr, group_col: &str, sum_col: &str) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        self.for_each_where(filter, |_, row| {
            if let Some(x) = row.get(sum_col).and_then(Value::as_f64) {
                let key = row
                    .get(group_col)
                    .map(|v| v.to_string())
                    .unwrap_or_else(|| "NULL".into());
                *out.entry(key).or_insert(0.0) += x;
            }
        });
        out
    }

    /// Index-only `GROUP BY col` count: reads the column's index b-tree
    /// directly — no row is touched. `None` when `col` has no index
    /// (callers fall back to [`Table::group_count`]). Counts one probe.
    pub fn group_count_indexed(&self, col: &str) -> Option<Vec<(super::index::IndexKey, usize)>> {
        let idx = self.indexes.get(col)?;
        self.probes.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        idx.for_each_key(|key, n| out.push((key.clone(), n)));
        Some(out)
    }

    /// Index-to-index equi-join driver: for each left-side row id, probe
    /// *this* table's `col` for rows whose cell equals that id, visiting
    /// each `(left_id, right_row)` pair. This is the join shape of the
    /// occupancy query (`jobs.state` index → `assignments.jobId` index);
    /// each probe counts like the [`Table::for_each_eq`] it rides on.
    pub fn join_eq_ids(&self, left_ids: &[u64], col: &str, mut f: impl FnMut(u64, &Row)) {
        for &lid in left_ids {
            let key = Value::Int(lid as i64);
            self.for_each_eq(col, &key, |_, row| f(lid, row));
        }
    }

    // ------------------------------------------------------ snapshot ----

    /// Snapshot encoding (indexes are derived state and not serialized).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(id, row)| {
                let cells: BTreeMap<String, Json> = row
                    .iter()
                    .map(|(k, v)| (k.to_string(), v.to_json()))
                    .collect();
                Json::obj(vec![
                    ("id", Json::Num(*id as f64)),
                    ("row", Json::Obj(cells)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("next_id", Json::Num(self.next_id as f64)),
            ("rows", Json::Arr(rows)),
        ])
    }

    /// Decode the [`Table::to_json`] encoding. The restored table has no
    /// indexes; callers recreate them (`Db::restore` re-applies the
    /// standard schema's indexes).
    pub fn from_json(j: &crate::util::Json) -> crate::Result<Table> {
        use crate::util::Json;
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("table missing name"))?
            .to_string();
        let next_id = j
            .get("next_id")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("table missing next_id"))? as u64;
        let mut rows = BTreeMap::new();
        for item in j
            .get("rows")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("table missing rows"))?
        {
            let id = item
                .get("id")
                .and_then(Json::as_i64)
                .ok_or_else(|| anyhow::anyhow!("row missing id"))? as u64;
            let cells = match item.get("row") {
                Some(Json::Obj(m)) => m,
                _ => anyhow::bail!("row missing cells"),
            };
            let mut row = Row::new();
            for (k, v) in cells {
                row.insert(k.clone().into(), Value::from_json(v)?);
            }
            rows.insert(id, row);
        }
        Ok(Table {
            name,
            next_id,
            rows,
            ..Table::default()
        })
    }
}

/// Tiny helper to build rows inline: `rowvec![ "a" => 1i64, "b" => "x" ]`.
#[macro_export]
macro_rules! rowvec {
    ($($k:expr => $v:expr),* $(,)?) => {{
        let mut row = $crate::db::Row::new();
        $( row.insert($k.into(), $crate::db::Value::from($v)); )*
        row
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> Table {
        let mut t = Table::new("nodes");
        t.insert(rowvec!["hostname" => "n1", "mem" => 256i64]);
        t.insert(rowvec!["hostname" => "n2", "mem" => 512i64]);
        t.insert(rowvec!["hostname" => "n3", "mem" => 1024i64]);
        t
    }

    #[test]
    fn insert_assigns_sequential_ids() {
        let t = fixture();
        assert_eq!(t.len(), 3);
        assert_eq!(t.get(1).unwrap()["hostname"], Value::Text("n1".into()));
        assert_eq!(t.get(3).unwrap()["id"], Value::Int(3));
    }

    #[test]
    fn select_where() {
        let t = fixture();
        let e = Expr::parse("mem >= 512").unwrap();
        let got = t.select(&e);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].0, 2);
    }

    #[test]
    fn update_where() {
        let mut t = fixture();
        let e = Expr::parse("mem < 1024").unwrap();
        let n = t.update_where(&e, "state", Value::Text("old".into()));
        assert_eq!(n, 2);
        assert_eq!(t.get(1).unwrap()["state"], Value::Text("old".into()));
        assert!(t.get(3).unwrap().get("state").is_none());
    }

    #[test]
    fn delete_and_ids_not_reused() {
        let mut t = fixture();
        assert!(t.delete(2));
        assert!(!t.delete(2));
        let id = t.insert(rowvec!["hostname" => "n4"]);
        assert_eq!(id, 4, "auto-increment must not reuse ids");
    }

    #[test]
    fn aggregates() {
        let t = fixture();
        let all = Expr::parse("").unwrap();
        assert_eq!(t.sum_where(&all, "mem"), 1792.0);
        assert_eq!(t.count_where(&Expr::parse("mem = 512").unwrap()), 1);
        let g = t.group_count(&all, "hostname");
        assert_eq!(g.len(), 3);
    }

    // ------------------------------------------------ query engine ----

    #[test]
    fn index_probe_answers_equality() {
        let mut t = fixture();
        t.create_index("mem");
        t.reset_plan_counters();
        let e = Expr::parse("mem = 512").unwrap();
        let plan = t.plan(&e);
        assert_eq!(plan.kind, PlanKind::IndexEq);
        assert_eq!(plan.column.as_deref(), Some("mem"));
        assert_eq!(plan.estimated_rows, 1);
        let got = t.select(&e);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0, 2);
        let (probes, scans) = t.plan_counters();
        assert_eq!((probes, scans), (1, 0), "the select must probe, not scan");
    }

    #[test]
    fn unindexed_query_scans() {
        let t = fixture();
        let e = Expr::parse("mem = 512").unwrap();
        assert_eq!(t.plan(&e).kind, PlanKind::FullScan);
        let got = t.select(&e);
        assert_eq!(got.len(), 1);
        let (probes, scans) = t.plan_counters();
        assert_eq!((probes, scans), (0, 1));
    }

    #[test]
    fn range_and_in_plans() {
        let mut t = fixture();
        t.create_index("mem");
        t.create_index("hostname");
        let e = Expr::parse("mem >= 512").unwrap();
        assert_eq!(t.plan(&e).kind, PlanKind::IndexRange);
        assert_eq!(t.select_ids(&e), vec![2, 3]);
        let e = Expr::parse("hostname IN ('n1', 'n3')").unwrap();
        assert_eq!(t.plan(&e).kind, PlanKind::IndexIn);
        assert_eq!(t.select_ids(&e), vec![1, 3]);
        let e = Expr::parse("mem BETWEEN 256 AND 512").unwrap();
        assert_eq!(t.plan(&e).kind, PlanKind::IndexRange);
        assert_eq!(t.select_ids(&e), vec![1, 2]);
    }

    #[test]
    fn planner_picks_most_selective_index() {
        let mut t = Table::new("jobs");
        for i in 0..100i64 {
            t.insert(rowvec![
                "state" => if i < 99 { "Terminated" } else { "Waiting" },
                "queueName" => "default"
            ]);
        }
        t.create_index("state");
        t.create_index("queueName");
        let e = Expr::parse("state = 'Waiting' AND queueName = 'default'").unwrap();
        let plan = t.plan(&e);
        assert_eq!(plan.column.as_deref(), Some("state"), "1 row beats 100");
        assert_eq!(plan.estimated_rows, 1);
        assert_eq!(t.select_ids(&e).len(), 1);
    }

    #[test]
    fn residual_filter_keeps_nonsargable_conjuncts_exact() {
        let mut t = fixture();
        t.create_index("hostname");
        // hostname probe narrows to one row; the LIKE conjunct is residual
        let e = Expr::parse("hostname = 'n2' AND hostname LIKE 'n%'").unwrap();
        assert_eq!(t.select_ids(&e), vec![2]);
        let e = Expr::parse("hostname = 'n2' AND mem > 9999").unwrap();
        assert!(t.select_ids(&e).is_empty());
    }

    #[test]
    fn indexes_follow_all_mutation_paths() {
        let mut t = fixture();
        t.create_index("mem");
        // insert
        let id = t.insert(rowvec!["hostname" => "n4", "mem" => 512i64]);
        let e512 = Expr::parse("mem = 512").unwrap();
        assert_eq!(t.select_ids(&e512), vec![2, id]);
        // set_cell moves the row between keys
        assert!(t.set_cell(2, "mem", Value::Int(2048)));
        assert_eq!(t.select_ids(&e512), vec![id]);
        assert_eq!(t.select_ids(&Expr::parse("mem = 2048").unwrap()), vec![2]);
        // update_where through the engine
        t.update_where(&e512, "mem", Value::Int(1));
        assert!(t.select_ids(&e512).is_empty());
        assert_eq!(t.select_ids(&Expr::parse("mem = 1").unwrap()), vec![id]);
        // delete
        t.delete(id);
        assert!(t.select_ids(&Expr::parse("mem = 1").unwrap()).is_empty());
        // every plan above still returns exactly what a scan would
        t.drop_all_indexes();
        assert!(t.select_ids(&Expr::parse("mem = 1").unwrap()).is_empty());
        assert_eq!(t.select_ids(&Expr::parse("mem = 2048").unwrap()), vec![2]);
    }

    #[test]
    fn index_and_scan_agree_on_mixed_expressions() {
        let mut indexed = Table::new("t");
        for i in 0..40i64 {
            indexed.insert(rowvec![
                "state" => if i % 4 == 0 { "Waiting" } else { "Running" },
                "mem" => (i % 7) * 128,
                "host" => format!("n{}", i % 3)
            ]);
        }
        let mut scanned = indexed.clone();
        scanned.drop_all_indexes();
        indexed.create_index("state");
        indexed.create_index("mem");
        for src in [
            "state = 'Waiting'",
            "state = 'Waiting' AND mem >= 256",
            "mem BETWEEN 128 AND 384",
            "mem > 100 AND mem < 600 AND host LIKE 'n1'",
            "state IN ('Waiting', 'Running') AND mem = 0",
            "state = 'Waiting' OR mem = 128",
            "mem > 500 AND mem < 100",
            "state = 'Gone'",
        ] {
            let e = Expr::parse(src).unwrap();
            assert_eq!(
                indexed.select_ids(&e),
                scanned.select_ids(&e),
                "expr {src:?}"
            );
        }
    }

    #[test]
    fn find_and_count_eq() {
        let mut t = fixture();
        t.create_index("hostname");
        let (id, row) = t.find_eq("hostname", &Value::Text("n2".into())).unwrap();
        assert_eq!(id, 2);
        assert_eq!(row["mem"], Value::Int(512));
        assert!(t.find_eq("hostname", &Value::Text("nope".into())).is_none());
        assert_eq!(t.count_eq("hostname", &Value::Text("n3".into())), 1);
        // numeric coercion: Int column probed with Real
        t.create_index("mem");
        assert_eq!(t.count_eq("mem", &Value::Real(512.0)), 1);
        assert_eq!(t.eq_estimate("mem", &Value::Int(512)), Some(1));
        assert_eq!(t.eq_estimate("absent", &Value::Int(0)), None);
    }

    #[test]
    fn zero_copy_visitors() {
        let t = fixture();
        let e = Expr::parse("mem >= 512").unwrap();
        let mut hosts = Vec::new();
        t.for_each_where(&e, |_, row| {
            hosts.push(row["hostname"].to_string());
        });
        assert_eq!(hosts, vec!["'n2'", "'n3'"]);
        let mems: Vec<i64> = t.select_map(&e, |_, row| row["mem"].as_i64());
        assert_eq!(mems, vec![512, 1024]);
        let mut n = 0;
        t.for_each_all(|_, _| n += 1);
        assert_eq!(n, 3);
    }
}
