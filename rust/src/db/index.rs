//! Secondary indexes for [`super::table::Table`].
//!
//! One [`ColumnIndex`] maps the *key form* of a column's values to the set
//! of row ids holding that value, kept incrementally consistent by every
//! mutation path of the table (insert / delete / `set_cell` /
//! `update_where`). The key form ([`IndexKey`]) mirrors
//! [`Value::compare`]'s semantics exactly, so an index probe and a full
//! scan always agree:
//!
//! * all numeric values (`Int`/`Real`/`Bool`) collapse into one
//!   f64-ordered key space (MySQL-style numeric coercion);
//! * text is its own lexicographic key space (`Num` sorts before `Text`
//!   in the tree, and probes never cross spaces — text never equals a
//!   number, as in `Value::compare`);
//! * `NULL` (and the never-parsed `NaN`) are unindexable: rows holding
//!   them are simply absent, which is the WHERE semantics (`col = x`,
//!   ranges, `BETWEEN` and non-negated `IN` are never true for `NULL`).

use std::cmp::Ordering;
use std::collections::{BTreeMap, BTreeSet};
use std::ops::Bound;

use super::value::Value;

/// f64 with a total order; construction normalizes `-0.0` to `0.0` (so
/// key equality matches `partial_cmp` equality) and rejects `NaN`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrdF64(f64);

impl OrdF64 {
    fn new(x: f64) -> Option<OrdF64> {
        if x.is_nan() {
            None
        } else {
            Some(OrdF64(if x == 0.0 { 0.0 } else { x }))
        }
    }
}

impl Eq for OrdF64 {}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Totally-ordered key form of a cell value (see module docs).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub enum IndexKey {
    Num(OrdF64),
    Text(String),
}

impl IndexKey {
    /// Key form of a value, or `None` when the value is unindexable
    /// (`NULL`, `NaN`).
    pub fn of(v: &Value) -> Option<IndexKey> {
        match v {
            Value::Text(s) => Some(IndexKey::Text(s.clone())),
            Value::Null => None,
            other => other.as_f64().and_then(OrdF64::new).map(IndexKey::Num),
        }
    }

    /// Smallest key of the numeric key space.
    pub fn num_min() -> IndexKey {
        IndexKey::Num(OrdF64(f64::NEG_INFINITY))
    }

    /// Largest key of the numeric key space (everything above is text).
    pub fn num_max() -> IndexKey {
        IndexKey::Num(OrdF64(f64::INFINITY))
    }

    /// Smallest key of the text key space.
    pub fn text_min() -> IndexKey {
        IndexKey::Text(String::new())
    }
}

/// `true` when the key range can contain no key at all (contradictory
/// bounds like `x > 5 AND x < 3` compile to such ranges).
pub fn range_empty(lo: &Bound<IndexKey>, hi: &Bound<IndexKey>) -> bool {
    fn key(b: &Bound<IndexKey>) -> Option<(&IndexKey, bool)> {
        match b {
            Bound::Included(k) => Some((k, true)),
            Bound::Excluded(k) => Some((k, false)),
            Bound::Unbounded => None,
        }
    }
    match (key(lo), key(hi)) {
        (Some((l, l_inc)), Some((h, h_inc))) => match l.cmp(h) {
            Ordering::Greater => true,
            Ordering::Equal => !(l_inc && h_inc),
            Ordering::Less => false,
        },
        _ => false,
    }
}

/// One column's secondary index: value key → sorted set of row ids.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ColumnIndex {
    map: BTreeMap<IndexKey, BTreeSet<u64>>,
    entries: usize,
}

impl ColumnIndex {
    /// Register `id` under the key of `v` (no-op for unindexable values).
    pub fn add(&mut self, v: &Value, id: u64) {
        if let Some(k) = IndexKey::of(v) {
            if self.map.entry(k).or_default().insert(id) {
                self.entries += 1;
            }
        }
    }

    /// Remove `id` from the key of `v` (no-op for unindexable values).
    pub fn remove(&mut self, v: &Value, id: u64) {
        if let Some(k) = IndexKey::of(v) {
            if let Some(set) = self.map.get_mut(&k) {
                if set.remove(&id) {
                    self.entries -= 1;
                }
                if set.is_empty() {
                    self.map.remove(&k);
                }
            }
        }
    }

    /// Rows currently indexed (rows with `NULL` in the column are absent).
    pub fn entries(&self) -> usize {
        self.entries
    }

    /// Distinct keys currently present.
    pub fn distinct_keys(&self) -> usize {
        self.map.len()
    }

    /// Ids holding exactly this value's key, in ascending id order.
    pub fn eq_ids(&self, v: &Value) -> Option<&BTreeSet<u64>> {
        IndexKey::of(v).and_then(|k| self.map.get(&k))
    }

    /// Number of rows holding exactly this value's key.
    pub fn eq_count(&self, v: &Value) -> usize {
        self.eq_ids(v).map(|s| s.len()).unwrap_or(0)
    }

    /// Visit every distinct key with its row count, in key order — the
    /// index-only `GROUP BY` walk ([`super::table::Table::group_count_indexed`]).
    pub fn for_each_key(&self, mut f: impl FnMut(&IndexKey, usize)) {
        for (k, ids) in &self.map {
            f(k, ids.len());
        }
    }

    /// Number of rows inside a key range (cost estimation).
    pub fn range_count(&self, lo: &Bound<IndexKey>, hi: &Bound<IndexKey>) -> usize {
        if range_empty(lo, hi) {
            return 0;
        }
        self.map
            .range((lo.clone(), hi.clone()))
            .map(|(_, s)| s.len())
            .sum()
    }

    /// Ids inside a key range, in ascending id order.
    pub fn range_ids(&self, lo: &Bound<IndexKey>, hi: &Bound<IndexKey>) -> Vec<u64> {
        if range_empty(lo, hi) {
            return Vec::new();
        }
        let mut out: Vec<u64> = self
            .map
            .range((lo.clone(), hi.clone()))
            .flat_map(|(_, s)| s.iter().copied())
            .collect();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numeric_values_share_one_key_space() {
        assert_eq!(
            IndexKey::of(&Value::Int(2)),
            IndexKey::of(&Value::Real(2.0))
        );
        assert_eq!(
            IndexKey::of(&Value::Bool(true)),
            IndexKey::of(&Value::Int(1))
        );
        assert_ne!(
            IndexKey::of(&Value::Text("2".into())),
            IndexKey::of(&Value::Int(2))
        );
        assert_eq!(IndexKey::of(&Value::Null), None);
        assert_eq!(
            IndexKey::of(&Value::Real(-0.0)),
            IndexKey::of(&Value::Real(0.0))
        );
    }

    #[test]
    fn num_sorts_before_text() {
        assert!(IndexKey::num_max() < IndexKey::text_min());
        assert!(IndexKey::of(&Value::Int(i64::MAX)).unwrap() < IndexKey::text_min());
    }

    #[test]
    fn add_remove_and_probe() {
        let mut idx = ColumnIndex::default();
        idx.add(&Value::Text("Waiting".into()), 1);
        idx.add(&Value::Text("Waiting".into()), 2);
        idx.add(&Value::Text("Running".into()), 3);
        idx.add(&Value::Null, 4); // unindexable
        assert_eq!(idx.entries(), 3);
        assert_eq!(idx.eq_count(&Value::Text("Waiting".into())), 2);
        assert_eq!(idx.eq_count(&Value::Text("Running".into())), 1);
        assert_eq!(idx.eq_count(&Value::Text("Hold".into())), 0);
        idx.remove(&Value::Text("Waiting".into()), 1);
        assert_eq!(idx.eq_count(&Value::Text("Waiting".into())), 1);
        assert_eq!(idx.entries(), 2);
    }

    #[test]
    fn ranges_stay_inside_their_key_space() {
        let mut idx = ColumnIndex::default();
        idx.add(&Value::Int(1), 1);
        idx.add(&Value::Int(5), 2);
        idx.add(&Value::Int(9), 3);
        idx.add(&Value::Text("zzz".into()), 4);
        // x > 4 numerically must not leak into the text keys
        let lo = Bound::Excluded(IndexKey::of(&Value::Int(4)).unwrap());
        let hi = Bound::Included(IndexKey::num_max());
        assert_eq!(idx.range_ids(&lo, &hi), vec![2, 3]);
        assert_eq!(idx.range_count(&lo, &hi), 2);
        // text range from the bottom of the text space excludes numbers
        let lo = Bound::Included(IndexKey::text_min());
        let hi = Bound::Unbounded;
        assert_eq!(idx.range_ids(&lo, &hi), vec![4]);
    }

    #[test]
    fn contradictory_range_is_empty_not_panicking() {
        let mut idx = ColumnIndex::default();
        idx.add(&Value::Int(4), 1);
        let five = IndexKey::of(&Value::Int(5)).unwrap();
        let three = IndexKey::of(&Value::Int(3)).unwrap();
        let lo = Bound::Excluded(five.clone());
        let hi = Bound::Excluded(three);
        assert!(range_empty(&lo, &hi));
        assert_eq!(idx.range_ids(&lo, &hi), Vec::<u64>::new());
        // equal bounds, one exclusive -> empty
        let lo = Bound::Included(five.clone());
        let hi = Bound::Excluded(five);
        assert!(range_empty(&lo, &hi));
        assert_eq!(idx.range_count(&lo, &hi), 0);
    }
}
