//! The SQL `WHERE`-expression engine.
//!
//! This is the language of the `properties` field of fig. 2 ("sql
//! expression used to match ressources compatible with the job") and of
//! ad-hoc queries against any table. Grammar (case-insensitive keywords):
//!
//! ```text
//! expr     := or
//! or       := and (OR and)*
//! and      := not (AND not)*
//! not      := NOT not | cmp
//! cmp      := sum ((=|!=|<>|<|<=|>|>=) sum)
//!           | sum LIKE string | sum NOT? IN '(' literal,* ')'
//!           | sum IS NOT? NULL | sum BETWEEN sum AND sum
//! sum      := primary (('+'|'-') primary)*
//! primary  := literal | identifier | '(' expr ')'
//! literal  := integer | float | 'single-quoted string' | TRUE | FALSE | NULL
//! ```
//!
//! Besides exact evaluation against a row, conjunctive comparisons over
//! numeric columns can be *compiled to interval constraints*
//! ([`Expr::to_intervals`]) — this is the bridge from OAR's SQL matching to
//! the dense L1 kernel: `mem >= 512 AND cpu_mhz > 2000` becomes per-property
//! `[lo, hi]` rows of the `job_lo`/`job_hi` tensors.

use std::collections::BTreeMap;
use std::fmt;


use super::value::Value;
use super::table::Row;

/// Column lookup abstraction: evaluation reads cells through this trait,
/// so callers can expose *virtual* rows — e.g. the node-property view the
/// resource matcher uses — without materializing a [`Row`]. This is what
/// makes zero-copy evaluation possible on stored rows of any shape.
pub trait Columns {
    fn col(&self, name: &str) -> Option<&Value>;
}

impl Columns for Row {
    fn col(&self, name: &str) -> Option<&Value> {
        self.get(name)
    }
}

/// Binary comparison operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

/// Parsed expression AST.
#[derive(Debug, Clone)]
pub enum Expr {
    Literal(Value),
    Column(String),
    Cmp(CmpOp, Box<Expr>, Box<Expr>),
    And(Box<Expr>, Box<Expr>),
    Or(Box<Expr>, Box<Expr>),
    Not(Box<Expr>),
    Like(Box<Expr>, String),
    In(Box<Expr>, Vec<Value>, /*negated*/ bool),
    IsNull(Box<Expr>, /*negated*/ bool),
    Between(Box<Expr>, Box<Expr>, Box<Expr>),
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
}

/// Parse error with position information.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub message: String,
    pub position: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "parse error at {}: {}", self.position, self.message)
    }
}

impl std::error::Error for ParseError {}

// ------------------------------------------------------------ lexer ----

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Ident(String),
    Int(i64),
    Real(f64),
    Str(String),
    Op(&'static str),
    LParen,
    RParen,
    Comma,
}

fn lex(src: &str) -> Result<Vec<(Tok, usize)>, ParseError> {
    let bytes = src.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        let start = i;
        match c {
            '(' => {
                toks.push((Tok::LParen, start));
                i += 1;
            }
            ')' => {
                toks.push((Tok::RParen, start));
                i += 1;
            }
            ',' => {
                toks.push((Tok::Comma, start));
                i += 1;
            }
            '+' => {
                toks.push((Tok::Op("+"), start));
                i += 1;
            }
            '-' => {
                toks.push((Tok::Op("-"), start));
                i += 1;
            }
            '=' => {
                toks.push((Tok::Op("="), start));
                i += 1;
            }
            '!' if bytes.get(i + 1) == Some(&b'=') => {
                toks.push((Tok::Op("!="), start));
                i += 2;
            }
            '<' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    toks.push((Tok::Op("<="), start));
                    i += 2;
                } else if bytes.get(i + 1) == Some(&b'>') {
                    toks.push((Tok::Op("!="), start));
                    i += 2;
                } else {
                    toks.push((Tok::Op("<"), start));
                    i += 1;
                }
            }
            '>' => {
                if bytes.get(i + 1) == Some(&b'=') {
                    toks.push((Tok::Op(">="), start));
                    i += 2;
                } else {
                    toks.push((Tok::Op(">"), start));
                    i += 1;
                }
            }
            '\'' => {
                i += 1;
                let mut s = String::new();
                loop {
                    match bytes.get(i) {
                        Some(b'\'') if bytes.get(i + 1) == Some(&b'\'') => {
                            s.push('\'');
                            i += 2;
                        }
                        Some(b'\'') => {
                            i += 1;
                            break;
                        }
                        Some(&b) => {
                            s.push(b as char);
                            i += 1;
                        }
                        None => {
                            return Err(ParseError {
                                message: "unterminated string".into(),
                                position: start,
                            })
                        }
                    }
                }
                toks.push((Tok::Str(s), start));
            }
            c if c.is_ascii_digit() => {
                let mut j = i;
                let mut is_real = false;
                while j < bytes.len()
                    && ((bytes[j] as char).is_ascii_digit() || bytes[j] == b'.')
                {
                    if bytes[j] == b'.' {
                        is_real = true;
                    }
                    j += 1;
                }
                let text = &src[i..j];
                if is_real {
                    let v = text.parse::<f64>().map_err(|e| ParseError {
                        message: format!("bad number {text}: {e}"),
                        position: start,
                    })?;
                    toks.push((Tok::Real(v), start));
                } else {
                    let v = text.parse::<i64>().map_err(|e| ParseError {
                        message: format!("bad number {text}: {e}"),
                        position: start,
                    })?;
                    toks.push((Tok::Int(v), start));
                }
                i = j;
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let mut j = i;
                while j < bytes.len()
                    && ((bytes[j] as char).is_ascii_alphanumeric() || bytes[j] == b'_')
                {
                    j += 1;
                }
                toks.push((Tok::Ident(src[i..j].to_string()), start));
                i = j;
            }
            other => {
                return Err(ParseError {
                    message: format!("unexpected character {other:?}"),
                    position: start,
                })
            }
        }
    }
    Ok(toks)
}

// ----------------------------------------------------------- parser ----

struct Parser {
    toks: Vec<(Tok, usize)>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|(t, _)| t)
    }

    fn peek_kw(&self, kw: &str) -> bool {
        matches!(self.peek(), Some(Tok::Ident(s)) if s.eq_ignore_ascii_case(kw))
    }

    fn bump(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).map(|(t, _)| t.clone());
        self.pos += 1;
        t
    }

    fn here(&self) -> usize {
        self.toks
            .get(self.pos)
            .or_else(|| self.toks.last())
            .map(|(_, p)| *p)
            .unwrap_or(0)
    }

    fn err(&self, msg: impl Into<String>) -> ParseError {
        ParseError {
            message: msg.into(),
            position: self.here(),
        }
    }

    fn expect_kw(&mut self, kw: &str) -> Result<(), ParseError> {
        if self.peek_kw(kw) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(format!("expected {kw}")))
        }
    }

    fn parse_or(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_and()?;
        while self.peek_kw("OR") {
            self.pos += 1;
            let rhs = self.parse_and()?;
            lhs = Expr::Or(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn parse_and(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_not()?;
        while self.peek_kw("AND") {
            self.pos += 1;
            let rhs = self.parse_not()?;
            lhs = Expr::And(Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn parse_not(&mut self) -> Result<Expr, ParseError> {
        if self.peek_kw("NOT") {
            self.pos += 1;
            let inner = self.parse_not()?;
            return Ok(Expr::Not(Box::new(inner)));
        }
        self.parse_cmp()
    }

    fn parse_cmp(&mut self) -> Result<Expr, ParseError> {
        let lhs = self.parse_sum()?;
        // IS [NOT] NULL
        if self.peek_kw("IS") {
            self.pos += 1;
            let negated = if self.peek_kw("NOT") {
                self.pos += 1;
                true
            } else {
                false
            };
            self.expect_kw("NULL")?;
            return Ok(Expr::IsNull(Box::new(lhs), negated));
        }
        // [NOT] IN / LIKE
        let negated_in = if self.peek_kw("NOT") {
            self.pos += 1;
            true
        } else {
            false
        };
        if self.peek_kw("LIKE") {
            self.pos += 1;
            match self.bump() {
                Some(Tok::Str(p)) => {
                    let like = Expr::Like(Box::new(lhs), p);
                    return Ok(if negated_in {
                        Expr::Not(Box::new(like))
                    } else {
                        like
                    });
                }
                _ => return Err(self.err("LIKE expects a string pattern")),
            }
        }
        if self.peek_kw("IN") {
            self.pos += 1;
            if self.bump() != Some(Tok::LParen) {
                return Err(self.err("IN expects '('"));
            }
            let mut items = Vec::new();
            loop {
                match self.bump() {
                    Some(Tok::Int(i)) => items.push(Value::Int(i)),
                    Some(Tok::Real(r)) => items.push(Value::Real(r)),
                    Some(Tok::Str(s)) => items.push(Value::Text(s)),
                    _ => return Err(self.err("IN list expects literals")),
                }
                match self.bump() {
                    Some(Tok::Comma) => continue,
                    Some(Tok::RParen) => break,
                    _ => return Err(self.err("expected ',' or ')' in IN list")),
                }
            }
            return Ok(Expr::In(Box::new(lhs), items, negated_in));
        }
        if negated_in {
            return Err(self.err("dangling NOT"));
        }
        if self.peek_kw("BETWEEN") {
            self.pos += 1;
            let lo = self.parse_sum()?;
            self.expect_kw("AND")?;
            let hi = self.parse_sum()?;
            return Ok(Expr::Between(Box::new(lhs), Box::new(lo), Box::new(hi)));
        }
        let op = match self.peek() {
            Some(Tok::Op("=")) => Some(CmpOp::Eq),
            Some(Tok::Op("!=")) => Some(CmpOp::Ne),
            Some(Tok::Op("<")) => Some(CmpOp::Lt),
            Some(Tok::Op("<=")) => Some(CmpOp::Le),
            Some(Tok::Op(">")) => Some(CmpOp::Gt),
            Some(Tok::Op(">=")) => Some(CmpOp::Ge),
            _ => None,
        };
        if let Some(op) = op {
            self.pos += 1;
            let rhs = self.parse_sum()?;
            return Ok(Expr::Cmp(op, Box::new(lhs), Box::new(rhs)));
        }
        Ok(lhs)
    }

    fn parse_sum(&mut self) -> Result<Expr, ParseError> {
        let mut lhs = self.parse_primary()?;
        loop {
            match self.peek() {
                Some(Tok::Op("+")) => {
                    self.pos += 1;
                    let rhs = self.parse_primary()?;
                    lhs = Expr::Add(Box::new(lhs), Box::new(rhs));
                }
                Some(Tok::Op("-")) => {
                    self.pos += 1;
                    let rhs = self.parse_primary()?;
                    lhs = Expr::Sub(Box::new(lhs), Box::new(rhs));
                }
                _ => break,
            }
        }
        Ok(lhs)
    }

    fn parse_primary(&mut self) -> Result<Expr, ParseError> {
        match self.bump() {
            Some(Tok::Int(i)) => Ok(Expr::Literal(Value::Int(i))),
            Some(Tok::Real(r)) => Ok(Expr::Literal(Value::Real(r))),
            Some(Tok::Str(s)) => Ok(Expr::Literal(Value::Text(s))),
            Some(Tok::Op("-")) => match self.bump() {
                Some(Tok::Int(i)) => Ok(Expr::Literal(Value::Int(-i))),
                Some(Tok::Real(r)) => Ok(Expr::Literal(Value::Real(-r))),
                _ => Err(self.err("expected number after unary -")),
            },
            Some(Tok::Ident(s)) if s.eq_ignore_ascii_case("TRUE") => {
                Ok(Expr::Literal(Value::Bool(true)))
            }
            Some(Tok::Ident(s)) if s.eq_ignore_ascii_case("FALSE") => {
                Ok(Expr::Literal(Value::Bool(false)))
            }
            Some(Tok::Ident(s)) if s.eq_ignore_ascii_case("NULL") => {
                Ok(Expr::Literal(Value::Null))
            }
            Some(Tok::Ident(s)) => Ok(Expr::Column(s)),
            Some(Tok::LParen) => {
                let e = self.parse_or()?;
                if self.bump() != Some(Tok::RParen) {
                    return Err(self.err("expected ')'"));
                }
                Ok(e)
            }
            _ => Err(self.err("expected expression")),
        }
    }
}

// -------------------------------------------------------- evaluation ----

impl Expr {
    /// Parse a WHERE clause. An empty/whitespace string parses to `TRUE`
    /// (a job without a `properties` constraint matches every node).
    pub fn parse(src: &str) -> Result<Expr, ParseError> {
        if src.trim().is_empty() {
            return Ok(Expr::Literal(Value::Bool(true)));
        }
        let toks = lex(src)?;
        let mut p = Parser { toks, pos: 0 };
        let e = p.parse_or()?;
        if p.pos != p.toks.len() {
            return Err(p.err("trailing tokens"));
        }
        Ok(e)
    }

    /// Evaluate against a row to a value (missing columns read as NULL).
    pub fn eval(&self, row: &Row) -> Value {
        self.eval_cols(row)
    }

    /// Evaluate against any column source (missing columns read as NULL).
    pub fn eval_cols<C: Columns + ?Sized>(&self, row: &C) -> Value {
        match self {
            Expr::Literal(v) => v.clone(),
            Expr::Column(name) => row.col(name).cloned().unwrap_or(Value::Null),
            Expr::Cmp(op, a, b) => {
                let (va, vb) = (a.eval_cols(row), b.eval_cols(row));
                match va.compare(&vb) {
                    None => {
                        // Ne on comparable-but-unequal types: still false
                        // under three-valued logic when NULL is involved.
                        if matches!(op, CmpOp::Ne)
                            && !va.is_null()
                            && !vb.is_null()
                        {
                            Value::Bool(true)
                        } else {
                            Value::Bool(false)
                        }
                    }
                    Some(ord) => Value::Bool(match op {
                        CmpOp::Eq => ord == std::cmp::Ordering::Equal,
                        CmpOp::Ne => ord != std::cmp::Ordering::Equal,
                        CmpOp::Lt => ord == std::cmp::Ordering::Less,
                        CmpOp::Le => ord != std::cmp::Ordering::Greater,
                        CmpOp::Gt => ord == std::cmp::Ordering::Greater,
                        CmpOp::Ge => ord != std::cmp::Ordering::Less,
                    }),
                }
            }
            Expr::And(a, b) => {
                Value::Bool(a.eval_cols(row).is_truthy() && b.eval_cols(row).is_truthy())
            }
            Expr::Or(a, b) => {
                Value::Bool(a.eval_cols(row).is_truthy() || b.eval_cols(row).is_truthy())
            }
            Expr::Not(a) => Value::Bool(!a.eval_cols(row).is_truthy()),
            Expr::Like(a, pat) => match a.eval_cols(row) {
                Value::Text(s) => Value::Bool(like_match(&s, pat)),
                _ => Value::Bool(false),
            },
            Expr::In(a, items, negated) => {
                let v = a.eval_cols(row);
                let found = items.iter().any(|it| v.sql_eq(it));
                Value::Bool(found != *negated)
            }
            Expr::IsNull(a, negated) => Value::Bool(a.eval_cols(row).is_null() != *negated),
            Expr::Between(a, lo, hi) => {
                let v = a.eval_cols(row);
                let (l, h) = (lo.eval_cols(row), hi.eval_cols(row));
                let ok = matches!(
                    v.compare(&l),
                    Some(std::cmp::Ordering::Greater | std::cmp::Ordering::Equal)
                ) && matches!(
                    v.compare(&h),
                    Some(std::cmp::Ordering::Less | std::cmp::Ordering::Equal)
                );
                Value::Bool(ok)
            }
            Expr::Add(a, b) => num_binop(a.eval_cols(row), b.eval_cols(row), |x, y| x + y),
            Expr::Sub(a, b) => num_binop(a.eval_cols(row), b.eval_cols(row), |x, y| x - y),
        }
    }

    /// WHERE-clause result: truthiness of [`Expr::eval`].
    pub fn matches(&self, row: &Row) -> bool {
        self.eval_cols(row).is_truthy()
    }

    /// WHERE-clause result against any column source.
    pub fn matches_cols<C: Columns + ?Sized>(&self, row: &C) -> bool {
        self.eval_cols(row).is_truthy()
    }

    /// Column names referenced by the expression.
    pub fn columns(&self) -> Vec<String> {
        let mut out = Vec::new();
        self.collect_columns(&mut out);
        out.sort();
        out.dedup();
        out
    }

    fn collect_columns(&self, out: &mut Vec<String>) {
        match self {
            Expr::Literal(_) => {}
            Expr::Column(c) => out.push(c.clone()),
            Expr::Cmp(_, a, b)
            | Expr::And(a, b)
            | Expr::Or(a, b)
            | Expr::Add(a, b)
            | Expr::Sub(a, b) => {
                a.collect_columns(out);
                b.collect_columns(out);
            }
            Expr::Not(a) | Expr::Like(a, _) | Expr::In(a, _, _) | Expr::IsNull(a, _) => {
                a.collect_columns(out)
            }
            Expr::Between(a, lo, hi) => {
                a.collect_columns(out);
                lo.collect_columns(out);
                hi.collect_columns(out);
            }
        }
    }

    /// Compile a *conjunctive numeric* expression to per-column interval
    /// constraints `[lo, hi]` — the bridge to the L1 matching kernel.
    /// Returns `None` when the expression is not interval-expressible
    /// (OR, NOT, LIKE, text comparisons...), in which case the matcher
    /// falls back to exact row-by-row evaluation.
    pub fn to_intervals(&self) -> Option<BTreeMap<String, (f64, f64)>> {
        let mut map = BTreeMap::new();
        if self.fill_intervals(&mut map) {
            Some(map)
        } else {
            None
        }
    }

    fn fill_intervals(&self, map: &mut BTreeMap<String, (f64, f64)>) -> bool {
        fn tighten(map: &mut BTreeMap<String, (f64, f64)>, col: &str, lo: f64, hi: f64) {
            let e = map
                .entry(col.to_string())
                .or_insert((f64::NEG_INFINITY, f64::INFINITY));
            e.0 = e.0.max(lo);
            e.1 = e.1.min(hi);
        }
        match self {
            Expr::Literal(Value::Bool(true)) => true,
            Expr::And(a, b) => fill2(a, b, map),
            Expr::Cmp(op, a, b) => {
                // Accept `col OP literal` and `literal OP col`.
                let (col, lit, op) = match (&**a, &**b) {
                    (Expr::Column(c), Expr::Literal(v)) => (c, v, *op),
                    (Expr::Literal(v), Expr::Column(c)) => (c, v, flip(*op)),
                    _ => return false,
                };
                let x = match lit.as_f64() {
                    Some(x) => x,
                    None => return false,
                };
                match op {
                    CmpOp::Eq => tighten(map, col, x, x),
                    CmpOp::Le => tighten(map, col, f64::NEG_INFINITY, x),
                    CmpOp::Lt => tighten(map, col, f64::NEG_INFINITY, x.next_down()),
                    CmpOp::Ge => tighten(map, col, x, f64::INFINITY),
                    CmpOp::Gt => tighten(map, col, x.next_up(), f64::INFINITY),
                    CmpOp::Ne => return false,
                }
                true
            }
            Expr::Between(a, lo, hi) => {
                let col = match &**a {
                    Expr::Column(c) => c,
                    _ => return false,
                };
                let (l, h) = match (&**lo, &**hi) {
                    (Expr::Literal(l), Expr::Literal(h)) => {
                        match (l.as_f64(), h.as_f64()) {
                            (Some(l), Some(h)) => (l, h),
                            _ => return false,
                        }
                    }
                    _ => return false,
                };
                tighten(map, col, l, h);
                true
            }
            _ => false,
        }
    }
}

fn fill2(a: &Expr, b: &Expr, map: &mut BTreeMap<String, (f64, f64)>) -> bool {
    a.fill_intervals(map) && b.fill_intervals(map)
}

fn flip(op: CmpOp) -> CmpOp {
    match op {
        CmpOp::Lt => CmpOp::Gt,
        CmpOp::Le => CmpOp::Ge,
        CmpOp::Gt => CmpOp::Lt,
        CmpOp::Ge => CmpOp::Le,
        other => other,
    }
}

fn num_binop(a: Value, b: Value, f: impl Fn(f64, f64) -> f64) -> Value {
    match (a.as_f64(), b.as_f64()) {
        (Some(x), Some(y)) => Value::Real(f(x, y)),
        _ => Value::Null,
    }
}

/// SQL LIKE with `%` (any run) and `_` (any char); case-sensitive.
fn like_match(s: &str, pat: &str) -> bool {
    fn rec(s: &[u8], p: &[u8]) -> bool {
        match p.first() {
            None => s.is_empty(),
            Some(b'%') => {
                (0..=s.len()).any(|k| rec(&s[k..], &p[1..]))
            }
            Some(b'_') => !s.is_empty() && rec(&s[1..], &p[1..]),
            Some(&c) => s.first() == Some(&c) && rec(&s[1..], &p[1..]),
        }
    }
    rec(s.as_bytes(), pat.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(pairs: &[(&str, Value)]) -> Row {
        let mut r = Row::new();
        for (k, v) in pairs {
            r.insert(k.to_string().into(), v.clone());
        }
        r
    }

    #[test]
    fn parse_and_eval_comparison() {
        let e = Expr::parse("mem >= 512").unwrap();
        assert!(e.matches(&row(&[("mem", Value::Int(512))])));
        assert!(!e.matches(&row(&[("mem", Value::Int(256))])));
    }

    #[test]
    fn conjunction_and_disjunction() {
        let e = Expr::parse("mem >= 512 AND switch = 'sw1' OR cpu_mhz > 2000").unwrap();
        assert!(e.matches(&row(&[
            ("mem", Value::Int(1024)),
            ("switch", Value::Text("sw1".into())),
            ("cpu_mhz", Value::Int(733)),
        ])));
        assert!(e.matches(&row(&[
            ("mem", Value::Int(0)),
            ("switch", Value::Text("x".into())),
            ("cpu_mhz", Value::Int(2400)),
        ])));
        assert!(!e.matches(&row(&[
            ("mem", Value::Int(0)),
            ("switch", Value::Text("x".into())),
            ("cpu_mhz", Value::Int(733)),
        ])));
    }

    #[test]
    fn precedence_and_binds_tighter_than_or() {
        // a OR b AND c === a OR (b AND c)
        let e = Expr::parse("a = 1 OR b = 1 AND c = 1").unwrap();
        assert!(e.matches(&row(&[
            ("a", Value::Int(1)),
            ("b", Value::Int(0)),
            ("c", Value::Int(0)),
        ])));
        assert!(!e.matches(&row(&[
            ("a", Value::Int(0)),
            ("b", Value::Int(1)),
            ("c", Value::Int(0)),
        ])));
    }

    #[test]
    fn missing_column_is_null_and_never_matches() {
        let e = Expr::parse("mem >= 0").unwrap();
        assert!(!e.matches(&row(&[])));
        let e = Expr::parse("mem IS NULL").unwrap();
        assert!(e.matches(&row(&[])));
    }

    #[test]
    fn like_patterns() {
        let e = Expr::parse("hostname LIKE 'node-%'").unwrap();
        assert!(e.matches(&row(&[("hostname", Value::Text("node-17".into()))])));
        assert!(!e.matches(&row(&[("hostname", Value::Text("server".into()))])));
        let e = Expr::parse("hostname LIKE 'n_de'").unwrap();
        assert!(e.matches(&row(&[("hostname", Value::Text("node".into()))])));
        assert!(!e.matches(&row(&[("hostname", Value::Text("noode".into()))])));
    }

    #[test]
    fn in_and_not_in() {
        let e = Expr::parse("switch IN ('sw1', 'sw2')").unwrap();
        assert!(e.matches(&row(&[("switch", Value::Text("sw2".into()))])));
        assert!(!e.matches(&row(&[("switch", Value::Text("sw3".into()))])));
        let e = Expr::parse("switch NOT IN ('sw1')").unwrap();
        assert!(e.matches(&row(&[("switch", Value::Text("sw9".into()))])));
    }

    #[test]
    fn between() {
        let e = Expr::parse("mem BETWEEN 256 AND 512").unwrap();
        assert!(e.matches(&row(&[("mem", Value::Int(256))])));
        assert!(e.matches(&row(&[("mem", Value::Int(512))])));
        assert!(!e.matches(&row(&[("mem", Value::Int(513))])));
    }

    #[test]
    fn empty_expression_matches_everything() {
        let e = Expr::parse("  ").unwrap();
        assert!(e.matches(&row(&[])));
    }

    #[test]
    fn arithmetic() {
        let e = Expr::parse("mem + swap >= 1024").unwrap();
        assert!(e.matches(&row(&[
            ("mem", Value::Int(512)),
            ("swap", Value::Int(512)),
        ])));
        assert!(!e.matches(&row(&[
            ("mem", Value::Int(512)),
            ("swap", Value::Int(0)),
        ])));
    }

    #[test]
    fn parse_errors_carry_position() {
        let err = Expr::parse("mem >=").unwrap_err();
        assert!(err.position > 0);
        assert!(Expr::parse("mem @@ 3").is_err());
        assert!(Expr::parse("(mem > 1").is_err());
        assert!(Expr::parse("mem > 1 extra").is_err());
    }

    #[test]
    fn intervals_simple_conjunction() {
        let e = Expr::parse("mem >= 512 AND cpu_mhz > 2000 AND mem <= 2048").unwrap();
        let iv = e.to_intervals().unwrap();
        assert_eq!(iv["mem"].0, 512.0);
        assert_eq!(iv["mem"].1, 2048.0);
        assert!(iv["cpu_mhz"].0 > 2000.0);
        assert_eq!(iv["cpu_mhz"].1, f64::INFINITY);
    }

    #[test]
    fn intervals_equality_and_flipped() {
        let e = Expr::parse("512 <= mem AND nb_procs = 2").unwrap();
        let iv = e.to_intervals().unwrap();
        assert_eq!(iv["mem"], (512.0, f64::INFINITY));
        assert_eq!(iv["nb_procs"], (2.0, 2.0));
    }

    #[test]
    fn intervals_reject_disjunction_and_text() {
        assert!(Expr::parse("mem >= 1 OR mem <= 0").unwrap().to_intervals().is_none());
        assert!(Expr::parse("switch = 'sw1'").unwrap().to_intervals().is_none());
        assert!(Expr::parse("NOT mem > 1").unwrap().to_intervals().is_none());
    }

    #[test]
    fn intervals_match_eval_semantics() {
        // For interval-expressible expressions, interval containment must
        // agree with exact evaluation (this is the kernel-vs-SQL bridge).
        let e = Expr::parse("mem >= 512 AND cpu_mhz BETWEEN 1000 AND 3000").unwrap();
        let iv = e.to_intervals().unwrap();
        for mem in [0i64, 511, 512, 4096] {
            for mhz in [999i64, 1000, 3000, 3001] {
                let r = row(&[("mem", Value::Int(mem)), ("cpu_mhz", Value::Int(mhz))]);
                let exact = e.matches(&r);
                let via_iv = (mem as f64) >= iv["mem"].0
                    && (mem as f64) <= iv["mem"].1
                    && (mhz as f64) >= iv["cpu_mhz"].0
                    && (mhz as f64) <= iv["cpu_mhz"].1;
                assert_eq!(exact, via_iv, "mem={mem} mhz={mhz}");
            }
        }
    }
}
