//! Write-ahead log: the durability subsystem under [`super::store::Db`].
//!
//! The paper's robustness argument (§1, §2) rests on the database being
//! the single durable source of truth — "the database engine can handle
//! the data safety" — so that any module can crash and be re-run. A purely
//! in-memory store with occasional snapshots does not actually provide
//! that: a crash between snapshots loses every mutation since the last
//! one. This module closes the gap with a classic WAL design:
//!
//! * every **logical mutation** (`insert` / `delete` / `set_cell` /
//!   `update_where` / `log_event`) is serialized as a [`Mutation`] record
//!   and appended to the log *before* it is applied in memory
//!   (write-ahead discipline);
//! * records are framed as `LLLLLLLL CCCCCCCCCCCCCCCC payload\n` — an
//!   8-hex-digit payload length, a 16-hex-digit FNV-1a checksum, the JSON
//!   payload, a newline — so a torn tail (a crash mid-write) is detected
//!   at *any* byte boundary and never replayed;
//! * periodically the store **checkpoints**: it writes a new snapshot
//!   generation atomically (temp file + rename) and rotates to an empty
//!   log, bounding recovery time;
//! * [`super::store::Db::recover`] loads the newest snapshot generation,
//!   replays the matching log tail deterministically (mutations are
//!   *physical-logical*: they carry resolved row ids, so replay never
//!   re-runs validation logic), truncates any torn tail, and rebuilds the
//!   secondary indexes, which are derived state and never logged.
//!
//! Crash injection for the test harness: [`Wal::inject_failure`] arms a
//! fail point that, after N successful appends, writes only a prefix of
//! the next framed record (possibly zero bytes), flushes it, and poisons
//! the log. A poisoned log models a dead process: every later mutation is
//! neither logged nor applied, so the in-memory state at "death" is
//! exactly the prefix of fully-written records — which is exactly what
//! recovery must reproduce.
//!
//! **Group commit**: framed records are accepted into a pending buffer
//! and reach the file in batches. By default every append flushes its own
//! record immediately (the classic one-`write(2)`-per-record discipline);
//! a store under a reader-writer core enables *group-commit mode*, where
//! appends only buffer and a [`WalCommit`] handle — callable **without**
//! the database lock — flushes everything pending in one write. Several
//! writers that mutate back-to-back then share a single log write (and a
//! single `sync_data`, when sync mode is on): the first committer's flush
//! covers every record buffered so far, and the others find the buffer
//! empty and return without touching the file. Callers must not
//! acknowledge a write before committing it; every crash-shaped exit
//! (poison, fail-point tear, drop, rotation) flushes the buffer first, so
//! the recoverable prefix is never behind the acknowledged state.
//!
//! **Durability model**: appends reach the kernel via `write(2)` but are
//! not fsynced per record, so the guarantee covers *process* death
//! (crash, `kill -9`, the injected fail points) — what the paper's
//! module-robustness argument needs — not power loss or kernel panic.
//! Snapshots, being rare, *are* fsynced before the rename that publishes
//! them. Setting `OAR_WAL_SYNC=1` (or [`Wal::set_sync_on_flush`]) extends
//! the guarantee to power failure by fsyncing every flush — group commit
//! is what makes that affordable, since one `sync_data` then covers a
//! whole batch of writers.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::types::{JobId, Time};
use crate::util::Json;

use super::table::Row;
use super::value::Value;

/// The tables a [`Mutation`] can address (the standard schema).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableId {
    Jobs,
    Nodes,
    Assignments,
    Queues,
    AdmissionRules,
    /// Grid federation: campaign headers (one row per bag of tasks).
    Campaigns,
    /// Grid federation: one row per task, tracking remote placement.
    GridTasks,
    /// Hierarchical resources (cluster/switch/host/cpu/core); the nodes
    /// table is a derived view of the host level.
    Resources,
}

impl TableId {
    pub fn as_str(self) -> &'static str {
        match self {
            TableId::Jobs => "jobs",
            TableId::Nodes => "nodes",
            TableId::Assignments => "assignments",
            TableId::Queues => "queues",
            TableId::AdmissionRules => "admission_rules",
            TableId::Campaigns => "campaigns",
            TableId::GridTasks => "grid_tasks",
            TableId::Resources => "resources",
        }
    }

    pub fn parse(s: &str) -> Option<TableId> {
        Some(match s {
            "jobs" => TableId::Jobs,
            "nodes" => TableId::Nodes,
            "assignments" => TableId::Assignments,
            "queues" => TableId::Queues,
            "admission_rules" => TableId::AdmissionRules,
            "campaigns" => TableId::Campaigns,
            "grid_tasks" => TableId::GridTasks,
            "resources" => TableId::Resources,
            _ => return None,
        })
    }
}

/// One logical mutation, as logged. Inserts carry the row *without* its
/// id (the table assigns it; `next_id` is monotonic and snapshotted, so
/// replay assigns identical ids). Cell writes and deletes carry resolved
/// row ids — replay is pure application, no validation re-runs.
#[derive(Debug, Clone, PartialEq)]
pub enum Mutation {
    Insert {
        table: TableId,
        row: Row,
    },
    Delete {
        table: TableId,
        id: u64,
    },
    SetCell {
        table: TableId,
        id: u64,
        col: String,
        value: Value,
    },
    UpdateWhere {
        table: TableId,
        filter: String,
        col: String,
        value: Value,
    },
    LogEvent {
        time: Time,
        kind: String,
        job: Option<JobId>,
        detail: String,
    },
}

fn row_to_json(row: &Row) -> Json {
    Json::Obj(
        row.iter()
            .map(|(k, v)| (k.to_string(), v.to_json()))
            .collect(),
    )
}

fn row_from_json(j: &Json) -> crate::Result<Row> {
    let Json::Obj(m) = j else {
        anyhow::bail!("row must be an object");
    };
    let mut row = Row::new();
    for (k, v) in m {
        row.insert(k.clone().into(), Value::from_json(v)?);
    }
    Ok(row)
}

impl Mutation {
    pub fn to_json(&self) -> Json {
        match self {
            Mutation::Insert { table, row } => Json::obj(vec![
                ("op", Json::Str("insert".into())),
                ("t", Json::Str(table.as_str().into())),
                ("row", row_to_json(row)),
            ]),
            Mutation::Delete { table, id } => Json::obj(vec![
                ("op", Json::Str("delete".into())),
                ("t", Json::Str(table.as_str().into())),
                ("id", Json::Num(*id as f64)),
            ]),
            Mutation::SetCell {
                table,
                id,
                col,
                value,
            } => Json::obj(vec![
                ("op", Json::Str("set".into())),
                ("t", Json::Str(table.as_str().into())),
                ("id", Json::Num(*id as f64)),
                ("c", Json::Str(col.clone())),
                ("v", value.to_json()),
            ]),
            Mutation::UpdateWhere {
                table,
                filter,
                col,
                value,
            } => Json::obj(vec![
                ("op", Json::Str("update".into())),
                ("t", Json::Str(table.as_str().into())),
                ("f", Json::Str(filter.clone())),
                ("c", Json::Str(col.clone())),
                ("v", value.to_json()),
            ]),
            Mutation::LogEvent {
                time,
                kind,
                job,
                detail,
            } => Json::obj(vec![
                ("op", Json::Str("event".into())),
                ("time", Json::Num(*time as f64)),
                ("k", Json::Str(kind.clone())),
                (
                    "j",
                    job.map(|j| Json::Num(j as f64)).unwrap_or(Json::Null),
                ),
                ("d", Json::Str(detail.clone())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> crate::Result<Mutation> {
        let op = j
            .get("op")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("mutation missing op"))?;
        let table = || -> crate::Result<TableId> {
            j.get("t")
                .and_then(Json::as_str)
                .and_then(TableId::parse)
                .ok_or_else(|| anyhow::anyhow!("mutation has bad table"))
        };
        let text = |key: &str| -> crate::Result<String> {
            Ok(j.get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("mutation missing {key}"))?
                .to_string())
        };
        Ok(match op {
            "insert" => Mutation::Insert {
                table: table()?,
                row: row_from_json(
                    j.get("row")
                        .ok_or_else(|| anyhow::anyhow!("insert missing row"))?,
                )?,
            },
            "delete" => Mutation::Delete {
                table: table()?,
                id: j
                    .get("id")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| anyhow::anyhow!("delete missing id"))?
                    as u64,
            },
            "set" => Mutation::SetCell {
                table: table()?,
                id: j
                    .get("id")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| anyhow::anyhow!("set missing id"))? as u64,
                col: text("c")?,
                value: Value::from_json(
                    j.get("v").ok_or_else(|| anyhow::anyhow!("set missing v"))?,
                )?,
            },
            "update" => Mutation::UpdateWhere {
                table: table()?,
                filter: text("f")?,
                col: text("c")?,
                value: Value::from_json(
                    j.get("v")
                        .ok_or_else(|| anyhow::anyhow!("update missing v"))?,
                )?,
            },
            "event" => Mutation::LogEvent {
                time: j.get("time").and_then(Json::as_i64).unwrap_or(0),
                kind: text("k")?,
                job: j.get("j").and_then(Json::as_i64).map(|v| v as JobId),
                detail: text("d")?,
            },
            other => anyhow::bail!("unknown mutation op {other:?}"),
        })
    }
}

// ----------------------------------------------------------- framing ----

/// FNV-1a 64-bit: tiny, dependency-free, and plenty to reject torn or
/// bit-rotted records (this is corruption *detection*, not security).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// `8-hex len` + space + `16-hex checksum` + space.
const HEADER_LEN: usize = 8 + 1 + 16 + 1;

fn frame(payload: &str) -> Vec<u8> {
    format!(
        "{:08x} {:016x} {}\n",
        payload.len(),
        fnv1a(payload.as_bytes()),
        payload
    )
    .into_bytes()
}

fn parse_hex(bytes: &[u8]) -> Option<u64> {
    let s = std::str::from_utf8(bytes).ok()?;
    if !s.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(s, 16).ok()
}

/// Decode every complete record; returns `(records, valid_bytes, torn)`.
/// `valid_bytes` is the clean prefix length; anything past it is a torn
/// tail (crash mid-write) and must be truncated, never applied.
fn decode_all(bytes: &[u8]) -> (Vec<Mutation>, usize, bool) {
    let mut out = Vec::new();
    let mut at = 0usize;
    loop {
        if at == bytes.len() {
            return (out, at, false);
        }
        let torn = |out: Vec<Mutation>, at: usize| (out, at, true);
        let Some(header) = bytes.get(at..at + HEADER_LEN) else {
            return torn(out, at);
        };
        if header[8] != b' ' || header[25] != b' ' {
            return torn(out, at);
        }
        let (Some(len), Some(crc)) = (parse_hex(&header[..8]), parse_hex(&header[9..25]))
        else {
            return torn(out, at);
        };
        let start = at + HEADER_LEN;
        let end = start + len as usize;
        if bytes.len() < end + 1 || bytes[end] != b'\n' {
            return torn(out, at);
        }
        let payload = &bytes[start..end];
        if fnv1a(payload) != crc {
            return torn(out, at);
        }
        let Some(m) = std::str::from_utf8(payload)
            .ok()
            .and_then(|s| Json::parse(s).ok())
            .and_then(|j| Mutation::from_json(&j).ok())
        else {
            return torn(out, at);
        };
        out.push(m);
        at = end + 1;
    }
}

// --------------------------------------------------------------- wal ----

/// Crash fail point: after `after` more successful appends, write only
/// `partial` bytes of the next framed record (clamped below the full
/// frame, so an injected crash always leaves nothing or a torn record —
/// never a silently-complete one) and poison the log.
#[derive(Debug, Clone, Copy)]
struct FailPoint {
    after: u64,
    partial: usize,
}

/// Why an append did not happen. The distinction matters: an *injected*
/// crash (or a log already poisoned by one) models a dead process — the
/// store silently stops, exactly like `kill -9` — while a *real* I/O
/// failure (disk full, permission lost) must never be swallowed, or a
/// live server would keep acknowledging writes that are neither durable
/// nor applied.
#[derive(Debug)]
pub enum AppendError {
    /// The crash harness tore this write (or poisoned the log earlier).
    Injected,
    /// The underlying file write genuinely failed.
    Io(std::io::Error),
}

/// What [`super::store::Db::recover`] found on disk.
#[derive(Debug, Clone, Copy)]
pub struct RecoverStats {
    /// Snapshot/log generation recovered from.
    pub generation: u64,
    /// Whether a snapshot file seeded the state (false: replayed from
    /// an empty base — a database that never checkpointed).
    pub snapshot_loaded: bool,
    /// WAL records replayed on top of the snapshot.
    pub replayed: u64,
    /// Whether a torn tail (crash mid-append) was detected and truncated.
    pub torn_tail: bool,
}

/// In group-commit mode the pending buffer is force-flushed once it
/// grows past this, bounding the window a store that never commits
/// explicitly (e.g. a test driving `Db` directly) keeps in user space.
const GROUP_FLUSH_BYTES: usize = 256 * 1024;

/// The shared append sink: the open log file, the pending (not yet
/// written) framed records, and the crash state. It lives behind its own
/// lock, *separate* from the database lock, so a [`WalCommit`] handle can
/// flush a batch while the next writer is already mutating the store —
/// the mechanism behind group commit.
#[derive(Debug)]
struct Sink {
    file: File,
    /// Framed records accepted by `append` but not yet written to `file`.
    pending: Vec<u8>,
    /// Record count inside `pending` (the group-commit batch-size
    /// distribution is reported in records as well as bytes).
    pending_records: usize,
    /// Buffer appends for batched flushes (off: flush every record).
    group: bool,
    /// `sync_data` after every flush: power-loss durability, amortized
    /// across the batch.
    sync_on_flush: bool,
    failpoint: Option<FailPoint>,
    crashed: bool,
}

impl Sink {
    /// Write everything pending in one `write(2)` (+ optional fsync).
    /// On error the buffer is kept; callers poison the log.
    fn flush(&mut self) -> std::io::Result<()> {
        if self.pending.is_empty() {
            return Ok(());
        }
        self.file.write_all(&self.pending)?;
        self.pending.clear();
        self.pending_records = 0;
        if self.sync_on_flush {
            self.file.sync_data()?;
        }
        Ok(())
    }
}

/// Cloneable commit handle: flushes every record appended so far without
/// taking the database lock. The group-commit fast path is structural —
/// whichever committer runs first writes the whole batch; later
/// committers find nothing pending and return immediately.
#[derive(Debug, Clone)]
pub struct WalCommit {
    sink: Arc<Mutex<Sink>>,
}

impl WalCommit {
    /// Make every acknowledged-to-be-appended record durable (to the
    /// degree the sync mode promises). Call before acking a write.
    ///
    /// Telemetry (flush latency + batch-size distribution) is captured
    /// under the sink lock but recorded after it drops — the R7 lint
    /// (docs/LINTS.md) forbids metric calls while the sink is held.
    pub fn commit(&self) -> Result<(), AppendError> {
        let t0 = crate::obs::clock::now_us();
        let (batch_bytes, batch_records) = {
            let mut s = self.sink.lock().unwrap();
            if s.crashed {
                // Dead process: the tear already flushed what it accepted.
                return Err(AppendError::Injected);
            }
            let bytes = s.pending.len();
            let records = s.pending_records;
            if let Err(e) = s.flush() {
                s.crashed = true;
                return Err(AppendError::Io(e));
            }
            (bytes, records)
        };
        if batch_bytes > 0 {
            crate::obs::metrics::WAL_FLUSH_US
                .observe(crate::obs::clock::now_us().saturating_sub(t0));
            crate::obs::metrics::WAL_BATCH_BYTES.observe(batch_bytes as u64);
            crate::obs::metrics::WAL_BATCH_RECORDS.observe(batch_records as u64);
        }
        Ok(())
    }
}

/// The open write-ahead log of one durable database.
#[derive(Debug)]
pub struct Wal {
    dir: PathBuf,
    generation: u64,
    sink: Arc<Mutex<Sink>>,
    /// Records successfully appended over this object's lifetime
    /// (including the replayed tail it was opened with) — the crash
    /// harness counts boundaries in this unit.
    total: u64,
    since_checkpoint: u64,
    checkpoint_every: u64,
}

impl Wal {
    pub fn snapshot_path(dir: &Path, generation: u64) -> PathBuf {
        dir.join(format!("snapshot-{generation:06}.json"))
    }

    pub fn log_path(dir: &Path, generation: u64) -> PathBuf {
        dir.join(format!("wal-{generation:06}.log"))
    }

    /// Newest generation present in `dir` (snapshot or log file), or 0.
    pub fn latest_generation(dir: &Path) -> crate::Result<u64> {
        let mut latest = 0u64;
        for entry in std::fs::read_dir(dir)? {
            let name = entry?.file_name();
            let Some(name) = name.to_str() else { continue };
            let generation = name
                .strip_prefix("snapshot-")
                .and_then(|s| s.strip_suffix(".json"))
                .or_else(|| {
                    name.strip_prefix("wal-").and_then(|s| s.strip_suffix(".log"))
                })
                .and_then(|s| s.parse::<u64>().ok());
            if let Some(g) = generation {
                latest = latest.max(g);
            }
        }
        Ok(latest)
    }

    /// Read and decode generation `generation`'s log; a torn tail is
    /// truncated off the file so the reopened log appends cleanly after
    /// the last valid record. Returns `(records, torn_tail_found)`.
    pub fn read_records(dir: &Path, generation: u64) -> crate::Result<(Vec<Mutation>, bool)> {
        let path = Self::log_path(dir, generation);
        if !path.exists() {
            return Ok((Vec::new(), false));
        }
        let bytes = std::fs::read(&path)?;
        let (records, valid, torn) = decode_all(&bytes);
        if valid < bytes.len() {
            OpenOptions::new().write(true).open(&path)?.set_len(valid as u64)?;
        }
        Ok((records, torn))
    }

    /// Open generation `generation` for appending (creating the file if
    /// missing); `replayed` seeds the record counters. Older generations
    /// and stale checkpoint temp files are swept — recovery is the other
    /// point (besides rotation) where crash debris gets cleaned up.
    pub fn open(dir: &Path, generation: u64, replayed: u64) -> crate::Result<Wal> {
        Self::sweep_older_than(dir, generation);
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(Self::log_path(dir, generation))?;
        let sync_on_flush = std::env::var("OAR_WAL_SYNC")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false);
        Ok(Wal {
            dir: dir.to_path_buf(),
            generation,
            sink: Arc::new(Mutex::new(Sink {
                file,
                pending: Vec::new(),
                pending_records: 0,
                group: false,
                sync_on_flush,
                failpoint: None,
                crashed: false,
            })),
            total: replayed,
            since_checkpoint: replayed,
            checkpoint_every: 0,
        })
    }

    /// Append one record (write-ahead: callers apply only after `Ok`).
    /// Outside group-commit mode the record is flushed immediately; in
    /// group-commit mode it only enters the pending buffer, and the
    /// caller must [`WalCommit::commit`] (or [`Wal::flush`]) before
    /// acknowledging the write. Any failure poisons the log; see
    /// [`AppendError`] for how callers must treat the two failure classes
    /// differently.
    pub fn append(&mut self, m: &Mutation) -> Result<(), AppendError> {
        let t0 = crate::obs::clock::now_us();
        let mut s = self.sink.lock().unwrap();
        if s.crashed {
            return Err(AppendError::Injected);
        }
        let framed = frame(&m.to_json().dump());
        if let Some(fp) = s.failpoint {
            if fp.after == 0 {
                // Tear exactly as a dying process would: every record
                // accepted before this one reaches the file (they were
                // `write(2)`-durable in spirit the moment they were
                // acknowledged), then a prefix of the failing frame.
                let _ = s.flush();
                let cut = fp.partial.min(framed.len().saturating_sub(1));
                let _ = s.file.write_all(&framed[..cut]);
                let _ = s.file.flush();
                s.crashed = true;
                return Err(AppendError::Injected);
            }
            s.failpoint = Some(FailPoint {
                after: fp.after - 1,
                ..fp
            });
        }
        s.pending.extend_from_slice(&framed);
        s.pending_records += 1;
        if !s.group || s.pending.len() >= GROUP_FLUSH_BYTES {
            if let Err(e) = s.flush() {
                s.crashed = true;
                return Err(AppendError::Io(e));
            }
        }
        drop(s);
        self.total += 1;
        self.since_checkpoint += 1;
        crate::obs::metrics::WAL_APPEND_US
            .observe(crate::obs::clock::now_us().saturating_sub(t0));
        Ok(())
    }

    /// Flush the pending buffer from the owning side (a committer that
    /// already holds the store mutably). Equivalent to
    /// [`WalCommit::commit`].
    pub fn flush(&mut self) -> Result<(), AppendError> {
        WalCommit {
            sink: self.sink.clone(),
        }
        .commit()
    }

    /// A cloneable commit handle sharing this log's sink; committing
    /// through it does not require the database lock.
    pub fn commit_handle(&self) -> WalCommit {
        WalCommit {
            sink: self.sink.clone(),
        }
    }

    /// Enable/disable group-commit mode (buffered appends + batched
    /// flushes). Off by default: a store without a committing front-end
    /// keeps the one-write-per-record discipline.
    pub fn set_group_commit(&mut self, enabled: bool) {
        let mut s = self.sink.lock().unwrap();
        s.group = enabled;
        if !enabled && !s.crashed {
            let _ = s.flush();
        }
    }

    /// Fsync every flush (power-loss durability; see the module docs).
    pub fn set_sync_on_flush(&mut self, enabled: bool) {
        self.sink.lock().unwrap().sync_on_flush = enabled;
    }

    /// Rotate to a fresh log for `new_generation` (called after that
    /// generation's snapshot has been durably renamed into place); every
    /// older generation's files are swept best-effort — including debris
    /// from checkpoints that crashed between rename and rotation.
    pub fn rotate(&mut self, new_generation: u64) -> crate::Result<()> {
        let file = OpenOptions::new()
            .create(true)
            .write(true)
            .truncate(true)
            .open(Self::log_path(&self.dir, new_generation))?;
        {
            let mut s = self.sink.lock().unwrap();
            // Pending records were applied in memory, so the snapshot
            // that precedes rotation already covers them: they must land
            // in the *old* generation's file (about to be swept), never
            // the new one, or recovery would apply them twice.
            s.flush()
                .map_err(|e| anyhow::anyhow!("wal flush before rotate: {e}"))?;
            s.file = file;
        }
        self.generation = new_generation;
        self.since_checkpoint = 0;
        Self::sweep_older_than(&self.dir, new_generation);
        Ok(())
    }

    /// Remove snapshot/log files of every generation below `keep`, plus
    /// stale snapshot temp files (a crash mid-checkpoint leaves either a
    /// `.tmp` that was never renamed, or — when it died between rename
    /// and rotation — a whole previous generation). Best-effort: sweep
    /// failures never affect correctness, only disk usage.
    pub fn sweep_older_than(dir: &Path, keep: u64) {
        let Ok(entries) = std::fs::read_dir(dir) else {
            return;
        };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let generation = name
                .strip_prefix("snapshot-")
                .and_then(|s| s.strip_suffix(".json"))
                .or_else(|| {
                    name.strip_prefix("wal-").and_then(|s| s.strip_suffix(".log"))
                })
                .and_then(|s| s.parse::<u64>().ok());
            let stale = match generation {
                Some(g) => g < keep,
                None => name.starts_with("snapshot-") && name.ends_with(".tmp"),
            };
            if stale {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }

    /// Arm the crash fail point: `after` more appends succeed, then the
    /// next one writes only `partial` bytes (clamped to frame length − 1)
    /// and poisons the log.
    pub fn inject_failure(&mut self, after: u64, partial: usize) {
        self.sink.lock().unwrap().failpoint = Some(FailPoint { after, partial });
    }

    /// Poison the log immediately — models `kill -9` right now. The
    /// pending buffer is flushed first: records appended before this
    /// instant were acknowledged, so the recoverable prefix must contain
    /// them (exactly the old per-record-`write(2)` behaviour).
    pub fn crash(&mut self) {
        let mut s = self.sink.lock().unwrap();
        let _ = s.flush();
        s.crashed = true;
    }

    pub fn crashed(&self) -> bool {
        self.sink.lock().unwrap().crashed
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Records appended over this log's lifetime (crash-harness unit).
    pub fn total_records(&self) -> u64 {
        self.total
    }

    pub fn set_checkpoint_every(&mut self, every: u64) {
        self.checkpoint_every = every;
    }

    /// Whether the store should checkpoint now (auto-compaction cadence).
    pub fn due_checkpoint(&self) -> bool {
        self.checkpoint_every > 0
            && self.since_checkpoint >= self.checkpoint_every
            && !self.crashed()
    }
}

impl Drop for Wal {
    /// A process exiting cleanly must leave its acknowledged records on
    /// disk even if nothing committed the last batch explicitly.
    fn drop(&mut self) {
        let Ok(mut s) = self.sink.lock() else { return };
        if !s.crashed {
            let _ = s.flush();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Mutation> {
        let mut row = Row::new();
        row.insert("user".into(), Value::Text("alice".into()));
        row.insert("nbNodes".into(), Value::Int(4));
        vec![
            Mutation::Insert {
                table: TableId::Jobs,
                row,
            },
            Mutation::SetCell {
                table: TableId::Jobs,
                id: 1,
                col: "state".into(),
                value: Value::Text("toLaunch".into()),
            },
            Mutation::Delete {
                table: TableId::Assignments,
                id: 7,
            },
            Mutation::UpdateWhere {
                table: TableId::Jobs,
                filter: "state = 'Waiting'".into(),
                col: "message".into(),
                value: Value::Text("bulk".into()),
            },
            Mutation::LogEvent {
                time: 42,
                kind: "TEST".into(),
                job: Some(3),
                detail: "d\"e\n".into(),
            },
        ]
    }

    #[test]
    fn mutation_json_roundtrip() {
        for m in sample() {
            let back = Mutation::from_json(&Json::parse(&m.to_json().dump()).unwrap()).unwrap();
            assert_eq!(m, back);
        }
    }

    #[test]
    fn frames_decode_back() {
        let mut bytes = Vec::new();
        for m in sample() {
            bytes.extend(frame(&m.to_json().dump()));
        }
        let (records, valid, torn) = decode_all(&bytes);
        assert_eq!(records, sample());
        assert_eq!(valid, bytes.len());
        assert!(!torn);
    }

    #[test]
    fn torn_tail_is_detected_at_every_cut_point() {
        let mut bytes = Vec::new();
        for m in sample() {
            bytes.extend(frame(&m.to_json().dump()));
        }
        let boundaries: Vec<usize> = {
            let mut at = 0;
            let mut b = vec![0];
            for m in sample() {
                at += frame(&m.to_json().dump()).len();
                b.push(at);
            }
            b
        };
        for cut in 0..bytes.len() {
            let (records, valid, torn) = decode_all(&bytes[..cut]);
            // the decoded prefix is exactly the whole records before the cut
            let whole = boundaries.iter().filter(|b| **b <= cut).count() - 1;
            assert_eq!(records.len(), whole, "cut {cut}");
            assert_eq!(valid, boundaries[whole], "cut {cut}");
            assert_eq!(torn, cut != boundaries[whole], "cut {cut}");
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("oar_wal_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn group_commit_buffers_until_committed() {
        let dir = tmp_dir("group");
        let mut wal = Wal::open(&dir, 0, 0).unwrap();
        wal.set_group_commit(true);
        for m in sample() {
            wal.append(&m).unwrap();
        }
        // Nothing on disk yet: the records are pending in the sink.
        let on_disk = std::fs::read(Wal::log_path(&dir, 0)).unwrap();
        assert!(on_disk.is_empty(), "group mode must not write per record");
        assert_eq!(wal.total_records(), sample().len() as u64);

        // One commit (via the lock-free handle) lands the whole batch.
        wal.commit_handle().commit().unwrap();
        let (records, _) = Wal::read_records(&dir, 0).unwrap();
        assert_eq!(records, sample());
        drop(wal);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crash_and_drop_flush_the_pending_batch() {
        // Poisoning models a dead process whose acknowledged appends had
        // already hit write(2): the buffer must reach the file first.
        let dir = tmp_dir("crashflush");
        let mut wal = Wal::open(&dir, 0, 0).unwrap();
        wal.set_group_commit(true);
        for m in sample() {
            wal.append(&m).unwrap();
        }
        wal.crash();
        assert!(wal.crashed());
        assert!(matches!(
            wal.append(&sample()[0]),
            Err(AppendError::Injected)
        ));
        let (records, torn) = Wal::read_records(&dir, 0).unwrap();
        assert_eq!(records, sample());
        assert!(!torn);
        drop(wal);

        // Clean drop flushes too.
        let dir2 = tmp_dir("dropflush");
        let mut wal = Wal::open(&dir2, 0, 0).unwrap();
        wal.set_group_commit(true);
        wal.append(&sample()[0]).unwrap();
        drop(wal);
        let (records, _) = Wal::read_records(&dir2, 0).unwrap();
        assert_eq!(records.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&dir2);
    }

    #[test]
    fn injected_tear_lands_prior_batch_then_torn_frame() {
        let dir = tmp_dir("tearflush");
        let mut wal = Wal::open(&dir, 0, 0).unwrap();
        wal.set_group_commit(true);
        wal.inject_failure(2, 7);
        let ms = sample();
        wal.append(&ms[0]).unwrap();
        wal.append(&ms[1]).unwrap();
        assert!(matches!(wal.append(&ms[2]), Err(AppendError::Injected)));
        // The two acknowledged records recover; the torn third does not.
        let (records, torn) = Wal::read_records(&dir, 0).unwrap();
        assert_eq!(records, ms[..2].to_vec());
        assert!(torn);
        drop(wal);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_byte_stops_replay() {
        let mut bytes = Vec::new();
        for m in sample() {
            bytes.extend(frame(&m.to_json().dump()));
        }
        let first = frame(&sample()[0].to_json().dump()).len();
        // flip one payload byte of the second record
        let mut bad = bytes.clone();
        bad[first + HEADER_LEN + 2] ^= 0x20;
        let (records, valid, torn) = decode_all(&bad);
        assert_eq!(records.len(), 1, "only the intact prefix replays");
        assert_eq!(valid, first);
        assert!(torn);
    }
}
