//! SQL-ish scalar values with MySQL-flavoured comparison semantics.


/// A scalar cell value. Comparisons are numeric when both sides are
/// numeric (Int/Real mix coerces to f64, as MySQL does), lexicographic for
/// text, and `Null` never compares equal to anything (three-valued logic is
/// collapsed to false, which is what a WHERE clause observes).
#[derive(Debug, Clone)]
pub enum Value {
    Null,
    Int(i64),
    Real(f64),
    Text(String),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Real(r) => Some(*r),
            Value::Bool(b) => Some(if *b { 1.0 } else { 0.0 }),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Real(r) => Some(*r as i64),
            Value::Bool(b) => Some(*b as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Text(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Value::Null)
    }

    /// Truthiness of a WHERE result: NULL and 0 are false.
    pub fn is_truthy(&self) -> bool {
        match self {
            Value::Null => false,
            Value::Bool(b) => *b,
            Value::Int(i) => *i != 0,
            Value::Real(r) => *r != 0.0,
            Value::Text(s) => !s.is_empty(),
        }
    }

    /// SQL comparison: None when either side is NULL or the types are
    /// incomparable (text vs number never matches, as with strict modes).
    pub fn compare(&self, other: &Value) -> Option<std::cmp::Ordering> {
        use Value::*;
        match (self, other) {
            (Null, _) | (_, Null) => None,
            (Text(a), Text(b)) => Some(a.cmp(b)),
            (a, b) => {
                let (x, y) = (a.as_f64()?, b.as_f64()?);
                x.partial_cmp(&y)
            }
        }
    }

    /// Equality under SQL semantics (NULL = anything is false).
    pub fn sql_eq(&self, other: &Value) -> bool {
        self.compare(other) == Some(std::cmp::Ordering::Equal)
    }
}

impl PartialEq for Value {
    /// Structural equality (used by tests and map lookups); distinct from
    /// [`Value::sql_eq`] in that `Null == Null` is true here.
    fn eq(&self, other: &Self) -> bool {
        use Value::*;
        match (self, other) {
            (Null, Null) => true,
            (Int(a), Int(b)) => a == b,
            (Real(a), Real(b)) => a == b,
            (Text(a), Text(b)) => a == b,
            (Bool(a), Bool(b)) => a == b,
            (Int(a), Real(b)) | (Real(b), Int(a)) => *a as f64 == *b,
            _ => false,
        }
    }
}

impl Value {
    /// JSON encoding for snapshots (tagged so Int/Real survive).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        match self {
            Value::Null => Json::Null,
            Value::Bool(b) => Json::Bool(*b),
            Value::Int(i) => Json::obj(vec![("i", Json::Num(*i as f64))]),
            Value::Real(r) => Json::obj(vec![("r", Json::Num(*r))]),
            Value::Text(s) => Json::Str(s.clone()),
        }
    }

    /// Decode the [`Value::to_json`] encoding.
    pub fn from_json(j: &crate::util::Json) -> crate::Result<Value> {
        use crate::util::Json;
        Ok(match j {
            Json::Null => Value::Null,
            Json::Bool(b) => Value::Bool(*b),
            Json::Str(s) => Value::Text(s.clone()),
            Json::Obj(_) => {
                if let Some(i) = j.get("i").and_then(Json::as_f64) {
                    Value::Int(i as i64)
                } else if let Some(r) = j.get("r").and_then(Json::as_f64) {
                    Value::Real(r)
                } else {
                    anyhow::bail!("bad value object");
                }
            }
            Json::Num(_) | Json::Arr(_) => anyhow::bail!("bad value encoding"),
        })
    }
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::Null => write!(f, "NULL"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Real(r) => write!(f, "{r}"),
            Value::Text(s) => write!(f, "'{s}'"),
            Value::Bool(b) => write!(f, "{}", if *b { "TRUE" } else { "FALSE" }),
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::Int(v)
    }
}
impl From<u32> for Value {
    fn from(v: u32) -> Self {
        Value::Int(v as i64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Self {
        Value::Int(v as i64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::Real(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Text(v.to_string())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Text(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cmp::Ordering;

    #[test]
    fn numeric_coercion() {
        assert_eq!(Value::Int(2).compare(&Value::Real(2.0)), Some(Ordering::Equal));
        assert_eq!(Value::Int(1).compare(&Value::Real(1.5)), Some(Ordering::Less));
        assert!(Value::Int(3).sql_eq(&Value::Real(3.0)));
    }

    #[test]
    fn null_never_compares() {
        assert_eq!(Value::Null.compare(&Value::Null), None);
        assert!(!Value::Null.sql_eq(&Value::Null));
        assert!(!Value::Null.is_truthy());
    }

    #[test]
    fn text_is_lexicographic() {
        assert_eq!(
            Value::Text("abc".into()).compare(&Value::Text("abd".into())),
            Some(Ordering::Less)
        );
    }

    #[test]
    fn text_vs_number_is_incomparable() {
        assert_eq!(Value::Text("5".into()).compare(&Value::Int(5)), None);
    }

    #[test]
    fn truthiness() {
        assert!(Value::Int(1).is_truthy());
        assert!(!Value::Int(0).is_truthy());
        assert!(!Value::Text("".into()).is_truthy());
        assert!(Value::Text("x".into()).is_truthy());
    }
}
