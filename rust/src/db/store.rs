//! The database: OAR's full schema plus typed accessors.
//!
//! Tables, as in the paper: `jobs` (fig. 2), `nodes`, `assignments`
//! ("a table for describing the assignment of nodes to jobs"), `queues`,
//! `admission_rules` ("rules are stored as Perl code in the database" —
//! here as rule-DSL source, §2.1) and `events` (logging/accounting).
//!
//! Jobs and nodes genuinely live as rows; the typed [`crate::types::Job`]
//! view is converted on the way in and out, so every module interaction is
//! an honest table read/write and can be counted — [`QueryStats`]
//! reproduces the paper's "350 SQL queries for the processing of 10 jobs"
//! measurement (§3.2.2).
//!
//! The standard schema carries secondary indexes on its hot columns
//! ([`Db::create_standard_indexes`]): `jobs.state` and `jobs.queueName`
//! (every scheduler round filters on them), `nodes.nodeId` and
//! `nodes.hostname`, `assignments.jobId`, `queues.name`. The typed
//! accessors ride the table layer's planner: equality-shaped reads probe
//! those indexes and fall back to residual-filtered scans, and
//! [`QueryStats::index_probes`] / [`QueryStats::full_scans`] expose which
//! path ran. One logical statement still counts exactly once in
//! `selects`/`inserts`/`updates`/`deletes` regardless of the plan chosen,
//! so the §3.2.2 query-count reproduction is unchanged.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::types::{
    Campaign, CampaignId, CampaignSpec, CampaignState, GridTask, GridTaskState, Job, JobId,
    JobKind, JobState, Node, NodeId, NodeState, Queue, QueuePolicyKind, RecoveryPolicy,
    ReservationField, Time,
};

use super::accounting::{Accounting, AccountingBuilder};
use super::expr::{Columns, Expr};
use super::log::{EventLog, EventRecord};
use super::plan::QueryPlan;
use super::table::{Row, Table};
use super::view::{ClusterLoad, Views};
use super::value::Value;
use super::wal::{AppendError, Mutation, RecoverStats, TableId, Wal, WalCommit};

/// Errors surfaced by database operations.
#[derive(Debug, Clone, PartialEq)]
pub enum DbError {
    JobNotFound(JobId),
    NodeNotFound(NodeId),
    QueueNotFound(String),
    CampaignNotFound(CampaignId),
    GridTaskNotFound(u64),
    IllegalTransition { job: JobId, from: JobState, to: JobState },
    Corrupt(String),
    Parse(String),
}

impl std::fmt::Display for DbError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DbError::JobNotFound(id) => write!(f, "job {id} not found"),
            DbError::NodeNotFound(id) => write!(f, "node {id} not found"),
            DbError::QueueNotFound(q) => write!(f, "queue {q:?} not found"),
            DbError::CampaignNotFound(id) => write!(f, "campaign {id} not found"),
            DbError::GridTaskNotFound(id) => write!(f, "grid task {id} not found"),
            DbError::IllegalTransition { job, from, to } => {
                write!(f, "job {job}: illegal transition {from} -> {to}")
            }
            DbError::Corrupt(m) => write!(f, "corrupt row: {m}"),
            DbError::Parse(m) => write!(f, "parse: {m}"),
        }
    }
}

impl std::error::Error for DbError {}

/// Counters of SQL-equivalent statements, by kind, plus access-path
/// telemetry. `selects`/`inserts`/`updates`/`deletes` count *logical*
/// statements (one per call, whatever plan runs — this is what reproduces
/// the paper's §3.2.2 measurement); `index_probes`/`full_scans` count the
/// *physical* access paths those statements chose, and are deliberately
/// excluded from [`QueryStats::total`].
#[derive(Debug, Clone, Copy, Default)]
pub struct QueryStats {
    pub selects: u64,
    pub inserts: u64,
    pub updates: u64,
    pub deletes: u64,
    /// WHERE clauses answered by a secondary-index probe.
    pub index_probes: u64,
    /// WHERE clauses answered by visiting every row.
    pub full_scans: u64,
    /// Statements answered from a materialized view (no base-table row
    /// touched). Like the probe/scan telemetry, excluded from
    /// [`QueryStats::total`]: a view-backed read still counts its one
    /// logical `select`.
    pub view_hits: u64,
}

impl QueryStats {
    /// Logical statement count (plan-independent).
    pub fn total(&self) -> u64 {
        self.selects + self.inserts + self.updates + self.deletes
    }
}

/// Internal statement counters. Atomic (relaxed) so the read-only
/// accessors can take `&self` and run concurrently against a shared
/// `&Db` — e.g. many status queries under one `RwLock` read guard —
/// without losing counts. [`Db::stats`] snapshots them into the plain
/// [`QueryStats`] view.
#[derive(Debug, Default)]
struct StatCounters {
    selects: AtomicU64,
    inserts: AtomicU64,
    updates: AtomicU64,
    deletes: AtomicU64,
    view_hits: AtomicU64,
}

/// The whole database. Shared between modules as [`DbHandle`] — the only
/// communication medium, as in the paper.
#[derive(Debug, Default)]
pub struct Db {
    jobs: Table,
    nodes: Table,
    assignments: Table,
    queues: Table,
    admission_rules: Table,
    /// Grid federation: campaign headers (used by the grid meta-scheduler;
    /// empty on a plain cluster server).
    campaigns: Table,
    /// Grid federation: per-task placement rows.
    grid_tasks: Table,
    /// Hierarchical resources (cluster/switch/host/cpu/core); the nodes
    /// table is the derived host-level view. Empty on databases built
    /// through bare `add_node` calls (the pre-hierarchy fixtures).
    resources: Table,
    events: EventLog,
    stats: StatCounters,
    /// Incrementally-maintained materialized views (queue depth, node
    /// occupancy, cluster load). Derived state like the indexes: updated
    /// by [`Db::apply`] with an O(changed) delta per mutation, never
    /// serialized, rebuilt from the base tables on snapshot load.
    views: Views,
    /// Durability: when present, every logical mutation is WAL-logged
    /// before it is applied (see [`super::wal`]). `None` = volatile.
    wal: Option<Wal>,
    /// Test hook: abort the next snapshot write after this many bytes
    /// (atomicity proof for the crash-injection harness).
    snapshot_fail_after: Option<usize>,
}

/// Shared handle; modules hold this and nothing else.
pub type DbHandle = Arc<Mutex<Db>>;

/// Zero-copy adapter exposing a stored node row in the *property
/// namespace* that job `properties` expressions use: bare property names
/// map to the row's `prop_*` columns, while the implicit `hostname` and
/// `state` columns pass through. Replaces the old path that materialized
/// a [`Node`] plus a fresh property row for every candidate.
struct NodePropView<'a>(&'a Row);

impl Columns for NodePropView<'_> {
    fn col(&self, name: &str) -> Option<&Value> {
        if name == "hostname" || name == "state" {
            return self.0.get(name);
        }
        // Stack-compose the `prop_`-prefixed lookup key (no allocation in
        // the hot path; property names are short).
        const PREFIX: &[u8] = b"prop_";
        let mut buf = [0u8; 96];
        if PREFIX.len() + name.len() <= buf.len() {
            buf[..PREFIX.len()].copy_from_slice(PREFIX);
            buf[PREFIX.len()..PREFIX.len() + name.len()].copy_from_slice(name.as_bytes());
            // Concatenation of two UTF-8 strings is valid UTF-8.
            let key = std::str::from_utf8(&buf[..PREFIX.len() + name.len()]).ok()?;
            self.0.get(key)
        } else {
            self.0.get(format!("prop_{name}").as_str())
        }
    }
}

impl Db {
    pub fn new() -> Db {
        let mut db = Db {
            jobs: Table::new("jobs"),
            nodes: Table::new("nodes"),
            assignments: Table::new("assignments"),
            queues: Table::new("queues"),
            admission_rules: Table::new("admission_rules"),
            campaigns: Table::new("campaigns"),
            grid_tasks: Table::new("grid_tasks"),
            resources: Table::new("resources"),
            events: EventLog::new(),
            stats: StatCounters::default(),
            views: Views::default(),
            wal: None,
            snapshot_fail_after: None,
        };
        db.create_standard_indexes();
        db
    }

    /// Fresh database preloaded with the standard queue set.
    pub fn with_standard_queues() -> Db {
        let mut db = Db::new();
        for q in Queue::standard_set() {
            db.add_queue(q);
        }
        db
    }

    pub fn into_handle(self) -> DbHandle {
        Arc::new(Mutex::new(self))
    }

    /// Secondary indexes on the standard schema's hot columns. Idempotent
    /// (re-creating rebuilds from the rows).
    pub fn create_standard_indexes(&mut self) {
        self.jobs.create_index("state");
        self.jobs.create_index("queueName");
        self.nodes.create_index("nodeId");
        self.nodes.create_index("hostname");
        self.assignments.create_index("jobId");
        self.queues.create_index("name");
        self.grid_tasks.create_index("state");
        self.grid_tasks.create_index("campaignId");
        self.resources.create_index("level");
        self.resources.create_index("parent");
    }

    /// Drop every secondary index on every table — benchmarks use this to
    /// measure the scan path against the probe path on identical data.
    pub fn drop_all_indexes(&mut self) {
        for t in [
            &mut self.jobs,
            &mut self.nodes,
            &mut self.assignments,
            &mut self.queues,
            &mut self.admission_rules,
            &mut self.campaigns,
            &mut self.grid_tasks,
            &mut self.resources,
        ] {
            t.drop_all_indexes();
        }
    }

    /// `EXPLAIN`: the access path `filter` would take against a table.
    pub fn explain(&self, table: &str, filter: &Expr) -> Option<QueryPlan> {
        self.table(table).map(|t| t.plan(filter))
    }

    fn table(&self, name: &str) -> Option<&Table> {
        match name {
            "jobs" => Some(&self.jobs),
            "nodes" => Some(&self.nodes),
            "assignments" => Some(&self.assignments),
            "queues" => Some(&self.queues),
            "admission_rules" => Some(&self.admission_rules),
            "campaigns" => Some(&self.campaigns),
            "grid_tasks" => Some(&self.grid_tasks),
            "resources" => Some(&self.resources),
            _ => None,
        }
    }

    fn table_mut(&mut self, t: TableId) -> &mut Table {
        match t {
            TableId::Jobs => &mut self.jobs,
            TableId::Nodes => &mut self.nodes,
            TableId::Assignments => &mut self.assignments,
            TableId::Queues => &mut self.queues,
            TableId::AdmissionRules => &mut self.admission_rules,
            TableId::Campaigns => &mut self.campaigns,
            TableId::GridTasks => &mut self.grid_tasks,
            TableId::Resources => &mut self.resources,
        }
    }

    // ---------------------------------------------------- durability ----

    /// The single durable write path: WAL-append first, apply second.
    /// When the WAL is poisoned (a simulated or injected crash), the
    /// mutation is neither logged nor applied — the process is dead, and
    /// the in-memory state stays exactly the durable prefix. Volatile
    /// databases (no WAL) apply directly.
    fn mutate(&mut self, m: Mutation) -> u64 {
        if let Some(wal) = &mut self.wal {
            match wal.append(&m) {
                Ok(()) => {}
                // Injected crash (or a log it already poisoned): the
                // process is conceptually dead — silently drop, like
                // `kill -9` would.
                Err(AppendError::Injected) => return 0,
                // A genuine I/O failure must not be swallowed: a server
                // that keeps acknowledging unlogged, unapplied writes is
                // a data black hole. Die loudly instead, which is also
                // what preserves the write-ahead invariant.
                Err(AppendError::Io(e)) => {
                    panic!("WAL append failed, refusing to acknowledge further mutations: {e}")
                }
            }
        }
        let result = self.apply(&m);
        if self.wal.as_ref().map(Wal::due_checkpoint).unwrap_or(false) {
            // Auto-compaction is best-effort: a failed snapshot leaves the
            // WAL growing, never loses state.
            let _ = self.checkpoint();
        }
        result
    }

    /// Apply one logical mutation to the in-memory state. Deterministic:
    /// recovery replays the WAL through this exact function — which is
    /// why the materialized views are maintained here and nowhere else:
    /// live writes and crash-recovery replay keep them current through
    /// the same O(changed) delta. The observer runs *before* the table
    /// op (deletes and cell writes reverse the outgoing row's
    /// contribution) and touches no query counter.
    fn apply(&mut self, m: &Mutation) -> u64 {
        self.views
            .observe(m, &self.jobs, &self.nodes, &self.assignments);
        match m {
            Mutation::Insert { table, row } => self.table_mut(*table).insert(row.clone()),
            Mutation::Delete { table, id } => self.table_mut(*table).delete(*id) as u64,
            Mutation::SetCell {
                table,
                id,
                col,
                value,
            } => self.table_mut(*table).set_cell(*id, col.clone(), value.clone()) as u64,
            Mutation::UpdateWhere {
                table,
                filter,
                col,
                value,
            } => match Expr::parse(filter) {
                Ok(e) => self.table_mut(*table).update_where(&e, col, value.clone()) as u64,
                Err(_) => 0,
            },
            Mutation::LogEvent {
                time,
                kind,
                job,
                detail,
            } => {
                self.events.append(EventRecord {
                    time: *time,
                    kind: kind.clone(),
                    job: *job,
                    detail: detail.clone(),
                });
                1
            }
        }
    }

    /// Recover a durable database from `dir`: load the newest snapshot
    /// generation (fresh base if none), deterministically replay the
    /// matching WAL tail, truncate any torn record, rebuild the standard
    /// indexes and reopen the log for appending. An empty or missing
    /// directory yields a fresh durable database.
    pub fn recover(dir: &Path) -> crate::Result<(Db, RecoverStats)> {
        std::fs::create_dir_all(dir)?;
        let generation = Wal::latest_generation(dir)?;
        let snap = Wal::snapshot_path(dir, generation);
        let (mut db, snapshot_loaded) = if snap.exists() {
            let text = std::fs::read_to_string(&snap)?;
            (Db::from_snapshot_doc(&crate::util::Json::parse(&text)?)?, true)
        } else if generation == 0 {
            (Db::new(), false)
        } else {
            anyhow::bail!(
                "generation {generation} has a WAL but no snapshot {}",
                snap.display()
            );
        };
        let (records, torn_tail) = Wal::read_records(dir, generation)?;
        for m in &records {
            db.apply(m);
        }
        let replayed = records.len() as u64;
        db.wal = Some(Wal::open(dir, generation, replayed)?);
        Ok((
            db,
            RecoverStats {
                generation,
                snapshot_loaded,
                replayed,
                torn_tail,
            },
        ))
    }

    /// Checkpoint (compaction): atomically write the next snapshot
    /// generation (temp file + rename — a crash mid-write can never
    /// corrupt the previous generation), then rotate to an empty WAL and
    /// drop the old generation's files. On any error the WAL keeps
    /// growing and nothing is lost.
    pub fn checkpoint(&mut self) -> crate::Result<()> {
        let Some(wal) = &self.wal else {
            anyhow::bail!("checkpoint on a volatile database");
        };
        anyhow::ensure!(!wal.crashed(), "wal is poisoned");
        let next = wal.generation() + 1;
        let snap = Wal::snapshot_path(wal.dir(), next);
        self.write_snapshot_atomic(&snap)?;
        if let Err(e) = self.wal.as_mut().unwrap().rotate(next) {
            // Roll the generation bump back: leaving snapshot-(next) in
            // place while appends continue on the old log would make the
            // next recovery load that snapshot, treat the missing new log
            // as an empty tail, and sweep the still-growing old one —
            // silently losing every mutation acknowledged since.
            if std::fs::remove_file(&snap).is_err() {
                panic!(
                    "checkpoint rotation failed and snapshot {} could not be rolled back: {e}",
                    snap.display()
                );
            }
            return Err(e);
        }
        Ok(())
    }

    /// Whether this database WAL-logs its mutations.
    pub fn is_durable(&self) -> bool {
        self.wal.is_some()
    }

    /// Whether the WAL is poisoned (the simulated process is dead).
    pub fn wal_crashed(&self) -> bool {
        self.wal.as_ref().map(Wal::crashed).unwrap_or(false)
    }

    /// Simulate `kill -9` right now: every mutation from this instant is
    /// neither logged nor applied.
    pub fn crash_wal(&mut self) {
        if let Some(wal) = &mut self.wal {
            wal.crash();
        }
    }

    /// Records appended since the WAL was opened (crash-boundary unit).
    pub fn wal_records(&self) -> u64 {
        self.wal.as_ref().map(Wal::total_records).unwrap_or(0)
    }

    /// Arm the WAL fail point: `after` more appends succeed, then the
    /// next record is torn at `partial` bytes and the log is poisoned.
    pub fn wal_inject_failure(&mut self, after: u64, partial: usize) {
        if let Some(wal) = &mut self.wal {
            wal.inject_failure(after, partial);
        }
    }

    /// Abort the next snapshot write after `fail_after` bytes (`None`
    /// disarms) — the mid-snapshot crash of the recovery test harness.
    pub fn inject_snapshot_failure(&mut self, fail_after: Option<usize>) {
        self.snapshot_fail_after = fail_after;
    }

    /// WAL records between automatic checkpoints (0 = manual only).
    pub fn set_checkpoint_every(&mut self, every: u64) {
        if let Some(wal) = &mut self.wal {
            wal.set_checkpoint_every(every);
        }
    }

    /// Enable (or disable) group commit on the WAL: appends buffer in
    /// memory and land as one batched log write at the next
    /// [`Db::flush_wal`] / [`WalCommit::commit`]. Callers that enable
    /// this own the commit discipline: flush before acknowledging a
    /// mutation to a client. No-op on a volatile database.
    pub fn set_wal_group_commit(&mut self, enabled: bool) {
        if let Some(wal) = &mut self.wal {
            wal.set_group_commit(enabled);
        }
    }

    /// Force `fsync` on every WAL flush (power-loss durability). With
    /// group commit enabled, one fsync covers the whole batch.
    pub fn set_wal_sync(&mut self, enabled: bool) {
        if let Some(wal) = &mut self.wal {
            wal.set_sync_on_flush(enabled);
        }
    }

    /// A cloneable commit handle for the WAL's shared sink: lets the
    /// server flush a group-commit batch *after* releasing the database
    /// write lock, so the fsync-amortized write never extends the
    /// critical section. `None` on a volatile database.
    pub fn wal_commit_handle(&self) -> Option<WalCommit> {
        self.wal.as_ref().map(Wal::commit_handle)
    }

    /// Flush any group-commit-buffered WAL records. Same discipline as
    /// [`Db::mutate`]: a poisoned log (simulated crash) is silent — the
    /// process is conceptually dead — while a genuine I/O failure dies
    /// loudly rather than acknowledge buffered, unlogged writes.
    pub fn flush_wal(&mut self) {
        if let Some(wal) = &mut self.wal {
            match wal.flush() {
                Ok(()) | Err(AppendError::Injected) => {}
                Err(AppendError::Io(e)) => {
                    panic!("WAL flush failed, refusing to acknowledge buffered mutations: {e}")
                }
            }
        }
    }

    /// Recovery invariant: every secondary index agrees with a fresh
    /// rebuild from the rows it indexes.
    pub fn verify_indexes(&self) -> bool {
        [
            &self.jobs,
            &self.nodes,
            &self.assignments,
            &self.queues,
            &self.admission_rules,
            &self.campaigns,
            &self.grid_tasks,
            &self.resources,
        ]
        .iter()
        .all(|t| t.indexes_consistent())
    }

    // ------------------------------------------------- reconciliation ----

    /// Restart reconciliation (run once after [`Db::recover`], before
    /// scheduling resumes): jobs stranded in states whose driving threads
    /// died with the process are either failed through the abnormal path
    /// or stripped and requeued, per `policy`; every touched job gets a
    /// logged `RECOVERY_*` event. Returns `(job, stranded state)` pairs.
    pub fn reconcile_in_flight(
        &mut self,
        policy: RecoveryPolicy,
        now: Time,
    ) -> Vec<(JobId, JobState)> {
        let mut out = Vec::new();
        // Half-finished abnormal paths always complete to Error.
        for job in self.jobs_in_state(JobState::ToError) {
            let _ = self.set_job_state(job.id, JobState::Error, now);
            self.log_event(now, "RECOVERY_FAIL", Some(job.id), "toError at crash");
            out.push((job.id, JobState::ToError));
        }
        // A lost reservation acknowledgment goes back to Waiting (the
        // scheduler re-confirms it on the next round).
        for job in self.jobs_in_state(JobState::ToAckReservation) {
            let _ = self.set_job_state(job.id, JobState::Waiting, now);
            self.log_event(now, "RECOVERY_REQUEUE", Some(job.id), "ack lost at crash");
            out.push((job.id, JobState::ToAckReservation));
        }
        // In-flight jobs: their launcher/execution threads are gone.
        for state in [JobState::ToLaunch, JobState::Launching, JobState::Running] {
            for job in self.jobs_in_state(state) {
                match policy {
                    RecoveryPolicy::FailInFlight => {
                        let _ = self.fail_job(job.id, "in-flight at crash", now);
                        self.log_event(now, "RECOVERY_FAIL", Some(job.id), state.as_str());
                    }
                    RecoveryPolicy::Requeue => {
                        self.remove_assignments(job.id);
                        // Administrative override of fig. 1 (Running →
                        // Waiting is deliberately not a user transition):
                        // primitive cell writes, audited by the event.
                        self.stats.updates.fetch_add(1, Ordering::Relaxed);
                        for (col, value) in [
                            ("state", Value::Text("Waiting".into())),
                            ("startTime", Value::Null),
                            ("bpid", Value::Null),
                        ] {
                            self.mutate(Mutation::SetCell {
                                table: TableId::Jobs,
                                id: job.id,
                                col: col.into(),
                                value,
                            });
                        }
                        if job.reservation == ReservationField::Scheduled {
                            // Its slot assignment was just stripped: send
                            // the reservation back through negotiation,
                            // or a Scheduled-but-assignment-less job
                            // would "start" on zero nodes.
                            self.mutate(Mutation::SetCell {
                                table: TableId::Jobs,
                                id: job.id,
                                col: "reservation".into(),
                                value: Value::Text(
                                    ReservationField::ToSchedule.as_str().into(),
                                ),
                            });
                        }
                        self.log_event(now, "RECOVERY_REQUEUE", Some(job.id), state.as_str());
                    }
                }
                out.push((job.id, state));
            }
        }
        out
    }

    // ------------------------------------------------------- queries ----

    /// Statement counters plus access-path telemetry aggregated over all
    /// tables. A relaxed-atomic snapshot: concurrent readers may be
    /// mid-bump, but every counted statement lands exactly once.
    pub fn stats(&self) -> QueryStats {
        let mut s = QueryStats {
            selects: self.stats.selects.load(Ordering::Relaxed),
            inserts: self.stats.inserts.load(Ordering::Relaxed),
            updates: self.stats.updates.load(Ordering::Relaxed),
            deletes: self.stats.deletes.load(Ordering::Relaxed),
            index_probes: 0,
            full_scans: 0,
            view_hits: self.stats.view_hits.load(Ordering::Relaxed),
        };
        for t in [
            &self.jobs,
            &self.nodes,
            &self.assignments,
            &self.queues,
            &self.admission_rules,
            &self.campaigns,
            &self.grid_tasks,
            &self.resources,
        ] {
            let (probes, scans) = t.plan_counters();
            s.index_probes += probes;
            s.full_scans += scans;
        }
        s
    }

    pub fn reset_stats(&self) {
        for c in [
            &self.stats.selects,
            &self.stats.inserts,
            &self.stats.updates,
            &self.stats.deletes,
            &self.stats.view_hits,
        ] {
            c.store(0, Ordering::Relaxed);
        }
        for t in [
            &self.jobs,
            &self.nodes,
            &self.assignments,
            &self.queues,
            &self.admission_rules,
            &self.campaigns,
            &self.grid_tasks,
            &self.resources,
        ] {
            t.reset_plan_counters();
        }
    }

    // ---------------------------------------------------------- jobs ----

    /// INSERT a job row; returns the assigned `idJob`.
    pub fn insert_job(&mut self, mut job: Job) -> JobId {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        let row = job_to_row(&job);
        let id = self.mutate(Mutation::Insert {
            table: TableId::Jobs,
            row,
        });
        job.id = id;
        id
    }

    pub fn job(&self, id: JobId) -> Result<Job, DbError> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let row = self.jobs.get(id).ok_or(DbError::JobNotFound(id))?;
        job_from_row(row)
    }

    pub fn job_count(&self) -> usize {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.jobs.len()
    }

    /// All jobs matching a WHERE clause over the raw job columns. Rides
    /// the planner: sargable filters (e.g. `state = 'Waiting'`) probe the
    /// secondary indexes.
    pub fn jobs_where(&self, filter: &Expr) -> Vec<Job> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.jobs.select_map(filter, |_, r| job_from_row(r).ok())
    }

    pub fn jobs_in_state(&self, state: JobState) -> Vec<Job> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let key = Value::Text(state.as_str().to_string());
        let mut out = Vec::new();
        self.jobs.for_each_eq("state", &key, |_, r| {
            if let Ok(j) = job_from_row(r) {
                out.push(j);
            }
        });
        out
    }

    /// `SELECT COUNT(*) FROM jobs WHERE state = ?` — answered entirely
    /// from the state index (no row materialization at all).
    pub fn count_jobs_in_state(&self, state: JobState) -> usize {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.jobs
            .count_eq("state", &Value::Text(state.as_str().to_string()))
    }

    /// Waiting jobs of one queue, in submission (id) order. Probes the
    /// more selective of the `state` / `queueName` indexes and residual-
    /// filters on the other column.
    pub fn waiting_jobs_in_queue(&self, queue: &str) -> Vec<Job> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let state_key = Value::Text("Waiting".to_string());
        let queue_key = Value::Text(queue.to_string());
        let by_queue = self.jobs.eq_estimate("queueName", &queue_key);
        let by_state = self.jobs.eq_estimate("state", &state_key);
        let mut out = Vec::new();
        match (by_queue, by_state) {
            (Some(q), Some(s)) if q < s => {
                self.jobs.for_each_eq("queueName", &queue_key, |_, r| {
                    if r.get("state").and_then(Value::as_str) == Some("Waiting") {
                        if let Ok(j) = job_from_row(r) {
                            out.push(j);
                        }
                    }
                });
            }
            _ => {
                self.jobs.for_each_eq("state", &state_key, |_, r| {
                    if r.get("queueName").and_then(Value::as_str) == Some(queue) {
                        if let Ok(j) = job_from_row(r) {
                            out.push(j);
                        }
                    }
                });
            }
        }
        out
    }

    /// Validated state transition (fig. 1); the heart of the coherence
    /// discipline. Also stamps start/stop times at the relevant edges.
    /// Writes go through the table's `set_cell`, keeping the state index
    /// coherent.
    pub fn set_job_state(
        &mut self,
        id: JobId,
        to: JobState,
        now: Time,
    ) -> Result<(), DbError> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let row = self.jobs.get(id).ok_or(DbError::JobNotFound(id))?;
        let from = row
            .get("state")
            .and_then(Value::as_str)
            .and_then(JobState::parse)
            .ok_or_else(|| DbError::Corrupt(format!("job {id} has bad state")))?;
        if !from.can_transition_to(to) {
            return Err(DbError::IllegalTransition { job: id, from, to });
        }
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        self.set_job_cell(id, "state", Value::Text(to.as_str().into()));
        match to {
            JobState::Running => {
                self.set_job_cell(id, "startTime", Value::Int(now));
            }
            JobState::Terminated | JobState::Error => {
                self.set_job_cell(id, "stopTime", Value::Int(now));
            }
            _ => {}
        }
        Ok(())
    }

    /// `oarhold`: suspend a job, gated to the automaton's one legal edge
    /// into `Hold` (fig. 1: `Waiting → Hold`). Any other source state —
    /// running, launching, terminal — is an [`DbError::IllegalTransition`];
    /// holding a job that already holds resources would strand its node
    /// assignment and desync the occupancy accounting.
    pub fn hold_job(&mut self, id: JobId, now: Time) -> Result<(), DbError> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let row = self.jobs.get(id).ok_or(DbError::JobNotFound(id))?;
        let from = row
            .get("state")
            .and_then(Value::as_str)
            .and_then(JobState::parse)
            .ok_or_else(|| DbError::Corrupt(format!("job {id} has bad state")))?;
        if from != JobState::Waiting {
            return Err(DbError::IllegalTransition {
                job: id,
                from,
                to: JobState::Hold,
            });
        }
        self.set_job_state(id, JobState::Hold, now)
    }

    /// One logged cell write into the jobs table.
    fn set_job_cell(&mut self, id: JobId, col: &str, value: Value) -> bool {
        self.mutate(Mutation::SetCell {
            table: TableId::Jobs,
            id,
            col: col.into(),
            value,
        }) != 0
    }

    /// Force the abnormal path from any live state: `* → toError → Error`.
    pub fn fail_job(&mut self, id: JobId, reason: &str, now: Time) -> Result<(), DbError> {
        let state = self.job(id)?.state;
        if state.is_terminal() {
            return Ok(());
        }
        if state != JobState::ToError {
            self.set_job_state(id, JobState::ToError, now)?;
        }
        self.set_job_message(id, reason)?;
        self.set_job_state(id, JobState::Error, now)
    }

    pub fn set_job_message(&mut self, id: JobId, message: &str) -> Result<(), DbError> {
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        if self.jobs.get(id).is_none() {
            return Err(DbError::JobNotFound(id));
        }
        self.set_job_cell(id, "message", Value::Text(message.into()));
        Ok(())
    }

    pub fn set_job_bpid(&mut self, id: JobId, bpid: Option<u32>) -> Result<(), DbError> {
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        if self.jobs.get(id).is_none() {
            return Err(DbError::JobNotFound(id));
        }
        let value = bpid.map(|p| Value::Int(p as i64)).unwrap_or(Value::Null);
        self.set_job_cell(id, "bpid", value);
        Ok(())
    }

    pub fn set_job_reservation(
        &mut self,
        id: JobId,
        f: ReservationField,
    ) -> Result<(), DbError> {
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        if self.jobs.get(id).is_none() {
            return Err(DbError::JobNotFound(id));
        }
        self.set_job_cell(id, "reservation", Value::Text(f.as_str().into()));
        Ok(())
    }

    /// `UPDATE jobs SET col = value WHERE filter` — the logged bulk
    /// update path; the filter source replays deterministically.
    pub fn update_jobs_where(
        &mut self,
        filter: &str,
        col: &str,
        value: Value,
    ) -> Result<usize, DbError> {
        Expr::parse(filter).map_err(|e| DbError::Parse(e.to_string()))?;
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        Ok(self.mutate(Mutation::UpdateWhere {
            table: TableId::Jobs,
            filter: filter.into(),
            col: col.into(),
            value,
        }) as usize)
    }

    /// Persist the shape a moldable job was actually granted: the
    /// scheduler picked one of the request's alternatives, and the job
    /// row's flat `nbNodes`/`weight` must match it before the node
    /// assignment rows are written (occupancy accounting and the next
    /// round's phase-1 re-occupation both read them).
    pub fn set_job_shape(&mut self, id: JobId, nb_nodes: u32, weight: u32) -> Result<(), DbError> {
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        if self.jobs.get(id).is_none() {
            return Err(DbError::JobNotFound(id));
        }
        for (col, value) in [
            ("nbNodes", Value::Int(nb_nodes as i64)),
            ("weight", Value::Int(weight as i64)),
        ] {
            self.mutate(Mutation::SetCell {
                table: TableId::Jobs,
                id,
                col: col.into(),
                value,
            });
        }
        Ok(())
    }

    // --------------------------------------------------------- nodes ----

    pub fn add_node(&mut self, node: Node) -> NodeId {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        let row = node_to_row(&node);
        self.mutate(Mutation::Insert {
            table: TableId::Nodes,
            row,
        });
        node.id
    }

    pub fn node(&self, id: NodeId) -> Result<Node, DbError> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.nodes
            .find_eq("nodeId", &Value::Int(id as i64))
            .map(|(_, r)| node_from_row(r))
            .ok_or(DbError::NodeNotFound(id))?
    }

    pub fn all_nodes(&self) -> Vec<Node> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        self.nodes.for_each_all(|_, r| {
            if let Ok(n) = node_from_row(r) {
                out.push(n);
            }
        });
        out
    }

    pub fn alive_nodes(&self) -> Vec<Node> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        self.nodes.for_each_all(|_, r| {
            if r.get("state").and_then(Value::as_str) != Some("Alive") {
                return;
            }
            if let Ok(n) = node_from_row(r) {
                out.push(n);
            }
        });
        out
    }

    pub fn set_node_state(&mut self, id: NodeId, state: NodeState) -> Result<(), DbError> {
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        let rid = self
            .nodes
            .find_eq("nodeId", &Value::Int(id as i64))
            .map(|(rid, _)| rid)
            .ok_or(DbError::NodeNotFound(id))?;
        self.mutate(Mutation::SetCell {
            table: TableId::Nodes,
            id: rid,
            col: "state".into(),
            value: Value::Text(state.as_str().into()),
        });
        Ok(())
    }

    /// Nodes whose property row matches a job's `properties` expression —
    /// the SQL resource-matching path ("using the rich expressive power of
    /// sql queries", §2). One SELECT per call. The expression is evaluated
    /// *in place* over the stored rows through [`NodePropView`]; only the
    /// matching nodes are materialized.
    pub fn matching_nodes(&self, properties: &str) -> Result<Vec<Node>, DbError> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let expr = Expr::parse(properties).map_err(|e| DbError::Parse(e.to_string()))?;
        let mut out = Vec::new();
        self.nodes.for_each_all(|_, r| {
            if r.get("state").and_then(Value::as_str) != Some("Alive") {
                return;
            }
            if expr.matches_cols(&NodePropView(r)) {
                if let Ok(n) = node_from_row(r) {
                    out.push(n);
                }
            }
        });
        Ok(out)
    }

    // ----------------------------------------------------- resources ----

    /// INSERT one vertex of the resource tree (see [`crate::resources`]);
    /// returns the assigned resource id. Rides [`Db::mutate`] like every
    /// other write, so the tree is WAL-durable by construction.
    pub fn add_resource(
        &mut self,
        level: crate::resources::Level,
        parent: Option<u64>,
        name: &str,
        node_id: Option<NodeId>,
    ) -> u64 {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        let row = crate::resources::resource_to_row(&crate::resources::Resource {
            id: 0, // assigned by the table on insert
            level,
            parent,
            name: name.into(),
            node_id,
        });
        self.mutate(Mutation::Insert {
            table: TableId::Resources,
            row,
        })
    }

    /// Every vertex of the resource tree, in id order.
    pub fn resources(&self) -> Vec<crate::resources::Resource> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        self.resources.for_each_all(|id, r| {
            if let Ok(res) = crate::resources::resource_from_row(id, r) {
                out.push(res);
            }
        });
        out
    }

    /// Vertices at one level — `SELECT * FROM resources WHERE level = ?`,
    /// answered from the `level` index.
    pub fn resources_at(&self, level: crate::resources::Level) -> Vec<crate::resources::Resource> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let key = Value::Text(level.as_str().to_string());
        let mut out = Vec::new();
        self.resources.for_each_eq("level", &key, |id, r| {
            if let Ok(res) = crate::resources::resource_from_row(id, r) {
                out.push(res);
            }
        });
        out
    }

    /// Children of one vertex — answered from the `parent` index.
    pub fn resource_children(&self, parent: u64) -> Vec<crate::resources::Resource> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let key = Value::Int(parent as i64);
        let mut out = Vec::new();
        self.resources.for_each_eq("parent", &key, |id, r| {
            if let Ok(res) = crate::resources::resource_from_row(id, r) {
                out.push(res);
            }
        });
        out
    }

    pub fn resource_count(&self) -> usize {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.resources.len()
    }

    /// The placement view the scheduler matches tree requests against:
    /// built from the `resources` table when populated, else derived
    /// from the nodes' `switch` property (databases registered before
    /// the table existed behave exactly as they used to).
    pub fn hierarchy(&self) -> crate::resources::Hierarchy {
        if self.resources.is_empty() {
            return crate::resources::Hierarchy::from_nodes(&self.all_nodes());
        }
        crate::resources::Hierarchy::from_resources(&self.resources(), &self.all_nodes())
    }

    // --------------------------------------------------- assignments ----

    /// Record that `job` runs on `nodes` (`procs_per_node` each).
    pub fn assign_nodes(&mut self, job: JobId, nodes: &[NodeId], procs_per_node: u32) {
        for n in nodes {
            self.stats.inserts.fetch_add(1, Ordering::Relaxed);
            let mut row = Row::new();
            row.insert("jobId".into(), Value::Int(job as i64));
            row.insert("nodeId".into(), Value::Int(*n as i64));
            row.insert("procs".into(), Value::Int(procs_per_node as i64));
            self.mutate(Mutation::Insert {
                table: TableId::Assignments,
                row,
            });
        }
    }

    /// DELETE a job's assignment rows (requeue/cleanup path); returns the
    /// number removed.
    pub fn remove_assignments(&mut self, job: JobId) -> usize {
        self.stats.deletes.fetch_add(1, Ordering::Relaxed);
        let mut rids = Vec::new();
        self.assignments
            .for_each_eq("jobId", &Value::Int(job as i64), |rid, _| rids.push(rid));
        for rid in &rids {
            self.mutate(Mutation::Delete {
                table: TableId::Assignments,
                id: *rid,
            });
        }
        rids.len()
    }

    pub fn assigned_nodes(&self, job: JobId) -> Vec<NodeId> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        self.assignments
            .for_each_eq("jobId", &Value::Int(job as i64), |_, r| {
                if let Some(n) = r.get("nodeId").and_then(Value::as_i64) {
                    out.push(n as NodeId);
                }
            });
        out
    }

    /// Busy processors per node, recomputed from the base tables. The
    /// join runs index-to-index through [`Table::join_eq_ids`]: live job
    /// ids come off the jobs state index, their assignment rows off the
    /// assignments jobId index. This is the from-scratch baseline the
    /// `node_busy` materialized view replaces on the hot paths (and the
    /// ablation benchmark measures it against the view).
    pub fn busy_procs_by_node(&self) -> BTreeMap<NodeId, u32> {
        self.stats.selects.fetch_add(2, Ordering::Relaxed); // join over jobs + assignments
        let mut busy = BTreeMap::new();
        for state in JobState::ALL.iter().filter(|s| s.holds_resources()) {
            let key = Value::Text(state.as_str().to_string());
            let mut live: Vec<JobId> = Vec::new();
            self.jobs.for_each_eq("state", &key, |id, _| live.push(id));
            self.assignments.join_eq_ids(&live, "jobId", |_, r| {
                let nid = r.get("nodeId").and_then(Value::as_i64).unwrap_or(-1) as NodeId;
                let procs = r.get("procs").and_then(Value::as_i64).unwrap_or(0) as u32;
                *busy.entry(nid).or_insert(0) += procs;
            });
        }
        busy
    }

    // --------------------------------------------- materialized views ----

    /// `Waiting` jobs in `queue`, answered from the `queue_depth` view:
    /// O(log queues) whatever the jobs table holds. Counts one logical
    /// select and one view hit.
    pub fn queue_depth(&self, queue: &str) -> u64 {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.stats.view_hits.fetch_add(1, Ordering::Relaxed);
        self.views.queue_depth(queue)
    }

    /// Jobs currently in `state`, answered from the `jobs_by_state` view
    /// in O(1). Counts one logical select and one view hit.
    pub fn state_depth(&self, state: JobState) -> u64 {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.stats.view_hits.fetch_add(1, Ordering::Relaxed);
        self.views.state_count(state)
    }

    /// The cluster-load scalars (node/processor totals, alive capacity,
    /// busy processors), answered from the views in O(1). `procs_busy`
    /// counts every processor claimed by a resource-holding job, dead
    /// node or not — see [`Views`] for the coherence argument.
    pub fn cluster_load(&self) -> ClusterLoad {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.stats.view_hits.fetch_add(1, Ordering::Relaxed);
        self.views.cluster_load()
    }

    /// Busy processors per node, answered from the `node_busy` view —
    /// the O(changed) replacement for [`Db::busy_procs_by_node`].
    pub fn node_occupancy(&self) -> BTreeMap<NodeId, u32> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.stats.view_hits.fetch_add(1, Ordering::Relaxed);
        self.views.node_busy().clone()
    }

    /// The fleet summary — `(hostname, state, nbProcs)` per valid node
    /// row, in row order — answered from the `fleet` view without
    /// decoding a single node row.
    pub fn fleet_view(&self) -> Vec<(String, String, u32)> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.stats.view_hits.fetch_add(1, Ordering::Relaxed);
        self.views
            .fleet_rows()
            .map(|(h, s, p)| (h.to_string(), s.as_str().to_string(), p))
            .collect()
    }

    /// From-scratch [`ClusterLoad`] off the base tables — the recompute
    /// baseline for the view ablation (full node scan + the occupancy
    /// join), counted like the reads it is made of.
    pub fn cluster_load_recompute(&self) -> ClusterLoad {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let mut load = ClusterLoad::default();
        self.nodes.for_each_all(|_, row| {
            if row.get("nodeId").and_then(Value::as_i64).is_none() {
                return;
            }
            let Some(state) = row
                .get("state")
                .and_then(Value::as_str)
                .and_then(NodeState::parse)
            else {
                return;
            };
            let procs = row.get("nbProcs").and_then(Value::as_i64).unwrap_or(1) as u32;
            load.nodes_total += 1;
            load.procs_total += procs;
            if state == NodeState::Alive {
                load.nodes_alive += 1;
                load.procs_alive += procs;
            }
        });
        load.procs_busy = self.busy_procs_by_node().values().sum();
        load
    }

    /// `SELECT queueName, COUNT(*) FROM jobs WHERE state = 'Waiting'
    /// GROUP BY queueName` — the group-by aggregate the `queue_depth`
    /// view caches, recomputed from the base table. Keys are the bare
    /// queue names (the `'...'` of [`Table::group_count`]'s stringified
    /// text keys stripped), so entries compare directly against
    /// [`Db::queue_depth`]. The ablation benchmark runs it against the
    /// view.
    pub fn queue_depths_recompute(&self) -> BTreeMap<String, u64> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let waiting = Expr::parse("state = 'Waiting'").expect("static filter");
        self.jobs
            .group_count(&waiting, "queueName")
            .into_iter()
            .map(|(k, n)| (k.trim_matches('\'').to_string(), n as u64))
            .collect()
    }

    /// `SELECT state, COUNT(*) FROM jobs GROUP BY state` — answered
    /// index-only off the `state` index when it exists (one probe, no row
    /// touched), falling back to a grouped scan. Keys are bare state
    /// names; rows with non-text states are skipped on the indexed path
    /// exactly as they fail to parse everywhere else. The recompute
    /// baseline for the `jobs_by_state` view.
    pub fn jobs_by_state_recompute(&self) -> BTreeMap<String, u64> {
        use super::index::IndexKey;
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        if let Some(groups) = self.jobs.group_count_indexed("state") {
            return groups
                .into_iter()
                .filter_map(|(k, n)| match k {
                    IndexKey::Text(s) => Some((s, n as u64)),
                    IndexKey::Num(_) => None, // states are text columns
                })
                .collect();
        }
        let all = Expr::parse("id >= 0").expect("static filter");
        self.jobs
            .group_count(&all, "state")
            .into_iter()
            .map(|(k, n)| (k.trim_matches('\'').to_string(), n as u64))
            .collect()
    }

    /// `EXPLAIN` for a view-backed read: the plan is a [`PlanKind::ViewHit`]
    /// with the view's entry count; `None` for an unknown view name.
    /// Registered views: `jobs_by_state`, `queue_depth`, `node_busy`,
    /// `cluster_load`, `fleet`.
    pub fn explain_view(&self, view: &str) -> Option<QueryPlan> {
        use super::plan::PlanKind;
        let entries = self.views.entries(view)?;
        Some(QueryPlan {
            kind: PlanKind::ViewHit,
            column: Some(view.to_string()),
            estimated_rows: entries,
        })
    }

    /// Invariant oracle: do the incrementally-maintained views equal a
    /// from-scratch recomputation off the base tables? Touches no query
    /// counter (like [`Db::verify_indexes`]).
    pub fn verify_views(&self) -> bool {
        self.views == Views::recompute(&self.jobs, &self.nodes, &self.assignments)
    }

    // -------------------------------------------------------- queues ----

    pub fn add_queue(&mut self, q: Queue) {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        let mut row = Row::new();
        row.insert("name".into(), Value::Text(q.name.clone()));
        row.insert("priority".into(), Value::Int(q.priority as i64));
        row.insert("policy".into(), Value::Text(q.policy.as_str().into()));
        row.insert("defaultMaxTime".into(), Value::Int(q.default_max_time));
        row.insert(
            "maxProcsPerJob".into(),
            Value::Int(q.max_procs_per_job as i64),
        );
        row.insert("active".into(), Value::Bool(q.active));
        self.mutate(Mutation::Insert {
            table: TableId::Queues,
            row,
        });
    }

    pub fn queue(&self, name: &str) -> Result<Queue, DbError> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.queues
            .find_eq("name", &Value::Text(name.to_string()))
            .map(|(_, r)| queue_from_row(r))
            .ok_or_else(|| DbError::QueueNotFound(name.into()))?
    }

    /// All queues by decreasing priority — the meta-scheduler's iteration
    /// order (§2.3).
    pub fn queues_by_priority(&self) -> Vec<Queue> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let mut qs: Vec<Queue> = Vec::new();
        self.queues.for_each_all(|_, r| {
            if let Ok(q) = queue_from_row(r) {
                qs.push(q);
            }
        });
        qs.sort_by(|a, b| b.priority.cmp(&a.priority).then(a.name.cmp(&b.name)));
        qs
    }

    pub fn set_queue_active(&mut self, name: &str, active: bool) -> Result<(), DbError> {
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        // Index probe instead of the old string-built WHERE clause (which
        // broke on names containing quotes).
        let rid = self
            .queues
            .find_eq("name", &Value::Text(name.to_string()))
            .map(|(rid, _)| rid)
            .ok_or_else(|| DbError::QueueNotFound(name.into()))?;
        self.mutate(Mutation::SetCell {
            table: TableId::Queues,
            id: rid,
            col: "active".into(),
            value: Value::Bool(active),
        });
        Ok(())
    }

    // ----------------------------------------------- admission rules ----

    /// Store an admission rule (rule-DSL source, see [`crate::admission`]).
    pub fn add_admission_rule(&mut self, priority: i32, source: &str) {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        let mut row = Row::new();
        row.insert("priority".into(), Value::Int(priority as i64));
        row.insert("source".into(), Value::Text(source.into()));
        self.mutate(Mutation::Insert {
            table: TableId::AdmissionRules,
            row,
        });
    }

    /// Rules in priority order (ascending: lower runs first).
    pub fn admission_rules(&self) -> Vec<(i32, String)> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let mut rules: Vec<(i32, String)> = Vec::new();
        self.admission_rules.for_each_all(|_, r| {
            if let (Some(p), Some(s)) = (
                r.get("priority").and_then(Value::as_i64),
                r.get("source").and_then(Value::as_str),
            ) {
                rules.push((p as i32, s.to_string()));
            }
        });
        rules.sort_by_key(|(p, _)| *p);
        rules
    }

    // ----------------------------------------------- grid federation ----

    /// INSERT a campaign header plus one `grid_tasks` row per task (all
    /// `Pending`); returns the campaign id. Used by the grid
    /// meta-scheduler — a plain cluster server never touches these
    /// tables.
    pub fn insert_campaign(&mut self, spec: &CampaignSpec, now: Time) -> CampaignId {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        // Random token (std-only: RandomState seeds from the OS): minted
        // once here, then WAL-logged with the row, so replay and
        // restarts see the same value. Masked to 53 bits — WAL records
        // and snapshots round-trip `Value::Int` through `Json::Num`
        // (f64), which is exact only below 2^53; a full-range u64 would
        // corrupt on recovery and break every tag comparison.
        let token = {
            use std::hash::{BuildHasher, Hasher};
            let mut h = std::collections::hash_map::RandomState::new().build_hasher();
            h.write_i64(now);
            h.finish() & ((1 << 53) - 1)
        };
        let mut row = Row::new();
        row.insert("token".into(), Value::Int(token as i64));
        row.insert("name".into(), Value::Text(spec.name.clone()));
        row.insert("user".into(), Value::Text(spec.user.clone()));
        row.insert("command".into(), Value::Text(spec.command.clone()));
        row.insert("nbNodes".into(), Value::Int(spec.nb_nodes as i64));
        row.insert("weight".into(), Value::Int(spec.weight as i64));
        row.insert("maxTime".into(), Value::Int(spec.max_time));
        row.insert("tasks".into(), Value::Int(spec.tasks as i64));
        row.insert(
            "state".into(),
            Value::Text(CampaignState::Active.as_str().into()),
        );
        row.insert("submissionTime".into(), Value::Int(now));
        let id = self.mutate(Mutation::Insert {
            table: TableId::Campaigns,
            row,
        });
        for index in 0..spec.tasks {
            self.insert_grid_task(id, index);
        }
        id
    }

    /// INSERT one `Pending` task row. The header goes in first and each
    /// task row is its own WAL record, so a crash can cut the loop short
    /// — a campaign's bag is fully derivable from its header, and the
    /// grid re-inserts missing indices at boot ([`Db::repair_campaigns`]).
    pub fn insert_grid_task(&mut self, campaign: CampaignId, index: u32) -> u64 {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        let mut row = Row::new();
        row.insert("campaignId".into(), Value::Int(campaign as i64));
        row.insert("idx".into(), Value::Int(index as i64));
        row.insert(
            "state".into(),
            Value::Text(GridTaskState::Pending.as_str().into()),
        );
        row.insert("cluster".into(), Value::Null);
        row.insert("jobId".into(), Value::Null);
        row.insert("attempts".into(), Value::Int(0));
        row.insert("dispatchedAt".into(), Value::Int(0));
        row.insert("message".into(), Value::Text(String::new()));
        self.mutate(Mutation::Insert {
            table: TableId::GridTasks,
            row,
        })
    }

    /// Boot-time repair for campaigns a crash cut short mid-insert: every
    /// index in `0..tasks` without a row gets a fresh `Pending` one, and
    /// a `Dispatched` row with no cluster — impossible under the
    /// cell-ordering contract of [`Db::mark_grid_task_dispatched`], but
    /// unresolvable by any live code path if it ever existed — is
    /// requeued. Returns how many rows were repaired.
    pub fn repair_campaigns(&mut self) -> usize {
        let mut repaired = 0;
        for c in self.campaigns() {
            let have: std::collections::BTreeSet<u32> = self
                .grid_tasks_of_campaign(c.id)
                .iter()
                .map(|t| t.index)
                .collect();
            for index in 0..c.tasks {
                if !have.contains(&index) {
                    self.insert_grid_task(c.id, index);
                    repaired += 1;
                }
            }
        }
        let clusterless: Vec<u64> = self
            .grid_tasks_in_state(GridTaskState::Dispatched)
            .iter()
            .filter(|t| t.cluster.is_none())
            .map(|t| t.id)
            .collect();
        for id in clusterless {
            if self.requeue_grid_task(id, "recovered intent had no cluster").is_ok() {
                repaired += 1;
            }
        }
        repaired
    }

    pub fn campaign(&self, id: CampaignId) -> Result<Campaign, DbError> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let row = self
            .campaigns
            .get(id)
            .ok_or(DbError::CampaignNotFound(id))?;
        campaign_from_row(row)
    }

    /// Look a campaign up by its random tag token (small table scan; the
    /// rejoin sweep uses this to tell our jobs from another grid's).
    pub fn campaign_by_token(&self, token: u64) -> Option<Campaign> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let mut found = None;
        self.campaigns.for_each_all(|_, r| {
            if found.is_none()
                && r.get("token").and_then(Value::as_i64).map(|t| t as u64) == Some(token)
            {
                found = campaign_from_row(r).ok();
            }
        });
        found
    }

    /// All campaigns, in submission (id) order.
    pub fn campaigns(&self) -> Vec<Campaign> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let mut out = Vec::new();
        self.campaigns.for_each_all(|_, r| {
            if let Ok(c) = campaign_from_row(r) {
                out.push(c);
            }
        });
        out
    }

    pub fn set_campaign_state(
        &mut self,
        id: CampaignId,
        state: CampaignState,
    ) -> Result<(), DbError> {
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        if self.campaigns.get(id).is_none() {
            return Err(DbError::CampaignNotFound(id));
        }
        self.mutate(Mutation::SetCell {
            table: TableId::Campaigns,
            id,
            col: "state".into(),
            value: Value::Text(state.as_str().into()),
        });
        Ok(())
    }

    pub fn grid_task(&self, id: u64) -> Result<GridTask, DbError> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let row = self.grid_tasks.get(id).ok_or(DbError::GridTaskNotFound(id))?;
        grid_task_from_row(id, row)
    }

    /// Tasks in one state, in id (campaign, then index) order — an index
    /// probe on `grid_tasks.state`.
    pub fn grid_tasks_in_state(&self, state: GridTaskState) -> Vec<GridTask> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let key = Value::Text(state.as_str().to_string());
        let mut out = Vec::new();
        self.grid_tasks.for_each_eq("state", &key, |id, r| {
            if let Ok(t) = grid_task_from_row(id, r) {
                out.push(t);
            }
        });
        out
    }

    /// All tasks of one campaign, by index — probes `grid_tasks.campaignId`.
    pub fn grid_tasks_of_campaign(&self, campaign: CampaignId) -> Vec<GridTask> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let key = Value::Int(campaign as i64);
        let mut out = Vec::new();
        self.grid_tasks.for_each_eq("campaignId", &key, |id, r| {
            if let Ok(t) = grid_task_from_row(id, r) {
                out.push(t);
            }
        });
        out.sort_by_key(|t| t.index);
        out
    }

    /// `SELECT COUNT(*) FROM grid_tasks WHERE state = ?` off the index.
    pub fn count_grid_tasks_in_state(&self, state: GridTaskState) -> usize {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.grid_tasks
            .count_eq("state", &Value::Text(state.as_str().to_string()))
    }

    /// Per-state counts of one campaign's tasks, in [`GridTaskState::ALL`]
    /// order, without materializing a single row — progress polls run
    /// every few ms against campaigns up to a million tasks.
    pub fn count_campaign_tasks(&self, campaign: CampaignId) -> [usize; 4] {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let key = Value::Int(campaign as i64);
        let mut counts = [0usize; 4];
        self.grid_tasks.for_each_eq("campaignId", &key, |_, r| {
            if let Some(s) = r
                .get("state")
                .and_then(Value::as_str)
                .and_then(GridTaskState::parse)
            {
                if let Some(i) = GridTaskState::ALL.iter().position(|x| *x == s) {
                    counts[i] += 1;
                }
            }
        });
        counts
    }

    /// Are all tasks of `campaign` terminal? Walks the campaign index
    /// until the first counterexample, materializing nothing — the
    /// grid's close pass runs this every round on every Active campaign,
    /// and a mid-drain campaign answers at its first live task.
    pub fn campaign_tasks_all_terminal(&self, campaign: CampaignId) -> bool {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let key = Value::Int(campaign as i64);
        let mut all = true;
        self.grid_tasks.for_each_eq_while("campaignId", &key, |_, r| {
            all = r
                .get("state")
                .and_then(Value::as_str)
                .and_then(GridTaskState::parse)
                .map(|s| s.is_terminal())
                .unwrap_or(false);
            all
        });
        all
    }

    /// The first `max` tasks in one state (id order), visiting only that
    /// many index entries. The dispatch loop only ever places
    /// `sum(headrooms)` tasks per wave, so a million-task backlog costs
    /// a wave-sized walk, not a million-row one.
    pub fn grid_tasks_in_state_capped(
        &self,
        state: GridTaskState,
        max: usize,
    ) -> Vec<GridTask> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let key = Value::Text(state.as_str().to_string());
        let mut out = Vec::new();
        self.grid_tasks.for_each_eq_while("state", &key, |id, r| {
            if out.len() >= max {
                return false;
            }
            if let Ok(t) = grid_task_from_row(id, r) {
                out.push(t);
            }
            out.len() < max
        });
        out
    }

    fn set_grid_task_cell(&mut self, id: u64, col: &str, value: Value) {
        self.mutate(Mutation::SetCell {
            table: TableId::GridTasks,
            id,
            col: col.into(),
            value,
        });
    }

    /// Record a placement intent *before* the remote submission goes out
    /// (write-ahead at the grid level): state `Dispatched`, the target
    /// cluster, no job id yet, attempts + 1, and the dispatch instant
    /// (grid clock) the staleness check measures from. If the grid dies
    /// between this write and the remote ack, the reconciler resolves
    /// the window by the task tag instead of double-dispatching.
    ///
    /// Each cell is its own WAL record, so the `state` cell goes in
    /// **last**: any crash-truncated prefix recovers as a `Pending` task
    /// with half-updated placement cells — harmless, the next dispatch
    /// overwrites them — never as a `Dispatched` task with no cluster,
    /// which nothing could ever resolve.
    pub fn mark_grid_task_dispatched(
        &mut self,
        id: u64,
        cluster: &str,
        now: Time,
    ) -> Result<(), DbError> {
        let task = self.grid_task(id)?;
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        self.set_grid_task_cell(id, "cluster", Value::Text(cluster.into()));
        self.set_grid_task_cell(id, "jobId", Value::Null);
        self.set_grid_task_cell(id, "attempts", Value::Int(task.attempts as i64 + 1));
        self.set_grid_task_cell(id, "dispatchedAt", Value::Int(now));
        self.set_grid_task_cell(
            id,
            "state",
            Value::Text(GridTaskState::Dispatched.as_str().into()),
        );
        Ok(())
    }

    /// Reset the dispatch instants of every `Dispatched` task to 0 (=
    /// "as of grid boot"). A restarted grid has a fresh monotonic clock,
    /// so persisted instants from the previous process are meaningless —
    /// resetting restarts each in-flight task's staleness timer instead
    /// of comparing clocks that never shared an epoch.
    pub fn reset_grid_dispatch_clocks(&mut self) {
        let ids: Vec<u64> = self
            .grid_tasks_in_state(GridTaskState::Dispatched)
            .iter()
            .map(|t| t.id)
            .collect();
        for id in ids {
            self.stats.updates.fetch_add(1, Ordering::Relaxed);
            self.set_grid_task_cell(id, "dispatchedAt", Value::Int(0));
        }
    }

    /// Record the acknowledged remote job id of a dispatched task.
    pub fn set_grid_task_job(&mut self, id: u64, job: JobId) -> Result<(), DbError> {
        if self.grid_tasks.get(id).is_none() {
            return Err(DbError::GridTaskNotFound(id));
        }
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        self.set_grid_task_cell(id, "jobId", Value::Int(job as i64));
        Ok(())
    }

    /// The remote job terminated normally: task `Done` (terminal).
    pub fn complete_grid_task(&mut self, id: u64) -> Result<(), DbError> {
        if self.grid_tasks.get(id).is_none() {
            return Err(DbError::GridTaskNotFound(id));
        }
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        self.set_grid_task_cell(id, "state", Value::Text(GridTaskState::Done.as_str().into()));
        Ok(())
    }

    /// Retry budget exhausted: task `Failed` (terminal) with the reason.
    pub fn fail_grid_task(&mut self, id: u64, why: &str) -> Result<(), DbError> {
        if self.grid_tasks.get(id).is_none() {
            return Err(DbError::GridTaskNotFound(id));
        }
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        self.set_grid_task_cell(
            id,
            "state",
            Value::Text(GridTaskState::Failed.as_str().into()),
        );
        self.set_grid_task_cell(id, "message", Value::Text(why.into()));
        Ok(())
    }

    /// Send a task back to `Pending` (preempted / lost / cluster died):
    /// the placement is cleared, the reason recorded, and the next
    /// dispatch wave places it again (attempts keep accumulating). The
    /// `state` cell goes in first — `Pending` is the safe state, and a
    /// crash-truncated prefix then recovers as a requeued task with a
    /// stale placement that the next dispatch overwrites.
    pub fn requeue_grid_task(&mut self, id: u64, why: &str) -> Result<(), DbError> {
        if self.grid_tasks.get(id).is_none() {
            return Err(DbError::GridTaskNotFound(id));
        }
        self.stats.updates.fetch_add(1, Ordering::Relaxed);
        self.set_grid_task_cell(
            id,
            "state",
            Value::Text(GridTaskState::Pending.as_str().into()),
        );
        self.set_grid_task_cell(id, "cluster", Value::Null);
        self.set_grid_task_cell(id, "jobId", Value::Null);
        self.set_grid_task_cell(id, "message", Value::Text(why.into()));
        Ok(())
    }

    // -------------------------------------------------------- events ----

    pub fn log_event(&mut self, now: Time, kind: &str, job: Option<JobId>, detail: &str) {
        self.stats.inserts.fetch_add(1, Ordering::Relaxed);
        self.mutate(Mutation::LogEvent {
            time: now,
            kind: kind.into(),
            job,
            detail: detail.into(),
        });
    }

    pub fn events(&self) -> &[EventRecord] {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.events.all()
    }

    /// The `events` RPC read: the newest `tail` events matching the
    /// optional kind/job filters, oldest first, plus the total match
    /// count inside the retained window. One logical SELECT.
    pub fn events_tail(
        &self,
        tail: usize,
        kind: Option<&str>,
        job: Option<JobId>,
    ) -> (Vec<EventRecord>, usize) {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let matches: Vec<&EventRecord> = self
            .events
            .all()
            .iter()
            .filter(|r| kind.is_none_or(|k| r.kind == k))
            .filter(|r| job.is_none_or(|j| r.job == Some(j)))
            .collect();
        let total = matches.len();
        let start = total.saturating_sub(tail);
        (matches[start..].iter().map(|r| (*r).clone()).collect(), total)
    }

    /// Configure the event-log retention cap (see `db/log.rs`: evicts
    /// oldest-first immediately and on every later append). Not a
    /// logged mutation — a recovered server must be configured with the
    /// same cap (the snapshot records it) to converge to the same
    /// retained window.
    pub fn set_event_retention(&mut self, cap: usize) {
        self.events.set_retention(cap);
    }

    /// The event-log retention cap (records).
    pub fn event_retention(&self) -> usize {
        self.events.retention()
    }

    /// Events evicted by the retention cap over this database's life
    /// (surfaced as `oar_db_events_evicted_total`).
    pub fn events_evicted(&self) -> u64 {
        self.events.evicted()
    }

    /// Events whose kind starts with `prefix` (e.g. `RECOVERY_` — the
    /// restart-reconciliation audit trail), in time order.
    pub fn events_with_kind_prefix(&self, prefix: &str) -> Vec<&EventRecord> {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        self.events.of_kind_prefix(prefix)
    }

    // ---------------------------------------------------- accounting ----

    /// `oarstat --accounting` aggregation, computed in one zero-copy pass
    /// over the jobs table (one logical SELECT; no `Job` materialization).
    pub fn accounting(&self) -> Accounting {
        self.stats.selects.fetch_add(1, Ordering::Relaxed);
        let mut b = AccountingBuilder::new();
        self.jobs.for_each_all(|_, r| {
            let Some(state) = r
                .get("state")
                .and_then(Value::as_str)
                .and_then(JobState::parse)
            else {
                return;
            };
            let nb_nodes = r.get("nbNodes").and_then(Value::as_i64).unwrap_or(1) as u32;
            let weight = r.get("weight").and_then(Value::as_i64).unwrap_or(1) as u32;
            b.add(
                r.get("user").and_then(Value::as_str).unwrap_or(""),
                r.get("queueName").and_then(Value::as_str).unwrap_or("default"),
                state,
                r.get("submissionTime").and_then(Value::as_i64).unwrap_or(0),
                r.get("startTime").and_then(Value::as_i64),
                r.get("stopTime").and_then(Value::as_i64),
                nb_nodes * weight,
            );
        });
        b.finish()
    }

    // --------------------------------------------------- persistence ----

    /// The snapshot document (also the canonical state comparison form
    /// used by the crash tests: two databases are equal iff their dumps
    /// are byte-identical — BTreeMaps make the encoding deterministic).
    fn snapshot_doc(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj(vec![
            ("jobs", self.jobs.to_json()),
            ("nodes", self.nodes.to_json()),
            ("assignments", self.assignments.to_json()),
            ("queues", self.queues.to_json()),
            ("admission_rules", self.admission_rules.to_json()),
            ("campaigns", self.campaigns.to_json()),
            ("grid_tasks", self.grid_tasks.to_json()),
            ("resources", self.resources.to_json()),
            ("events", self.events.to_json()),
            // Bounded-log bookkeeping: the window above is only
            // interpretable with its cap, and the eviction odometer must
            // survive restarts (or recovery would silently zero it).
            ("events_cap", Json::Num(self.events.retention() as f64)),
            ("events_evicted", Json::Num(self.events.evicted() as f64)),
        ])
    }

    /// Serialized state (volatile counters and the WAL excluded).
    pub fn dump(&self) -> String {
        self.snapshot_doc().dump()
    }

    /// Snapshot the entire database to JSON — the paper's §2 argument that
    /// "the database engine can handle the data safety" as long as modules
    /// make atomic coherent modifications. Atomic: the document is written
    /// to a temp file and renamed over `path`, so a crash mid-write can
    /// never corrupt an existing snapshot.
    pub fn snapshot(&self, path: &Path) -> crate::Result<()> {
        self.write_snapshot_atomic(path)
    }

    fn write_snapshot_atomic(&self, path: &Path) -> crate::Result<()> {
        use std::io::Write as _;
        let doc = self.dump();
        let tmp = path.with_extension("tmp");
        if let Some(n) = self.snapshot_fail_after {
            // Injected mid-write crash: leave a partial temp file behind
            // and never rename — the previous generation stays intact.
            let cut = n.min(doc.len().saturating_sub(1));
            std::fs::write(&tmp, &doc.as_bytes()[..cut])?;
            anyhow::bail!("injected snapshot failure after {cut} bytes");
        }
        let mut file = std::fs::File::create(&tmp)?;
        file.write_all(doc.as_bytes())?;
        // The rename must never become visible before its contents are on
        // disk, or a power cut could leave a complete-looking but empty
        // snapshot as the newest generation.
        file.sync_all()?;
        drop(file);
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Decode a snapshot document; the standard schema's secondary
    /// indexes are rebuilt (they are derived state and never serialized).
    fn from_snapshot_doc(doc: &crate::util::Json) -> crate::Result<Db> {
        let table = |key: &str| -> crate::Result<Table> {
            Table::from_json(
                doc.get(key)
                    .ok_or_else(|| anyhow::anyhow!("snapshot missing {key}"))?,
            )
        };
        // The grid tables were added after the snapshot format shipped: a
        // snapshot written before them simply has no such state, so their
        // absence decodes as empty tables (never an error).
        let table_or_empty = |key: &str| -> crate::Result<Table> {
            match doc.get(key) {
                Some(j) => Table::from_json(j),
                None => Ok(Table::new(key)),
            }
        };
        let mut db = Db {
            jobs: table("jobs")?,
            nodes: table("nodes")?,
            assignments: table("assignments")?,
            queues: table("queues")?,
            admission_rules: table("admission_rules")?,
            campaigns: table_or_empty("campaigns")?,
            grid_tasks: table_or_empty("grid_tasks")?,
            resources: table_or_empty("resources")?,
            events: EventLog::from_json(
                doc.get("events")
                    .ok_or_else(|| anyhow::anyhow!("snapshot missing events"))?,
            )?,
            stats: StatCounters::default(),
            views: Views::default(),
            wal: None,
            snapshot_fail_after: None,
        };
        db.create_standard_indexes();
        // Bounded-log bookkeeping (absent in pre-cap snapshots: keep
        // the defaults). Restore the cap *before* WAL replay appends —
        // eviction during replay must run under the same cap as the
        // run that wrote the log.
        if let Some(cap) = doc.get("events_cap").and_then(crate::util::Json::as_i64) {
            db.events.set_retention(cap.max(0) as usize);
        }
        if let Some(evicted) = doc.get("events_evicted").and_then(crate::util::Json::as_i64) {
            db.events.set_evicted_total(evicted.max(0) as u64);
        }
        // Views are derived state, never serialized: rebuild them from
        // the loaded base tables, exactly like the indexes above. WAL
        // replay then maintains them through `apply`.
        db.views = Views::recompute(&db.jobs, &db.nodes, &db.assignments);
        Ok(db)
    }

    /// Restore a snapshot file (volatile — no WAL attached; durable
    /// recovery goes through [`Db::recover`]).
    pub fn restore(path: &Path) -> crate::Result<Db> {
        let text = std::fs::read_to_string(path)?;
        Db::from_snapshot_doc(&crate::util::Json::parse(&text)?)
    }
}

// ----------------------------------------------------- row conversion ----

fn job_to_row(job: &Job) -> Row {
    let mut r = Row::new();
    r.insert("jobType".into(), Value::Text(job.kind.as_str().into()));
    r.insert(
        "infoType".into(),
        job.info_type
            .clone()
            .map(Value::Text)
            .unwrap_or(Value::Null),
    );
    r.insert("state".into(), Value::Text(job.state.as_str().into()));
    r.insert(
        "reservation".into(),
        Value::Text(job.reservation.as_str().into()),
    );
    r.insert("message".into(), Value::Text(job.message.clone()));
    r.insert("user".into(), Value::Text(job.user.clone()));
    r.insert("nbNodes".into(), Value::Int(job.nb_nodes as i64));
    r.insert("weight".into(), Value::Int(job.weight as i64));
    r.insert("command".into(), Value::Text(job.command.clone()));
    r.insert(
        "bpid".into(),
        job.bpid.map(|p| Value::Int(p as i64)).unwrap_or(Value::Null),
    );
    r.insert("queueName".into(), Value::Text(job.queue_name.clone()));
    r.insert("maxTime".into(), Value::Int(job.max_time));
    r.insert("properties".into(), Value::Text(job.properties.clone()));
    r.insert(
        "launchingDirectory".into(),
        Value::Text(job.launching_directory.clone()),
    );
    r.insert("submissionTime".into(), Value::Int(job.submission_time));
    r.insert(
        "startTime".into(),
        job.start_time.map(Value::Int).unwrap_or(Value::Null),
    );
    r.insert(
        "stopTime".into(),
        job.stop_time.map(Value::Int).unwrap_or(Value::Null),
    );
    r.insert("bestEffort".into(), Value::Bool(job.best_effort));
    r.insert(
        "reservationStart".into(),
        job.reservation_start.map(Value::Int).unwrap_or(Value::Null),
    );
    r.insert(
        "resources".into(),
        job.resources
            .clone()
            .map(Value::Text)
            .unwrap_or(Value::Null),
    );
    r
}

fn job_from_row(r: &Row) -> Result<Job, DbError> {
    let corrupt = |f: &str| DbError::Corrupt(format!("jobs.{f}"));
    Ok(Job {
        id: r.get("id").and_then(Value::as_i64).ok_or_else(|| corrupt("id"))? as JobId,
        kind: match r.get("jobType").and_then(Value::as_str) {
            Some("INTERACTIVE") => JobKind::Interactive,
            _ => JobKind::Passive,
        },
        info_type: r
            .get("infoType")
            .and_then(Value::as_str)
            .map(str::to_string),
        state: r
            .get("state")
            .and_then(Value::as_str)
            .and_then(JobState::parse)
            .ok_or_else(|| corrupt("state"))?,
        reservation: match r.get("reservation").and_then(Value::as_str) {
            Some("toSchedule") => ReservationField::ToSchedule,
            Some("Scheduled") => ReservationField::Scheduled,
            _ => ReservationField::None,
        },
        message: r
            .get("message")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        user: r
            .get("user")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        nb_nodes: r.get("nbNodes").and_then(Value::as_i64).unwrap_or(1) as u32,
        weight: r.get("weight").and_then(Value::as_i64).unwrap_or(1) as u32,
        command: r
            .get("command")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        bpid: r.get("bpid").and_then(Value::as_i64).map(|p| p as u32),
        queue_name: r
            .get("queueName")
            .and_then(Value::as_str)
            .unwrap_or("default")
            .to_string(),
        max_time: r.get("maxTime").and_then(Value::as_i64).unwrap_or(0),
        properties: r
            .get("properties")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        launching_directory: r
            .get("launchingDirectory")
            .and_then(Value::as_str)
            .unwrap_or("/tmp")
            .to_string(),
        submission_time: r
            .get("submissionTime")
            .and_then(Value::as_i64)
            .unwrap_or(0),
        start_time: r.get("startTime").and_then(Value::as_i64),
        stop_time: r.get("stopTime").and_then(Value::as_i64),
        best_effort: r
            .get("bestEffort")
            .map(Value::is_truthy)
            .unwrap_or(false),
        reservation_start: r.get("reservationStart").and_then(Value::as_i64),
        // Absent on rows written before the hierarchical request model
        // existed — those jobs are plain flat submissions.
        resources: r
            .get("resources")
            .and_then(Value::as_str)
            .map(str::to_string),
    })
}

fn node_to_row(node: &Node) -> Row {
    let mut r = Row::new();
    r.insert("nodeId".into(), Value::Int(node.id as i64));
    r.insert("hostname".into(), Value::Text(node.hostname.clone()));
    r.insert("state".into(), Value::Text(node.state.as_str().into()));
    r.insert("nbProcs".into(), Value::Int(node.nb_procs as i64));
    for (k, v) in &node.properties {
        r.insert(format!("prop_{k}").into(), v.clone());
    }
    r
}

fn node_from_row(r: &Row) -> Result<Node, DbError> {
    let corrupt = |f: &str| DbError::Corrupt(format!("nodes.{f}"));
    let mut properties = BTreeMap::new();
    for (k, v) in r.iter() {
        if let Some(name) = k.strip_prefix("prop_") {
            properties.insert(name.to_string(), v.clone());
        }
    }
    Ok(Node {
        id: r
            .get("nodeId")
            .and_then(Value::as_i64)
            .ok_or_else(|| corrupt("nodeId"))? as NodeId,
        hostname: r
            .get("hostname")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        state: match r.get("state").and_then(Value::as_str) {
            Some("Alive") => NodeState::Alive,
            Some("Suspected") => NodeState::Suspected,
            Some("Absent") => NodeState::Absent,
            _ => return Err(corrupt("state")),
        },
        nb_procs: r.get("nbProcs").and_then(Value::as_i64).unwrap_or(1) as u32,
        properties,
    })
}

fn queue_from_row(r: &Row) -> Result<Queue, DbError> {
    let corrupt = |f: &str| DbError::Corrupt(format!("queues.{f}"));
    Ok(Queue {
        name: r
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| corrupt("name"))?
            .to_string(),
        priority: r.get("priority").and_then(Value::as_i64).unwrap_or(0) as i32,
        policy: r
            .get("policy")
            .and_then(Value::as_str)
            .and_then(QueuePolicyKind::parse)
            .ok_or_else(|| corrupt("policy"))?,
        default_max_time: r
            .get("defaultMaxTime")
            .and_then(Value::as_i64)
            .unwrap_or(3600),
        max_procs_per_job: r
            .get("maxProcsPerJob")
            .and_then(Value::as_i64)
            .unwrap_or(i64::MAX) as u32,
        active: r.get("active").map(Value::is_truthy).unwrap_or(true),
    })
}

fn campaign_from_row(r: &Row) -> Result<Campaign, DbError> {
    let corrupt = |f: &str| DbError::Corrupt(format!("campaigns.{f}"));
    Ok(Campaign {
        id: r.get("id").and_then(Value::as_i64).ok_or_else(|| corrupt("id"))? as CampaignId,
        token: r.get("token").and_then(Value::as_i64).unwrap_or(0) as u64,
        name: r
            .get("name")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        user: r
            .get("user")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        command: r
            .get("command")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        nb_nodes: r.get("nbNodes").and_then(Value::as_i64).unwrap_or(1) as u32,
        weight: r.get("weight").and_then(Value::as_i64).unwrap_or(1) as u32,
        max_time: r.get("maxTime").and_then(Value::as_i64).unwrap_or(3600),
        tasks: r.get("tasks").and_then(Value::as_i64).unwrap_or(0) as u32,
        state: r
            .get("state")
            .and_then(Value::as_str)
            .and_then(CampaignState::parse)
            .ok_or_else(|| corrupt("state"))?,
        submission_time: r
            .get("submissionTime")
            .and_then(Value::as_i64)
            .unwrap_or(0),
    })
}

fn grid_task_from_row(id: u64, r: &Row) -> Result<GridTask, DbError> {
    let corrupt = |f: &str| DbError::Corrupt(format!("grid_tasks.{f}"));
    Ok(GridTask {
        id,
        campaign: r
            .get("campaignId")
            .and_then(Value::as_i64)
            .ok_or_else(|| corrupt("campaignId"))? as CampaignId,
        index: r.get("idx").and_then(Value::as_i64).unwrap_or(0) as u32,
        state: r
            .get("state")
            .and_then(Value::as_str)
            .and_then(GridTaskState::parse)
            .ok_or_else(|| corrupt("state"))?,
        cluster: r
            .get("cluster")
            .and_then(Value::as_str)
            .map(str::to_string),
        job: r.get("jobId").and_then(Value::as_i64).map(|j| j as JobId),
        attempts: r.get("attempts").and_then(Value::as_i64).unwrap_or(0) as u32,
        dispatched_at: r.get("dispatchedAt").and_then(Value::as_i64).unwrap_or(0),
        message: r
            .get("message")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::JobSpec;

    fn make_job(spec: &JobSpec, now: Time) -> Job {
        Job::from_spec(spec, now)
    }

    #[test]
    fn job_roundtrip_through_rows() {
        let mut db = Db::with_standard_queues();
        let spec = JobSpec::batch("alice", "echo hi", 4, 600);
        let id = db.insert_job(make_job(&spec, 42));
        let job = db.job(id).unwrap();
        assert_eq!(job.id, id);
        assert_eq!(job.user, "alice");
        assert_eq!(job.nb_nodes, 4);
        assert_eq!(job.state, JobState::Waiting);
        assert_eq!(job.submission_time, 42);
    }

    #[test]
    fn state_transitions_are_validated() {
        let mut db = Db::with_standard_queues();
        let id = db.insert_job(make_job(&JobSpec::default(), 0));
        // Waiting -> Running is illegal (must pass through toLaunch).
        let err = db.set_job_state(id, JobState::Running, 1).unwrap_err();
        assert!(matches!(err, DbError::IllegalTransition { .. }));
        db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
        db.set_job_state(id, JobState::Launching, 2).unwrap();
        db.set_job_state(id, JobState::Running, 3).unwrap();
        db.set_job_state(id, JobState::Terminated, 9).unwrap();
        let job = db.job(id).unwrap();
        assert_eq!(job.start_time, Some(3));
        assert_eq!(job.stop_time, Some(9));
        assert_eq!(job.response_time(), Some(9));
    }

    #[test]
    fn fail_job_reaches_error_from_any_state() {
        let mut db = Db::with_standard_queues();
        let id = db.insert_job(make_job(&JobSpec::default(), 0));
        db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
        db.fail_job(id, "node died", 2).unwrap();
        let job = db.job(id).unwrap();
        assert_eq!(job.state, JobState::Error);
        assert_eq!(job.message, "node died");
        // idempotent on terminal jobs
        db.fail_job(id, "again", 3).unwrap();
    }

    #[test]
    fn matching_nodes_uses_expressions() {
        let mut db = Db::new();
        db.add_node(Node::new(1, "n1", 2).with_prop("mem", Value::Int(256)));
        db.add_node(Node::new(2, "n2", 2).with_prop("mem", Value::Int(1024)));
        let got = db.matching_nodes("mem >= 512").unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 2);
        // empty properties match all alive nodes
        assert_eq!(db.matching_nodes("").unwrap().len(), 2);
        // suspected nodes never match
        db.set_node_state(2, NodeState::Suspected).unwrap();
        assert!(db.matching_nodes("mem >= 512").unwrap().is_empty());
    }

    #[test]
    fn matching_nodes_sees_builtin_columns() {
        let mut db = Db::new();
        db.add_node(Node::new(1, "node-1", 2));
        db.add_node(Node::new(2, "node-2", 4));
        let got = db.matching_nodes("hostname = 'node-2'").unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 2);
        // nb_procs is mirrored as a bare property
        let got = db.matching_nodes("nb_procs >= 4").unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 2);
    }

    #[test]
    fn assignments_and_busy_procs() {
        let mut db = Db::with_standard_queues();
        db.add_node(Node::new(1, "n1", 2));
        db.add_node(Node::new(2, "n2", 2));
        let id = db.insert_job(make_job(&JobSpec::batch("u", "c", 2, 60), 0));
        db.assign_nodes(id, &[1, 2], 1);
        db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
        let busy = db.busy_procs_by_node();
        assert_eq!(busy[&1], 1);
        assert_eq!(busy[&2], 1);
        // After termination the procs are free again.
        db.set_job_state(id, JobState::Launching, 2).unwrap();
        db.set_job_state(id, JobState::Running, 2).unwrap();
        db.set_job_state(id, JobState::Terminated, 3).unwrap();
        assert!(db.busy_procs_by_node().is_empty());
    }

    #[test]
    fn queues_by_priority_order() {
        let mut db = Db::with_standard_queues();
        db.add_queue(Queue::new("urgent", 100, QueuePolicyKind::FifoConservative));
        let qs = db.queues_by_priority();
        assert_eq!(qs[0].name, "urgent");
        assert_eq!(qs.last().unwrap().name, "besteffort");
    }

    #[test]
    fn query_stats_count_statements() {
        let mut db = Db::with_standard_queues();
        db.reset_stats();
        let id = db.insert_job(make_job(&JobSpec::default(), 0));
        let _ = db.job(id);
        db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
        let s = db.stats();
        assert_eq!(s.inserts, 1);
        assert!(s.selects >= 2);
        assert_eq!(s.updates, 1);
    }

    #[test]
    fn logical_select_counts_once_regardless_of_plan() {
        // §3.2.2 reproduction invariant: the statement counters must not
        // depend on whether the planner probed an index or scanned.
        let mut indexed = Db::with_standard_queues();
        let mut scanning = Db::with_standard_queues();
        scanning.drop_all_indexes();
        for db in [&mut indexed, &mut scanning] {
            for i in 0..10 {
                db.insert_job(make_job(&JobSpec::default(), i));
            }
            db.reset_stats();
            let _ = db.jobs_in_state(JobState::Waiting);
            let _ = db.waiting_jobs_in_queue("default");
            let _ = db.count_jobs_in_state(JobState::Running);
        }
        let (a, b) = (indexed.stats(), scanning.stats());
        assert_eq!(a.selects, b.selects, "logical counts must match");
        assert_eq!(a.selects, 3);
        assert!(a.index_probes > 0, "indexed db must probe");
        assert_eq!(a.full_scans, 0, "indexed db must not scan");
        assert!(b.full_scans > 0, "unindexed db must scan");
        assert_eq!(b.index_probes, 0);
    }

    #[test]
    fn state_index_tracks_transitions() {
        let mut db = Db::with_standard_queues();
        let a = db.insert_job(make_job(&JobSpec::default(), 0));
        let b = db.insert_job(make_job(&JobSpec::default(), 1));
        assert_eq!(db.count_jobs_in_state(JobState::Waiting), 2);
        db.set_job_state(a, JobState::ToLaunch, 1).unwrap();
        assert_eq!(db.count_jobs_in_state(JobState::Waiting), 1);
        assert_eq!(db.count_jobs_in_state(JobState::ToLaunch), 1);
        let waiting = db.jobs_in_state(JobState::Waiting);
        assert_eq!(waiting.len(), 1);
        assert_eq!(waiting[0].id, b);
        // jobs_where with a sargable filter agrees with the typed probe
        let via_where = db.jobs_where(&Expr::parse("state = 'Waiting'").unwrap());
        assert_eq!(via_where.len(), 1);
        assert_eq!(via_where[0].id, b);
    }

    #[test]
    fn explain_shows_the_plan() {
        let db = Db::with_standard_queues();
        let e = Expr::parse("state = 'Waiting'").unwrap();
        let plan = db.explain("jobs", &e).unwrap();
        assert_eq!(plan.kind, crate::db::PlanKind::IndexEq);
        assert_eq!(plan.column.as_deref(), Some("state"));
        let e = Expr::parse("message LIKE '%x%'").unwrap();
        let plan = db.explain("jobs", &e).unwrap();
        assert_eq!(plan.kind, crate::db::PlanKind::FullScan);
        assert!(db.explain("no_such_table", &e).is_none());
    }

    /// Drive a fresh job into `target` through legal edges only.
    fn job_in_state(db: &mut Db, target: JobState) -> JobId {
        use JobState::*;
        let id = db.insert_job(make_job(&JobSpec::default(), 0));
        let chain: &[JobState] = match target {
            Waiting => &[],
            Hold => &[Hold],
            ToLaunch => &[ToLaunch],
            Launching => &[ToLaunch, Launching],
            Running => &[ToLaunch, Launching, Running],
            Terminated => &[ToLaunch, Launching, Running, Terminated],
            ToError => &[ToError],
            Error => &[ToError, Error],
            ToAckReservation => &[ToAckReservation],
        };
        for (i, s) in chain.iter().enumerate() {
            db.set_job_state(id, *s, i as Time).unwrap();
        }
        id
    }

    #[test]
    fn hold_gate_rejects_every_illegal_source_state() {
        // fig. 1 admits exactly one edge into Hold: Waiting -> Hold.
        let mut db = Db::with_standard_queues();
        for &target in JobState::ALL.iter() {
            let id = job_in_state(&mut db, target);
            let res = db.hold_job(id, 100);
            if target == JobState::Waiting {
                res.unwrap();
                assert_eq!(db.job(id).unwrap().state, JobState::Hold);
            } else {
                let err = res.unwrap_err();
                match err {
                    DbError::IllegalTransition { job, from, to } => {
                        assert_eq!(job, id);
                        assert_eq!(from, target);
                        assert_eq!(to, JobState::Hold);
                    }
                    other => panic!("expected IllegalTransition, got {other}"),
                }
                // The gate must not have moved the job.
                assert_eq!(db.job(id).unwrap().state, target);
            }
        }
        assert!(matches!(
            db.hold_job(9999, 0),
            Err(DbError::JobNotFound(9999))
        ));
        assert!(db.verify_views());
    }

    #[test]
    fn views_track_lifecycle_and_match_recompute() {
        let mut db = Db::with_standard_queues();
        db.add_node(Node::new(1, "n1", 2));
        db.add_node(Node::new(2, "n2", 2));
        assert_eq!(db.cluster_load().procs_alive, 4);

        let a = db.insert_job(make_job(&JobSpec::batch("u", "c", 2, 60), 0));
        let b = db.insert_job(make_job(&JobSpec::default(), 1));
        assert_eq!(db.queue_depth("default"), 2);
        assert_eq!(db.state_depth(JobState::Waiting), 2);
        assert!(db.verify_views());

        db.assign_nodes(a, &[1, 2], 1);
        // Assignments of a still-Waiting job claim nothing yet.
        assert_eq!(db.cluster_load().procs_busy, 0);
        db.set_job_state(a, JobState::ToLaunch, 1).unwrap();
        assert_eq!(db.queue_depth("default"), 1);
        assert_eq!(db.cluster_load().procs_busy, 2);
        assert_eq!(db.node_occupancy(), db.busy_procs_by_node());
        assert!(db.verify_views());

        // A node death must NOT release the claimed processors: the view
        // (and the load probe built on it) keeps them busy until the
        // automaton fails or requeues the job.
        db.set_node_state(2, NodeState::Suspected).unwrap();
        let load = db.cluster_load();
        assert_eq!(load.nodes_alive, 1);
        assert_eq!(load.procs_alive, 2);
        assert_eq!(load.procs_busy, 2);
        assert_eq!(load, db.cluster_load_recompute());
        assert!(db.verify_views());

        // Failing the job releases its claim; removing assignments after
        // the state flip must not double-subtract.
        db.fail_job(a, "node died", 2).unwrap();
        db.remove_assignments(a);
        assert_eq!(db.cluster_load().procs_busy, 0);
        assert!(db.node_occupancy().is_empty());
        assert!(db.verify_views());

        db.set_job_state(b, JobState::ToLaunch, 3).unwrap();
        assert_eq!(db.queue_depth("default"), 0);
        assert!(db.verify_views());
    }

    #[test]
    fn view_reads_count_one_select_plus_view_hit() {
        let mut db = Db::with_standard_queues();
        db.add_node(Node::new(1, "n1", 2));
        db.insert_job(make_job(&JobSpec::default(), 0));
        db.reset_stats();
        let _ = db.queue_depth("default");
        let _ = db.state_depth(JobState::Waiting);
        let _ = db.cluster_load();
        let _ = db.fleet_view();
        let s = db.stats();
        assert_eq!(s.selects, 4, "each view read is one logical select");
        assert_eq!(s.view_hits, 4);
        assert_eq!(s.index_probes, 0, "view reads touch no base table");
        assert_eq!(s.full_scans, 0);
        assert_eq!(s.total(), 4, "view hits are telemetry, not statements");
    }

    #[test]
    fn explain_reports_view_hits() {
        let mut db = Db::with_standard_queues();
        db.add_node(Node::new(1, "n1", 2));
        let plan = db.explain_view("cluster_load").unwrap();
        assert_eq!(plan.kind, crate::db::PlanKind::ViewHit);
        assert_eq!(plan.column.as_deref(), Some("cluster_load"));
        assert_eq!(plan.estimated_rows, 1);
        let plan = db.explain_view("fleet").unwrap();
        assert_eq!(plan.estimated_rows, 1);
        assert!(db.explain_view("no_such_view").is_none());
    }

    #[test]
    fn views_maintained_without_any_index() {
        // Maintenance must not depend on the standard indexes existing
        // (it falls back to raw scans, still uncounted).
        let mut db = Db::with_standard_queues();
        db.drop_all_indexes();
        db.add_node(Node::new(1, "n1", 4));
        let id = db.insert_job(make_job(&JobSpec::batch("u", "c", 1, 60), 0));
        db.assign_nodes(id, &[1], 4);
        db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
        assert_eq!(db.cluster_load().procs_busy, 4);
        assert!(db.verify_views());
        db.reset_stats();
        let _ = db.cluster_load();
        let s = db.stats();
        assert_eq!((s.selects, s.view_hits, s.full_scans), (1, 1, 0));
    }

    #[test]
    fn views_follow_update_where_and_deletes() {
        let mut db = Db::with_standard_queues();
        for i in 0..4 {
            db.insert_job(make_job(&JobSpec::default(), i));
        }
        // Bulk cell write through the WHERE path — including a raw bulk
        // state flip, which bypasses the automaton but must still be
        // tracked by the views.
        let n = db
            .update_jobs_where("state = 'Waiting'", "message", Value::Text("swept".into()))
            .unwrap();
        assert_eq!(n, 4);
        assert!(db.verify_views());
        let n = db
            .update_jobs_where(
                "state = 'Waiting' AND id <= 2",
                "state",
                Value::Text("Hold".into()),
            )
            .unwrap();
        assert_eq!(n, 2);
        assert_eq!(db.state_depth(JobState::Waiting), 2);
        assert_eq!(db.state_depth(JobState::Hold), 2);
        assert_eq!(db.queue_depth("default"), 2);
        assert!(db.verify_views());
    }

    #[test]
    fn accounting_pass_matches_job_based_compute() {
        let mut db = Db::with_standard_queues();
        for i in 0..6u32 {
            let id = db.insert_job(make_job(
                &JobSpec::batch(&format!("u{}", i % 2), "c", 1 + i % 3, 60),
                i as Time,
            ));
            if i % 2 == 0 {
                db.set_job_state(id, JobState::ToLaunch, 10).unwrap();
                db.set_job_state(id, JobState::Launching, 11).unwrap();
                db.set_job_state(id, JobState::Running, 12).unwrap();
                db.set_job_state(id, JobState::Terminated, 40).unwrap();
            }
        }
        let via_rows = db.accounting();
        let jobs = db.jobs_where(&Expr::parse("").unwrap());
        let via_jobs = Accounting::compute(&jobs);
        assert_eq!(via_rows.by_user.len(), via_jobs.by_user.len());
        for (user, usage) in &via_jobs.by_user {
            let got = &via_rows.by_user[user];
            assert_eq!(got.jobs_submitted, usage.jobs_submitted, "{user}");
            assert_eq!(got.jobs_terminated, usage.jobs_terminated, "{user}");
            assert_eq!(got.cpu_seconds, usage.cpu_seconds, "{user}");
            assert_eq!(got.total_wait, usage.total_wait, "{user}");
        }
        assert_eq!(via_rows.total_cpu_seconds, via_jobs.total_cpu_seconds);
        assert_eq!(via_rows.mean_response_time, via_jobs.mean_response_time);
        assert_eq!(via_rows.by_queue, via_jobs.by_queue);
    }

    #[test]
    fn queue_names_with_quotes_are_handled() {
        let mut db = Db::with_standard_queues();
        db.add_queue(Queue::new("o'brien", 5, QueuePolicyKind::FifoConservative));
        db.set_queue_active("o'brien", false).unwrap();
        assert!(!db.queue("o'brien").unwrap().active);
        assert!(db.set_queue_active("missing", true).is_err());
    }

    #[test]
    fn bulk_update_and_assignment_removal() {
        let mut db = Db::with_standard_queues();
        let a = db.insert_job(make_job(&JobSpec::default(), 0));
        let b = db.insert_job(make_job(&JobSpec::default(), 1));
        db.set_job_state(a, JobState::ToLaunch, 1).unwrap();
        let n = db
            .update_jobs_where("state = 'Waiting'", "message", Value::Text("queued".into()))
            .unwrap();
        assert_eq!(n, 1);
        assert_eq!(db.job(b).unwrap().message, "queued");
        assert_eq!(db.job(a).unwrap().message, "");
        assert!(db.update_jobs_where("state = '", "x", Value::Null).is_err());

        db.assign_nodes(a, &[1, 2], 1);
        assert_eq!(db.assigned_nodes(a).len(), 2);
        assert_eq!(db.remove_assignments(a), 2);
        assert!(db.assigned_nodes(a).is_empty());
        assert!(db.verify_indexes());
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let dir = std::env::temp_dir().join("oar_db_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let mut db = Db::with_standard_queues();
        let id = db.insert_job(make_job(&JobSpec::batch("bob", "x", 1, 10), 5));
        db.snapshot(&path).unwrap();
        let mut back = Db::restore(&path).unwrap();
        assert_eq!(back.job(id).unwrap().user, "bob");
        assert_eq!(back.queues_by_priority().len(), 2);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn campaign_and_grid_task_lifecycle() {
        let mut db = Db::new();
        let spec = CampaignSpec::bag("sweep", "alice", "sleep 1 --p {i}", 3);
        let id = db.insert_campaign(&spec, 42);
        let c = db.campaign(id).unwrap();
        assert_eq!(c.name, "sweep");
        assert_eq!(c.tasks, 3);
        assert_eq!(c.state, CampaignState::Active);
        assert_eq!(c.submission_time, 42);
        assert!(matches!(
            db.campaign(999),
            Err(DbError::CampaignNotFound(999))
        ));

        let tasks = db.grid_tasks_of_campaign(id);
        assert_eq!(tasks.len(), 3);
        assert!(tasks.iter().all(|t| t.state == GridTaskState::Pending));
        assert_eq!(tasks.iter().map(|t| t.index).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(db.count_grid_tasks_in_state(GridTaskState::Pending), 3);

        // Dispatch intent → ack → done, with the state index tracking.
        let t0 = tasks[0].id;
        db.mark_grid_task_dispatched(t0, "clusterA", 55).unwrap();
        let t = db.grid_task(t0).unwrap();
        assert_eq!(t.state, GridTaskState::Dispatched);
        assert_eq!(t.cluster.as_deref(), Some("clusterA"));
        assert_eq!(t.job, None);
        assert_eq!(t.attempts, 1);
        assert_eq!(t.dispatched_at, 55);
        db.set_grid_task_job(t0, 17).unwrap();
        assert_eq!(db.grid_task(t0).unwrap().job, Some(17));
        db.complete_grid_task(t0).unwrap();
        assert_eq!(db.count_grid_tasks_in_state(GridTaskState::Done), 1);

        // Requeue clears the placement but keeps the attempt count.
        let t1 = tasks[1].id;
        db.mark_grid_task_dispatched(t1, "clusterB", 60).unwrap();
        db.requeue_grid_task(t1, "cluster died").unwrap();
        let t = db.grid_task(t1).unwrap();
        assert_eq!(t.state, GridTaskState::Pending);
        assert_eq!(t.cluster, None);
        assert_eq!(t.job, None);
        assert_eq!(t.attempts, 1);
        assert_eq!(t.message, "cluster died");

        let t2 = tasks[2].id;
        assert!(!db.campaign_tasks_all_terminal(id));
        db.fail_grid_task(t2, "budget exhausted").unwrap();
        assert_eq!(db.grid_task(t2).unwrap().state, GridTaskState::Failed);
        // t0 Done, t1 Pending (requeued), t2 Failed → not all terminal.
        assert!(!db.campaign_tasks_all_terminal(id));
        db.mark_grid_task_dispatched(t1, "clusterC", 70).unwrap();
        db.set_grid_task_job(t1, 18).unwrap();
        db.complete_grid_task(t1).unwrap();
        assert!(db.campaign_tasks_all_terminal(id));
        // [pending, dispatched, done, failed] — index-walk counts.
        assert_eq!(db.count_campaign_tasks(id), [0, 0, 2, 1]);

        db.set_campaign_state(id, CampaignState::Done).unwrap();
        assert_eq!(db.campaign(id).unwrap().state, CampaignState::Done);
        assert!(db.verify_indexes());
    }

    #[test]
    fn grid_task_reads_probe_their_indexes() {
        let mut db = Db::new();
        let a = db.insert_campaign(&CampaignSpec::bag("a", "u", "c", 4), 0);
        let _b = db.insert_campaign(&CampaignSpec::bag("b", "u", "c", 2), 1);
        // Tag tokens are random and unique; by-token lookup resolves them.
        let (ta, tb) = (db.campaign(a).unwrap().token, db.campaign(_b).unwrap().token);
        assert_ne!(ta, tb, "campaign tokens must be distinct");
        assert_eq!(db.campaign_by_token(ta).map(|c| c.id), Some(a));
        assert_eq!(db.campaign_by_token(ta ^ tb ^ 1), None);
        db.reset_stats();
        assert_eq!(db.grid_tasks_in_state(GridTaskState::Pending).len(), 6);
        assert_eq!(db.grid_tasks_of_campaign(a).len(), 4);
        assert_eq!(db.count_grid_tasks_in_state(GridTaskState::Done), 0);
        let s = db.stats();
        assert_eq!(s.selects, 3);
        assert!(s.index_probes >= 3, "grid reads must probe, got {s:?}");
        assert_eq!(s.full_scans, 0);
        // Capped reads materialize only what a dispatch wave can place.
        let capped = db.grid_tasks_in_state_capped(GridTaskState::Pending, 2);
        assert_eq!(capped.len(), 2);
        assert!(db
            .grid_tasks_in_state_capped(GridTaskState::Pending, 100)
            .len()
            == 6);
    }

    #[test]
    fn snapshot_roundtrips_grid_tables_and_tolerates_their_absence() {
        let dir = std::env::temp_dir().join("oar_db_test_grid_snap");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let mut db = Db::with_standard_queues();
        let id = db.insert_campaign(&CampaignSpec::bag("s", "u", "cmd {i}", 2), 7);
        let t = db.grid_tasks_of_campaign(id)[0].id;
        db.mark_grid_task_dispatched(t, "c1", 8).unwrap();
        db.set_grid_task_job(t, 5).unwrap();
        db.snapshot(&path).unwrap();
        let mut back = Db::restore(&path).unwrap();
        assert_eq!(back.campaigns().len(), 1);
        // The tag token must survive the f64 JSON round-trip exactly —
        // it is the placement identity on remote clusters.
        assert_eq!(back.campaign(id).unwrap().token, db.campaign(id).unwrap().token);
        assert!(db.campaign(id).unwrap().token < (1 << 53));
        let task = back.grid_task(t).unwrap();
        assert_eq!(task.cluster.as_deref(), Some("c1"));
        assert_eq!(task.job, Some(5));
        assert!(back.verify_indexes());

        // A pre-grid snapshot (no campaigns/grid_tasks keys) still loads.
        let doc = crate::util::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let crate::util::Json::Obj(map) = doc else { unreachable!() };
        let mut no_grid = map.clone();
        no_grid.remove("campaigns");
        no_grid.remove("grid_tasks");
        std::fs::write(&path, crate::util::Json::Obj(no_grid).dump()).unwrap();
        let mut old = Db::restore(&path).unwrap();
        assert!(old.campaigns().is_empty());
        assert_eq!(old.count_grid_tasks_in_state(GridTaskState::Pending), 0);

        // A campaign whose task rows a crash truncated (here: all of
        // them) is repaired at boot: missing indices re-inserted Pending.
        let mut torn = map;
        torn.remove("grid_tasks");
        std::fs::write(&path, crate::util::Json::Obj(torn).dump()).unwrap();
        let mut repaired = Db::restore(&path).unwrap();
        assert_eq!(repaired.campaigns().len(), 1);
        assert_eq!(repaired.grid_tasks_of_campaign(id).len(), 0);
        assert_eq!(repaired.repair_campaigns(), 2);
        let rows = repaired.grid_tasks_of_campaign(id);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|t| t.state == GridTaskState::Pending));
        assert_eq!(repaired.repair_campaigns(), 0, "repair is idempotent");
        assert!(repaired.verify_indexes());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn restore_rebuilds_indexes() {
        let dir = std::env::temp_dir().join("oar_db_test_idx");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let mut db = Db::with_standard_queues();
        for i in 0..5 {
            db.insert_job(make_job(&JobSpec::default(), i));
        }
        db.snapshot(&path).unwrap();
        let mut back = Db::restore(&path).unwrap();
        back.reset_stats();
        assert_eq!(back.count_jobs_in_state(JobState::Waiting), 5);
        let s = back.stats();
        assert_eq!(s.index_probes, 1, "restored db must probe its indexes");
        assert_eq!(s.full_scans, 0);
        std::fs::remove_file(path).ok();
    }
}
