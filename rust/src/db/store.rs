//! The database: OAR's full schema plus typed accessors.
//!
//! Tables, as in the paper: `jobs` (fig. 2), `nodes`, `assignments`
//! ("a table for describing the assignment of nodes to jobs"), `queues`,
//! `admission_rules` ("rules are stored as Perl code in the database" —
//! here as rule-DSL source, §2.1) and `events` (logging/accounting).
//!
//! Jobs and nodes genuinely live as rows; the typed [`crate::types::Job`]
//! view is converted on the way in and out, so every module interaction is
//! an honest table read/write and can be counted — [`QueryStats`]
//! reproduces the paper's "350 SQL queries for the processing of 10 jobs"
//! measurement (§3.2.2).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};


use crate::types::{
    Job, JobId, JobKind, JobState, Node, NodeId, NodeState, Queue, QueuePolicyKind,
    ReservationField, Time,
};

use super::expr::Expr;
use super::log::{EventLog, EventRecord};
use super::table::{Row, Table};
use super::value::Value;

/// Errors surfaced by database operations.
#[derive(Debug, Clone, PartialEq)]
pub enum DbError {
    JobNotFound(JobId),
    NodeNotFound(NodeId),
    QueueNotFound(String),
    IllegalTransition { job: JobId, from: JobState, to: JobState },
    Corrupt(String),
    Parse(String),
}

impl std::fmt::Display for DbError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DbError::JobNotFound(id) => write!(f, "job {id} not found"),
            DbError::NodeNotFound(id) => write!(f, "node {id} not found"),
            DbError::QueueNotFound(q) => write!(f, "queue {q:?} not found"),
            DbError::IllegalTransition { job, from, to } => {
                write!(f, "job {job}: illegal transition {from} -> {to}")
            }
            DbError::Corrupt(m) => write!(f, "corrupt row: {m}"),
            DbError::Parse(m) => write!(f, "parse: {m}"),
        }
    }
}

impl std::error::Error for DbError {}

/// Counters of SQL-equivalent statements, by kind.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueryStats {
    pub selects: u64,
    pub inserts: u64,
    pub updates: u64,
    pub deletes: u64,
}

impl QueryStats {
    pub fn total(&self) -> u64 {
        self.selects + self.inserts + self.updates + self.deletes
    }
}

/// The whole database. Shared between modules as [`DbHandle`] — the only
/// communication medium, as in the paper.
#[derive(Debug, Default)]
pub struct Db {
    jobs: Table,
    nodes: Table,
    assignments: Table,
    queues: Table,
    admission_rules: Table,
    events: EventLog,
    stats: QueryStats,
}

/// Shared handle; modules hold this and nothing else.
pub type DbHandle = Arc<Mutex<Db>>;

impl Db {
    pub fn new() -> Db {
        Db {
            jobs: Table::new("jobs"),
            nodes: Table::new("nodes"),
            assignments: Table::new("assignments"),
            queues: Table::new("queues"),
            admission_rules: Table::new("admission_rules"),
            events: EventLog::new(),
            stats: QueryStats::default(),
        }
    }

    /// Fresh database preloaded with the standard queue set.
    pub fn with_standard_queues() -> Db {
        let mut db = Db::new();
        for q in Queue::standard_set() {
            db.add_queue(q);
        }
        db
    }

    pub fn into_handle(self) -> DbHandle {
        Arc::new(Mutex::new(self))
    }

    // ------------------------------------------------------- queries ----

    pub fn stats(&self) -> QueryStats {
        self.stats
    }

    pub fn reset_stats(&mut self) {
        self.stats = QueryStats::default();
    }

    // ---------------------------------------------------------- jobs ----

    /// INSERT a job row; returns the assigned `idJob`.
    pub fn insert_job(&mut self, mut job: Job) -> JobId {
        self.stats.inserts += 1;
        let row = job_to_row(&job);
        let id = self.jobs.insert(row);
        job.id = id;
        id
    }

    pub fn job(&mut self, id: JobId) -> Result<Job, DbError> {
        self.stats.selects += 1;
        let row = self.jobs.get(id).ok_or(DbError::JobNotFound(id))?;
        job_from_row(row)
    }

    pub fn job_count(&mut self) -> usize {
        self.stats.selects += 1;
        self.jobs.len()
    }

    /// All jobs matching a WHERE clause over the raw job columns.
    pub fn jobs_where(&mut self, filter: &Expr) -> Vec<Job> {
        self.stats.selects += 1;
        self.jobs
            .select(filter)
            .iter()
            .filter_map(|(_, r)| job_from_row(r).ok())
            .collect()
    }

    pub fn jobs_in_state(&mut self, state: JobState) -> Vec<Job> {
        self.stats.selects += 1;
        self.jobs
            .iter()
            .filter(|(_, r)| r.get("state").and_then(Value::as_str) == Some(state.as_str()))
            .filter_map(|(_, r)| job_from_row(r).ok())
            .collect()
    }

    /// Waiting jobs of one queue, in submission (id) order.
    pub fn waiting_jobs_in_queue(&mut self, queue: &str) -> Vec<Job> {
        self.stats.selects += 1;
        self.jobs
            .iter()
            .filter(|(_, r)| {
                r.get("state").and_then(Value::as_str) == Some("Waiting")
                    && r.get("queueName").and_then(Value::as_str) == Some(queue)
            })
            .filter_map(|(_, r)| job_from_row(r).ok())
            .collect()
    }

    /// Validated state transition (fig. 1); the heart of the coherence
    /// discipline. Also stamps start/stop times at the relevant edges.
    pub fn set_job_state(
        &mut self,
        id: JobId,
        to: JobState,
        now: Time,
    ) -> Result<(), DbError> {
        self.stats.selects += 1;
        let row = self.jobs.get_mut(id).ok_or(DbError::JobNotFound(id))?;
        let from = row
            .get("state")
            .and_then(Value::as_str)
            .and_then(JobState::parse)
            .ok_or_else(|| DbError::Corrupt(format!("job {id} has bad state")))?;
        if !from.can_transition_to(to) {
            return Err(DbError::IllegalTransition { job: id, from, to });
        }
        self.stats.updates += 1;
        row.insert("state".into(), Value::Text(to.as_str().into()));
        match to {
            JobState::Running => {
                row.insert("startTime".into(), Value::Int(now));
            }
            JobState::Terminated | JobState::Error => {
                row.insert("stopTime".into(), Value::Int(now));
            }
            _ => {}
        }
        Ok(())
    }

    /// Force the abnormal path from any live state: `* → toError → Error`.
    pub fn fail_job(&mut self, id: JobId, reason: &str, now: Time) -> Result<(), DbError> {
        let state = self.job(id)?.state;
        if state.is_terminal() {
            return Ok(());
        }
        if state != JobState::ToError {
            self.set_job_state(id, JobState::ToError, now)?;
        }
        self.set_job_message(id, reason)?;
        self.set_job_state(id, JobState::Error, now)
    }

    pub fn set_job_message(&mut self, id: JobId, message: &str) -> Result<(), DbError> {
        self.stats.updates += 1;
        let row = self.jobs.get_mut(id).ok_or(DbError::JobNotFound(id))?;
        row.insert("message".into(), Value::Text(message.into()));
        Ok(())
    }

    pub fn set_job_bpid(&mut self, id: JobId, bpid: Option<u32>) -> Result<(), DbError> {
        self.stats.updates += 1;
        let row = self.jobs.get_mut(id).ok_or(DbError::JobNotFound(id))?;
        row.insert(
            "bpid".into(),
            bpid.map(|p| Value::Int(p as i64)).unwrap_or(Value::Null),
        );
        Ok(())
    }

    pub fn set_job_reservation(
        &mut self,
        id: JobId,
        f: ReservationField,
    ) -> Result<(), DbError> {
        self.stats.updates += 1;
        let row = self.jobs.get_mut(id).ok_or(DbError::JobNotFound(id))?;
        row.insert("reservation".into(), Value::Text(f.as_str().into()));
        Ok(())
    }

    // --------------------------------------------------------- nodes ----

    pub fn add_node(&mut self, node: Node) -> NodeId {
        self.stats.inserts += 1;
        let row = node_to_row(&node);
        self.nodes.insert(row);
        node.id
    }

    pub fn node(&mut self, id: NodeId) -> Result<Node, DbError> {
        self.stats.selects += 1;
        self.nodes
            .iter()
            .find(|(_, r)| r.get("nodeId").and_then(Value::as_i64) == Some(id as i64))
            .map(|(_, r)| node_from_row(r))
            .ok_or(DbError::NodeNotFound(id))?
    }

    pub fn all_nodes(&mut self) -> Vec<Node> {
        self.stats.selects += 1;
        self.nodes
            .iter()
            .filter_map(|(_, r)| node_from_row(r).ok())
            .collect()
    }

    pub fn alive_nodes(&mut self) -> Vec<Node> {
        self.stats.selects += 1;
        self.nodes
            .iter()
            .filter_map(|(_, r)| node_from_row(r).ok())
            .filter(Node::is_alive)
            .collect()
    }

    pub fn set_node_state(&mut self, id: NodeId, state: NodeState) -> Result<(), DbError> {
        self.stats.updates += 1;
        let row = self
            .nodes
            .iter()
            .find(|(_, r)| r.get("nodeId").and_then(Value::as_i64) == Some(id as i64))
            .map(|(rid, _)| *rid)
            .ok_or(DbError::NodeNotFound(id))?;
        let row = self.nodes.get_mut(row).unwrap();
        row.insert("state".into(), Value::Text(state.as_str().into()));
        Ok(())
    }

    /// Nodes whose property row matches a job's `properties` expression —
    /// the SQL resource-matching path ("using the rich expressive power of
    /// sql queries", §2). One SELECT per call.
    pub fn matching_nodes(&mut self, properties: &str) -> Result<Vec<Node>, DbError> {
        self.stats.selects += 1;
        let expr = Expr::parse(properties).map_err(|e| DbError::Parse(e.to_string()))?;
        Ok(self
            .nodes
            .iter()
            .filter_map(|(_, r)| node_from_row(r).ok())
            .filter(|n| n.is_alive() && expr.matches(&n.property_row()))
            .collect())
    }

    // --------------------------------------------------- assignments ----

    /// Record that `job` runs on `nodes` (`procs_per_node` each).
    pub fn assign_nodes(&mut self, job: JobId, nodes: &[NodeId], procs_per_node: u32) {
        for n in nodes {
            self.stats.inserts += 1;
            let mut row = Row::new();
            row.insert("jobId".into(), Value::Int(job as i64));
            row.insert("nodeId".into(), Value::Int(*n as i64));
            row.insert("procs".into(), Value::Int(procs_per_node as i64));
            self.assignments.insert(row);
        }
    }

    pub fn assigned_nodes(&mut self, job: JobId) -> Vec<NodeId> {
        self.stats.selects += 1;
        self.assignments
            .iter()
            .filter(|(_, r)| r.get("jobId").and_then(Value::as_i64) == Some(job as i64))
            .filter_map(|(_, r)| r.get("nodeId").and_then(Value::as_i64))
            .map(|n| n as NodeId)
            .collect()
    }

    /// Busy processors per node, derived from assignments of live jobs.
    pub fn busy_procs_by_node(&mut self) -> BTreeMap<NodeId, u32> {
        self.stats.selects += 2; // join over jobs + assignments
        let live: Vec<JobId> = self
            .jobs
            .iter()
            .filter(|(_, r)| {
                r.get("state")
                    .and_then(Value::as_str)
                    .and_then(JobState::parse)
                    .map(JobState::holds_resources)
                    .unwrap_or(false)
            })
            .map(|(id, _)| *id)
            .collect();
        let mut busy = BTreeMap::new();
        for (_, r) in self.assignments.iter() {
            let jid = r.get("jobId").and_then(Value::as_i64).unwrap_or(-1) as JobId;
            if live.contains(&jid) {
                let nid = r.get("nodeId").and_then(Value::as_i64).unwrap_or(-1) as NodeId;
                let procs = r.get("procs").and_then(Value::as_i64).unwrap_or(0) as u32;
                *busy.entry(nid).or_insert(0) += procs;
            }
        }
        busy
    }

    // -------------------------------------------------------- queues ----

    pub fn add_queue(&mut self, q: Queue) {
        self.stats.inserts += 1;
        let mut row = Row::new();
        row.insert("name".into(), Value::Text(q.name.clone()));
        row.insert("priority".into(), Value::Int(q.priority as i64));
        row.insert("policy".into(), Value::Text(q.policy.as_str().into()));
        row.insert("defaultMaxTime".into(), Value::Int(q.default_max_time));
        row.insert(
            "maxProcsPerJob".into(),
            Value::Int(q.max_procs_per_job as i64),
        );
        row.insert("active".into(), Value::Bool(q.active));
        self.queues.insert(row);
    }

    pub fn queue(&mut self, name: &str) -> Result<Queue, DbError> {
        self.stats.selects += 1;
        self.queues
            .iter()
            .find(|(_, r)| r.get("name").and_then(Value::as_str) == Some(name))
            .map(|(_, r)| queue_from_row(r))
            .ok_or_else(|| DbError::QueueNotFound(name.into()))?
    }

    /// All queues by decreasing priority — the meta-scheduler's iteration
    /// order (§2.3).
    pub fn queues_by_priority(&mut self) -> Vec<Queue> {
        self.stats.selects += 1;
        let mut qs: Vec<Queue> = self
            .queues
            .iter()
            .filter_map(|(_, r)| queue_from_row(r).ok())
            .collect();
        qs.sort_by(|a, b| b.priority.cmp(&a.priority).then(a.name.cmp(&b.name)));
        qs
    }

    pub fn set_queue_active(&mut self, name: &str, active: bool) -> Result<(), DbError> {
        self.stats.updates += 1;
        let e = Expr::parse(&format!("name = '{name}'")).unwrap();
        if self.queues.update_where(&e, "active", Value::Bool(active)) == 0 {
            return Err(DbError::QueueNotFound(name.into()));
        }
        Ok(())
    }

    // ----------------------------------------------- admission rules ----

    /// Store an admission rule (rule-DSL source, see [`crate::admission`]).
    pub fn add_admission_rule(&mut self, priority: i32, source: &str) {
        self.stats.inserts += 1;
        let mut row = Row::new();
        row.insert("priority".into(), Value::Int(priority as i64));
        row.insert("source".into(), Value::Text(source.into()));
        self.admission_rules.insert(row);
    }

    /// Rules in priority order (ascending: lower runs first).
    pub fn admission_rules(&mut self) -> Vec<(i32, String)> {
        self.stats.selects += 1;
        let mut rules: Vec<(i32, String)> = self
            .admission_rules
            .iter()
            .filter_map(|(_, r)| {
                Some((
                    r.get("priority")?.as_i64()? as i32,
                    r.get("source")?.as_str()?.to_string(),
                ))
            })
            .collect();
        rules.sort_by_key(|(p, _)| *p);
        rules
    }

    // -------------------------------------------------------- events ----

    pub fn log_event(&mut self, now: Time, kind: &str, job: Option<JobId>, detail: &str) {
        self.stats.inserts += 1;
        self.events.append(EventRecord {
            time: now,
            kind: kind.into(),
            job,
            detail: detail.into(),
        });
    }

    pub fn events(&mut self) -> &[EventRecord] {
        self.stats.selects += 1;
        self.events.all()
    }

    // --------------------------------------------------- persistence ----

    /// Snapshot the entire database to JSON — the paper's §2 argument that
    /// "the database engine can handle the data safety" as long as modules
    /// make atomic coherent modifications.
    pub fn snapshot(&self, path: &Path) -> crate::Result<()> {
        use crate::util::Json;
        let doc = Json::obj(vec![
            ("jobs", self.jobs.to_json()),
            ("nodes", self.nodes.to_json()),
            ("assignments", self.assignments.to_json()),
            ("queues", self.queues.to_json()),
            ("admission_rules", self.admission_rules.to_json()),
            ("events", self.events.to_json()),
        ]);
        std::fs::write(path, doc.dump())?;
        Ok(())
    }

    pub fn restore(path: &Path) -> crate::Result<Db> {
        use crate::util::Json;
        let text = std::fs::read_to_string(path)?;
        let doc = Json::parse(&text)?;
        let table = |key: &str| -> crate::Result<Table> {
            Table::from_json(
                doc.get(key)
                    .ok_or_else(|| anyhow::anyhow!("snapshot missing {key}"))?,
            )
        };
        Ok(Db {
            jobs: table("jobs")?,
            nodes: table("nodes")?,
            assignments: table("assignments")?,
            queues: table("queues")?,
            admission_rules: table("admission_rules")?,
            events: EventLog::from_json(
                doc.get("events")
                    .ok_or_else(|| anyhow::anyhow!("snapshot missing events"))?,
            )?,
            stats: QueryStats::default(),
        })
    }
}

// ----------------------------------------------------- row conversion ----

fn job_to_row(job: &Job) -> Row {
    let mut r = Row::new();
    r.insert("jobType".into(), Value::Text(job.kind.as_str().into()));
    r.insert(
        "infoType".into(),
        job.info_type
            .clone()
            .map(Value::Text)
            .unwrap_or(Value::Null),
    );
    r.insert("state".into(), Value::Text(job.state.as_str().into()));
    r.insert(
        "reservation".into(),
        Value::Text(job.reservation.as_str().into()),
    );
    r.insert("message".into(), Value::Text(job.message.clone()));
    r.insert("user".into(), Value::Text(job.user.clone()));
    r.insert("nbNodes".into(), Value::Int(job.nb_nodes as i64));
    r.insert("weight".into(), Value::Int(job.weight as i64));
    r.insert("command".into(), Value::Text(job.command.clone()));
    r.insert(
        "bpid".into(),
        job.bpid.map(|p| Value::Int(p as i64)).unwrap_or(Value::Null),
    );
    r.insert("queueName".into(), Value::Text(job.queue_name.clone()));
    r.insert("maxTime".into(), Value::Int(job.max_time));
    r.insert("properties".into(), Value::Text(job.properties.clone()));
    r.insert(
        "launchingDirectory".into(),
        Value::Text(job.launching_directory.clone()),
    );
    r.insert("submissionTime".into(), Value::Int(job.submission_time));
    r.insert(
        "startTime".into(),
        job.start_time.map(Value::Int).unwrap_or(Value::Null),
    );
    r.insert(
        "stopTime".into(),
        job.stop_time.map(Value::Int).unwrap_or(Value::Null),
    );
    r.insert("bestEffort".into(), Value::Bool(job.best_effort));
    r.insert(
        "reservationStart".into(),
        job.reservation_start.map(Value::Int).unwrap_or(Value::Null),
    );
    r
}

fn job_from_row(r: &Row) -> Result<Job, DbError> {
    let corrupt = |f: &str| DbError::Corrupt(format!("jobs.{f}"));
    Ok(Job {
        id: r.get("id").and_then(Value::as_i64).ok_or_else(|| corrupt("id"))? as JobId,
        kind: match r.get("jobType").and_then(Value::as_str) {
            Some("INTERACTIVE") => JobKind::Interactive,
            _ => JobKind::Passive,
        },
        info_type: r
            .get("infoType")
            .and_then(Value::as_str)
            .map(str::to_string),
        state: r
            .get("state")
            .and_then(Value::as_str)
            .and_then(JobState::parse)
            .ok_or_else(|| corrupt("state"))?,
        reservation: match r.get("reservation").and_then(Value::as_str) {
            Some("toSchedule") => ReservationField::ToSchedule,
            Some("Scheduled") => ReservationField::Scheduled,
            _ => ReservationField::None,
        },
        message: r
            .get("message")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        user: r
            .get("user")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        nb_nodes: r.get("nbNodes").and_then(Value::as_i64).unwrap_or(1) as u32,
        weight: r.get("weight").and_then(Value::as_i64).unwrap_or(1) as u32,
        command: r
            .get("command")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        bpid: r.get("bpid").and_then(Value::as_i64).map(|p| p as u32),
        queue_name: r
            .get("queueName")
            .and_then(Value::as_str)
            .unwrap_or("default")
            .to_string(),
        max_time: r.get("maxTime").and_then(Value::as_i64).unwrap_or(0),
        properties: r
            .get("properties")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        launching_directory: r
            .get("launchingDirectory")
            .and_then(Value::as_str)
            .unwrap_or("/tmp")
            .to_string(),
        submission_time: r
            .get("submissionTime")
            .and_then(Value::as_i64)
            .unwrap_or(0),
        start_time: r.get("startTime").and_then(Value::as_i64),
        stop_time: r.get("stopTime").and_then(Value::as_i64),
        best_effort: r
            .get("bestEffort")
            .map(Value::is_truthy)
            .unwrap_or(false),
        reservation_start: r.get("reservationStart").and_then(Value::as_i64),
    })
}

fn node_to_row(node: &Node) -> Row {
    let mut r = Row::new();
    r.insert("nodeId".into(), Value::Int(node.id as i64));
    r.insert("hostname".into(), Value::Text(node.hostname.clone()));
    r.insert("state".into(), Value::Text(node.state.as_str().into()));
    r.insert("nbProcs".into(), Value::Int(node.nb_procs as i64));
    for (k, v) in &node.properties {
        r.insert(format!("prop_{k}"), v.clone());
    }
    r
}

fn node_from_row(r: &Row) -> Result<Node, DbError> {
    let corrupt = |f: &str| DbError::Corrupt(format!("nodes.{f}"));
    let mut properties = BTreeMap::new();
    for (k, v) in r.iter() {
        if let Some(name) = k.strip_prefix("prop_") {
            properties.insert(name.to_string(), v.clone());
        }
    }
    Ok(Node {
        id: r
            .get("nodeId")
            .and_then(Value::as_i64)
            .ok_or_else(|| corrupt("nodeId"))? as NodeId,
        hostname: r
            .get("hostname")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string(),
        state: match r.get("state").and_then(Value::as_str) {
            Some("Alive") => NodeState::Alive,
            Some("Suspected") => NodeState::Suspected,
            Some("Absent") => NodeState::Absent,
            _ => return Err(corrupt("state")),
        },
        nb_procs: r.get("nbProcs").and_then(Value::as_i64).unwrap_or(1) as u32,
        properties,
    })
}

fn queue_from_row(r: &Row) -> Result<Queue, DbError> {
    let corrupt = |f: &str| DbError::Corrupt(format!("queues.{f}"));
    Ok(Queue {
        name: r
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| corrupt("name"))?
            .to_string(),
        priority: r.get("priority").and_then(Value::as_i64).unwrap_or(0) as i32,
        policy: r
            .get("policy")
            .and_then(Value::as_str)
            .and_then(QueuePolicyKind::parse)
            .ok_or_else(|| corrupt("policy"))?,
        default_max_time: r
            .get("defaultMaxTime")
            .and_then(Value::as_i64)
            .unwrap_or(3600),
        max_procs_per_job: r
            .get("maxProcsPerJob")
            .and_then(Value::as_i64)
            .unwrap_or(i64::MAX) as u32,
        active: r.get("active").map(Value::is_truthy).unwrap_or(true),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::JobSpec;

    fn make_job(spec: &JobSpec, now: Time) -> Job {
        Job::from_spec(spec, now)
    }

    #[test]
    fn job_roundtrip_through_rows() {
        let mut db = Db::with_standard_queues();
        let spec = JobSpec::batch("alice", "echo hi", 4, 600);
        let id = db.insert_job(make_job(&spec, 42));
        let job = db.job(id).unwrap();
        assert_eq!(job.id, id);
        assert_eq!(job.user, "alice");
        assert_eq!(job.nb_nodes, 4);
        assert_eq!(job.state, JobState::Waiting);
        assert_eq!(job.submission_time, 42);
    }

    #[test]
    fn state_transitions_are_validated() {
        let mut db = Db::with_standard_queues();
        let id = db.insert_job(make_job(&JobSpec::default(), 0));
        // Waiting -> Running is illegal (must pass through toLaunch).
        let err = db.set_job_state(id, JobState::Running, 1).unwrap_err();
        assert!(matches!(err, DbError::IllegalTransition { .. }));
        db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
        db.set_job_state(id, JobState::Launching, 2).unwrap();
        db.set_job_state(id, JobState::Running, 3).unwrap();
        db.set_job_state(id, JobState::Terminated, 9).unwrap();
        let job = db.job(id).unwrap();
        assert_eq!(job.start_time, Some(3));
        assert_eq!(job.stop_time, Some(9));
        assert_eq!(job.response_time(), Some(9));
    }

    #[test]
    fn fail_job_reaches_error_from_any_state() {
        let mut db = Db::with_standard_queues();
        let id = db.insert_job(make_job(&JobSpec::default(), 0));
        db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
        db.fail_job(id, "node died", 2).unwrap();
        let job = db.job(id).unwrap();
        assert_eq!(job.state, JobState::Error);
        assert_eq!(job.message, "node died");
        // idempotent on terminal jobs
        db.fail_job(id, "again", 3).unwrap();
    }

    #[test]
    fn matching_nodes_uses_expressions() {
        let mut db = Db::new();
        db.add_node(Node::new(1, "n1", 2).with_prop("mem", Value::Int(256)));
        db.add_node(Node::new(2, "n2", 2).with_prop("mem", Value::Int(1024)));
        let got = db.matching_nodes("mem >= 512").unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 2);
        // empty properties match all alive nodes
        assert_eq!(db.matching_nodes("").unwrap().len(), 2);
        // suspected nodes never match
        db.set_node_state(2, NodeState::Suspected).unwrap();
        assert!(db.matching_nodes("mem >= 512").unwrap().is_empty());
    }

    #[test]
    fn assignments_and_busy_procs() {
        let mut db = Db::with_standard_queues();
        db.add_node(Node::new(1, "n1", 2));
        db.add_node(Node::new(2, "n2", 2));
        let id = db.insert_job(make_job(&JobSpec::batch("u", "c", 2, 60), 0));
        db.assign_nodes(id, &[1, 2], 1);
        db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
        let busy = db.busy_procs_by_node();
        assert_eq!(busy[&1], 1);
        assert_eq!(busy[&2], 1);
        // After termination the procs are free again.
        db.set_job_state(id, JobState::Launching, 2).unwrap();
        db.set_job_state(id, JobState::Running, 2).unwrap();
        db.set_job_state(id, JobState::Terminated, 3).unwrap();
        assert!(db.busy_procs_by_node().is_empty());
    }

    #[test]
    fn queues_by_priority_order() {
        let mut db = Db::with_standard_queues();
        db.add_queue(Queue::new("urgent", 100, QueuePolicyKind::FifoConservative));
        let qs = db.queues_by_priority();
        assert_eq!(qs[0].name, "urgent");
        assert_eq!(qs.last().unwrap().name, "besteffort");
    }

    #[test]
    fn query_stats_count_statements() {
        let mut db = Db::with_standard_queues();
        db.reset_stats();
        let id = db.insert_job(make_job(&JobSpec::default(), 0));
        let _ = db.job(id);
        db.set_job_state(id, JobState::ToLaunch, 1).unwrap();
        let s = db.stats();
        assert_eq!(s.inserts, 1);
        assert!(s.selects >= 2);
        assert_eq!(s.updates, 1);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let dir = std::env::temp_dir().join("oar_db_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("snap.json");
        let mut db = Db::with_standard_queues();
        let id = db.insert_job(make_job(&JobSpec::batch("bob", "x", 1, 10), 5));
        db.snapshot(&path).unwrap();
        let mut back = Db::restore(&path).unwrap();
        assert_eq!(back.job(id).unwrap().user, "bob");
        assert_eq!(back.queues_by_priority().len(), 2);
        std::fs::remove_file(path).ok();
    }
}
