//! Predicate pushdown: turn the sargable part of a WHERE expression into
//! index probes.
//!
//! The planner walks an [`Expr`]'s top-level `AND` chain and extracts
//! every conjunct an index could answer — equalities, ranges, `BETWEEN`
//! and (non-negated) `IN` over `column OP literal` shapes. The table then
//! scores each candidate against its secondary indexes and drives the
//! query off the most selective one, re-checking the *full* original
//! expression on every candidate row (residual filtering). That makes
//! correctness local: a probe only has to be a *superset* of the matching
//! rows, never an exact answer, so `OR`, `LIKE`, `NOT`, arithmetic and
//! columns without indexes all work unchanged — they just scan.
//!
//! [`QueryPlan`] is the `EXPLAIN` surface: which access path a filter
//! would take and how many rows it would touch.

use std::ops::Bound;

use super::expr::{CmpOp, Expr};
use super::index::IndexKey;
use super::value::Value;

/// How a statement's WHERE clause fetches its candidate rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    /// Single-key probe of a secondary index (`col = literal`).
    IndexEq,
    /// Union of single-key probes (`col IN (...)`).
    IndexIn,
    /// Ordered walk of a key range (`<`, `<=`, `>`, `>=`, `BETWEEN`).
    IndexRange,
    /// No usable index: every row is visited.
    FullScan,
    /// Answered from a materialized view: no base-table row is touched.
    ViewHit,
}

impl PlanKind {
    pub fn as_str(self) -> &'static str {
        match self {
            PlanKind::IndexEq => "index_eq",
            PlanKind::IndexIn => "index_in",
            PlanKind::IndexRange => "index_range",
            PlanKind::FullScan => "full_scan",
            PlanKind::ViewHit => "view_hit",
        }
    }
}

/// `EXPLAIN` output: the access path chosen for one WHERE clause.
#[derive(Debug, Clone)]
pub struct QueryPlan {
    pub kind: PlanKind,
    /// Index column driving the plan (`None` for full scans).
    pub column: Option<String>,
    /// Rows the access path will touch (table size for full scans).
    pub estimated_rows: usize,
}

/// One sargable conjunct: a single-column constraint an index can answer.
#[derive(Debug, Clone)]
pub(crate) enum Sarg {
    /// `col = literal` (also `literal = col`).
    Eq(String, Value),
    /// `col IN (v1, v2, ...)`, non-negated.
    In(String, Vec<Value>),
    /// `col` inside a key range (from `<`/`<=`/`>`/`>=`/`BETWEEN`).
    Range(String, Bound<IndexKey>, Bound<IndexKey>),
}

impl Sarg {
    pub(crate) fn column(&self) -> &str {
        match self {
            Sarg::Eq(c, _) | Sarg::In(c, _) | Sarg::Range(c, _, _) => c,
        }
    }

    pub(crate) fn kind(&self) -> PlanKind {
        match self {
            Sarg::Eq(_, _) => PlanKind::IndexEq,
            Sarg::In(_, _) => PlanKind::IndexIn,
            Sarg::Range(_, _, _) => PlanKind::IndexRange,
        }
    }
}

/// Split `e` into its top-level AND conjuncts.
fn conjuncts<'e>(e: &'e Expr, out: &mut Vec<&'e Expr>) {
    if let Expr::And(a, b) = e {
        conjuncts(a, out);
        conjuncts(b, out);
    } else {
        out.push(e);
    }
}

/// `column OP literal` in either order (flipping the operator when the
/// literal is on the left).
fn col_op_lit(op: CmpOp, a: &Expr, b: &Expr) -> Option<(String, CmpOp, Value)> {
    match (a, b) {
        (Expr::Column(c), Expr::Literal(v)) => Some((c.clone(), op, v.clone())),
        (Expr::Literal(v), Expr::Column(c)) => {
            let flipped = match op {
                CmpOp::Lt => CmpOp::Gt,
                CmpOp::Le => CmpOp::Ge,
                CmpOp::Gt => CmpOp::Lt,
                CmpOp::Ge => CmpOp::Le,
                other => other,
            };
            Some((c.clone(), flipped, v.clone()))
        }
        _ => None,
    }
}

/// Key-range form of `col OP v`, staying inside the value's key space
/// (see [`super::index`]: numbers and text never compare across spaces).
fn range_of(op: CmpOp, v: &Value) -> Option<(Bound<IndexKey>, Bound<IndexKey>)> {
    let key = IndexKey::of(v)?;
    let (space_min, space_max) = match key {
        IndexKey::Num(_) => (
            Bound::Included(IndexKey::num_min()),
            Bound::Included(IndexKey::num_max()),
        ),
        IndexKey::Text(_) => (Bound::Included(IndexKey::text_min()), Bound::Unbounded),
    };
    Some(match op {
        CmpOp::Lt => (space_min, Bound::Excluded(key)),
        CmpOp::Le => (space_min, Bound::Included(key)),
        CmpOp::Gt => (Bound::Excluded(key), space_max),
        CmpOp::Ge => (Bound::Included(key), space_max),
        CmpOp::Eq | CmpOp::Ne => return None, // Eq handled separately; Ne unsargable
    })
}

/// Every sargable conjunct of `e`. The caller is responsible for residual
/// filtering: these are candidate *supersets* per conjunct, not the query
/// answer.
pub(crate) fn sargs(e: &Expr) -> Vec<Sarg> {
    let mut parts = Vec::new();
    conjuncts(e, &mut parts);
    let mut out = Vec::new();
    for part in parts {
        match part {
            Expr::Cmp(op, a, b) => {
                if let Some((col, op, v)) = col_op_lit(*op, a, b) {
                    if op == CmpOp::Eq {
                        // `col = NULL` is never true: Eq with an
                        // unindexable key probes to the empty set, which
                        // is exact here.
                        out.push(Sarg::Eq(col, v));
                    } else if let Some((lo, hi)) = range_of(op, &v) {
                        out.push(Sarg::Range(col, lo, hi));
                    }
                }
            }
            Expr::Between(a, lo, hi) => {
                if let (Expr::Column(c), Expr::Literal(l), Expr::Literal(h)) =
                    (&**a, &**lo, &**hi)
                {
                    if let (Some(kl), Some(kh)) = (IndexKey::of(l), IndexKey::of(h)) {
                        // Mixed-space bounds (e.g. `BETWEEN 1 AND 'x'`)
                        // still yield a correct superset: the range is
                        // simply clamped by the tree order.
                        out.push(Sarg::Range(
                            c.clone(),
                            Bound::Included(kl),
                            Bound::Included(kh),
                        ));
                    }
                }
            }
            Expr::In(a, items, false) => {
                if let Expr::Column(c) = &**a {
                    out.push(Sarg::In(c.clone(), items.clone()));
                }
            }
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Expr {
        Expr::parse(s).unwrap()
    }

    #[test]
    fn extracts_equalities_from_and_chains() {
        let got = sargs(&parse("state = 'Waiting' AND queueName = 'default'"));
        assert_eq!(got.len(), 2);
        assert!(matches!(&got[0], Sarg::Eq(c, Value::Text(v)) if c == "state" && v == "Waiting"));
        assert!(
            matches!(&got[1], Sarg::Eq(c, Value::Text(v)) if c == "queueName" && v == "default")
        );
    }

    #[test]
    fn flips_literal_on_the_left() {
        let got = sargs(&parse("512 <= mem"));
        assert_eq!(got.len(), 1);
        match &got[0] {
            Sarg::Range(c, lo, hi) => {
                assert_eq!(c, "mem");
                assert_eq!(*lo, Bound::Included(IndexKey::of(&Value::Int(512)).unwrap()));
                assert_eq!(*hi, Bound::Included(IndexKey::num_max()));
            }
            other => panic!("expected range, got {other:?}"),
        }
    }

    #[test]
    fn between_and_in_are_sargable() {
        let got = sargs(&parse("mem BETWEEN 256 AND 512 AND switch IN ('sw1', 'sw2')"));
        assert_eq!(got.len(), 2);
        assert!(matches!(&got[0], Sarg::Range(c, _, _) if c == "mem"));
        assert!(matches!(&got[1], Sarg::In(c, items) if c == "switch" && items.len() == 2));
    }

    #[test]
    fn disjunctions_and_negations_yield_nothing() {
        assert!(sargs(&parse("a = 1 OR b = 2")).is_empty());
        assert!(sargs(&parse("NOT a = 1")).is_empty());
        assert!(sargs(&parse("a != 1")).is_empty());
        assert!(sargs(&parse("switch NOT IN ('sw1')")).is_empty());
        assert!(sargs(&parse("")).is_empty());
        assert!(sargs(&parse("a + b = 3")).is_empty());
    }

    #[test]
    fn mixed_conjunction_keeps_the_sargable_part() {
        let got = sargs(&parse("state = 'Waiting' AND (a = 1 OR b = 2) AND mem > 10"));
        assert_eq!(got.len(), 2);
        assert!(matches!(&got[0], Sarg::Eq(c, _) if c == "state"));
        assert!(matches!(&got[1], Sarg::Range(c, _, _) if c == "mem"));
    }
}
