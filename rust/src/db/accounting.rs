//! Accounting views over the jobs table — the "friendly and powerfull data
//! analysis and extraction" the paper buys by using a real database (§1).
//! These are the canned reports `oarstat --accounting` exposes.

use std::collections::BTreeMap;


use crate::types::{Job, JobState, Time};

/// Per-user consumption summary.
#[derive(Debug, Clone, Default)]
pub struct UserUsage {
    pub jobs_submitted: usize,
    pub jobs_terminated: usize,
    pub jobs_error: usize,
    /// Σ (stopTime − startTime) · procs over completed jobs: CPU·seconds.
    pub cpu_seconds: i64,
    /// Σ wait time (startTime − submissionTime) over started jobs.
    pub total_wait: i64,
}

/// Aggregated accounting over a set of job rows.
#[derive(Debug, Clone, Default)]
pub struct Accounting {
    pub by_user: BTreeMap<String, UserUsage>,
    pub by_queue: BTreeMap<String, usize>,
    pub total_cpu_seconds: i64,
    /// Mean response time (stop − submission) over terminated jobs.
    pub mean_response_time: f64,
}

impl Accounting {
    /// Build the report from job rows (typically `db.jobs_where(TRUE)`).
    /// `Db::accounting` computes the same report in one zero-copy pass
    /// over the raw rows through [`AccountingBuilder`].
    pub fn compute(jobs: &[Job]) -> Accounting {
        let mut b = AccountingBuilder::new();
        for j in jobs {
            b.add(
                &j.user,
                &j.queue_name,
                j.state,
                j.submission_time,
                j.start_time,
                j.stop_time,
                j.total_procs(),
            );
        }
        b.finish()
    }
}

/// Streaming accumulator behind [`Accounting::compute`]: takes one job's
/// raw cells at a time, so the database can feed it straight from the
/// stored rows without materializing `Job` values.
#[derive(Debug, Clone, Default)]
pub struct AccountingBuilder {
    acc: Accounting,
    resp_sum: i64,
    resp_n: i64,
}

impl AccountingBuilder {
    pub fn new() -> AccountingBuilder {
        AccountingBuilder::default()
    }

    /// Fold one job into the report.
    pub fn add(
        &mut self,
        user: &str,
        queue: &str,
        state: JobState,
        submission: Time,
        start: Option<Time>,
        stop: Option<Time>,
        procs: u32,
    ) {
        let u = self.acc.by_user.entry(user.to_string()).or_default();
        u.jobs_submitted += 1;
        *self.acc.by_queue.entry(queue.to_string()).or_default() += 1;
        match state {
            JobState::Terminated => {
                u.jobs_terminated += 1;
                if let (Some(start), Some(stop)) = (start, stop) {
                    let cpu = (stop - start) * procs as Time;
                    u.cpu_seconds += cpu;
                    self.acc.total_cpu_seconds += cpu;
                }
                if let Some(stop) = stop {
                    self.resp_sum += stop - submission;
                    self.resp_n += 1;
                }
            }
            JobState::Error => u.jobs_error += 1,
            _ => {}
        }
        if let Some(start) = start {
            u.total_wait += start - submission;
        }
    }

    pub fn finish(mut self) -> Accounting {
        self.acc.mean_response_time = if self.resp_n > 0 {
            self.resp_sum as f64 / self.resp_n as f64
        } else {
            0.0
        };
        self.acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{JobKind, ReservationField};

    fn job(user: &str, state: JobState, sub: Time, start: Option<Time>, stop: Option<Time>, procs: u32) -> Job {
        Job {
            id: 0,
            kind: JobKind::Passive,
            info_type: None,
            state,
            reservation: ReservationField::None,
            message: String::new(),
            user: user.into(),
            nb_nodes: procs,
            weight: 1,
            command: String::new(),
            bpid: None,
            queue_name: "default".into(),
            max_time: 100,
            properties: String::new(),
            launching_directory: String::new(),
            submission_time: sub,
            start_time: start,
            stop_time: stop,
            best_effort: false,
            reservation_start: None,
            resources: None,
        }
    }

    #[test]
    fn aggregates_per_user_and_total() {
        let jobs = vec![
            job("a", JobState::Terminated, 0, Some(10), Some(110), 2),
            job("a", JobState::Error, 0, None, Some(5), 1),
            job("b", JobState::Terminated, 50, Some(60), Some(70), 4),
            job("b", JobState::Waiting, 100, None, None, 1),
        ];
        let acc = Accounting::compute(&jobs);
        assert_eq!(acc.by_user["a"].jobs_submitted, 2);
        assert_eq!(acc.by_user["a"].jobs_terminated, 1);
        assert_eq!(acc.by_user["a"].jobs_error, 1);
        assert_eq!(acc.by_user["a"].cpu_seconds, 200);
        assert_eq!(acc.by_user["b"].cpu_seconds, 40);
        assert_eq!(acc.total_cpu_seconds, 240);
        // responses: (110-0)=110 and (70-50)=20 -> mean 65
        assert_eq!(acc.mean_response_time, 65.0);
        assert_eq!(acc.by_queue["default"], 4);
    }
}
