//! Accounting views over the jobs table — the "friendly and powerfull data
//! analysis and extraction" the paper buys by using a real database (§1).
//! These are the canned reports `oarstat --accounting` exposes.

use std::collections::BTreeMap;


use crate::types::{Job, JobState, Time};

/// Per-user consumption summary.
#[derive(Debug, Clone, Default)]
pub struct UserUsage {
    pub jobs_submitted: usize,
    pub jobs_terminated: usize,
    pub jobs_error: usize,
    /// Σ (stopTime − startTime) · procs over completed jobs: CPU·seconds.
    pub cpu_seconds: i64,
    /// Σ wait time (startTime − submissionTime) over started jobs.
    pub total_wait: i64,
}

/// Aggregated accounting over a set of job rows.
#[derive(Debug, Clone, Default)]
pub struct Accounting {
    pub by_user: BTreeMap<String, UserUsage>,
    pub by_queue: BTreeMap<String, usize>,
    pub total_cpu_seconds: i64,
    /// Mean response time (stop − submission) over terminated jobs.
    pub mean_response_time: f64,
}

impl Accounting {
    /// Build the report from job rows (typically `db.jobs_where(TRUE)`).
    pub fn compute(jobs: &[Job]) -> Accounting {
        let mut acc = Accounting::default();
        let mut resp_sum: i64 = 0;
        let mut resp_n: i64 = 0;
        for j in jobs {
            let u = acc.by_user.entry(j.user.clone()).or_default();
            u.jobs_submitted += 1;
            *acc.by_queue.entry(j.queue_name.clone()).or_default() += 1;
            match j.state {
                JobState::Terminated => {
                    u.jobs_terminated += 1;
                    if let (Some(start), Some(stop)) = (j.start_time, j.stop_time) {
                        let cpu = (stop - start) * j.total_procs() as Time;
                        u.cpu_seconds += cpu;
                        acc.total_cpu_seconds += cpu;
                    }
                    if let Some(r) = j.response_time() {
                        resp_sum += r;
                        resp_n += 1;
                    }
                }
                JobState::Error => u.jobs_error += 1,
                _ => {}
            }
            if let Some(w) = j.wait_time() {
                u.total_wait += w;
            }
        }
        acc.mean_response_time = if resp_n > 0 {
            resp_sum as f64 / resp_n as f64
        } else {
            0.0
        };
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::{JobKind, ReservationField};

    fn job(user: &str, state: JobState, sub: Time, start: Option<Time>, stop: Option<Time>, procs: u32) -> Job {
        Job {
            id: 0,
            kind: JobKind::Passive,
            info_type: None,
            state,
            reservation: ReservationField::None,
            message: String::new(),
            user: user.into(),
            nb_nodes: procs,
            weight: 1,
            command: String::new(),
            bpid: None,
            queue_name: "default".into(),
            max_time: 100,
            properties: String::new(),
            launching_directory: String::new(),
            submission_time: sub,
            start_time: start,
            stop_time: stop,
            best_effort: false,
            reservation_start: None,
        }
    }

    #[test]
    fn aggregates_per_user_and_total() {
        let jobs = vec![
            job("a", JobState::Terminated, 0, Some(10), Some(110), 2),
            job("a", JobState::Error, 0, None, Some(5), 1),
            job("b", JobState::Terminated, 50, Some(60), Some(70), 4),
            job("b", JobState::Waiting, 100, None, None, 1),
        ];
        let acc = Accounting::compute(&jobs);
        assert_eq!(acc.by_user["a"].jobs_submitted, 2);
        assert_eq!(acc.by_user["a"].jobs_terminated, 1);
        assert_eq!(acc.by_user["a"].jobs_error, 1);
        assert_eq!(acc.by_user["a"].cpu_seconds, 200);
        assert_eq!(acc.by_user["b"].cpu_seconds, 40);
        assert_eq!(acc.total_cpu_seconds, 240);
        // responses: (110-0)=110 and (70-50)=20 -> mean 65
        assert_eq!(acc.mean_response_time, 65.0);
        assert_eq!(acc.by_queue["default"], 4);
    }
}
