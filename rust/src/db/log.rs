//! Event log: the paper's logging requirement ("user-friendly logging
//! information analysis" is one of the four user needs of §1; a module
//! handles "errors logging" in §2). Events are rows too, so the same
//! query machinery analyzes them.
//!
//! The log is **bounded**: a long-running server logs an event per job
//! transition forever, so the in-memory window keeps only the most
//! recent [`EventLog::retention`] records (default
//! [`DEFAULT_EVENT_RETENTION`]) and counts what it evicts
//! ([`EventLog::evicted`], exposed as `oar_db_events_evicted_total`).
//! Durability is unaffected: every event still reaches the WAL as a
//! `LogEvent` mutation before it is applied, and replay drives eviction
//! through this same `append`, so a recovered log converges to the same
//! window a crash-free run would hold. Eviction is oldest-first and a
//! pure function of the append sequence and the cap — deterministic.

use crate::types::{JobId, Time};

/// Default retention cap (records). At ~5 events per job lifecycle this
/// keeps the last few thousand jobs' history resident.
pub const DEFAULT_EVENT_RETENTION: usize = 16_384;

/// One logged event.
#[derive(Debug, Clone)]
pub struct EventRecord {
    pub time: Time,
    /// Event kind, e.g. `SUBMISSION`, `SCHEDULED`, `LAUNCH`, `TERMINATED`,
    /// `ERROR`, `BESTEFFORT_KILL`, `NODE_SUSPECTED`, `SCHEDULER_ROUND`.
    pub kind: String,
    pub job: Option<JobId>,
    pub detail: String,
}

/// Bounded event log: append-only in order, evicting oldest-first past
/// the retention cap.
///
/// Storage is a `Vec` plus a `start` cursor: eviction advances the
/// cursor (O(1)) and the backing vector is compacted once the dead
/// prefix reaches the cap, so an append is amortized O(1) and the
/// buffer never holds more than two caps of records — while
/// [`EventLog::all`] stays a plain slice.
#[derive(Debug, Clone)]
pub struct EventLog {
    records: Vec<EventRecord>,
    /// Index of the oldest live record in `records`.
    start: usize,
    cap: usize,
    evicted: u64,
}

impl Default for EventLog {
    fn default() -> EventLog {
        EventLog {
            records: Vec::new(),
            start: 0,
            cap: DEFAULT_EVENT_RETENTION,
            evicted: 0,
        }
    }
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog::default()
    }

    pub fn append(&mut self, rec: EventRecord) {
        self.records.push(rec);
        self.enforce();
    }

    fn enforce(&mut self) {
        while self.records.len() - self.start > self.cap {
            self.start += 1;
            self.evicted += 1;
        }
        // Compact once the dead prefix is as large as the window can
        // be: one O(cap) drain per cap evictions.
        if self.start > self.cap.max(1) {
            self.records.drain(..self.start);
            self.start = 0;
        }
    }

    /// Change the retention cap. Takes effect immediately (a shrink
    /// evicts down to the new cap) and for all subsequent appends —
    /// including WAL replay, so a recovered server must be configured
    /// with the same cap to converge to the same window (the snapshot
    /// records the cap, see `Db::snapshot_doc`).
    pub fn set_retention(&mut self, cap: usize) {
        self.cap = cap;
        self.enforce();
    }

    /// The retention cap (records).
    pub fn retention(&self) -> usize {
        self.cap
    }

    /// Total records evicted by the cap over this log's lifetime.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// Restore the eviction tally when rebuilding from a snapshot (the
    /// in-window records travel in the snapshot; the tally of what was
    /// already gone must too, or recovery would zero the odometer).
    pub fn set_evicted_total(&mut self, evicted: u64) {
        self.evicted = evicted;
    }

    /// The live window, oldest first.
    pub fn all(&self) -> &[EventRecord] {
        &self.records[self.start..]
    }

    /// Live records (≤ the retention cap).
    pub fn len(&self) -> usize {
        self.records.len() - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events of one kind, in time order.
    pub fn of_kind(&self, kind: &str) -> Vec<&EventRecord> {
        self.all().iter().filter(|r| r.kind == kind).collect()
    }

    /// Events concerning one job.
    pub fn of_job(&self, job: JobId) -> Vec<&EventRecord> {
        self.all().iter().filter(|r| r.job == Some(job)).collect()
    }

    /// Events whose kind starts with `prefix` (e.g. `RECOVERY_` — the
    /// restart-reconciliation audit trail), in time order.
    pub fn of_kind_prefix(&self, prefix: &str) -> Vec<&EventRecord> {
        self.all()
            .iter()
            .filter(|r| r.kind.starts_with(prefix))
            .collect()
    }

    /// Snapshot encoding: the live window as a plain array (the cap and
    /// eviction tally are separate snapshot fields, so this shape is
    /// unchanged from the unbounded log).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::Arr(
            self.all()
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("t", Json::Num(r.time as f64)),
                        ("k", Json::Str(r.kind.clone())),
                        (
                            "j",
                            r.job.map(|j| Json::Num(j as f64)).unwrap_or(Json::Null),
                        ),
                        ("d", Json::Str(r.detail.clone())),
                    ])
                })
                .collect(),
        )
    }

    /// Decode the [`EventLog::to_json`] encoding.
    pub fn from_json(j: &crate::util::Json) -> crate::Result<EventLog> {
        use crate::util::Json;
        let mut log = EventLog::new();
        for item in j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("event log must be an array"))?
        {
            log.append(EventRecord {
                time: item.get("t").and_then(Json::as_i64).unwrap_or(0),
                kind: item
                    .get("k")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                job: item.get("j").and_then(Json::as_i64).map(|v| v as JobId),
                detail: item
                    .get("d")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
            });
        }
        Ok(log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: i64) -> EventRecord {
        EventRecord { time: i, kind: format!("K{}", i % 3), job: Some(i as JobId % 5), detail: String::new() }
    }

    #[test]
    fn filtering() {
        let mut log = EventLog::new();
        log.append(EventRecord { time: 1, kind: "SUBMISSION".into(), job: Some(1), detail: "".into() });
        log.append(EventRecord { time: 2, kind: "SCHEDULED".into(), job: Some(1), detail: "".into() });
        log.append(EventRecord { time: 3, kind: "SUBMISSION".into(), job: Some(2), detail: "".into() });
        assert_eq!(log.of_kind("SUBMISSION").len(), 2);
        assert_eq!(log.of_job(1).len(), 2);
        assert_eq!(log.len(), 3);
    }

    #[test]
    fn retention_cap_evicts_oldest_first_and_counts() {
        let mut log = EventLog::new();
        log.set_retention(10);
        for i in 0..35 {
            log.append(ev(i));
        }
        assert_eq!(log.len(), 10);
        assert_eq!(log.evicted(), 25);
        let times: Vec<i64> = log.all().iter().map(|r| r.time).collect();
        assert_eq!(times, (25..35).collect::<Vec<_>>());
        // The backing buffer is bounded too (compaction ran).
        assert!(log.records.len() <= 2 * 10 + 1, "buffer {} too large", log.records.len());
    }

    #[test]
    fn shrinking_the_cap_evicts_immediately() {
        let mut log = EventLog::new();
        for i in 0..8 {
            log.append(ev(i));
        }
        log.set_retention(3);
        assert_eq!(log.len(), 3);
        assert_eq!(log.evicted(), 5);
        assert_eq!(log.all()[0].time, 5);
        assert_eq!(log.retention(), 3);
    }

    #[test]
    fn eviction_is_a_pure_function_of_the_append_sequence() {
        // Same cap + same appends => same window and tally, regardless
        // of when compaction happened — the determinism WAL replay needs.
        let mut a = EventLog::new();
        let mut b = EventLog::new();
        a.set_retention(7);
        b.set_retention(7);
        for i in 0..100 {
            a.append(ev(i));
        }
        for i in 0..100 {
            b.append(ev(i));
        }
        assert_eq!(a.evicted(), b.evicted());
        let ta: Vec<i64> = a.all().iter().map(|r| r.time).collect();
        let tb: Vec<i64> = b.all().iter().map(|r| r.time).collect();
        assert_eq!(ta, tb);
    }

    #[test]
    fn zero_cap_keeps_nothing_but_counts_everything() {
        let mut log = EventLog::new();
        log.set_retention(0);
        for i in 0..5 {
            log.append(ev(i));
        }
        assert!(log.is_empty());
        assert_eq!(log.evicted(), 5);
    }

    #[test]
    fn json_roundtrip_preserves_window_shape() {
        let mut log = EventLog::new();
        log.set_retention(4);
        for i in 0..9 {
            log.append(ev(i));
        }
        let back = EventLog::from_json(&log.to_json()).unwrap();
        assert_eq!(back.len(), 4);
        let times: Vec<i64> = back.all().iter().map(|r| r.time).collect();
        assert_eq!(times, vec![5, 6, 7, 8]);
        // The tally is restored separately by the snapshot decoder.
        assert_eq!(back.evicted(), 0);
    }
}
