//! Event log: the paper's logging requirement ("user-friendly logging
//! information analysis" is one of the four user needs of §1; a module
//! handles "errors logging" in §2). Events are rows too, so the same
//! query machinery analyzes them.


use crate::types::{JobId, Time};

/// One logged event.
#[derive(Debug, Clone)]
pub struct EventRecord {
    pub time: Time,
    /// Event kind, e.g. `SUBMISSION`, `SCHEDULED`, `LAUNCH`, `TERMINATED`,
    /// `ERROR`, `BESTEFFORT_KILL`, `NODE_SUSPECTED`, `SCHEDULER_ROUND`.
    pub kind: String,
    pub job: Option<JobId>,
    pub detail: String,
}

/// Append-only event log.
#[derive(Debug, Clone, Default)]
pub struct EventLog {
    records: Vec<EventRecord>,
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog::default()
    }

    pub fn append(&mut self, rec: EventRecord) {
        self.records.push(rec);
    }

    pub fn all(&self) -> &[EventRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Events of one kind, in time order.
    pub fn of_kind(&self, kind: &str) -> Vec<&EventRecord> {
        self.records.iter().filter(|r| r.kind == kind).collect()
    }

    /// Events concerning one job.
    pub fn of_job(&self, job: JobId) -> Vec<&EventRecord> {
        self.records.iter().filter(|r| r.job == Some(job)).collect()
    }

    /// Events whose kind starts with `prefix` (e.g. `RECOVERY_` — the
    /// restart-reconciliation audit trail), in time order.
    pub fn of_kind_prefix(&self, prefix: &str) -> Vec<&EventRecord> {
        self.records
            .iter()
            .filter(|r| r.kind.starts_with(prefix))
            .collect()
    }

    /// Snapshot encoding.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::Arr(
            self.records
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("t", Json::Num(r.time as f64)),
                        ("k", Json::Str(r.kind.clone())),
                        (
                            "j",
                            r.job.map(|j| Json::Num(j as f64)).unwrap_or(Json::Null),
                        ),
                        ("d", Json::Str(r.detail.clone())),
                    ])
                })
                .collect(),
        )
    }

    /// Decode the [`EventLog::to_json`] encoding.
    pub fn from_json(j: &crate::util::Json) -> crate::Result<EventLog> {
        use crate::util::Json;
        let mut log = EventLog::new();
        for item in j
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("event log must be an array"))?
        {
            log.append(EventRecord {
                time: item.get("t").and_then(Json::as_i64).unwrap_or(0),
                kind: item
                    .get("k")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
                job: item.get("j").and_then(Json::as_i64).map(|v| v as JobId),
                detail: item
                    .get("d")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
            });
        }
        Ok(log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filtering() {
        let mut log = EventLog::new();
        log.append(EventRecord { time: 1, kind: "SUBMISSION".into(), job: Some(1), detail: "".into() });
        log.append(EventRecord { time: 2, kind: "SCHEDULED".into(), job: Some(1), detail: "".into() });
        log.append(EventRecord { time: 3, kind: "SUBMISSION".into(), job: Some(2), detail: "".into() });
        assert_eq!(log.of_kind("SUBMISSION").len(), 2);
        assert_eq!(log.of_job(1).len(), 2);
        assert_eq!(log.len(), 3);
    }
}
