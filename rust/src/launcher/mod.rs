//! The Taktuk-like parallel launcher (§2.4).
//!
//! "Launching, displaying and monitoring ... is performed using Taktuk ...
//! highly parallelized and distributed ... uses a dynamic work stealing
//! algorithm to distribute work among working nodes." Deployment therefore
//! proceeds as an adaptive tree: every already-reached node helps contact
//! the rest, so reaching `k` nodes costs ~`ceil(log2(k+1))` connection
//! rounds instead of `k` sequential connections.
//!
//! Failure detection is reachability-based: "any node that is not reached
//! by the time allowed for the initiation of the connection is considered
//! as failed" — a per-connection timeout, configurable to trade reactivity
//! against confidence (§2.4 last paragraph).
//!
//! The cluster is virtual (see [`crate::cluster`]), so connection costs
//! are *modeled* (protocol latency × tree rounds) and then actually
//! awaited, scaled by `time_scale`, so the burst experiments (figs. 9–10)
//! measure real end-to-end system behaviour with a latency-faithful
//! launcher in the loop.

use std::sync::Arc;
use std::time::Duration;

use crate::cluster::{Protocol, VirtualCluster};
use crate::types::NodeId;

/// Launcher configuration: fig. 10's four OAR settings are the cross
/// product of `protocol` × `check_before_launch`.
#[derive(Debug, Clone)]
pub struct LauncherConfig {
    pub protocol: Protocol,
    /// Reachability-check every node before launching the job ("a simple
    /// accessibility test using the distant execution of an empty
    /// command").
    pub check_before_launch: bool,
    /// Time allowed for the initiation of one connection.
    pub connect_timeout: Duration,
    /// Wall-clock scale applied to modeled latencies (1.0 = real-scale;
    /// tests use smaller values).
    pub time_scale: f64,
}

impl Default for LauncherConfig {
    fn default() -> Self {
        LauncherConfig {
            protocol: Protocol::Ssh,
            check_before_launch: true,
            connect_timeout: Duration::from_secs(5),
            time_scale: 1.0,
        }
    }
}

/// Outcome of one deployment.
#[derive(Debug, Clone)]
pub struct LaunchReport {
    /// Nodes actually reached, in id order.
    pub deployed: Vec<NodeId>,
    /// Nodes that failed the connection/timeout.
    pub failed: Vec<NodeId>,
    /// Modeled wall time of the deployment (pre-scaling).
    pub modeled: Duration,
}

/// The launcher module.
#[derive(Clone)]
pub struct Launcher {
    pub config: LauncherConfig,
    cluster: Arc<VirtualCluster>,
}

impl Launcher {
    pub fn new(cluster: Arc<VirtualCluster>, config: LauncherConfig) -> Launcher {
        Launcher { cluster, config }
    }

    /// Deployment rounds of the work-stealing tree for `k` targets: every
    /// reached node (plus the root) steals work, so coverage doubles each
    /// round.
    pub fn deployment_rounds(k: usize) -> u32 {
        (k + 1).next_power_of_two().trailing_zeros()
    }

    /// Modeled time to deploy on `k` reachable nodes.
    pub fn model_deploy(&self, k: usize) -> Duration {
        let rounds = Self::deployment_rounds(k) as u64;
        Duration::from_micros(rounds * self.config.protocol.connect_micros())
    }

    /// Modeled time of the pre-launch check over `k` nodes (parallel: one
    /// connection round; unreachable nodes cost the timeout).
    pub fn model_check(&self, any_failed: bool) -> Duration {
        let base = Duration::from_micros(self.config.protocol.connect_micros());
        if any_failed {
            base + self.config.connect_timeout
        } else {
            base
        }
    }

    fn wait(&self, modeled: Duration) {
        let scaled = modeled.mul_f64(self.config.time_scale.max(0.0));
        if !scaled.is_zero() {
            std::thread::sleep(scaled);
        }
    }

    /// Deploy a job on `nodes`. Reachability is taken from the virtual
    /// cluster; with `check_before_launch`, failed nodes are detected
    /// *before* deployment (the job can be rescheduled elsewhere), without
    /// it they surface as deployment failures.
    pub fn launch(&self, nodes: &[NodeId]) -> LaunchReport {
        let mut deployed = Vec::new();
        let mut failed = Vec::new();
        for n in nodes {
            if self.cluster.is_reachable(*n) {
                deployed.push(*n);
            } else {
                failed.push(*n);
            }
        }
        deployed.sort_unstable();
        failed.sort_unstable();

        let mut modeled = Duration::ZERO;
        if self.config.check_before_launch {
            modeled += self.model_check(!failed.is_empty());
        }
        modeled += self.model_deploy(deployed.len());
        if !self.config.check_before_launch && !failed.is_empty() {
            // Failures detected during deployment: the last connection's
            // timeout bounds the detection latency (§2.4).
            modeled += self.config.connect_timeout;
        }
        self.wait(modeled);
        LaunchReport {
            deployed,
            failed,
            modeled,
        }
    }

    /// Parallel reachability sweep used by the monitoring module: one
    /// connection round, plus one timeout when anything is down.
    pub fn ping_all(&self, nodes: &[NodeId]) -> Vec<(NodeId, bool)> {
        let states: Vec<(NodeId, bool)> = nodes
            .iter()
            .map(|n| (*n, self.cluster.is_reachable(*n)))
            .collect();
        let any_down = states.iter().any(|(_, up)| !up);
        let modeled = self.model_check(any_down);
        self.wait(modeled);
        states
    }

    /// Kill a job's processes on its nodes (one parallel round).
    pub fn kill(&self, nodes: &[NodeId]) {
        let modeled = Duration::from_micros(self.config.protocol.connect_micros());
        self.wait(modeled);
        let _ = nodes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn launcher(protocol: Protocol, check: bool) -> Launcher {
        Launcher::new(
            Arc::new(VirtualCluster::tiny(8, 1)),
            LauncherConfig {
                protocol,
                check_before_launch: check,
                connect_timeout: Duration::from_millis(500),
                time_scale: 0.0, // no real sleeping in tests
            },
        )
    }

    #[test]
    fn deployment_rounds_are_logarithmic() {
        assert_eq!(Launcher::deployment_rounds(0), 0);
        assert_eq!(Launcher::deployment_rounds(1), 1);
        assert_eq!(Launcher::deployment_rounds(3), 2);
        assert_eq!(Launcher::deployment_rounds(7), 3);
        assert_eq!(Launcher::deployment_rounds(119), 7);
    }

    #[test]
    fn launch_reports_reachable_nodes() {
        let l = launcher(Protocol::Rsh, false);
        let r = l.launch(&[1, 2, 3]);
        assert_eq!(r.deployed, vec![1, 2, 3]);
        assert!(r.failed.is_empty());
    }

    #[test]
    fn failed_node_detected_and_costed() {
        let cluster = Arc::new(VirtualCluster::tiny(4, 1));
        cluster.inject_failure(3);
        let l = Launcher::new(
            cluster,
            LauncherConfig {
                protocol: Protocol::Rsh,
                check_before_launch: false,
                connect_timeout: Duration::from_millis(500),
                time_scale: 0.0,
            },
        );
        let r = l.launch(&[1, 3]);
        assert_eq!(r.deployed, vec![1]);
        assert_eq!(r.failed, vec![3]);
        // no-check mode pays the timeout during deployment
        assert!(r.modeled >= Duration::from_millis(500));
    }

    #[test]
    fn ssh_costs_more_than_rsh_and_check_adds_a_round() {
        let rsh = launcher(Protocol::Rsh, false).launch(&[1, 2, 3, 4]);
        let ssh = launcher(Protocol::Ssh, false).launch(&[1, 2, 3, 4]);
        let ssh_check = launcher(Protocol::Ssh, true).launch(&[1, 2, 3, 4]);
        assert!(ssh.modeled > rsh.modeled);
        assert!(ssh_check.modeled > ssh.modeled);
    }

    #[test]
    fn ping_all_reports_states() {
        let cluster = Arc::new(VirtualCluster::tiny(3, 1));
        cluster.inject_failure(2);
        let l = Launcher::new(
            cluster,
            LauncherConfig {
                time_scale: 0.0,
                ..Default::default()
            },
        );
        let states = l.ping_all(&[1, 2, 3]);
        assert_eq!(states, vec![(1, true), (2, false), (3, true)]);
    }
}
