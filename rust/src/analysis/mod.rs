//! `oarlint` — a zero-dependency invariant checker for this repository.
//!
//! The paper's complexity argument (Table 1) is that a batch scheduler
//! stays maintainable when its coordination rules are few and explicit.
//! This crate's history shows the failure mode when those rules live
//! only in prose: PR 4 hand-fixed a remote cancel issued under the db
//! lock, PR 6's "zero `db.lock()` call sites" claim was checked by grep,
//! and PR 7's probe-coherence bug slipped past review. `oarlint` turns
//! the seven load-bearing invariants into machine-checked rules over the
//! source itself (management-as-data, applied to the code base):
//!
//! * **R1** lock-order — the acquisition graph over lock classes
//!   (`db`, `sink`, `active`, `queue`, …) stays acyclic, nothing is
//!   re-acquired while held.
//! * **R2** no guard held across a blocking call — the PR 4 bug class.
//! * **R3** WAL-commit-before-ack at every mutation boundary, and
//!   dispatch-intent-before-send in the grid scheduler.
//! * **R4** the database stays `RwLock<Db>` — pins PR 6's claim.
//! * **R5** panic-freedom in the RPC request paths.
//! * **R6** atomics-ordering calibration — counters `Relaxed`, `SeqCst`
//!   only on the known shutdown/drain flags.
//! * **R7** telemetry off the commit path — no metric/span call while
//!   the db write guard or the WAL sink lock is held (PR 10's overhead
//!   bound depends on it).
//!
//! Pipeline: [`lexer`] (total, literal-safe tokens) → [`parser`]
//! (delimiter tree, function items, suppression comments) → [`guards`]
//! (per-function guard-lifetime event streams) → [`rules`] (the seven
//! rules + suppression accounting) → [`report`] (human / JSON
//! rendering). Zero dependencies beyond `std`, by construction: the
//! linter must build in the same offline environment as the scheduler.
//! Findings are suppressed in place with `// oarlint: allow(<rule>)
//! <reason>` — the reason is mandatory and reported, never discarded.
//! See `docs/LINTS.md` for the full catalogue.

pub mod guards;
pub mod lexer;
pub mod parser;
pub mod report;
pub mod rules;

pub use report::{Finding, Report, Severity, Suppressed};
pub use rules::{Analyzer, RuleConfig};

use std::path::Path;

/// Lint every `.rs` file under `root`-relative `paths` (files or
/// directories, walked recursively in sorted order). Directories named
/// `fixtures` are skipped: the lint fixture corpus exists to *fail*.
pub fn analyze_paths(root: &Path, paths: &[&str], cfg: RuleConfig) -> std::io::Result<Report> {
    let mut files: Vec<std::path::PathBuf> = Vec::new();
    for rel in paths {
        collect_rs(&root.join(rel), &mut files)?;
    }
    files.sort();
    files.dedup();

    let mut analyzer = Analyzer::new(cfg);
    for file in &files {
        let src = std::fs::read_to_string(file)?;
        let rel = file
            .strip_prefix(root)
            .unwrap_or(file)
            .to_string_lossy()
            .replace('\\', "/");
        analyzer.add_file(&rel, &src);
    }
    Ok(analyzer.finish())
}

fn collect_rs(path: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    if path.is_file() {
        if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    if !path.is_dir() {
        // A configured path that does not exist is a usage error the
        // caller should see, not a silent zero-file "clean" run.
        return Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            format!("lint path not found: {}", path.display()),
        ));
    }
    if path.file_name().map(|n| n == "fixtures").unwrap_or(false) {
        return Ok(());
    }
    for entry in std::fs::read_dir(path)? {
        collect_rs(&entry?.path(), out)?;
    }
    Ok(())
}
