//! The seven invariant rules of `oarlint`, evaluated over the event
//! streams of [`super::guards`] plus two token-level scans.
//!
//! | rule | invariant |
//! |------|-----------|
//! | R1   | lock-order: the acquisition graph over lock classes is acyclic, and no class is acquired while a guard on the same class is live |
//! | R2   | no guard held across a blocking call (network, process control, disk sync, thread join) |
//! | R3   | WAL-commit-before-ack: a mutation's commit precedes its `notify`/`push_event`; a grid dispatch (`.sub`) follows a db write recording the intent |
//! | R4   | the database stays `RwLock<Db>`: no `Mutex<Db>`, no `db.lock()` (pins PR 6's concurrent-core claim) |
//! | R5   | panic-freedom in request paths: `unwrap`/`expect`/`panic!`/slice-indexing need an annotated `allow` |
//! | R6   | atomics stay calibrated: counters `Relaxed`, `SeqCst` only on the known shutdown/drain flags |
//! | R7   | telemetry stays off the commit path: no metric/span call while the db write guard or the WAL sink lock is held |
//!
//! R1/R2/R4/R6 apply everywhere they are enabled; R3, R5 and R7 are
//! scoped to the files whose invariants they encode (configurable, so
//! fixtures can exercise them anywhere). R2/R3/R5/R7 skip `#[test]`
//! code: tests may block and panic freely — lock *ordering* (R1) still
//! applies to them, since a deadlock in a test hangs the suite just as
//! hard.

use std::collections::{BTreeMap, BTreeSet};

use super::guards::{self, Event, Mode};
use super::lexer::{self, TokKind, Token};
use super::parser::{self, Node, Suppression};
use super::report::{Finding, Report, Severity, Suppressed};

/// Which rules run, and where the scoped ones apply. Scopes are path
/// suffixes; the empty suffix matches every file.
#[derive(Debug, Clone)]
pub struct RuleConfig {
    /// `enabled[k]` switches rule `R{k+1}`.
    pub enabled: [bool; 7],
    /// Files whose mutations must commit before acking (R3).
    pub commit_scope: Vec<String>,
    /// Files whose remote dispatches need a prior intent write (R3).
    pub intent_scope: Vec<String>,
    /// Files whose request paths must be panic-free (R5).
    pub panic_free_scope: Vec<String>,
    /// Atomic flag names allowed to use `SeqCst` (R6).
    pub seqcst_flags: Vec<String>,
    /// Instrumented files whose guarded regions must stay telemetry-free (R7).
    pub telemetry_scope: Vec<String>,
}

impl RuleConfig {
    /// The repository's real policy: every rule on, scoped to the files
    /// that carry each invariant.
    pub fn repo() -> Self {
        RuleConfig {
            enabled: [true; 7],
            commit_scope: vec!["src/server/mod.rs".to_string()],
            intent_scope: vec!["grid/scheduler.rs".to_string()],
            panic_free_scope: vec!["rpc/server.rs".to_string()],
            seqcst_flags: ["running", "draining", "stop", "REQUESTED", "shutdown"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
            telemetry_scope: [
                "src/server/mod.rs",
                "src/db/wal.rs",
                "src/rpc/server.rs",
                "src/grid/scheduler.rs",
                "src/monitor/mod.rs",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        }
    }

    /// Every rule, everywhere (fixture corpus).
    pub fn everywhere() -> Self {
        RuleConfig {
            enabled: [true; 7],
            commit_scope: vec![String::new()],
            intent_scope: vec![String::new()],
            panic_free_scope: vec![String::new()],
            seqcst_flags: vec!["running".to_string()],
            telemetry_scope: vec![String::new()],
        }
    }

    /// A single rule, everywhere (per-rule fixture tests).
    pub fn only(rule: &str) -> Self {
        let mut cfg = Self::everywhere();
        cfg.enabled = [false; 7];
        if let Some(ix) = rule_index(rule) {
            cfg.enabled[ix] = true;
        }
        cfg
    }
}

fn rule_index(rule: &str) -> Option<usize> {
    match rule {
        "R1" => Some(0),
        "R2" => Some(1),
        "R3" => Some(2),
        "R4" => Some(3),
        "R5" => Some(4),
        "R6" => Some(5),
        "R7" => Some(6),
        _ => None,
    }
}

fn in_scope(path: &str, scope: &[String]) -> bool {
    scope.iter().any(|s| path.ends_with(s.as_str()))
}

/// One observed "acquired `to` while holding `from`" edge, with its
/// first witness location.
#[derive(Debug)]
struct Edge {
    from: String,
    to: String,
    file: String,
    line: u32,
}

/// Feeds files in, produces a [`Report`]. Cross-file state is only the
/// R1 acquisition graph; everything else is judged per file.
pub struct Analyzer {
    cfg: RuleConfig,
    findings: Vec<Finding>,
    suppressions: Vec<(String, Suppression)>,
    edges: Vec<Edge>,
    files: usize,
    functions: usize,
}

impl Analyzer {
    pub fn new(cfg: RuleConfig) -> Self {
        Analyzer {
            cfg,
            findings: Vec::new(),
            suppressions: Vec::new(),
            edges: Vec::new(),
            files: 0,
            functions: 0,
        }
    }

    fn on(&self, rule: &str) -> bool {
        rule_index(rule).map(|ix| self.cfg.enabled[ix]).unwrap_or(false)
    }

    fn finding(&mut self, rule: &str, file: &str, line: u32, message: String) {
        let severity = if rule == "lint" {
            Severity::Warning
        } else {
            Severity::Error
        };
        self.findings.push(Finding {
            rule: rule.to_string(),
            severity,
            file: file.to_string(),
            line,
            message,
        });
    }

    /// Lint one source file.
    pub fn add_file(&mut self, path: &str, src: &str) {
        let tokens = lexer::lex(src);

        for s in parser::suppressions(&tokens) {
            match &s.problem {
                Some(problem) => self.finding(
                    "lint",
                    path,
                    s.line,
                    format!("malformed oarlint directive: {problem}"),
                ),
                None => self.suppressions.push((path.to_string(), s)),
            }
        }

        let nodes = parser::parse(&tokens);
        let fns = parser::functions(&nodes);
        self.files += 1;
        self.functions += fns.len();

        let r3_commit = self.on("R3") && in_scope(path, &self.cfg.commit_scope);
        let r3_intent = self.on("R3") && in_scope(path, &self.cfg.intent_scope);
        let r5_here = self.on("R5") && in_scope(path, &self.cfg.panic_free_scope);
        let r7_here = self.on("R7") && in_scope(path, &self.cfg.telemetry_scope);

        for f in &fns {
            let events = guards::analyze_fn(f.body);

            if self.on("R1") {
                self.check_lock_order(path, &f.name, &events);
            }
            if self.on("R2") && !f.in_test {
                self.check_blocking(path, &f.name, &events);
            }
            if r3_commit && !f.in_test {
                self.check_commit_before_ack(path, &f.name, &events);
            }
            if r3_intent && !f.in_test {
                self.check_intent_before_send(path, &f.name, &events);
            }
            if self.on("R4") {
                self.check_db_lock_regression(path, &events);
            }
            if r5_here && !f.in_test {
                self.check_panic_freedom(path, &f.name, f.body);
            }
            if r7_here && !f.in_test {
                self.check_telemetry(path, &f.name, &events);
            }
        }

        if self.on("R4") {
            self.check_mutex_db_type(path, &tokens);
        }
        if self.on("R6") {
            self.check_atomics(path, &tokens);
        }
    }

    // ------------------------------------------------------------ R1 --

    fn check_lock_order(&mut self, path: &str, fn_name: &str, events: &[Event]) {
        for ev in events {
            let Event::Acquire { guard, held } = ev else {
                continue;
            };
            for h in held {
                if h.class == guard.class {
                    self.finding(
                        "R1",
                        path,
                        guard.line,
                        format!(
                            "nested acquisition of `{}` in `{}` while a {} guard on it \
                             (line {}) is still live — self-deadlock on the mutex/write side",
                            guard.class,
                            fn_name,
                            h.mode.as_str(),
                            h.line
                        ),
                    );
                } else {
                    let exists = self
                        .edges
                        .iter()
                        .any(|e| e.from == h.class && e.to == guard.class);
                    if !exists {
                        self.edges.push(Edge {
                            from: h.class.clone(),
                            to: guard.class.clone(),
                            file: path.to_string(),
                            line: guard.line,
                        });
                    }
                }
            }
        }
    }

    // ------------------------------------------------------------ R2 --

    fn check_blocking(&mut self, path: &str, fn_name: &str, events: &[Event]) {
        for ev in events {
            let Event::Blocking { call, line, held } = ev else {
                continue;
            };
            let held_list: Vec<String> = held
                .iter()
                .map(|g| format!("`{}` ({}, line {})", g.class, g.mode.as_str(), g.line))
                .collect();
            self.finding(
                "R2",
                path,
                *line,
                format!(
                    "blocking call `{}` in `{}` while holding {} — \
                     every other thread on those locks stalls behind this I/O",
                    call,
                    fn_name,
                    held_list.join(", ")
                ),
            );
        }
    }

    // ------------------------------------------------------------ R3 --

    fn check_commit_before_ack(&mut self, path: &str, fn_name: &str, events: &[Event]) {
        let mut dirty = false;
        let mut dirty_line = 0u32;
        for ev in events {
            match ev {
                Event::Release {
                    class,
                    mode: Mode::Write,
                    line,
                } if class == "db" => {
                    dirty = true;
                    dirty_line = *line;
                }
                Event::Commit { .. } => dirty = false,
                Event::Ack { call, line, held } => {
                    if held
                        .iter()
                        .any(|g| g.class == "db" && g.mode == Mode::Write)
                    {
                        self.finding(
                            "R3",
                            path,
                            *line,
                            format!(
                                "`{call}` in `{fn_name}` while the db write guard is still \
                                 held — the WAL commit for that mutation cannot have \
                                 happened yet"
                            ),
                        );
                    } else if dirty {
                        self.finding(
                            "R3",
                            path,
                            *line,
                            format!(
                                "`{call}` in `{fn_name}` acknowledges a db write (guard \
                                 released line {dirty_line}) before its WAL commit — a \
                                 crash here acks state that was never durable"
                            ),
                        );
                    }
                }
                _ => {}
            }
        }
    }

    fn check_intent_before_send(&mut self, path: &str, fn_name: &str, events: &[Event]) {
        let mut intent = false;
        for ev in events {
            match ev {
                Event::Release {
                    class,
                    mode: Mode::Write,
                    ..
                } if class == "db" => intent = true,
                Event::Send { line } => {
                    if !intent {
                        self.finding(
                            "R3",
                            path,
                            *line,
                            format!(
                                "remote submission `.sub(..)` in `{fn_name}` without a \
                                 prior db write recording the dispatch intent — a crash \
                                 between send and record duplicates the task"
                            ),
                        );
                    }
                }
                _ => {}
            }
        }
    }

    // ------------------------------------------------------------ R4 --

    fn check_db_lock_regression(&mut self, path: &str, events: &[Event]) {
        for ev in events {
            let Event::Acquire { guard, .. } = ev else {
                continue;
            };
            if guard.class == "db" && guard.mode == Mode::Mutex {
                self.finding(
                    "R4",
                    path,
                    guard.line,
                    "`db.lock()` — the database is an RwLock since PR 6; mutex-style \
                     access serializes every reader behind every writer again"
                        .to_string(),
                );
            }
        }
    }

    fn check_mutex_db_type(&mut self, path: &str, tokens: &[Token]) {
        for w in tokens.windows(3) {
            let is_mutex = matches!(&w[0].kind, TokKind::Ident(s) if s == "Mutex");
            let lt = w[1].kind == TokKind::Punct('<');
            let is_db = matches!(&w[2].kind, TokKind::Ident(s) if s == "Db");
            if is_mutex && lt && is_db {
                self.finding(
                    "R4",
                    path,
                    w[0].line,
                    "`Mutex<Db>` — the database must stay `RwLock<Db>` (concurrent \
                     snapshot reads are load-bearing for stat/monitoring paths)"
                        .to_string(),
                );
            }
        }
    }

    // ------------------------------------------------------------ R5 --

    fn check_panic_freedom(&mut self, path: &str, fn_name: &str, body: &[Node]) {
        self.scan_panics(path, fn_name, body);
    }

    fn scan_panics(&mut self, path: &str, fn_name: &str, nodes: &[Node]) {
        for (i, n) in nodes.iter().enumerate() {
            match n {
                Node::Leaf(_) => {
                    if let Some(name) = n.ident() {
                        let prev_dot = i > 0 && nodes[i - 1].is_punct('.');
                        let next_call = matches!(
                            nodes.get(i + 1),
                            Some(Node::Group { delim: '(', .. })
                        );
                        if prev_dot && next_call && (name == "unwrap" || name == "expect") {
                            self.finding(
                                "R5",
                                path,
                                n.line(),
                                format!(
                                    "`.{name}(..)` in request path `{fn_name}` — a poisoned \
                                     lock or unexpected None kills the worker; handle the \
                                     error or add `// oarlint: allow(R5) <reason>`"
                                ),
                            );
                        }
                        if name == "panic"
                            && matches!(nodes.get(i + 1), Some(nx) if nx.is_punct('!'))
                        {
                            self.finding(
                                "R5",
                                path,
                                n.line(),
                                format!("`panic!` in request path `{fn_name}`"),
                            );
                        }
                    }
                }
                Node::Group {
                    delim: '[',
                    open_line,
                    ..
                } => {
                    if i > 0 && is_index_base(&nodes[i - 1]) {
                        self.finding(
                            "R5",
                            path,
                            *open_line,
                            format!(
                                "slice/array indexing in request path `{fn_name}` — \
                                 out-of-bounds panics; use .get()"
                            ),
                        );
                    }
                }
                _ => {}
            }
            if let Node::Group { children, .. } = n {
                self.scan_panics(path, fn_name, children);
            }
        }
    }

    // ------------------------------------------------------------ R6 --

    fn check_atomics(&mut self, path: &str, tokens: &[Token]) {
        for i in 0..tokens.len() {
            let TokKind::Ident(name) = &tokens[i].kind else {
                continue;
            };
            let rmw = matches!(
                name.as_str(),
                "fetch_add" | "fetch_sub" | "fetch_or" | "fetch_and" | "fetch_xor"
            );
            let rw = matches!(
                name.as_str(),
                "load" | "store" | "swap" | "compare_exchange" | "compare_exchange_weak"
            );
            if !rmw && !rw {
                continue;
            }
            if i == 0 || tokens[i - 1].kind != TokKind::Punct('.') {
                continue;
            }
            if !matches!(tokens.get(i + 1).map(|t| &t.kind), Some(TokKind::Open('('))) {
                continue;
            }
            let orderings = orderings_in_args(tokens, i + 1);
            if orderings.is_empty() {
                continue; // not an atomic call (e.g. client.load())
            }
            let recv = if i >= 2 {
                match &tokens[i - 2].kind {
                    TokKind::Ident(r) => r.as_str(),
                    _ => "<expr>",
                }
            } else {
                "<expr>"
            };
            let line = tokens[i].line;
            for ord in &orderings {
                if rmw && ord != "Relaxed" {
                    self.finding(
                        "R6",
                        path,
                        line,
                        format!(
                            "`{name}` on `{recv}` uses Ordering::{ord} — plan/stat \
                             counters are pure tallies and stay Relaxed (PR 6 calibration)"
                        ),
                    );
                } else if rw && ord == "SeqCst" && !self.cfg.seqcst_flags.iter().any(|f| f == recv)
                {
                    self.finding(
                        "R6",
                        path,
                        line,
                        format!(
                            "`{name}` on `{recv}` uses Ordering::SeqCst — SeqCst is \
                             reserved for the shutdown/drain flags ({}); new atomics \
                             justify their ordering or stay Relaxed/AcqRel",
                            self.cfg.seqcst_flags.join(", ")
                        ),
                    );
                }
            }
        }
    }

    // ------------------------------------------------------------ R7 --

    fn check_telemetry(&mut self, path: &str, fn_name: &str, events: &[Event]) {
        for ev in events {
            let Event::Telemetry { call, line, held } = ev else {
                continue;
            };
            let hot = held
                .iter()
                .find(|g| (g.class == "db" && g.mode == Mode::Write) || g.class == "sink");
            if let Some(g) = hot {
                self.finding(
                    "R7",
                    path,
                    *line,
                    format!(
                        "telemetry call `{}` in `{}` while the `{}` {} guard (line {}) \
                         is held — recording a metric extends the commit critical \
                         section; capture the timestamp under the guard and observe \
                         after release",
                        call,
                        fn_name,
                        g.class,
                        g.mode.as_str(),
                        g.line
                    ),
                );
            }
        }
    }

    // -------------------------------------------------------- finish --

    /// Close the run: R1 cycle detection over the accumulated graph,
    /// then suppression application and accounting.
    pub fn finish(mut self) -> Report {
        if self.cfg.enabled[0] {
            // Every Db mutation appends to the WAL under the sink lock —
            // an acquisition order invisible to per-function analysis, so
            // it is seeded as a policy edge.
            self.edges.push(Edge {
                from: "db".to_string(),
                to: "sink".to_string(),
                file: "(policy: Db mutations append under the WAL sink lock)".to_string(),
                line: 0,
            });
            self.report_cycles();
        }

        let mut used = vec![false; self.suppressions.len()];
        let mut kept: Vec<Finding> = Vec::new();
        let mut suppressed: Vec<Suppressed> = Vec::new();
        for f in std::mem::take(&mut self.findings) {
            let hit = self.suppressions.iter().position(|(file, s)| {
                *file == f.file && s.rule == f.rule && s.target_line == f.line
            });
            match hit {
                Some(ix) => {
                    used[ix] = true;
                    let reason = self.suppressions[ix].1.reason.clone();
                    suppressed.push(Suppressed { finding: f, reason });
                }
                None => kept.push(f),
            }
        }
        for (ix, (file, s)) in self.suppressions.iter().enumerate() {
            if !used[ix] {
                kept.push(Finding {
                    rule: "lint".to_string(),
                    severity: Severity::Warning,
                    file: file.clone(),
                    line: s.line,
                    message: format!(
                        "unused suppression: allow({}) matches no {} finding on line {}",
                        s.rule, s.rule, s.target_line
                    ),
                });
            }
        }

        kept.sort_by(|a, b| {
            (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule))
        });
        suppressed.sort_by(|a, b| {
            (&a.finding.file, a.finding.line).cmp(&(&b.finding.file, b.finding.line))
        });
        Report {
            findings: kept,
            suppressed,
            files_scanned: self.files,
            functions_scanned: self.functions,
        }
    }

    fn report_cycles(&mut self) {
        let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
        for e in &self.edges {
            adj.entry(e.from.as_str()).or_default().insert(e.to.as_str());
        }
        let mut seen: BTreeSet<BTreeSet<String>> = BTreeSet::new();
        let mut found: Vec<(String, String, u32)> = Vec::new();
        // Policy edge last, so a cycle is witnessed at real code when any
        // observed edge participates in it.
        for e in &self.edges {
            let Some(path) = find_path(&adj, &e.to, &e.from) else {
                continue;
            };
            // path = [e.to, ..., e.from], so prepending e.from closes
            // the loop: from -> to -> ... -> from.
            let mut cycle: Vec<String> = vec![e.from.clone()];
            cycle.extend(path);
            let signature: BTreeSet<String> = cycle.iter().cloned().collect();
            if !seen.insert(signature) {
                continue;
            }
            found.push((e.file.clone(), cycle.join(" -> "), e.line));
        }
        for (file, route, line) in found {
            self.finding(
                "R1",
                &file,
                line,
                format!(
                    "lock-order cycle: {route} — two threads taking these locks in \
                     opposing order deadlock"
                ),
            );
        }
    }
}

/// BFS path from `start` to `goal` over the acquisition graph; returns
/// the node list from `start` to `goal` inclusive.
fn find_path(
    adj: &BTreeMap<&str, BTreeSet<&str>>,
    start: &str,
    goal: &str,
) -> Option<Vec<String>> {
    let mut parent: BTreeMap<&str, &str> = BTreeMap::new();
    let mut queue: Vec<&str> = vec![];
    if let Some(next) = adj.get(start) {
        for &n in next {
            if !parent.contains_key(n) {
                parent.insert(n, start);
                queue.push(n);
            }
        }
    }
    let mut head = 0;
    let mut hit = parent.contains_key(goal);
    while head < queue.len() && !hit {
        let cur = queue[head];
        head += 1;
        if let Some(next) = adj.get(cur) {
            for &n in next {
                if !parent.contains_key(n) {
                    parent.insert(n, cur);
                    queue.push(n);
                    if n == goal {
                        hit = true;
                    }
                }
            }
        }
    }
    if !hit {
        return None;
    }
    // Reconstruct goal <- ... <- start, then reverse; prepend start.
    let mut rev = vec![goal.to_string()];
    let mut cur = goal;
    while let Some(&p) = parent.get(cur) {
        if p == start {
            break;
        }
        rev.push(p.to_string());
        cur = p;
    }
    rev.push(start.to_string());
    rev.reverse();
    Some(rev)
}

/// Can the node before a `[..]` group be an indexing base? Identifiers
/// (excluding keywords that introduce array literals/types) and closed
/// call/index groups can; punctuation (`: [u8; 8]`, `#[..]`, `vec![..]`)
/// cannot.
fn is_index_base(prev: &Node) -> bool {
    match prev {
        Node::Group { delim, .. } => matches!(delim, '(' | '['),
        Node::Leaf(_) => match prev.ident() {
            Some(s) => !matches!(
                s,
                "mut" | "ref" | "return" | "break" | "in" | "as" | "else" | "match" | "if"
                    | "while" | "box" | "move" | "static" | "dyn" | "impl" | "where" | "let"
                    | "const" | "type" | "use" | "pub" | "fn" | "loop" | "for" | "unsafe"
            ),
            None => false,
        },
    }
}

/// Atomic-ordering identifiers among a call's argument tokens, scanned
/// from the opening paren at `open_ix`.
fn orderings_in_args(tokens: &[Token], open_ix: usize) -> Vec<String> {
    const ORDS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];
    let mut out = Vec::new();
    let mut depth = 0usize;
    for t in &tokens[open_ix..] {
        match &t.kind {
            TokKind::Open(_) => depth += 1,
            TokKind::Close(_) => {
                depth = depth.saturating_sub(1);
                if depth == 0 {
                    break;
                }
            }
            TokKind::Ident(s) if ORDS.contains(&s.as_str()) => out.push(s.clone()),
            _ => {}
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(cfg: RuleConfig, src: &str) -> Report {
        let mut a = Analyzer::new(cfg);
        a.add_file("mem.rs", src);
        a.finish()
    }

    #[test]
    fn r1_cycle_across_functions() {
        let src = "
            fn ab(s: &S) {
                let a = s.alpha.lock().unwrap();
                let b = s.beta.lock().unwrap();
                drop(b);
                drop(a);
            }
            fn ba(s: &S) {
                let b = s.beta.lock().unwrap();
                let a = s.alpha.lock().unwrap();
                drop(a);
                drop(b);
            }
        ";
        let rep = run(RuleConfig::only("R1"), src);
        assert_eq!(rep.of_rule("R1").count(), 1, "{}", rep.render_human());
        assert!(rep.findings[0].message.contains("cycle"));
    }

    #[test]
    fn r3_ack_before_commit() {
        let src = "
            fn mutate(inner: &Inner) {
                let mut db = inner.db.write().unwrap();
                db.touch();
                drop(db);
                inner.hub.notify(Task::Schedule);
                inner.commit_wal();
            }
        ";
        let rep = run(RuleConfig::only("R3"), src);
        assert_eq!(rep.of_rule("R3").count(), 1, "{}", rep.render_human());
    }

    #[test]
    fn r6_seqcst_flag_allowlist() {
        let src = "
            fn f(s: &S) {
                s.running.store(false, Ordering::SeqCst);
                s.served.store(0, Ordering::SeqCst);
                s.served.fetch_add(1, Ordering::Relaxed);
            }
        ";
        let rep = run(RuleConfig::only("R6"), src);
        assert_eq!(rep.of_rule("R6").count(), 1, "{}", rep.render_human());
        assert!(rep.findings[0].message.contains("served"));
    }

    #[test]
    fn r7_telemetry_under_commit_guards() {
        let src = "
            fn mutate(inner: &Inner) {
                let t0 = clock::now_us();
                let mut db = inner.db.write().unwrap();
                db.touch();
                metrics::DB_WRITE_WAIT_US.observe(clock::now_us() - t0);
                drop(db);
                metrics::DB_WRITE_WAIT_US.observe(clock::now_us() - t0);
            }
            fn flush(w: &Wal) {
                let s = w.sink.lock().unwrap();
                let _span = Span::enter(FLUSH, &metrics::WAL_FLUSH_US);
                drop(s);
            }
        ";
        let rep = run(RuleConfig::only("R7"), src);
        assert_eq!(rep.of_rule("R7").count(), 2, "{}", rep.render_human());
        assert!(rep.findings[0].message.contains("observe"));
        assert!(rep.findings[1].message.contains("enter"));
    }

    #[test]
    fn suppression_silences_and_is_accounted() {
        let src = "
            fn f(s: &S) {
                let db = s.db.write().unwrap();
                db.checkpoint(); // oarlint: allow(R2) teardown must be atomic
                drop(db);
            }
        ";
        let rep = run(RuleConfig::only("R2"), src);
        assert_eq!(rep.findings.len(), 0, "{}", rep.render_human());
        assert_eq!(rep.suppressed.len(), 1);
        assert!(rep.suppressed[0].reason.contains("atomic"));
    }

    #[test]
    fn unused_suppression_warns() {
        let src = "
            fn f() {
                // oarlint: allow(R2) nothing blocks here
                let x = 1;
            }
        ";
        let rep = run(RuleConfig::only("R2"), src);
        assert_eq!(rep.warnings(), 1, "{}", rep.render_human());
        assert!(rep.findings[0].message.contains("unused suppression"));
    }
}
