//! Delimiter-tree parser and item extraction for `oarlint`.
//!
//! The parser turns the flat token stream into a tree of balanced
//! delimiter groups (`()`, `[]`, `{}`) with plain tokens as leaves, then
//! walks that tree to find function items — each with its name, line,
//! body, and whether it lives under `#[test]` / `#[cfg(test)]` (rules
//! that guard *request paths* skip test code). Suppression comments
//! (`// oarlint: allow(<rule>) <reason>`) are extracted from the raw
//! token stream, because they need to know what else shares their line.
//!
//! Like the lexer, everything here is total: unbalanced input produces a
//! best-effort tree, never a panic — the linter must survive any source
//! file it is pointed at.

use super::lexer::{TokKind, Token};

/// A node of the delimiter tree.
#[derive(Debug)]
pub enum Node {
    Leaf(Token),
    Group {
        delim: char,
        open_line: u32,
        close_line: u32,
        children: Vec<Node>,
    },
}

impl Node {
    /// The identifier text of this node, if it is an identifier leaf.
    pub fn ident(&self) -> Option<&str> {
        match self {
            Node::Leaf(t) => match &t.kind {
                TokKind::Ident(s) => Some(s.as_str()),
                _ => None,
            },
            _ => None,
        }
    }

    /// Is this node the punctuation character `c`?
    pub fn is_punct(&self, c: char) -> bool {
        matches!(self, Node::Leaf(t) if t.kind == TokKind::Punct(c))
    }

    /// The source line this node starts on.
    pub fn line(&self) -> u32 {
        match self {
            Node::Leaf(t) => t.line,
            Node::Group { open_line, .. } => *open_line,
        }
    }
}

/// Build the delimiter tree. Comment tokens are dropped here (they are
/// only meaningful to [`suppressions`]); stray closers are skipped and a
/// missing closer closes its group at end-of-input.
pub fn parse(tokens: &[Token]) -> Vec<Node> {
    let mut pos = 0usize;
    parse_group(tokens, &mut pos, None).0
}

fn closer_for(open: char) -> char {
    match open {
        '(' => ')',
        '[' => ']',
        _ => '}',
    }
}

/// Parse siblings until the matching closer for `open` (or EOF). Returns
/// the children and the line the group closed on.
fn parse_group(tokens: &[Token], pos: &mut usize, open: Option<char>) -> (Vec<Node>, u32) {
    let mut children = Vec::new();
    let mut last_line = tokens.first().map(|t| t.line).unwrap_or(1);
    while let Some(t) = tokens.get(*pos) {
        last_line = t.line;
        match &t.kind {
            TokKind::Comment(_) => {
                *pos += 1;
            }
            TokKind::Open(c) => {
                let delim = *c;
                let open_line = t.line;
                *pos += 1;
                let (inner, close_line) = parse_group(tokens, pos, Some(delim));
                children.push(Node::Group {
                    delim,
                    open_line,
                    close_line,
                    children: inner,
                });
            }
            TokKind::Close(c) => {
                match open {
                    Some(o) if closer_for(o) == *c => {
                        *pos += 1;
                        return (children, t.line);
                    }
                    Some(_) => {
                        // Mismatched closer: treat it as closing this
                        // group too (don't consume; the outer level will
                        // claim it), keeping the tree as sane as possible.
                        return (children, t.line);
                    }
                    None => {
                        // Stray closer at top level: skip it.
                        *pos += 1;
                    }
                }
            }
            _ => {
                children.push(Node::Leaf(t.clone()));
                *pos += 1;
            }
        }
    }
    (children, last_line)
}

/// A function item found in the tree. `body` borrows the children of its
/// brace group.
#[derive(Debug)]
pub struct FnItem<'a> {
    pub name: String,
    pub line: u32,
    pub in_test: bool,
    pub body: &'a [Node],
}

/// Collect every function item, tracking test scope: a fn under
/// `#[test]`, or anywhere inside a `#[cfg(test)] mod`, is `in_test`.
pub fn functions(nodes: &[Node]) -> Vec<FnItem<'_>> {
    let mut out = Vec::new();
    collect_fns(nodes, false, &mut out);
    out
}

fn attr_mentions_test(children: &[Node]) -> bool {
    children.iter().any(|n| match n {
        Node::Leaf(t) => matches!(&t.kind, TokKind::Ident(s) if s == "test"),
        Node::Group { children, .. } => attr_mentions_test(children),
    })
}

/// Find the body brace group of an item starting after index `from`,
/// stopping at `;` (body-less items: trait methods, `extern` decls,
/// `mod name;`). Returns (body-children, index just past it).
fn find_body(nodes: &[Node], from: usize) -> (Option<&[Node]>, usize) {
    let mut j = from;
    while let Some(n) = nodes.get(j) {
        match n {
            Node::Leaf(t) if t.kind == TokKind::Punct(';') => return (None, j + 1),
            Node::Group {
                delim: '{',
                children,
                ..
            } => return (Some(children), j + 1),
            _ => j += 1,
        }
    }
    (None, j)
}

fn collect_fns<'a>(nodes: &'a [Node], in_test: bool, out: &mut Vec<FnItem<'a>>) {
    let mut i = 0;
    let mut pending_test = false;
    while i < nodes.len() {
        match &nodes[i] {
            Node::Leaf(t) if t.kind == TokKind::Punct('#') => {
                // Attribute: `#` (optionally `!`) followed by a bracket
                // group. `#![...]` inner attributes are skipped the same
                // way.
                let mut j = i + 1;
                if matches!(nodes.get(j), Some(n) if n.is_punct('!')) {
                    j += 1;
                }
                if let Some(Node::Group {
                    delim: '[',
                    children,
                    ..
                }) = nodes.get(j)
                {
                    if attr_mentions_test(children) {
                        pending_test = true;
                    }
                    i = j + 1;
                } else {
                    i += 1;
                }
            }
            Node::Leaf(t) => {
                match &t.kind {
                    TokKind::Ident(w) if w == "fn" => {
                        let (name, line) = match nodes.get(i + 1).and_then(Node::ident) {
                            Some(n) => (n.to_string(), nodes[i + 1].line()),
                            None => ("?".to_string(), t.line),
                        };
                        let (body, next) = find_body(nodes, i + 2);
                        if let Some(body) = body {
                            out.push(FnItem {
                                name,
                                line,
                                in_test: in_test || pending_test,
                                body,
                            });
                        }
                        pending_test = false;
                        i = next;
                    }
                    TokKind::Ident(w) if w == "mod" => {
                        let mod_test = in_test || pending_test;
                        let (body, next) = find_body(nodes, i + 1);
                        if let Some(body) = body {
                            collect_fns(body, mod_test, out);
                        }
                        pending_test = false;
                        i = next;
                    }
                    _ => i += 1,
                }
            }
            Node::Group {
                delim: '{',
                children,
                ..
            } => {
                // impl / trait / extern blocks (and struct bodies, where
                // the recursion finds nothing): look inside for fns. fn
                // bodies themselves are claimed above and never reach
                // this arm.
                collect_fns(children, in_test, out);
                pending_test = false;
                i += 1;
            }
            _ => i += 1,
        }
    }
}

/// One `// oarlint: allow(<rule>) <reason>` comment, resolved to the
/// line it suppresses: its own line when trailing code, otherwise the
/// next line that carries code.
#[derive(Debug, Clone)]
pub struct Suppression {
    pub rule: String,
    /// Line of the comment itself.
    pub line: u32,
    /// Line the suppression applies to.
    pub target_line: u32,
    pub reason: String,
    /// Set when the comment is recognizably an oarlint directive but
    /// malformed (unknown rule, missing reason, bad syntax).
    pub problem: Option<String>,
}

const KNOWN_RULES: [&str; 6] = ["R1", "R2", "R3", "R4", "R5", "R6"];

/// Extract suppressions from the raw token stream.
pub fn suppressions(tokens: &[Token]) -> Vec<Suppression> {
    let mut out: Vec<Suppression> = Vec::new();
    let mut last_code_line = 0u32;
    for (idx, tok) in tokens.iter().enumerate() {
        let text = match &tok.kind {
            TokKind::Comment(t) => t,
            _ => {
                last_code_line = tok.line;
                continue;
            }
        };
        let trimmed = text.trim();
        let Some(directive) = trimmed.strip_prefix("oarlint:") else {
            continue;
        };
        let trailing = last_code_line == tok.line;
        let target_line = if trailing {
            tok.line
        } else {
            // Next token that carries code (skipping further comments);
            // a dangling directive at EOF targets its own line and will
            // be reported unused.
            tokens[idx + 1..]
                .iter()
                .find(|t| !matches!(t.kind, TokKind::Comment(_)))
                .map(|t| t.line)
                .unwrap_or(tok.line)
        };
        let mut s = Suppression {
            rule: String::new(),
            line: tok.line,
            target_line,
            reason: String::new(),
            problem: None,
        };
        let directive = directive.trim();
        match parse_allow(directive) {
            Ok((rule, reason)) => {
                if !KNOWN_RULES.contains(&rule.as_str()) {
                    s.problem = Some(format!("unknown rule {rule:?} (expected R1..R6)"));
                } else if reason.is_empty() {
                    s.problem = Some(format!(
                        "allow({rule}) requires a written reason after the closing paren"
                    ));
                }
                s.rule = rule;
                s.reason = reason;
            }
            Err(e) => s.problem = Some(e),
        }
        out.push(s);
    }
    out
}

fn parse_allow(directive: &str) -> Result<(String, String), String> {
    let Some(rest) = directive.strip_prefix("allow(") else {
        return Err(format!(
            "expected `allow(<rule>) <reason>`, got {directive:?}"
        ));
    };
    let Some(close) = rest.find(')') else {
        return Err("unterminated allow( — missing `)`".to_string());
    };
    let rule = rest[..close].trim().to_string();
    let reason = rest[close + 1..].trim().to_string();
    Ok((rule, reason))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    #[test]
    fn tree_balances_through_literals() {
        let src = r#"fn f() { let s = "{{{"; g(s); }"#;
        let nodes = parse(&lex(src));
        let fns = functions(&nodes);
        assert_eq!(fns.len(), 1);
        assert_eq!(fns[0].name, "f");
    }

    #[test]
    fn finds_fns_in_impl_and_test_mods() {
        let src = r#"
            impl Foo {
                pub fn alpha(&self) -> u32 { 1 }
            }
            #[cfg(test)]
            mod tests {
                #[test]
                fn beta() { assert!(true); }
            }
            fn gamma() {}
            extern "C" { fn socket(d: i32) -> i32; }
        "#;
        let nodes = parse(&lex(src));
        let fns = functions(&nodes);
        let names: Vec<(&str, bool)> = fns.iter().map(|f| (f.name.as_str(), f.in_test)).collect();
        assert_eq!(
            names,
            vec![("alpha", false), ("beta", true), ("gamma", false)]
        );
    }

    #[test]
    fn test_attr_marks_single_fn_only() {
        let src = "#[test]\nfn a() {}\nfn b() {}";
        let nodes = parse(&lex(src));
        let fns = functions(&nodes);
        assert!(fns[0].in_test);
        assert!(!fns[1].in_test);
    }

    #[test]
    fn suppression_trailing_and_own_line() {
        let src = "\
let a = x.unwrap(); // oarlint: allow(R5) trailing form
// oarlint: allow(R2) own-line form
let b = conn();
";
        let sup = suppressions(&lex(src));
        assert_eq!(sup.len(), 2);
        assert_eq!((sup[0].rule.as_str(), sup[0].target_line), ("R5", 1));
        assert_eq!((sup[1].rule.as_str(), sup[1].target_line), ("R2", 3));
        assert!(sup.iter().all(|s| s.problem.is_none()));
    }

    #[test]
    fn suppression_malformed_variants() {
        let src = "\
// oarlint: allow(R9) no such rule
// oarlint: allow(R1)
// oarlint: deny(R1) wrong verb
fn f() {}
";
        let sup = suppressions(&lex(src));
        assert_eq!(sup.len(), 3);
        assert!(sup.iter().all(|s| s.problem.is_some()));
    }
}
