//! Token-level lexer for `oarlint` (see [`crate::analysis`]).
//!
//! This is not a Rust compiler front-end: it produces exactly the token
//! stream the lint rules need — identifiers, punctuation, literals
//! (opaque), comments (kept, because suppressions live in them) — with a
//! line number on every token. The hard part of lexing Rust at this
//! level is *not* being fooled by literals: a `{` inside a string must
//! not unbalance the block parser, `'a` must lex as a lifetime while
//! `'a'` lexes as a char, and `r#"…"#` must swallow its body verbatim.
//! Everything the rules do downstream assumes this layer got those
//! right, so the corner cases are handled explicitly and unit-tested.
//!
//! The lexer is total: any input produces a token stream, never a panic
//! or an error. Unknown bytes become [`TokKind::Punct`] tokens.

/// One lexical token. Literal bodies are not retained (the rules never
/// look inside them); comments are, because `// oarlint: allow(..)`
/// suppressions are parsed out of them.
#[derive(Debug, Clone, PartialEq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `lock`, `db`, …).
    Ident(String),
    /// Lifetime (`'a`) — distinguished from char literals.
    Lifetime,
    /// Numeric literal (opaque).
    Num,
    /// String / raw string / byte string literal (opaque).
    Str,
    /// Char or byte-char literal (opaque).
    Char,
    /// Comment text without its `//` / `/* */` delimiters. Block
    /// comments are kept with empty text: suppressions are line
    /// comments by definition.
    Comment(String),
    /// Any single punctuation character that is not a delimiter.
    Punct(char),
    /// Opening delimiter: one of `(`, `[`, `{`.
    Open(char),
    /// Closing delimiter: one of `)`, `]`, `}`.
    Close(char),
}

/// A token plus the 1-based source line it starts on.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: TokKind,
    pub line: u32,
}

/// Lex `src` into a token stream. Total: never fails.
pub fn lex(src: &str) -> Vec<Token> {
    Lexer {
        chars: src.chars().collect(),
        pos: 0,
        line: 1,
        out: Vec::new(),
    }
    .run()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: u32,
    out: Vec<Token>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(c) = c {
            self.pos += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push(&mut self, kind: TokKind, line: u32) {
        self.out.push(Token { kind, line });
    }

    fn run(mut self) -> Vec<Token> {
        while let Some(c) = self.peek(0) {
            let line = self.line;
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek(1) == Some('/') {
                self.line_comment(line);
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment(line);
            } else if c == '"' {
                self.bump();
                self.string_body();
                self.push(TokKind::Str, line);
            } else if c == '\'' {
                self.quote(line);
            } else if c.is_ascii_digit() {
                self.number();
                self.push(TokKind::Num, line);
            } else if is_ident_start(c) {
                self.ident_or_prefixed_literal(line);
            } else if matches!(c, '(' | '[' | '{') {
                self.bump();
                self.push(TokKind::Open(c), line);
            } else if matches!(c, ')' | ']' | '}') {
                self.bump();
                self.push(TokKind::Close(c), line);
            } else {
                self.bump();
                self.push(TokKind::Punct(c), line);
            }
        }
        self.out
    }

    fn line_comment(&mut self, line: u32) {
        self.bump();
        self.bump(); // the two slashes
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.push(TokKind::Comment(text), line);
    }

    fn block_comment(&mut self, line: u32) {
        self.bump();
        self.bump(); // "/*"
        let mut depth = 1usize;
        while depth > 0 {
            match (self.peek(0), self.peek(1)) {
                (Some('/'), Some('*')) => {
                    depth += 1;
                    self.bump();
                    self.bump();
                }
                (Some('*'), Some('/')) => {
                    depth -= 1;
                    self.bump();
                    self.bump();
                }
                (Some(_), _) => {
                    self.bump();
                }
                (None, _) => break,
            }
        }
        self.push(TokKind::Comment(String::new()), line);
    }

    /// Body of a normal (escaped) string, opening quote already consumed.
    fn string_body(&mut self) {
        while let Some(c) = self.bump() {
            match c {
                '\\' => {
                    self.bump();
                }
                '"' => break,
                _ => {}
            }
        }
    }

    /// Raw string with `hashes` leading `#`s; positioned just after the
    /// opening quote. Consumes through the closing `"###…`.
    fn raw_string_body(&mut self, hashes: usize) {
        while let Some(c) = self.bump() {
            if c == '"' {
                let mut n = 0;
                while n < hashes && self.peek(n) == Some('#') {
                    n += 1;
                }
                if n == hashes {
                    for _ in 0..hashes {
                        self.bump();
                    }
                    break;
                }
            }
        }
    }

    /// `'` — lifetime or char literal, decided by lookahead: `'a` with no
    /// closing quote after the identifier run is a lifetime; anything
    /// else ( `'a'`, `'\n'`, `'('` ) is a char.
    fn quote(&mut self, line: u32) {
        let next = self.peek(1);
        let is_lifetime = match next {
            Some(c) if is_ident_start(c) => {
                let mut k = 2;
                while self.peek(k).map(is_ident_char) == Some(true) {
                    k += 1;
                }
                self.peek(k) != Some('\'')
            }
            _ => false,
        };
        if is_lifetime {
            self.bump(); // '
            while self.peek(0).map(is_ident_char) == Some(true) {
                self.bump();
            }
            self.push(TokKind::Lifetime, line);
            return;
        }
        self.bump(); // opening '
        loop {
            match self.peek(0) {
                Some('\\') => {
                    self.bump();
                    self.bump();
                }
                Some('\'') => {
                    self.bump();
                    break;
                }
                Some(_) => {
                    self.bump();
                }
                None => break,
            }
        }
        self.push(TokKind::Char, line);
    }

    /// Digits plus any alphanumeric suffix (`0xff`, `1_000u64`, `1e9`)
    /// and a single fractional part. Exponent signs end up as separate
    /// `Punct` tokens, which is harmless for the rules.
    fn number(&mut self) {
        while self.peek(0).map(is_ident_char) == Some(true) {
            self.bump();
        }
        if self.peek(0) == Some('.') && self.peek(1).map(|c| c.is_ascii_digit()) == Some(true) {
            self.bump();
            while self.peek(0).map(is_ident_char) == Some(true) {
                self.bump();
            }
        }
    }

    /// An identifier — unless it spells a literal prefix (`r"…"`,
    /// `r#"…"#`, `b"…"`, `br#"…"#`, `b'…'`), in which case the whole
    /// literal is consumed.
    fn ident_or_prefixed_literal(&mut self, line: u32) {
        let mut name = String::new();
        while let Some(c) = self.peek(0) {
            if is_ident_char(c) {
                name.push(c);
                self.bump();
            } else {
                break;
            }
        }
        match name.as_str() {
            "r" | "br" | "rb" => {
                let mut hashes = 0;
                while self.peek(hashes) == Some('#') {
                    hashes += 1;
                }
                if self.peek(hashes) == Some('"') {
                    for _ in 0..=hashes {
                        self.bump(); // hashes + opening quote
                    }
                    self.raw_string_body(hashes);
                    self.push(TokKind::Str, line);
                    return;
                }
                // `r#ident` raw identifiers fall through: the `#` lexes
                // as punctuation, the rest as a plain identifier.
            }
            "b" => {
                if self.peek(0) == Some('"') {
                    self.bump();
                    self.string_body();
                    self.push(TokKind::Str, line);
                    return;
                }
                if self.peek(0) == Some('\'') {
                    self.quote(line);
                    // quote() pushed Char (a byte char is never a
                    // lifetime); rewrite the prefix token away: nothing
                    // to do, `b` was not pushed yet.
                    return;
                }
            }
            _ => {}
        }
        self.push(TokKind::Ident(name), line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokKind> {
        lex(src).into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn idents_and_punct() {
        assert_eq!(
            kinds("db.lock()"),
            vec![
                TokKind::Ident("db".into()),
                TokKind::Punct('.'),
                TokKind::Ident("lock".into()),
                TokKind::Open('('),
                TokKind::Close(')'),
            ]
        );
    }

    #[test]
    fn strings_hide_delimiters() {
        // Braces and quotes inside literals must not produce delimiter
        // tokens — the block parser downstream depends on it.
        let toks = kinds(r#"f("{", '\'', '{', "\"}")"#);
        let opens = toks.iter().filter(|k| matches!(k, TokKind::Open('{'))).count();
        let closes = toks.iter().filter(|k| matches!(k, TokKind::Close('}'))).count();
        assert_eq!((opens, closes), (0, 0), "{toks:?}");
    }

    #[test]
    fn raw_strings() {
        let toks = kinds(r##"let s = r#"a " b { } "#; x"##);
        assert!(toks.contains(&TokKind::Str));
        assert!(toks.contains(&TokKind::Ident("x".into())));
        assert!(!toks.iter().any(|k| matches!(k, TokKind::Open('{'))));
    }

    #[test]
    fn lifetime_vs_char() {
        let toks = kinds("fn f<'a>(x: &'a str) { let c = 'a'; }");
        assert_eq!(toks.iter().filter(|k| **k == TokKind::Lifetime).count(), 2);
        assert_eq!(toks.iter().filter(|k| **k == TokKind::Char).count(), 1);
    }

    #[test]
    fn nested_block_comments_and_lines() {
        let toks = lex("a\n/* x /* y */ z */\nb");
        assert_eq!(toks.len(), 3);
        assert_eq!(toks[2].line, 3);
    }

    #[test]
    fn line_comment_text_is_kept() {
        let toks = lex("x // oarlint: allow(R5) reason\ny");
        assert!(matches!(
            &toks[1].kind,
            TokKind::Comment(t) if t.contains("oarlint: allow(R5)")
        ));
    }

    #[test]
    fn numbers_with_suffixes() {
        let toks = kinds("1_000u64 + 0xff + 3.25");
        assert_eq!(toks.iter().filter(|k| **k == TokKind::Num).count(), 3);
    }
}
