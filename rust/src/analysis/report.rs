//! Diagnostics and report rendering for `oarlint`.
//!
//! A [`Report`] carries the surviving findings (errors fail the run,
//! warnings do not), the findings that were silenced by `// oarlint:
//! allow(..)` comments — kept, with their written reasons, so suppression
//! stays visible instead of vanishing — and the scan counts. It renders
//! either as compiler-style human text or as JSON via [`crate::util::Json`]
//! for the CI artifact.

use crate::util::Json;

/// Finding severity. Only errors make the lint exit nonzero.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// One diagnostic: rule, severity, location, message.
#[derive(Debug, Clone)]
pub struct Finding {
    /// "R1".."R6" for invariant rules, "lint" for meta-diagnostics
    /// (malformed or unused suppressions).
    pub rule: String,
    pub severity: Severity,
    pub file: String,
    pub line: u32,
    pub message: String,
}

/// A finding that an `allow` comment silenced, with its reason.
#[derive(Debug, Clone)]
pub struct Suppressed {
    pub finding: Finding,
    pub reason: String,
}

/// The result of one lint run.
#[derive(Debug, Default)]
pub struct Report {
    /// Unsuppressed findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Findings silenced by a valid `allow`, same ordering.
    pub suppressed: Vec<Suppressed>,
    pub files_scanned: usize,
    pub functions_scanned: usize,
}

impl Report {
    pub fn errors(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.severity == Severity::Error)
            .count()
    }

    pub fn warnings(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| f.severity == Severity::Warning)
            .count()
    }

    /// Unsuppressed findings for one rule (tests use this).
    pub fn of_rule<'a>(&'a self, rule: &'a str) -> impl Iterator<Item = &'a Finding> {
        self.findings.iter().filter(move |f| f.rule == rule)
    }

    /// Compiler-style human rendering.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}: [{}] {}:{}: {}\n",
                f.severity.as_str(),
                f.rule,
                f.file,
                f.line,
                f.message
            ));
        }
        if !self.suppressed.is_empty() {
            out.push_str(&format!(
                "{} finding(s) suppressed by oarlint: allow comments:\n",
                self.suppressed.len()
            ));
            for s in &self.suppressed {
                out.push_str(&format!(
                    "  allowed: [{}] {}:{}: {} — {}\n",
                    s.finding.rule, s.finding.file, s.finding.line, s.finding.message, s.reason
                ));
            }
        }
        out.push_str(&format!(
            "oarlint: {} file(s), {} function(s) scanned; {} error(s), {} warning(s), {} suppressed\n",
            self.files_scanned,
            self.functions_scanned,
            self.errors(),
            self.warnings(),
            self.suppressed.len()
        ));
        out
    }

    /// JSON rendering for the CI artifact.
    pub fn to_json(&self) -> Json {
        fn finding_json(f: &Finding) -> Vec<(&'static str, Json)> {
            vec![
                ("rule", Json::Str(f.rule.clone())),
                ("severity", Json::Str(f.severity.as_str().to_string())),
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("message", Json::Str(f.message.clone())),
            ]
        }
        Json::obj(vec![
            ("tool", Json::Str("oarlint".to_string())),
            ("files_scanned", Json::Num(self.files_scanned as f64)),
            (
                "functions_scanned",
                Json::Num(self.functions_scanned as f64),
            ),
            ("errors", Json::Num(self.errors() as f64)),
            ("warnings", Json::Num(self.warnings() as f64)),
            (
                "findings",
                Json::Arr(
                    self.findings
                        .iter()
                        .map(|f| Json::obj(finding_json(f)))
                        .collect(),
                ),
            ),
            (
                "suppressed",
                Json::Arr(
                    self.suppressed
                        .iter()
                        .map(|s| {
                            let mut fields = finding_json(&s.finding);
                            fields.push(("reason", Json::Str(s.reason.clone())));
                            Json::obj(fields)
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        Report {
            findings: vec![Finding {
                rule: "R2".to_string(),
                severity: Severity::Error,
                file: "rust/src/x.rs".to_string(),
                line: 7,
                message: "blocking call `connect` while holding `db` (write)".to_string(),
            }],
            suppressed: vec![Suppressed {
                finding: Finding {
                    rule: "R5".to_string(),
                    severity: Severity::Error,
                    file: "rust/src/y.rs".to_string(),
                    line: 3,
                    message: "`unwrap()` in a request path".to_string(),
                },
                reason: "startup-fatal by design".to_string(),
            }],
            files_scanned: 2,
            functions_scanned: 5,
        }
    }

    #[test]
    fn human_rendering_has_locations_and_counts() {
        let text = sample().render_human();
        assert!(text.contains("error: [R2] rust/src/x.rs:7:"), "{text}");
        assert!(text.contains("startup-fatal by design"), "{text}");
        assert!(text.contains("1 error(s)"), "{text}");
    }

    #[test]
    fn json_round_trips() {
        let j = sample().to_json();
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.get("errors").and_then(Json::as_i64), Some(1));
        let findings = parsed.get("findings").and_then(Json::as_arr).unwrap();
        assert_eq!(findings.len(), 1);
        assert_eq!(
            findings[0].get("rule").and_then(Json::as_str),
            Some("R2")
        );
        let sup = parsed.get("suppressed").and_then(Json::as_arr).unwrap();
        assert_eq!(
            sup[0].get("reason").and_then(Json::as_str),
            Some("startup-fatal by design")
        );
    }
}
