//! Guard-lifetime dataflow for `oarlint`.
//!
//! Walks one function body (a slice of the delimiter tree) and emits a
//! linear event stream: guard acquisitions and releases, blocking calls
//! with the set of guards live at that point, WAL commits, and the
//! "ack" calls (`notify` / `push_event`) and remote submissions that the
//! R3 ordering rules reason about. The rules layer never re-walks the
//! tree: everything it needs is in the events.
//!
//! ## The lifetime model
//!
//! - An acquisition is `<chain>.lock()` / `.read()` / `.write()` with
//!   empty parens (argument-taking `read`/`write` are I/O, not locks),
//!   or `lock_sane(&<chain>)`. Its **class** is the last field name in
//!   the chain (`self.shared.active.lock()` → `active`): lock identity
//!   is keyed by field name, which is unique per lock in this codebase.
//! - `let g = <acquisition>.unwrap();` binds a **named guard**: it lives
//!   until `drop(g)`, or the end of the block that declared it. The
//!   binding is recognized only when the chain after the acquisition is
//!   nothing but `unwrap`/`expect`/`unwrap_or_else` — in
//!   `let n = q.lock().unwrap().len();` the guard is a temporary.
//! - Any other acquisition is a **temporary**: it dies at the end of its
//!   statement. A temporary in a `for`/`match` header lives through the
//!   body (Rust keeps scrutinee temporaries alive), which is exactly the
//!   shape of the PR 4 bug class. (`if`/`while` headers get the same
//!   conservative treatment; the tree has no guard-in-condition sites.)
//! - `read_db(|db| …)` / `write_db(|db| …)` / `with_db(|db| …)` are the
//!   server's closure-scoped guard helpers: modeled as a synthetic `db`
//!   guard covering the call's arguments, with `write_db`/`with_db`
//!   additionally committing at region end (their definitions do).
//! - Condvar waits (`.wait(g)` / `.wait_timeout(g, d)` / `wait_sane(cv,
//!   g, d)`) are a guard *transfer*, not a new acquisition: the guard
//!   named in the arguments is exempt from the blocking check, any other
//!   live guard is reported.

use super::lexer::TokKind;
use super::parser::Node;

/// How a guard locks its data.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Read,
    Write,
    Mutex,
}

impl Mode {
    pub fn as_str(self) -> &'static str {
        match self {
            Mode::Read => "read",
            Mode::Write => "write",
            Mode::Mutex => "mutex",
        }
    }
}

/// A guard as seen by the rules: lock class, mode, acquisition line.
#[derive(Debug, Clone)]
pub struct GuardRef {
    pub class: String,
    pub mode: Mode,
    pub line: u32,
}

/// One step of the per-function event stream, in source order.
#[derive(Debug)]
pub enum Event {
    /// A guard was acquired; `held` is what was already live.
    Acquire { guard: GuardRef, held: Vec<GuardRef> },
    /// A guard went out of scope (drop(), block end, statement end).
    Release { class: String, mode: Mode, line: u32 },
    /// A call from the blocking set, with the guards live across it.
    Blocking {
        call: String,
        line: u32,
        held: Vec<GuardRef>,
    },
    /// A WAL commit boundary (`commit_wal`, `flush_wal`, `.commit()`).
    Commit { line: u32 },
    /// An acknowledgement (`.notify(..)` / `.push_event(..)`).
    Ack {
        call: String,
        line: u32,
        held: Vec<GuardRef>,
    },
    /// A remote submission (`.sub(..)`) — R3's grid-side trigger.
    Send { line: u32 },
    /// A telemetry call (`.observe(..)` / `.inc()` / `.rise()` /
    /// `.fall()` / `Span::enter(..)`) with guards live across it —
    /// R7's raw material. Only emitted when something is held: an
    /// unguarded metric update is always fine.
    Telemetry {
        call: String,
        line: u32,
        held: Vec<GuardRef>,
    },
}

/// Walk `body` and produce its event stream.
pub fn analyze_fn(body: &[Node]) -> Vec<Event> {
    let close_line = body.last().map(Node::line).unwrap_or(0);
    let mut w = Walker {
        live: Vec::new(),
        events: Vec::new(),
        stmt_temps: Vec::new(),
        next_id: 0,
        depth: 0,
    };
    w.walk_block(body, close_line);
    w.events
}

struct LiveGuard {
    id: u64,
    class: String,
    mode: Mode,
    line: u32,
    var: Option<String>,
}

struct TempRec {
    id: u64,
    promotable: bool,
}

struct Walker {
    live: Vec<LiveGuard>,
    events: Vec<Event>,
    /// Guards acquired by the statement currently being scanned.
    stmt_temps: Vec<TempRec>,
    next_id: u64,
    /// Paren/bracket nesting inside the current statement (0 = the
    /// statement's own expression level; promotion requires 0).
    depth: usize,
}

/// Names whose calls block (network, process control, disk sync, thread
/// join). `.flush()`/`write_all` on the WAL sink are deliberately *not*
/// here: serializing those writes is the sink lock's whole job.
fn is_blocking(name: &str, args: &[Node]) -> bool {
    match name {
        "connect" | "connect_timeout" | "sleep" | "launch" | "kill" | "shutdown"
        | "checkpoint" | "snapshot" | "flush_wal" | "accept" | "ping_all" | "sub" | "del" => true,
        // Thread join only: `path.join("x")` takes arguments.
        "join" => args.is_empty(),
        _ => false,
    }
}

fn idents_in(nodes: &[Node]) -> Vec<String> {
    let mut out = Vec::new();
    for n in nodes {
        match n {
            Node::Leaf(t) => {
                if let TokKind::Ident(s) = &t.kind {
                    out.push(s.clone());
                }
            }
            Node::Group { children, .. } => out.extend(idents_in(children)),
        }
    }
    out
}

/// The lock class of a `<chain>.lock()` acquisition: the identifier just
/// before the final `.`.
fn chain_class(nodes: &[Node], call_idx: usize) -> String {
    if call_idx >= 2 {
        if let Some(s) = nodes[call_idx - 2].ident() {
            return s.to_string();
        }
    }
    "anon".to_string()
}

/// Last identifier inside a `lock_sane(&self.shared.active)` argument.
fn last_arg_ident(args: &[Node]) -> String {
    idents_in(args)
        .into_iter()
        .next_back()
        .unwrap_or_else(|| "anon".to_string())
}

/// After an acquisition's `()` at sibling index `after`, is the rest of
/// the chain just unwrap-family calls followed by a statement end? That
/// is the shape under which a `let` binds the guard itself.
fn clean_tail(nodes: &[Node], mut after: usize) -> bool {
    loop {
        if nodes.get(after).map(|n| n.is_punct('.')) == Some(true) {
            let name = nodes.get(after + 1).and_then(Node::ident);
            let is_call = matches!(
                nodes.get(after + 2),
                Some(Node::Group { delim: '(', .. })
            );
            if is_call && matches!(name, Some("unwrap" | "expect" | "unwrap_or_else")) {
                after += 3;
                continue;
            }
            return false;
        }
        break;
    }
    match nodes.get(after) {
        None => true,
        Some(n) => n.is_punct(';') || n.is_punct('?') || n.ident() == Some("else"),
    }
}

/// Identifiers that can appear in a `let` pattern without being the
/// binding we want.
fn pattern_filler(s: &str) -> bool {
    matches!(s, "mut" | "ref" | "box" | "Ok" | "Err" | "Some" | "None")
}

fn first_pattern_ident(n: &Node) -> Option<String> {
    match n {
        Node::Leaf(t) => match &t.kind {
            TokKind::Ident(s) if !pattern_filler(s) => Some(s.clone()),
            _ => None,
        },
        Node::Group { children, .. } => children.iter().find_map(first_pattern_ident),
    }
}

impl Walker {
    fn held_refs(&self) -> Vec<GuardRef> {
        self.live
            .iter()
            .map(|g| GuardRef {
                class: g.class.clone(),
                mode: g.mode,
                line: g.line,
            })
            .collect()
    }

    fn acquire(&mut self, class: String, mode: Mode, line: u32) -> u64 {
        let held = self.held_refs();
        self.events.push(Event::Acquire {
            guard: GuardRef {
                class: class.clone(),
                mode,
                line,
            },
            held,
        });
        let id = self.next_id;
        self.next_id += 1;
        self.live.push(LiveGuard {
            id,
            class,
            mode,
            line,
            var: None,
        });
        id
    }

    fn release_id(&mut self, id: u64, line: u32) {
        if let Some(pos) = self.live.iter().position(|g| g.id == id) {
            let g = self.live.remove(pos);
            self.events.push(Event::Release {
                class: g.class,
                mode: g.mode,
                line,
            });
        }
    }

    fn release_var(&mut self, var: &str, line: u32) {
        if let Some(pos) = self
            .live
            .iter()
            .rposition(|g| g.var.as_deref() == Some(var))
        {
            let id = self.live[pos].id;
            self.release_id(id, line);
        }
    }

    fn walk_block(&mut self, nodes: &[Node], close_line: u32) {
        let mut owned: Vec<u64> = Vec::new();
        let mut i = 0;
        while i < nodes.len() {
            i = self.statement(nodes, i, &mut owned);
        }
        for id in owned.iter().rev() {
            self.release_id(*id, close_line);
        }
    }

    /// Process one statement starting at `start`; returns the index just
    /// past it. Handles `let`-binding promotion and temporary lifetimes.
    fn statement(&mut self, nodes: &[Node], start: usize, owned: &mut Vec<u64>) -> usize {
        let saved_temps = std::mem::take(&mut self.stmt_temps);
        let saved_depth = std::mem::replace(&mut self.depth, 0);

        let is_let = nodes[start].ident() == Some("let");
        let mut i = start;
        let mut pat_var: Option<String> = None;
        let mut end_line = nodes[start].line();

        if is_let {
            // Pattern region: up to the `=` (or `;` for `let x;`).
            i += 1;
            while let Some(n) = nodes.get(i) {
                if n.is_punct('=') {
                    i += 1;
                    break;
                }
                if n.is_punct(';') {
                    break;
                }
                if pat_var.is_none() {
                    pat_var = first_pattern_ident(n);
                }
                i += 1;
            }
        }

        loop {
            let Some(n) = nodes.get(i) else { break };
            match n {
                Node::Leaf(t) => {
                    end_line = t.line;
                    if matches!(t.kind, TokKind::Punct(';') | TokKind::Punct(',')) {
                        i += 1;
                        break;
                    }
                    i = self.leaf(nodes, i);
                }
                Node::Group {
                    delim: '{',
                    children,
                    close_line,
                    ..
                } => {
                    self.walk_block(children, *close_line);
                    end_line = *close_line;
                    i += 1;
                    if !is_let {
                        // A block ends the statement unless the grammar
                        // continues it (`else` chains, method-on-block).
                        match nodes.get(i) {
                            Some(nx)
                                if nx.ident() == Some("else")
                                    || nx.is_punct('.')
                                    || nx.is_punct('?') => {}
                            _ => break,
                        }
                    }
                }
                Node::Group {
                    children,
                    close_line,
                    ..
                } => {
                    self.depth += 1;
                    self.scan_nodes(children);
                    self.depth -= 1;
                    end_line = *close_line;
                    i += 1;
                }
            }
        }

        // Statement end: promote the single clean `let`-bound guard,
        // release every other temporary (for/match header temporaries
        // have already lived through their body above).
        let temps = std::mem::take(&mut self.stmt_temps);
        if is_let && temps.len() == 1 && temps[0].promotable && pat_var.is_some() {
            if let Some(g) = self.live.iter_mut().find(|g| g.id == temps[0].id) {
                g.var = pat_var;
                owned.push(temps[0].id);
            }
        } else {
            for t in temps.iter().rev() {
                self.release_id(t.id, end_line);
            }
        }

        self.stmt_temps = saved_temps;
        self.depth = saved_depth;
        i
    }

    /// Expression-level scan (inside paren/bracket groups): no statement
    /// semantics, but brace groups still open scopes.
    fn scan_nodes(&mut self, nodes: &[Node]) {
        let mut i = 0;
        while i < nodes.len() {
            match &nodes[i] {
                Node::Leaf(_) => i = self.leaf(nodes, i),
                Node::Group {
                    delim: '{',
                    children,
                    close_line,
                    ..
                } => {
                    self.walk_block(children, *close_line);
                    i += 1;
                }
                Node::Group { children, .. } => {
                    self.depth += 1;
                    self.scan_nodes(children);
                    self.depth -= 1;
                    i += 1;
                }
            }
        }
    }

    /// Handle the leaf at `i` (with sibling lookaround for call shapes);
    /// returns the next index to process.
    fn leaf(&mut self, nodes: &[Node], i: usize) -> usize {
        let Some(name) = nodes[i].ident().map(str::to_string) else {
            return i + 1;
        };
        let line = nodes[i].line();
        let is_method = i > 0 && nodes[i - 1].is_punct('.');
        let (args, args_close) = match nodes.get(i + 1) {
            Some(Node::Group {
                delim: '(',
                children,
                close_line,
                ..
            }) => (children.as_slice(), *close_line),
            _ => return i + 1, // not a call shape (macros have `!` between)
        };

        match name.as_str() {
            "lock" | "read" | "write" if is_method && args.is_empty() => {
                let mode = match name.as_str() {
                    "read" => Mode::Read,
                    "write" => Mode::Write,
                    _ => Mode::Mutex,
                };
                let class = chain_class(nodes, i);
                let id = self.acquire(class, mode, line);
                self.stmt_temps.push(TempRec {
                    id,
                    promotable: self.depth == 0 && clean_tail(nodes, i + 2),
                });
                return i + 2;
            }
            "lock_sane" if !is_method => {
                let class = last_arg_ident(args);
                let id = self.acquire(class, Mode::Mutex, line);
                self.stmt_temps.push(TempRec {
                    id,
                    promotable: self.depth == 0 && clean_tail(nodes, i + 2),
                });
                return i + 2;
            }
            "read_db" | "write_db" | "with_db" => {
                let mode = if name == "read_db" {
                    Mode::Read
                } else {
                    Mode::Write
                };
                let id = self.acquire("db".to_string(), mode, line);
                self.depth += 1;
                self.scan_nodes(args);
                self.depth -= 1;
                self.release_id(id, args_close);
                if mode == Mode::Write {
                    // write_db/with_db commit before returning.
                    self.events.push(Event::Commit { line: args_close });
                }
                return i + 2;
            }
            "wait" | "wait_timeout" | "wait_sane" => {
                // Condvar transfer: the guard passed in is exempt.
                let arg_idents = idents_in(args);
                let held: Vec<GuardRef> = self
                    .live
                    .iter()
                    .filter(|g| match &g.var {
                        Some(v) => !arg_idents.contains(v),
                        None => true,
                    })
                    .map(|g| GuardRef {
                        class: g.class.clone(),
                        mode: g.mode,
                        line: g.line,
                    })
                    .collect();
                if !held.is_empty() {
                    self.events.push(Event::Blocking {
                        call: name,
                        line,
                        held,
                    });
                }
                self.depth += 1;
                self.scan_nodes(args);
                self.depth -= 1;
                return i + 2;
            }
            "drop" if !is_method => {
                if let [only] = args {
                    if let Some(v) = only.ident() {
                        self.release_var(v, line);
                        return i + 2;
                    }
                }
            }
            _ => {}
        }

        if name == "commit_wal" || name == "flush_wal" || (name == "commit" && is_method && args.is_empty()) {
            self.events.push(Event::Commit { line });
        }
        if is_method && (name == "notify" || name == "push_event") {
            self.events.push(Event::Ack {
                call: name.clone(),
                line,
                held: self.held_refs(),
            });
        }
        if is_method && name == "sub" {
            self.events.push(Event::Send { line });
        }
        // Telemetry sites: metric-record methods, plus the path-call
        // `Span::enter` (`is_method` is false for `::` calls). Recorded
        // only while guards are live — that is the only case R7 reads.
        if !self.live.is_empty()
            && ((is_method && matches!(name.as_str(), "observe" | "inc" | "rise" | "fall"))
                || (!is_method && name == "enter"))
        {
            self.events.push(Event::Telemetry {
                call: name.clone(),
                line,
                held: self.held_refs(),
            });
        }
        if is_blocking(&name, args) && !self.live.is_empty() {
            self.events.push(Event::Blocking {
                call: name.clone(),
                line,
                held: self.held_refs(),
            });
        }

        self.depth += 1;
        self.scan_nodes(args);
        self.depth -= 1;
        i + 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::{lexer::lex, parser};

    fn events_of(src: &str) -> Vec<Event> {
        let tokens = lex(src);
        let nodes = parser::parse(&tokens);
        let fns = parser::functions(&nodes);
        assert_eq!(fns.len(), 1, "test source must hold exactly one fn");
        analyze_fn(fns[0].body)
    }

    fn acquires(evs: &[Event]) -> Vec<(&str, Mode)> {
        evs.iter()
            .filter_map(|e| match e {
                Event::Acquire { guard, .. } => Some((guard.class.as_str(), guard.mode)),
                _ => None,
            })
            .collect()
    }

    fn blocking(evs: &[Event]) -> Vec<&str> {
        evs.iter()
            .filter_map(|e| match e {
                Event::Blocking { call, .. } => Some(call.as_str()),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn named_guard_lives_until_drop() {
        let evs = events_of(
            "fn f(s: &S) {
                let mut db = s.db.write().unwrap();
                db.touch();
                drop(db);
                std::thread::sleep(d);
            }",
        );
        assert_eq!(acquires(&evs), vec![("db", Mode::Write)]);
        assert!(blocking(&evs).is_empty(), "{evs:?}");
    }

    #[test]
    fn temporary_dies_at_statement_end() {
        let evs = events_of(
            "fn f(s: &S) {
                s.active.lock().unwrap().push(1);
                std::thread::sleep(d);
            }",
        );
        assert!(blocking(&evs).is_empty(), "{evs:?}");
    }

    #[test]
    fn for_header_temporary_lives_through_body() {
        let evs = events_of(
            "fn f(s: &S) {
                for (_, stream) in s.active.lock().unwrap().iter() {
                    let _ = stream.shutdown(Shutdown::Read);
                }
            }",
        );
        assert_eq!(blocking(&evs), vec!["shutdown"]);
    }

    #[test]
    fn blocking_under_named_guard_is_reported() {
        let evs = events_of(
            "fn f(s: &S) {
                let db = s.db.write().unwrap();
                std::thread::sleep(d);
                drop(db);
            }",
        );
        assert_eq!(blocking(&evs), vec!["sleep"]);
    }

    #[test]
    fn condvar_wait_exempts_its_own_guard() {
        let evs = events_of(
            "fn f(s: &S) {
                let mut q = s.queue.lock().unwrap();
                while q.len() > 4 {
                    q = wait_sane(&s.cv, q, d);
                }
                drop(q);
            }",
        );
        assert!(blocking(&evs).is_empty(), "{evs:?}");
    }

    #[test]
    fn condvar_wait_reports_other_guards() {
        let evs = events_of(
            "fn f(s: &S) {
                let db = s.db.read().unwrap();
                let mut q = s.queue.lock().unwrap();
                q = wait_sane(&s.cv, q, d);
                drop(q);
                drop(db);
            }",
        );
        assert_eq!(blocking(&evs), vec!["wait_sane"]);
    }

    #[test]
    fn helper_regions_are_synthetic_guards_with_commit() {
        let evs = events_of(
            "fn f(s: &S) {
                s.write_db(|db| db.touch());
                s.hub.notify(Task::Schedule);
            }",
        );
        // Acquire(db,W), Release, Commit, Ack — in that order.
        assert_eq!(acquires(&evs), vec![("db", Mode::Write)]);
        let shape: Vec<&str> = evs
            .iter()
            .map(|e| match e {
                Event::Acquire { .. } => "acq",
                Event::Release { .. } => "rel",
                Event::Commit { .. } => "commit",
                Event::Ack { .. } => "ack",
                _ => "?",
            })
            .collect();
        assert_eq!(shape, vec!["acq", "rel", "commit", "ack"]);
    }

    #[test]
    fn block_scoped_guard_releases_at_brace() {
        let evs = events_of(
            "fn f(s: &S) {
                {
                    let mut db = s.db.write().unwrap();
                    db.touch();
                }
                s.launcher.kill(&nodes);
            }",
        );
        assert!(blocking(&evs).is_empty(), "{evs:?}");
    }

    #[test]
    fn nested_acquisition_reports_held_guards() {
        let evs = events_of(
            "fn f(s: &S) {
                let db = s.db.write().unwrap();
                let sink = s.sink.lock().unwrap();
                drop(sink);
                drop(db);
            }",
        );
        let nested: Vec<(&str, &str)> = evs
            .iter()
            .filter_map(|e| match e {
                Event::Acquire { guard, held } if !held.is_empty() => {
                    Some((held[0].class.as_str(), guard.class.as_str()))
                }
                _ => None,
            })
            .collect();
        assert_eq!(nested, vec![("db", "sink")]);
    }

    #[test]
    fn let_with_trailing_method_is_a_temporary() {
        // `let n = q.lock().unwrap().len();` must NOT bind a guard to n.
        let evs = events_of(
            "fn f(s: &S) {
                let n = s.queue.lock().unwrap().len();
                std::thread::sleep(d);
            }",
        );
        assert!(blocking(&evs).is_empty(), "{evs:?}");
    }
}
