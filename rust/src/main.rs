//! `oar` — leader entrypoint and CLI.
//!
//! See `oar help` for the command list: one evaluation subcommand per
//! paper table/figure, plus a live demo of the full system.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match oar::cli::run(args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
