//! The live system: database + central automaton + meta-scheduler +
//! launcher + monitoring wired together, with the submission interface of
//! §2.1 (`oarsub`/`oardel`/`oarstat` semantics).
//!
//! Threading model (the paper's §2.2 structure): ONE automaton thread runs
//! all executive modules sequentially, reading work from the
//! [`NotificationHub`]; submissions and job-end events only touch the
//! database and then notify the hub. Job execution gets a thread per
//! launched job (the paper forks per-job execution processes), which
//! drives the launcher, simulates the command's runtime on the virtual
//! cluster, and reports termination as an event.
//!
//! Clock: the server counts **milliseconds** since startup (`Time` is
//! unit-agnostic; the discrete-event simulator uses seconds). `maxTime`
//! given in seconds by `submit` is converted. Modeled latencies (launcher)
//! and simulated command runtimes are scaled by `time_scale`, so the burst
//! benchmarks (figs. 9–10) can run a latency-faithful stack quickly.
//!
//! Locking model: the database sits behind an [`RwLock`]. Read-only
//! commands (`stat`, `nodes`, `queues`, `load`, accounting, the
//! terminal-state poll) share read guards and never wait behind a
//! scheduling round; every mutation takes the write half, and the round
//! itself *plans* under a read guard and only takes the write lock to
//! apply its decision. On a durable server the write path runs the WAL
//! in group-commit mode: appends buffer while the lock is held and land
//! as one batched log write (one `fsync` when enabled) right after it is
//! released, before the mutation is acknowledged to anyone.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::admission::{self, Admission};
use crate::central::{JobEvent, NotificationHub, Planner, Task, Work};
use crate::cluster::VirtualCluster;
use crate::db::{Accounting, AppendError, Db, DbError, Expr, WalCommit};
use crate::launcher::{Launcher, LauncherConfig};
use crate::matching::ScheduleStep;
use crate::monitor;
use crate::sched::{MetaScheduler, SchedulerConfig, SchedulerDecision};
use crate::types::{
    Job, JobId, JobSpec, JobState, NodeId, Queue, RecoveryPolicy, ReservationField, Time,
};
use crate::Result;

/// Server configuration.
pub struct ServerConfig {
    pub launcher: LauncherConfig,
    pub sched: SchedulerConfig,
    /// Periodic (redundant) re-execution periods, §2.2.
    pub schedule_every: Duration,
    pub monitor_every: Duration,
    pub check_jobs_every: Duration,
    /// Scale applied to simulated command runtimes (`sleep N`).
    pub time_scale: f64,
    /// Durable state directory. When set, [`Server::open`] recovers the
    /// database (snapshot + WAL replay) from it at startup and every
    /// mutation is WAL-logged before it is applied.
    pub data_dir: Option<PathBuf>,
    /// What restart reconciliation does with jobs stranded in-flight.
    pub recovery: RecoveryPolicy,
    /// WAL records between automatic snapshot+truncate checkpoints
    /// (0 = checkpoint only at shutdown).
    pub checkpoint_every: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            launcher: LauncherConfig::default(),
            sched: SchedulerConfig::default(),
            schedule_every: Duration::from_secs(30),
            monitor_every: Duration::from_secs(60),
            check_jobs_every: Duration::from_secs(30),
            time_scale: 1.0,
            data_dir: None,
            recovery: RecoveryPolicy::default(),
            checkpoint_every: 4096,
        }
    }
}

/// Snapshot of a cluster's occupancy, answered by the `load` RPC method —
/// the probe the grid meta-scheduler sizes its dispatch waves with
/// (load-aware placement across federated clusters).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LoadInfo {
    pub nodes_total: u32,
    pub nodes_alive: u32,
    pub procs_total: u32,
    /// Processors on `Alive` nodes (the schedulable pool).
    pub procs_alive: u32,
    /// Processors held by jobs in resource-holding states — *whatever*
    /// the state of the node they sit on. A dead node's claim stays
    /// counted until the automaton fails or requeues its jobs, so
    /// `procs_free` never resurrects capacity that a node death already
    /// removed from `procs_alive`.
    pub procs_busy: u32,
    /// `procs_alive - procs_busy` (saturating): capacity a dispatcher
    /// may actually aim new work at.
    pub procs_free: u32,
    /// Jobs waiting to be scheduled (`Waiting`).
    pub waiting_jobs: u32,
    /// Jobs holding or about to hold resources (`toLaunch`/`Launching`/
    /// `Running`).
    pub running_jobs: u32,
}

/// What [`Server::open`] found and did while bringing the durable
/// database back: the recovery path (generation, snapshot, replayed WAL
/// tail) and the restart reconciliation (stranded jobs and the state each
/// was stranded in).
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    pub generation: u64,
    pub snapshot_loaded: bool,
    pub replayed_records: u64,
    pub torn_tail: bool,
    pub reconciled: Vec<(JobId, JobState)>,
}

impl ServerConfig {
    /// Fast configuration for tests and benchmarks: modeled latencies are
    /// compressed by `scale`.
    pub fn fast(scale: f64) -> ServerConfig {
        ServerConfig {
            launcher: LauncherConfig {
                time_scale: scale,
                ..Default::default()
            },
            schedule_every: Duration::from_millis(200),
            monitor_every: Duration::from_millis(500),
            check_jobs_every: Duration::from_millis(200),
            time_scale: scale,
            ..Default::default()
        }
    }
}

/// Shared innards handed to execution threads.
struct Inner {
    db: RwLock<Db>,
    /// Group-commit handle to the WAL's shared sink (`None` on a
    /// volatile server): flushes the buffered batch *outside* the
    /// database lock, so the log write never extends a critical section.
    wal: Option<WalCommit>,
    hub: NotificationHub,
    launcher: Launcher,
    epoch: Instant,
    time_scale: f64,
    running: AtomicBool,
}

impl Inner {
    /// Milliseconds since server start.
    fn now(&self) -> Time {
        self.epoch.elapsed().as_millis() as Time
    }

    /// Write path: run `f` under the exclusive lock, then land the
    /// group-commit batch before returning — no mutation is ever
    /// acknowledged ahead of its log records. Concurrent writers that
    /// queued behind the same batch find it already flushed and return
    /// without touching the file (that is the group commit).
    fn write_db<T>(&self, f: impl FnOnce(&mut Db) -> T) -> T {
        let t0 = crate::obs::clock::now_us();
        let (out, wait_us) = {
            let mut db = self.db.write().unwrap();
            let wait = crate::obs::clock::now_us().saturating_sub(t0);
            (f(&mut db), wait)
        };
        self.commit_wal();
        // Recorded only after the guard dropped *and* the batch landed:
        // telemetry never runs inside the commit path (oarlint R7).
        crate::obs::metrics::DB_WRITE_WAIT_US.observe(wait_us);
        out
    }

    /// Read path: run `f` against a shared snapshot of the database.
    /// Many readers proceed concurrently; none blocks a scheduling
    /// round's planning phase.
    fn read_db<T>(&self, f: impl FnOnce(&Db) -> T) -> T {
        let t0 = crate::obs::clock::now_us();
        let (out, wait_us) = {
            let db = self.db.read().unwrap();
            let wait = crate::obs::clock::now_us().saturating_sub(t0);
            (f(&db), wait)
        };
        crate::obs::metrics::DB_READ_WAIT_US.observe(wait_us);
        out
    }

    /// Flush WAL records buffered by write guards that already dropped.
    /// Same discipline as `Db::mutate`: a poisoned log (simulated crash)
    /// is silent, a genuine I/O failure dies loudly.
    fn commit_wal(&self) {
        if let Some(wal) = &self.wal {
            match wal.commit() {
                Ok(()) | Err(AppendError::Injected) => {}
                Err(AppendError::Io(e)) => {
                    panic!("WAL commit failed, refusing to acknowledge mutations: {e}")
                }
            }
        }
    }
}

/// The OAR server.
pub struct Server {
    inner: Arc<Inner>,
    cluster: Arc<VirtualCluster>,
    automaton: Option<std::thread::JoinHandle<()>>,
    recovery: Option<RecoveryReport>,
}

impl Server {
    /// Build a server over a virtual cluster. The database is created,
    /// populated with the standard queues, default admission rules and the
    /// cluster inventory.
    pub fn new(cluster: Arc<VirtualCluster>, config: ServerConfig) -> Server {
        let mut db = Db::with_standard_queues();
        admission::install_default_rules(&mut db);
        cluster.register(&mut db);
        Self::from_db(db, cluster, config)
    }

    /// Build a **durable** server: recover the database from
    /// `config.data_dir` (fresh directory → fresh database, every
    /// mutation WAL-logged), populate the standard schema if this is the
    /// first boot, reconcile jobs stranded in-flight by the previous
    /// process per `config.recovery`, then start the automaton.
    /// [`Server::recovery_report`] describes what happened.
    pub fn open(cluster: Arc<VirtualCluster>, config: ServerConfig) -> Result<Server> {
        let dir = config
            .data_dir
            .clone()
            .ok_or_else(|| anyhow::anyhow!("Server::open requires config.data_dir"))?;
        let (mut db, stats) = Db::recover(&dir)?;
        db.set_checkpoint_every(config.checkpoint_every);
        // First boot of this directory: install the standard schema (all
        // of it WAL-logged, so even a crash before the first checkpoint
        // recovers a complete database).
        if db.queues_by_priority().is_empty() {
            for q in Queue::standard_set() {
                db.add_queue(q);
            }
        }
        if db.admission_rules().is_empty() {
            admission::install_default_rules(&mut db);
        }
        if db.all_nodes().is_empty() {
            cluster.register(&mut db);
        }
        // Reconcile before scheduling resumes; recovered timestamps are
        // from the previous epoch, so stamp recovery events just after
        // the last logged instant.
        let now = db.events().last().map(|e| e.time).unwrap_or(0);
        let reconciled = db.reconcile_in_flight(config.recovery, now);
        // Apply cancellation intents a crash interrupted *before* the
        // automaton exists: an acked `del` logs `DELETION_REQUESTED`
        // (WAL-appended) ahead of its in-memory Cancel event, and a
        // recovered short job could otherwise be rescheduled and run to
        // completion before any replayed event is processed. Terminal
        // jobs (the cancel did run, or reconciliation failed them) are
        // left alone; no launcher kill is needed — the previous
        // process's executions died with it. One pass over the event
        // log, the same order of work recovery already did to replay it.
        let pending: std::collections::BTreeSet<JobId> = db
            .events()
            .iter()
            .filter(|e| e.kind == "DELETION_REQUESTED")
            .filter_map(|e| e.job)
            .collect();
        for id in pending {
            let Ok(job) = db.job(id) else { continue };
            if job.state.is_terminal() {
                continue;
            }
            let _ = db.fail_job(id, "cancelled by user", now);
            db.log_event(now, "DELETION", Some(id), &job.user);
        }
        let report = RecoveryReport {
            generation: stats.generation,
            snapshot_loaded: stats.snapshot_loaded,
            replayed_records: stats.replayed,
            torn_tail: stats.torn_tail,
            reconciled,
        };
        let mut server = Self::from_db(db, cluster, config);
        server.recovery = Some(report);
        server.kick();
        Ok(server)
    }

    /// The recovery/reconciliation report of a [`Server::open`] boot.
    pub fn recovery_report(&self) -> Option<&RecoveryReport> {
        self.recovery.as_ref()
    }

    /// Simulate a whole-process crash (`kill -9`): the WAL stops
    /// accepting writes — every mutation from this instant is lost, as it
    /// would be with a real crash — and the server is torn down without a
    /// checkpoint. Bring the system back with [`Server::open`] on the
    /// same `data_dir`.
    pub fn simulate_crash(self) {
        self.with_db(|db| db.crash_wal());
    }

    /// Build over an existing database (e.g. restored from a snapshot).
    /// A durable database is switched to group-commit WAL mode: its
    /// appends buffer under the write lock and are flushed by the server
    /// write path before each mutation is acknowledged.
    pub fn from_db(mut db: Db, cluster: Arc<VirtualCluster>, config: ServerConfig) -> Server {
        db.set_wal_group_commit(true);
        let wal = db.wal_commit_handle();
        let launcher = Launcher::new(cluster.clone(), config.launcher.clone());
        let inner = Arc::new(Inner {
            db: RwLock::new(db),
            wal,
            hub: NotificationHub::new(),
            launcher,
            epoch: Instant::now(),
            time_scale: config.time_scale,
            running: AtomicBool::new(true),
        });

        let planner = Planner::new(
            config.schedule_every,
            config.monitor_every,
            config.check_jobs_every,
        );

        // The PJRT executable is not Send: build the engine (and therefore
        // the meta-scheduler) *inside* the automaton thread.
        let sched_cfg = config.sched.clone();
        let automaton = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("oar-central".into())
                .spawn(move || {
                    let engine: Box<dyn ScheduleStep> = if sched_cfg.dense_matching {
                        crate::runtime::HloStep::best_available()
                    } else {
                        Box::new(crate::matching::ReferenceStep)
                    };
                    let meta = MetaScheduler::new(sched_cfg, engine);
                    automaton_loop(inner, meta, planner)
                })
                .expect("spawn automaton")
        };

        Server {
            inner,
            cluster,
            automaton: Some(automaton),
            recovery: None,
        }
    }

    /// Milliseconds since server start (the server's `Time`).
    pub fn now(&self) -> Time {
        self.inner.now()
    }

    pub fn cluster(&self) -> &Arc<VirtualCluster> {
        &self.cluster
    }

    /// Run `f` against the database under the **write** lock (the only
    /// shared state there is); any WAL records it buffers are committed
    /// before this returns. Use [`Server::read_db`] for read-only work —
    /// it shares the lock with other readers.
    pub fn with_db<T>(&self, f: impl FnOnce(&mut Db) -> T) -> T {
        self.inner.write_db(f)
    }

    /// Run `f` against a shared read guard of the database: a consistent
    /// snapshot (no half-applied scheduling rounds) that other readers
    /// share concurrently. Reads may trail the latest acknowledged write
    /// by whatever the write lock is currently applying.
    pub fn read_db<T>(&self, f: impl FnOnce(&Db) -> T) -> T {
        self.inner.read_db(f)
    }

    // ------------------------------------------------------ commands ----

    /// `oarsub`: run admission, insert the job, notify the central module
    /// (§2.1 fig. 3). `max_time` in the spec is in *seconds*.
    pub fn submit(&self, spec: &JobSpec) -> Result<std::result::Result<JobId, String>> {
        let now = self.inner.now();
        let mut db = self.inner.db.write().unwrap();
        let admitted = match admission::admit(&mut db, spec)? {
            Admission::Accepted(s) => s,
            Admission::Rejected(reason) => return Ok(Err(reason)),
        };
        let mut job = Job::from_spec(&admitted, now);
        job.max_time = admitted.max_time.unwrap_or(3600) * 1000; // s → ms
        if let Some(r) = job.reservation_start {
            job.reservation_start = Some(r * 1000);
        }
        let id = db.insert_job(job);
        db.log_event(now, "SUBMISSION", Some(id), &admitted.user);
        drop(db);
        // Durable before acknowledged: the group-commit batch lands here,
        // outside the lock, before the id is returned or the round poked.
        self.inner.commit_wal();
        self.inner.hub.notify(Task::Schedule);
        Ok(Ok(id))
    }

    /// `oarsub --array N`: multi-parametric campaign submission (the §1
    /// user need OAR was built for: "support for multi-parametric
    /// applications (for large simulations composed of many small
    /// independent computations)"). Submits `n` copies of `spec`; every
    /// occurrence of `{i}` in the command is replaced by the task index.
    /// One admission pass per task (rules may depend on the command).
    pub fn submit_array(
        &self,
        spec: &JobSpec,
        n: u32,
    ) -> Result<std::result::Result<Vec<JobId>, String>> {
        let mut ids = Vec::with_capacity(n as usize);
        for i in 0..n {
            let task = JobSpec {
                command: spec.command.replace("{i}", &i.to_string()),
                ..spec.clone()
            };
            match self.submit(&task)? {
                Ok(id) => ids.push(id),
                Err(reason) => {
                    // All-or-nothing: cancel what was already inserted.
                    // Deliberately the *synchronous* path: the rejection
                    // must not be returned while rolled-back tasks are
                    // still live (an async Cancel could let a fast task
                    // finish after the client was told nothing was
                    // admitted). `cancel_job` holds the db lock and
                    // every consumer re-checks job state under it, so
                    // running here — including on an RPC worker — cannot
                    // corrupt a concurrent scheduling round.
                    for id in ids {
                        let _ = self.delete(id);
                    }
                    return Ok(Err(format!("task {i}: {reason}")));
                }
            }
        }
        Ok(Ok(ids))
    }

    /// `oardel`: cancel a job (waiting → Error; running → killed).
    /// Synchronous form for in-process callers; the body is the same
    /// [`cancel_job`] the automaton runs for [`JobEvent::Cancel`].
    pub fn delete(&self, id: JobId) -> Result<()> {
        cancel_job(&self.inner, id, self.inner.now())?;
        Ok(())
    }

    /// `oardel` over RPC: route the cancellation through the central
    /// automaton's event buffer instead of running it on the caller's
    /// thread, so a delete serializes with scheduling rounds (it can
    /// never interleave with the apply phase of a round). Returns the
    /// state the job was observed in at enqueue time; a terminal state
    /// means there was nothing left to cancel.
    ///
    /// The acknowledgment is durable: a `DELETION_REQUESTED` event is
    /// logged (and therefore WAL-appended on a durable server) *before*
    /// the in-memory event is enqueued, and [`Server::open`] applies
    /// cancellations whose processing a crash interrupted directly to
    /// the recovered database, before scheduling resumes — an acked
    /// `del` is never silently forgotten.
    pub fn request_delete(&self, id: JobId) -> Result<JobState> {
        let now = self.inner.now();
        let mut db = self.inner.db.write().unwrap();
        let job = db.job(id)?;
        let state = job.state;
        if !state.is_terminal() {
            // The audit trail records who the cancellation targets, like
            // SUBMISSION/DELETION do.
            db.log_event(now, "DELETION_REQUESTED", Some(id), &job.user);
            drop(db);
            // The durable-acknowledgment contract: the event is on disk
            // before the in-memory Cancel is enqueued.
            self.inner.commit_wal();
            self.inner.hub.push_event(JobEvent::Cancel { job: id, at: now });
        }
        Ok(state)
    }

    /// `oarstat`: all jobs (optionally filtered by a WHERE clause over the
    /// raw job columns, e.g. `state = 'Running' AND user = 'alice'`).
    pub fn stat(&self, filter: Option<&str>) -> Result<Vec<Job>> {
        let expr = Expr::parse(filter.unwrap_or(""))
            .map_err(|e| anyhow::anyhow!("bad filter: {e}"))?;
        Ok(self.read_db(|db| db.jobs_where(&expr)))
    }

    /// `oarstat --accounting`: aggregate usage report, computed in one
    /// zero-copy pass over the jobs table.
    pub fn accounting(&self) -> Accounting {
        self.read_db(|db| db.accounting())
    }

    /// `oarnodes`: fleet state.
    pub fn nodes(&self) -> Vec<(String, String, u32)> {
        self.read_db(monitor::fleet_summary)
    }

    /// The queue table, by decreasing priority (`queues` RPC method).
    pub fn queues(&self) -> Vec<Queue> {
        self.read_db(|db| db.queues_by_priority())
    }

    /// Typed snapshot of the whole metrics registry (`metrics` RPC
    /// method, `oar metrics` / `oar top`): the static catalogue merged
    /// with the database's per-plan counters and the event log's
    /// retention accounting, the latter read under one shared read
    /// guard so the db-derived numbers are mutually coherent.
    pub fn metrics_snapshot(&self) -> crate::obs::MetricsSnapshot {
        let dbc = self.read_db(|db| {
            let s = db.stats();
            crate::obs::DbCounters {
                selects: s.selects,
                inserts: s.inserts,
                updates: s.updates,
                deletes: s.deletes,
                index_probes: s.index_probes,
                full_scans: s.full_scans,
                view_hits: s.view_hits,
                events_len: db.events().len() as u64,
                events_evicted: db.events_evicted(),
                events_cap: db.event_retention() as u64,
            }
        });
        crate::obs::snapshot(Some(&dbc))
    }

    /// The newest `tail` events (returned oldest-first), optionally
    /// filtered by kind and/or job, plus the total number of live
    /// records matching the filter — the `events` RPC method. Read
    /// guard only: tailing the log never waits behind a round's apply
    /// phase.
    pub fn events_tail(
        &self,
        tail: usize,
        kind: Option<&str>,
        job: Option<JobId>,
    ) -> (Vec<crate::db::EventRecord>, usize) {
        self.read_db(|db| db.events_tail(tail, kind, job))
    }

    /// The `load` probe: current occupancy, answered from the database's
    /// materialized views under one read guard — O(1) whatever the table
    /// sizes, and mutually coherent because every view is maintained by
    /// the same write path.
    ///
    /// `procs_busy` counts every processor claimed by a resource-holding
    /// job, *including jobs on nodes that have since died*: a dead node's
    /// capacity already left `procs_alive`, so also dropping its jobs'
    /// claim from `procs_busy` would double-count the loss — the old
    /// alive-nodes-only sum made `procs_free` overshoot right when
    /// `running_jobs` still counted the stranded jobs, and the grid
    /// dispatched waves against capacity that did not exist.
    pub fn load_info(&self) -> LoadInfo {
        self.read_db(|db| {
            let load = db.cluster_load();
            let running: u64 = JobState::ALL
                .iter()
                .filter(|s| s.holds_resources())
                .map(|s| db.state_depth(*s))
                .sum();
            LoadInfo {
                nodes_total: load.nodes_total,
                nodes_alive: load.nodes_alive,
                procs_total: load.procs_total,
                procs_alive: load.procs_alive,
                procs_busy: load.procs_busy,
                procs_free: load.procs_alive.saturating_sub(load.procs_busy),
                waiting_jobs: db.state_depth(JobState::Waiting) as u32,
                running_jobs: running as u32,
            }
        })
    }

    /// `oarhold` / `oarresume`.
    pub fn hold(&self, id: JobId) -> Result<()> {
        let now = self.inner.now();
        // Gated inside the database to fig. 1's one edge into Hold
        // (Waiting → Hold): holding a launching/running job would strand
        // its assignment. Anything else surfaces as `illegal_state` over
        // RPC, mirroring `resume`'s gate.
        self.with_db(|db| db.hold_job(id, now))?;
        Ok(())
    }

    pub fn resume(&self, id: JobId) -> Result<()> {
        let now = self.inner.now();
        // Only the user-hold edge: fig. 1 also allows
        // toAckReservation → Waiting, but that edge belongs to the
        // automaton's reservation negotiation — `oarresume` must not
        // yank a reservation out from under it (the RPC contract
        // promises `illegal_state` for anything but Hold).
        self.with_db(|db| -> std::result::Result<(), DbError> {
            let job = db.job(id)?;
            if job.state != JobState::Hold {
                return Err(DbError::IllegalTransition {
                    job: id,
                    from: job.state,
                    to: JobState::Waiting,
                });
            }
            db.set_job_state(id, JobState::Waiting, now)
        })?;
        self.inner.hub.notify(Task::Schedule);
        Ok(())
    }

    /// Force a scheduling round soon (used by tests/benches).
    pub fn kick(&self) {
        self.inner.hub.notify(Task::Schedule);
    }

    /// Notification telemetry: (accepted, discarded-as-redundant).
    pub fn hub_stats(&self) -> (u64, u64) {
        (
            self.inner.hub.accepted.load(Ordering::Relaxed),
            self.inner.hub.discarded.load(Ordering::Relaxed),
        )
    }

    /// Block until every job is terminal (or `timeout`); returns success.
    pub fn wait_all_terminal(&self, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        loop {
            // Index-only counts: this poll loop used to materialize every
            // live job on each tick. A read guard — polling never stalls
            // the automaton's write path.
            let pending = self.read_db(|db| {
                JobState::ALL
                    .iter()
                    .filter(|s| !s.is_terminal())
                    .map(|s| db.count_jobs_in_state(*s))
                    .sum::<usize>()
            });
            if pending == 0 {
                return true;
            }
            if Instant::now() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Stop the automaton and join it; returns the final database for
    /// inspection (reports, snapshots).
    pub fn shutdown(mut self) -> Db {
        self.inner.running.store(false, Ordering::SeqCst);
        self.inner.hub.notify(Task::Shutdown);
        if let Some(h) = self.automaton.take() {
            let _ = h.join();
        }
        let inner = self.inner.clone();
        drop(self);
        match Arc::try_unwrap(inner) {
            Ok(i) => {
                let mut db = i.db.into_inner().unwrap();
                if db.is_durable() {
                    // Clean shutdown = checkpoint: compact the WAL into a
                    // snapshot generation so the next boot replays nothing
                    // (rotation flushes any group-commit remainder first).
                    let _ = db.checkpoint();
                }
                db
            }
            Err(shared) => {
                // Execution threads may still hold clones briefly: go
                // through a snapshot instead of waiting on them.
                let mut db = shared.db.write().unwrap();
                if db.is_durable() {
                    // oarlint: allow(R2) teardown: the final checkpoint must be atomic with the guard, or a straggler could write after it
                    let _ = db.checkpoint();
                }
                let tmp = std::env::temp_dir().join(format!(
                    "oar-shutdown-{}-{:?}.json",
                    std::process::id(),
                    std::thread::current().id()
                ));
                // oarlint: allow(R2) teardown: the snapshot must capture the exact guarded state; nothing else runs at shutdown
                db.snapshot(&tmp).expect("snapshot");
                drop(db);
                let restored = Db::restore(&tmp).expect("restore");
                let _ = std::fs::remove_file(tmp);
                restored
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.inner.running.store(false, Ordering::SeqCst);
        self.inner.hub.notify(Task::Shutdown);
        if let Some(h) = self.automaton.take() {
            let _ = h.join();
        }
    }
}

// -------------------------------------------------------- automaton ----

fn automaton_loop(inner: Arc<Inner>, mut meta: MetaScheduler, mut planner: Planner) {
    while inner.running.load(Ordering::SeqCst) {
        planner.tick(Instant::now(), &inner.hub);
        while let Some(work) = inner.hub.poll() {
            match work {
                Work::Task(Task::Shutdown) => return,
                Work::Task(Task::Schedule) => run_schedule(&inner, &mut meta),
                Work::Task(Task::Monitor) => {
                    let now = inner.now();
                    let _ = monitor::monitor_round(&inner.db, &inner.launcher, now);
                    inner.commit_wal();
                }
                Work::Task(Task::CheckJobs) => check_jobs(&inner),
                Work::Event(JobEvent::Ended { job, at, ok }) => finish_job(&inner, job, at, ok),
                Work::Event(JobEvent::Cancel { job, at }) => {
                    let _ = cancel_job(&inner, job, at);
                }
                Work::Event(JobEvent::LaunchFailed { job, at }) => {
                    let mut db = inner.db.write().unwrap();
                    let _ = db.fail_job(job, "launch failed", at);
                    db.log_event(at, "LAUNCH_FAILED", Some(job), "");
                    drop(db);
                    inner.commit_wal();
                    inner.hub.notify(Task::Schedule);
                }
            }
        }
        inner.hub.wait_timeout(planner.min_period());
    }
}

fn run_schedule(inner: &Arc<Inner>, meta: &mut MetaScheduler) {
    let now = inner.now();
    // Round span declared before any guard: locals drop in reverse
    // declaration order, so every guard taken below is released before
    // the span records (oarlint R7 — no telemetry under the write lock).
    let _round = crate::obs::Span::enter("sched.round", &crate::obs::metrics::SCHED_ROUND_US);
    crate::obs::metrics::SCHED_ROUNDS.inc();
    // Planning is pure and runs under a *read* guard: `stat`/`load`/grid
    // probes keep answering while the round computes its placement.
    let decision = {
        let _plan = crate::obs::Span::enter("sched.plan", &crate::obs::metrics::SCHED_PLAN_US);
        let db = inner.db.read().unwrap();
        match meta.round(&db, now) {
            Ok(d) => d,
            Err(e) => {
                drop(db);
                inner.write_db(|db| {
                    db.log_event(now, "SCHEDULER_ERROR", None, &e.to_string())
                });
                return;
            }
        }
    };
    apply_decision(inner, &decision, now);
}

fn apply_decision(inner: &Arc<Inner>, decision: &SchedulerDecision, now: Time) {
    // Declared before the write guard: the guard (and the group-commit
    // flush below) finish before this span records its duration.
    let _apply = crate::obs::Span::enter("sched.apply", &crate::obs::metrics::SCHED_APPLY_US);
    let mut db = inner.db.write().unwrap();

    for (id, nodes) in &decision.reservations_confirmed {
        // The grant was planned under a read guard: re-check the job is
        // still negotiating before pinning the slot (a concurrent delete
        // may have raced the round).
        let Ok(job) = db.job(*id) else { continue };
        if job.state != JobState::Waiting || job.reservation != ReservationField::ToSchedule {
            continue; // stale decision
        }
        if db.assigned_nodes(*id).is_empty() {
            db.assign_nodes(*id, nodes, job.weight);
        }
        let _ = db.set_job_reservation(*id, ReservationField::Scheduled);
        // fig. 1: Waiting → toAckReservation → (user ack) → Waiting.
        let _ = db.set_job_state(*id, JobState::ToAckReservation, now);
        let _ = db.set_job_state(*id, JobState::Waiting, now);
        db.log_event(now, "RESERVATION_CONFIRMED", Some(*id), "");
    }
    for id in &decision.reservations_rejected {
        let _ = db.fail_job(*id, "reservation slot unavailable", now);
        db.log_event(now, "RESERVATION_REJECTED", Some(*id), "");
    }
    for (id, why) in &decision.rejected {
        let _ = db.fail_job(*id, why, now);
        db.log_event(now, "REJECTED", Some(*id), why);
    }

    let mut kills: Vec<(JobId, Vec<NodeId>)> = Vec::new();
    for id in &decision.cancellations {
        let nodes = db.assigned_nodes(*id);
        let _ = db.fail_job(*id, "best-effort resources reclaimed", now);
        db.log_event(now, "BESTEFFORT_KILL", Some(*id), "");
        kills.push((*id, nodes));
    }

    // Moldable placements: persist the winning alternative's shape
    // *before* the assignment below reads the row, so `assign_nodes`
    // records the right per-node processor count.
    for (id, nb_nodes, weight) in &decision.reshapes {
        let Ok(job) = db.job(*id) else { continue };
        if job.state != JobState::Waiting {
            continue; // stale decision
        }
        let _ = db.set_job_shape(*id, *nb_nodes, *weight);
        db.log_event(
            now,
            "RESHAPED",
            Some(*id),
            &format!("nbNodes={nb_nodes} weight={weight}"),
        );
    }

    let mut launches: Vec<(JobId, Vec<NodeId>, Time)> = Vec::new();
    for (id, nodes) in &decision.starts {
        let Ok(job) = db.job(*id) else { continue };
        if job.state != JobState::Waiting {
            continue; // stale decision (job deleted meanwhile)
        }
        if db.assigned_nodes(*id).is_empty() {
            db.assign_nodes(*id, nodes, job.weight);
        }
        if db.set_job_state(*id, JobState::ToLaunch, now).is_ok() {
            db.log_event(now, "SCHEDULED", Some(*id), &format!("{nodes:?}"));
            let runtime = command_runtime(&job.command);
            launches.push((*id, nodes.clone(), runtime));
        }
    }
    drop(db);
    // One batched log write covers the whole round's mutations, before
    // any of its consequences (kills, launches, re-notify) take effect.
    inner.commit_wal();

    for (_id, nodes) in &kills {
        inner.launcher.kill(nodes);
    }
    if !decision.cancellations.is_empty() {
        inner.hub.notify(Task::Schedule);
    }
    for (id, nodes, runtime_ms) in launches {
        spawn_execution(inner.clone(), id, nodes, runtime_ms);
    }
}

/// The execution module: one thread per launched job (§2: "a module ...
/// for launching and controlling the execution of jobs").
fn spawn_execution(inner: Arc<Inner>, id: JobId, nodes: Vec<NodeId>, runtime_ms: Time) {
    std::thread::Builder::new()
        .name(format!("oar-exec-{id}"))
        .spawn(move || {
            let now = inner.now();
            {
                let mut db = inner.db.write().unwrap();
                if db.set_job_state(id, JobState::Launching, now).is_err() {
                    return; // cancelled before we started
                }
            }
            inner.commit_wal();
            let report = inner.launcher.launch(&nodes);
            let now = inner.now();
            if report.deployed.len() < nodes.len() {
                // The launcher's reachability/timeout detection (§2.4):
                // suspect the unreachable nodes right away so the next
                // scheduling round avoids them (the monitor will recover
                // them when they answer again).
                {
                    let mut db = inner.db.write().unwrap();
                    for n in &report.failed {
                        let _ = db.set_node_state(*n, crate::types::NodeState::Suspected);
                        db.log_event(now, "NODE_SUSPECTED", Some(id), &format!("node {n}"));
                    }
                }
                inner.commit_wal();
                inner.hub.push_event(JobEvent::LaunchFailed { job: id, at: now });
                return;
            }
            {
                let mut db = inner.db.write().unwrap();
                if db.set_job_state(id, JobState::Running, now).is_err() {
                    return; // killed during deployment
                }
                let _ = db.set_job_bpid(id, Some((id % u32::MAX as u64) as u32));
                db.log_event(now, "RUNNING", Some(id), "");
            }
            inner.commit_wal();
            // Simulate the command's execution on the virtual cluster.
            let scaled = Duration::from_millis(runtime_ms.max(0) as u64)
                .mul_f64(inner.time_scale.max(0.0));
            if !scaled.is_zero() {
                std::thread::sleep(scaled);
            }
            let at = inner.now();
            inner.hub.push_event(JobEvent::Ended { job: id, at, ok: true });
        })
        .expect("spawn execution thread");
}

/// The `oardel` body, shared by the synchronous command path and the
/// automaton's [`JobEvent::Cancel`] arm: fail the job through the
/// abnormal path, reclaim its nodes, trigger a scheduling round.
/// Idempotent — an already-terminal job is a successful no-op, so a
/// delete racing normal termination is harmless from either path;
/// unknown ids are an error (one lock acquisition covers the existence
/// check and the cancellation).
fn cancel_job(inner: &Arc<Inner>, id: JobId, at: Time) -> std::result::Result<(), DbError> {
    let mut db = inner.db.write().unwrap();
    let job = db.job(id)?;
    if job.state.is_terminal() {
        return Ok(());
    }
    let nodes = db.assigned_nodes(id);
    let _ = db.fail_job(id, "cancelled by user", at);
    db.log_event(at, "DELETION", Some(id), &job.user);
    drop(db);
    inner.commit_wal();
    if !nodes.is_empty() {
        inner.launcher.kill(&nodes);
    }
    inner.hub.notify(Task::Schedule);
    Ok(())
}

fn finish_job(inner: &Arc<Inner>, id: JobId, at: Time, ok: bool) {
    let mut db = inner.db.write().unwrap();
    let Ok(job) = db.job(id) else { return };
    if job.state.is_terminal() {
        return; // already failed/cancelled
    }
    let res = if ok {
        db.set_job_state(id, JobState::Terminated, at)
    } else {
        db.fail_job(id, "execution failed", at)
    };
    if res.is_ok() {
        db.log_event(at, "TERMINATED", Some(id), "");
    }
    drop(db);
    inner.commit_wal();
    inner.hub.notify(Task::Schedule);
}

/// Redundant safety net (§2.2): re-drive jobs that a lost notification or
/// a crashed execution thread left behind. `Running` past its
/// `maxTime` + grace is failed; `toLaunch`/`Launching` are left to their
/// execution threads (they always emit an event).
fn check_jobs(inner: &Arc<Inner>) {
    let now = inner.now();
    let overdue: Vec<JobId> = inner.read_db(|db| {
        db.jobs_in_state(JobState::Running)
            .into_iter()
            .filter(|j| {
                let started = j.start_time.unwrap_or(j.submission_time);
                now - started > j.max_time + 60_000
            })
            .map(|j| j.id)
            .collect()
    });
    if overdue.is_empty() {
        return; // the common case never takes the write lock
    }
    inner.write_db(|db| {
        for id in overdue {
            // Re-check under the write lock: the job may have terminated
            // between the read guard and here.
            if db.job(id).map(|j| j.state) != Ok(JobState::Running) {
                continue;
            }
            let _ = db.fail_job(id, "walltime exceeded", now);
            db.log_event(now, "WALLTIME_KILL", Some(id), "");
        }
    });
}

/// Simulated runtime of a job command, in milliseconds: `sleep N` runs N
/// seconds; anything else (`date`, `/bin/true`...) is instantaneous. This
/// is the virtual-cluster substitute for actually executing user binaries.
pub fn command_runtime(command: &str) -> Time {
    let mut parts = command.split_whitespace();
    match parts.next() {
        Some("sleep") => parts
            .next()
            .and_then(|s| s.parse::<f64>().ok())
            .map(|secs| (secs * 1000.0) as Time)
            .unwrap_or(0),
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_server() -> Server {
        test_server_scaled(0.0)
    }

    /// `scale` compresses modeled latencies and simulated runtimes.
    fn test_server_scaled(scale: f64) -> Server {
        let cluster = Arc::new(VirtualCluster::tiny(4, 1));
        let mut cfg = ServerConfig::fast(scale);
        cfg.sched.dense_matching = false; // keep unit tests artifact-free
        Server::new(cluster, cfg)
    }

    #[test]
    fn command_runtime_parses() {
        assert_eq!(command_runtime("date"), 0);
        assert_eq!(command_runtime("sleep 2"), 2000);
        assert_eq!(command_runtime("sleep 0.25"), 250);
        assert_eq!(command_runtime("sleep"), 0);
    }

    #[test]
    fn submit_runs_and_terminates() {
        let server = test_server();
        let id = server
            .submit(&JobSpec::batch("alice", "date", 2, 60))
            .unwrap()
            .unwrap();
        assert!(server.wait_all_terminal(Duration::from_secs(10)));
        let job = server.with_db(|db| db.job(id)).unwrap();
        assert_eq!(job.state, JobState::Terminated);
        assert!(job.response_time().is_some());
        let kinds: Vec<String> = server.with_db(|db| {
            db.events().iter().map(|e| e.kind.clone()).collect()
        });
        assert!(kinds.iter().any(|k| k == "SUBMISSION"));
        assert!(kinds.iter().any(|k| k == "SCHEDULED"));
        assert!(kinds.iter().any(|k| k == "TERMINATED"));
    }

    #[test]
    fn admission_rejection_is_reported() {
        let server = test_server();
        let res = server
            .submit(&JobSpec {
                queue: Some("nope".into()),
                ..JobSpec::default()
            })
            .unwrap();
        assert!(res.is_err());
        assert_eq!(server.with_db(|db| db.job_count()), 0);
    }

    #[test]
    fn delete_waiting_job() {
        // Non-zero scale: the blocker really occupies the cluster for
        // ~1.5 s, so job b is deterministically still Waiting when deleted.
        let server = test_server_scaled(0.05);
        let _block = server
            .submit(&JobSpec::batch("a", "sleep 30", 4, 60))
            .unwrap()
            .unwrap();
        let id = server
            .submit(&JobSpec::batch("b", "date", 4, 60))
            .unwrap()
            .unwrap();
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(
            server.with_db(|db| db.job(id)).unwrap().state,
            JobState::Waiting
        );
        server.delete(id).unwrap();
        let job = server.with_db(|db| db.job(id)).unwrap();
        assert_eq!(job.state, JobState::Error);
        assert!(server.wait_all_terminal(Duration::from_secs(20)));
    }

    #[test]
    fn request_delete_routes_through_the_automaton() {
        let server = test_server_scaled(0.05);
        let _block = server
            .submit(&JobSpec::batch("a", "sleep 30", 4, 60))
            .unwrap()
            .unwrap();
        let id = server
            .submit(&JobSpec::batch("b", "date", 4, 60))
            .unwrap()
            .unwrap();
        std::thread::sleep(Duration::from_millis(100));
        let state = server.request_delete(id).unwrap();
        assert_eq!(state, JobState::Waiting);
        // The Cancel event is processed by the automaton thread, not the
        // caller: poll for the outcome.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let s = server.with_db(|db| db.job(id)).unwrap().state;
            if s == JobState::Error {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "cancel event not processed, job stuck in {s}"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
        assert!(server.request_delete(999_999).is_err(), "unknown id must error");
        assert!(server.wait_all_terminal(Duration::from_secs(30)));
    }

    #[test]
    fn load_info_tracks_occupancy() {
        let server = test_server_scaled(0.05);
        let idle = server.load_info();
        assert_eq!(idle.nodes_total, 4);
        assert_eq!(idle.nodes_alive, 4);
        assert_eq!(idle.procs_total, 4);
        assert_eq!(idle.procs_free, 4);
        assert_eq!(idle.waiting_jobs, 0);
        let _block = server
            .submit(&JobSpec::batch("a", "sleep 30", 4, 60))
            .unwrap()
            .unwrap();
        // The blocker occupies the whole cluster once launched.
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let info = server.load_info();
            if info.procs_busy == 4 {
                assert_eq!(info.procs_free, 0);
                assert_eq!(info.running_jobs, 1);
                break;
            }
            assert!(Instant::now() < deadline, "blocker never occupied the cluster");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(server.wait_all_terminal(Duration::from_secs(30)));
        assert_eq!(server.load_info().procs_free, 4);
    }

    #[test]
    fn load_info_stays_coherent_when_a_node_dies_mid_run() {
        // Regression: the old probe summed procs_busy over Alive nodes
        // only, while running_jobs counted every resource-holding job —
        // killing a node under a running job inflated procs_free with
        // capacity that was already claimed, and the grid dispatched
        // waves against it.
        let server = test_server_scaled(0.05);
        let _job = server
            .submit(&JobSpec::batch("a", "sleep 30", 2, 60))
            .unwrap()
            .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while server.load_info().procs_busy != 2 {
            assert!(Instant::now() < deadline, "job never occupied its nodes");
            std::thread::sleep(Duration::from_millis(10));
        }
        let victim = server.with_db(|db| db.assigned_nodes(_job))[0];
        // Fail it for real (so the monitor keeps it Suspected) and mark
        // the database, as a monitoring round would.
        server.cluster().inject_failure(victim);
        server
            .with_db(|db| db.set_node_state(victim, crate::types::NodeState::Suspected))
            .unwrap();

        let info = server.load_info();
        assert_eq!(info.nodes_alive, 3);
        assert_eq!(info.procs_alive, 3);
        // The dead node's claimed proc is still claimed.
        assert_eq!(info.procs_busy, 2, "dead node's claim must stay counted");
        assert_eq!(info.running_jobs, 1);
        assert_eq!(
            info.procs_free,
            info.procs_alive.saturating_sub(info.procs_busy),
            "procs_free must stay coherent with the busy count"
        );
        assert_eq!(info.procs_free, 1);
        assert!(server.with_db(|db| db.verify_views()));
    }

    #[test]
    fn queues_are_served_by_priority() {
        let server = test_server();
        let queues = server.queues();
        assert_eq!(queues.len(), 2);
        assert_eq!(queues[0].name, "default");
        assert_eq!(queues[1].name, "besteffort");
    }

    #[test]
    fn impossible_job_becomes_error() {
        let server = test_server();
        let id = server
            .submit(&JobSpec::batch("a", "date", 64, 60))
            .unwrap()
            .unwrap();
        assert!(server.wait_all_terminal(Duration::from_secs(10)));
        let job = server.with_db(|db| db.job(id)).unwrap();
        assert_eq!(job.state, JobState::Error);
        assert!(job.message.contains("unsatisfiable"));
    }

    #[test]
    fn burst_of_jobs_all_terminate() {
        let server = test_server();
        let ids: Vec<JobId> = (0..50)
            .map(|i| {
                server
                    .submit(&JobSpec::batch(&format!("u{i}"), "date", 1, 60))
                    .unwrap()
                    .unwrap()
            })
            .collect();
        assert!(server.wait_all_terminal(Duration::from_secs(30)));
        let db_jobs = server.stat(Some("state = 'Terminated'")).unwrap();
        assert_eq!(db_jobs.len(), ids.len());
        let (_accepted, discarded) = server.hub_stats();
        // coalescing must have absorbed part of the submission storm
        assert!(discarded > 0, "expected redundant notifications");
    }

    #[test]
    fn hold_and_resume() {
        let server = test_server_scaled(0.05);
        let blocker = server
            .submit(&JobSpec::batch("a", "sleep 30", 4, 60))
            .unwrap()
            .unwrap();
        let id = server
            .submit(&JobSpec::batch("b", "date", 4, 60))
            .unwrap()
            .unwrap();
        server.hold(id).unwrap();
        std::thread::sleep(Duration::from_millis(100));
        let job = server.with_db(|db| db.job(id)).unwrap();
        assert_eq!(job.state, JobState::Hold);
        server.resume(id).unwrap();
        assert!(server.wait_all_terminal(Duration::from_secs(20)));
        assert_eq!(
            server.with_db(|db| db.job(id)).unwrap().state,
            JobState::Terminated
        );
        let _ = blocker;
    }

    #[test]
    fn array_submission_expands_parameters() {
        let server = test_server();
        let ids = server
            .submit_array(&JobSpec::batch("sweep", "date --param {i}", 1, 60), 5)
            .unwrap()
            .unwrap();
        assert_eq!(ids.len(), 5);
        assert!(server.wait_all_terminal(Duration::from_secs(20)));
        let cmds: Vec<String> = ids
            .iter()
            .map(|id| server.with_db(|db| db.job(*id)).unwrap().command)
            .collect();
        assert_eq!(cmds[0], "date --param 0");
        assert_eq!(cmds[4], "date --param 4");
    }

    #[test]
    fn array_submission_is_all_or_nothing() {
        let server = test_server();
        server.with_db(|db| {
            db.add_admission_rule(5, "IF command = 'date --p 3' THEN REJECT 'banned'")
        });
        let res = server
            .submit_array(&JobSpec::batch("sweep", "date --p {i}", 1, 60), 5)
            .unwrap();
        assert!(res.is_err(), "{res:?}");
        // earlier tasks were cancelled: nothing stays live, and anything
        // that slipped into execution before the rejection is at most the
        // 3 tasks submitted before the banned one.
        assert!(server.wait_all_terminal(Duration::from_secs(10)));
        assert!(server.stat(Some("state = 'Waiting'")).unwrap().is_empty());
        let cancelled = server.stat(Some("state = 'Error'")).unwrap();
        assert!(!cancelled.is_empty(), "at least one task must be cancelled");
    }

    #[test]
    fn shutdown_returns_database() {
        let server = test_server();
        let id = server
            .submit(&JobSpec::batch("a", "date", 1, 60))
            .unwrap()
            .unwrap();
        server.wait_all_terminal(Duration::from_secs(10));
        let mut db = server.shutdown();
        assert_eq!(db.job(id).unwrap().state, JobState::Terminated);
    }
}
