//! `oarlint` — lint the repository against its seven concurrency/durability
//! invariants (see `oar::analysis` and `docs/LINTS.md`).
//!
//! ```text
//! oarlint [--format human|json] [--root DIR] [--out FILE] [PATH...]
//! ```
//!
//! Defaults: root = the crate directory, paths = `rust/src rust/tests`,
//! human output. Exits 1 when any unsuppressed error survives — warnings
//! (malformed or unused suppressions) are reported but do not fail the
//! run, so a stale `allow` cannot mask a build while still being visible.
//! `--out FILE` writes the JSON report to a file regardless of the
//! terminal format (the CI job uploads it as an artifact).

use std::path::PathBuf;
use std::process::ExitCode;

use oar::analysis::{analyze_paths, RuleConfig};

fn main() -> ExitCode {
    let mut format = "human".to_string();
    let mut out_file: Option<PathBuf> = None;
    let mut root: Option<PathBuf> = None;
    let mut paths: Vec<String> = Vec::new();

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--format" => match args.next() {
                Some(v) if v == "human" || v == "json" => format = v,
                other => return usage(&format!("--format expects human|json, got {other:?}")),
            },
            "--out" => match args.next() {
                Some(v) => out_file = Some(PathBuf::from(v)),
                None => return usage("--out expects a file path"),
            },
            "--root" => match args.next() {
                Some(v) => root = Some(PathBuf::from(v)),
                None => return usage("--root expects a directory"),
            },
            "--help" | "-h" => {
                print!("{}", HELP);
                return ExitCode::SUCCESS;
            }
            other if other.starts_with('-') => {
                return usage(&format!("unknown flag {other:?}"));
            }
            path => paths.push(path.to_string()),
        }
    }

    let root = root.unwrap_or_else(|| {
        // The manifest dir when run via `cargo run`, else the cwd.
        std::env::var_os("CARGO_MANIFEST_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."))
    });
    if paths.is_empty() {
        paths = vec!["rust/src".to_string(), "rust/tests".to_string()];
    }
    let path_refs: Vec<&str> = paths.iter().map(String::as_str).collect();

    let report = match analyze_paths(&root, &path_refs, RuleConfig::repo()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("oarlint: {e}");
            return ExitCode::FAILURE;
        }
    };

    if let Some(out) = &out_file {
        if let Err(e) = std::fs::write(out, report.to_json().dump()) {
            eprintln!("oarlint: writing {}: {e}", out.display());
            return ExitCode::FAILURE;
        }
    }
    match format.as_str() {
        "json" => println!("{}", report.to_json().dump()),
        _ => print!("{}", report.render_human()),
    }

    if report.errors() > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

const HELP: &str = "\
oarlint — invariant checker for the oar scheduler (docs/LINTS.md)

USAGE: oarlint [--format human|json] [--root DIR] [--out FILE] [PATH...]

  --format   terminal output format (default human)
  --out      also write the JSON report to FILE (CI artifact)
  --root     repository root (default: CARGO_MANIFEST_DIR, else .)
  PATH...    root-relative files/dirs to lint (default: rust/src rust/tests)

Exit status: 1 if any unsuppressed error finding remains, else 0.
";

fn usage(msg: &str) -> ExitCode {
    eprintln!("oarlint: {msg}");
    eprint!("{}", HELP);
    ExitCode::FAILURE
}
