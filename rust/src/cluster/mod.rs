//! The virtual cluster substrate.
//!
//! The paper evaluates on two real testbeds — *Xeon* (17 bi-Xeon 2.4 GHz
//! compute nodes + 1 server, 34 processors) and *Icluster* (119 PIII
//! 733 MHz nodes + 1 PIII 866 server). We do not have those machines, so
//! this module simulates them: node inventories with the paper's property
//! values, plus a failure-injection surface the launcher's reachability
//! test observes (DESIGN.md substitution table).

use std::collections::HashSet;
use std::sync::Mutex;

use crate::db::Value;
use crate::types::{Node, NodeId};

/// Latency model of one remote-execution protocol (§2.4: Taktuk drives
/// standard rsh/ssh clients).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Protocol {
    /// Insecure, cheap connections.
    Rsh,
    /// Secure; key exchange makes connections an order of magnitude
    /// slower.
    Ssh,
}

impl Protocol {
    /// Per-connection setup latency, in microseconds. Values are
    /// representative of 2005-era LAN rsh vs ssh handshakes and are the
    /// knob behind fig. 10's four OAR settings.
    pub fn connect_micros(self) -> u64 {
        match self {
            Protocol::Rsh => 10_000,  // 10 ms
            Protocol::Ssh => 150_000, // 150 ms
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Protocol::Rsh => "rsh",
            Protocol::Ssh => "ssh",
        }
    }
}

/// A simulated cluster: inventory + failure set.
#[derive(Debug)]
pub struct VirtualCluster {
    pub name: &'static str,
    nodes: Vec<Node>,
    /// Nodes that currently do not answer connections.
    failed: Mutex<HashSet<NodeId>>,
}

impl VirtualCluster {
    /// The *Xeon* platform: 17 compute nodes, bi-Xeon 2.4 GHz, 512 MB RAM,
    /// 1 Gb/s Ethernet (34 processors exploited by the scheduler).
    pub fn xeon() -> VirtualCluster {
        let nodes = (1..=17)
            .map(|i| {
                Node::new(i, &format!("xeon-{i:02}"), 2)
                    .with_prop("mem", Value::Int(512))
                    .with_prop("cpu_mhz", Value::Int(2400))
                    .with_prop("eth_mbps", Value::Int(1000))
                    .with_prop("switch", Value::Text("sw1".into()))
            })
            .collect();
        VirtualCluster {
            name: "xeon",
            nodes,
            failed: Mutex::new(HashSet::new()),
        }
    }

    /// The *Icluster* platform: 119 PIII 733 MHz nodes, 256 MB RAM,
    /// 100 Mb/s Ethernet, spread over 5 switches.
    pub fn icluster() -> VirtualCluster {
        let nodes = (1..=119)
            .map(|i| {
                Node::new(i, &format!("ic-{i:03}"), 1)
                    .with_prop("mem", Value::Int(256))
                    .with_prop("cpu_mhz", Value::Int(733))
                    .with_prop("eth_mbps", Value::Int(100))
                    .with_prop("switch", Value::Text(format!("sw{}", (i - 1) / 24 + 1)))
            })
            .collect();
        VirtualCluster {
            name: "icluster",
            nodes,
            failed: Mutex::new(HashSet::new()),
        }
    }

    /// A tiny synthetic cluster for tests/examples.
    pub fn tiny(n: u32, procs: u32) -> VirtualCluster {
        let nodes = (1..=n)
            .map(|i| {
                Node::new(i, &format!("tiny-{i}"), procs)
                    .with_prop("mem", Value::Int(1024))
                    .with_prop("cpu_mhz", Value::Int(2000))
                    .with_prop("switch", Value::Text("sw1".into()))
            })
            .collect();
        VirtualCluster {
            name: "tiny",
            nodes,
            failed: Mutex::new(HashSet::new()),
        }
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn total_procs(&self) -> u32 {
        self.nodes.iter().map(|n| n.nb_procs).sum()
    }

    /// Register the inventory into a database: the resource *tree* is
    /// the source of truth — one cluster root, a switch row per distinct
    /// `switch` property, a host row per node (with cpu and core rows
    /// beneath it) — and the nodes table is materialized as the derived
    /// host-level view, exactly as the scheduler keeps reading it.
    pub fn register(&self, db: &mut crate::db::Db) {
        use crate::resources::Level;
        let root = db.add_resource(Level::Cluster, None, self.name, None);
        let mut switch_ids: std::collections::BTreeMap<String, u64> = std::collections::BTreeMap::new();
        for n in &self.nodes {
            let sw = n
                .properties
                .get("switch")
                .and_then(Value::as_str)
                .unwrap_or("sw0")
                .to_string();
            let sw_id = *switch_ids
                .entry(sw.clone())
                .or_insert_with(|| db.add_resource(Level::Switch, Some(root), &sw, None));
            let host = db.add_resource(Level::Host, Some(sw_id), &n.hostname, Some(n.id));
            // Model each host as one cpu holding its cores; per-core
            // rows make the core level queryable (`WHERE level='core'`).
            let cpu = db.add_resource(Level::Cpu, Some(host), &format!("{}-cpu0", n.hostname), None);
            for c in 0..n.nb_procs {
                db.add_resource(
                    Level::Core,
                    Some(cpu),
                    &format!("{}-core{c}", n.hostname),
                    None,
                );
            }
            db.add_node(n.clone());
        }
    }

    // ------------------------------------------------ failure surface ----

    /// Make a node stop answering connections.
    pub fn inject_failure(&self, node: NodeId) {
        self.failed.lock().unwrap().insert(node);
    }

    /// Bring a node back.
    pub fn repair(&self, node: NodeId) {
        self.failed.lock().unwrap().remove(&node);
    }

    /// Does the node answer connection attempts?
    pub fn is_reachable(&self, node: NodeId) -> bool {
        self.nodes.iter().any(|n| n.id == node) && !self.failed.lock().unwrap().contains(&node)
    }

    pub fn failed_nodes(&self) -> Vec<NodeId> {
        let mut v: Vec<NodeId> = self.failed.lock().unwrap().iter().copied().collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xeon_matches_paper_platform() {
        let c = VirtualCluster::xeon();
        assert_eq!(c.nodes().len(), 17);
        assert_eq!(c.total_procs(), 34);
        assert!(c.nodes().iter().all(|n| n.nb_procs == 2));
    }

    #[test]
    fn icluster_matches_paper_platform() {
        let c = VirtualCluster::icluster();
        assert_eq!(c.nodes().len(), 119);
        assert_eq!(c.total_procs(), 119);
        // spread over 5 switches
        let switches: std::collections::HashSet<_> = c
            .nodes()
            .iter()
            .filter_map(|n| n.properties.get("switch").and_then(Value::as_str))
            .map(str::to_string)
            .collect();
        assert_eq!(switches.len(), 5);
    }

    #[test]
    fn failure_injection_round_trip() {
        let c = VirtualCluster::tiny(3, 1);
        assert!(c.is_reachable(2));
        c.inject_failure(2);
        assert!(!c.is_reachable(2));
        assert_eq!(c.failed_nodes(), vec![2]);
        c.repair(2);
        assert!(c.is_reachable(2));
        // unknown nodes are never reachable
        assert!(!c.is_reachable(99));
    }

    #[test]
    fn protocol_latencies_ordered() {
        assert!(Protocol::Ssh.connect_micros() > Protocol::Rsh.connect_micros());
    }

    #[test]
    fn register_writes_the_resource_tree_and_derived_nodes() {
        use crate::resources::Level;
        let c = VirtualCluster::icluster();
        let mut db = crate::db::Db::with_standard_queues();
        c.register(&mut db);
        // 1 root + 5 switches + 119 hosts + 119 cpus + 119 cores.
        assert_eq!(db.resource_count(), 1 + 5 + 119 + 119 + 119);
        assert_eq!(db.resources_at(Level::Switch).len(), 5);
        assert_eq!(db.resources_at(Level::Host).len(), 119);
        // The nodes table is the derived host-level view.
        assert_eq!(db.all_nodes().len(), 119);
        // And the placement hierarchy reads back from the table.
        let h = db.hierarchy();
        assert_eq!(h.switches.len(), 5);
        assert_eq!(h.host_count(), 119);
        assert_eq!(h.core_count(), 119);
    }
}
