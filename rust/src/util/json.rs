//! Minimal JSON: enough for database snapshots, the AOT manifest, and
//! benchmark report files. Numbers are f64 (i64 values round-trip exactly
//! up to 2^53, far beyond any id or timestamp here).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::Result;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        anyhow::ensure!(p.pos == p.bytes.len(), "trailing data at {}", p.pos);
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self
            .bytes
            .get(self.pos)
            .map(|b| b.is_ascii_whitespace())
            .unwrap_or(false)
        {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        anyhow::ensure!(
            self.bytes.get(self.pos) == Some(&b),
            "expected {:?} at {}",
            b as char,
            self.pos
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.bytes.get(self.pos) {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                self.ws();
                if self.bytes.get(self.pos) == Some(&b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    self.ws();
                    items.push(self.value()?);
                    self.ws();
                    match self.bytes.get(self.pos) {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            break;
                        }
                        _ => anyhow::bail!("expected , or ] at {}", self.pos),
                    }
                }
                Ok(Json::Arr(items))
            }
            Some(b'{') => {
                self.pos += 1;
                let mut map = BTreeMap::new();
                self.ws();
                if self.bytes.get(self.pos) == Some(&b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                loop {
                    self.ws();
                    let key = self.string()?;
                    self.ws();
                    self.expect(b':')?;
                    self.ws();
                    let v = self.value()?;
                    map.insert(key, v);
                    self.ws();
                    match self.bytes.get(self.pos) {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            break;
                        }
                        _ => anyhow::bail!("expected , or }} at {}", self.pos),
                    }
                }
                Ok(Json::Obj(map))
            }
            Some(c) if c.is_ascii_digit() || *c == b'-' => self.number(),
            other => anyhow::bail!("unexpected {:?} at {}", other, self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "bad literal at {}",
            self.pos
        );
        self.pos += word.len();
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.bytes.get(self.pos) == Some(&b'-') {
            self.pos += 1;
        }
        while self
            .bytes
            .get(self.pos)
            .map(|b| b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bytes.get(self.pos) {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.bytes.get(self.pos) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run
                    let start = self.pos;
                    while matches!(self.bytes.get(self.pos), Some(b) if *b != b'"' && *b != b'\\')
                    {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
                None => anyhow::bail!("unterminated string"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::Num(1.0)),
            ("b", Json::Str("x\"y\n".into())),
            (
                "c",
                Json::Arr(vec![Json::Null, Json::Bool(true), Json::Num(-2.5)]),
            ),
            ("d", Json::obj(vec![("nested", Json::Num(9e15))])),
        ]);
        let text = v.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn integers_stay_integers_textually() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(-7.0).dump(), "-7");
        assert_eq!(Json::Num(1.5).dump(), "1.5");
    }

    #[test]
    fn parses_whitespace_and_unicode() {
        let v = Json::parse(" { \"k\" : [ 1 , \"\\u00e9t\\u00e9\" ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap()[1].as_str(), Some("été"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }
}
