//! Deterministic PRNG (SplitMix64) for workload generation and property
//! tests. Seeded runs are exactly reproducible across platforms.

/// SplitMix64: tiny, fast, passes BigCrush for these purposes.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`; n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // rejection sampling to avoid modulo bias
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick one element.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::new(7);
            (0..5).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(7);
            (0..5).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Rng::new(8);
            (0..5).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn ranges_hold() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
            let u = r.below(10);
            assert!(u < 10);
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Rng::new(2);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((800..1200).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
