//! Summary statistics for the benchmark harnesses (mean / percentiles /
//! stddev over latency samples).

/// Summary of a sample set.
#[derive(Debug, Clone, Copy, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Compute from raw samples (empty input → all zeros).
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |q: f64| sorted[(((n - 1) as f64) * q).round() as usize];
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: pct(0.5),
            p95: pct(0.95),
            max: sorted[n - 1],
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} std={:.3} min={:.3} p50={:.3} p95={:.3} max={:.3}",
            self.n, self.mean, self.std, self.min, self.p50, self.p95, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.std - 1.4142).abs() < 1e-3);
    }

    #[test]
    fn empty_is_zeroed() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }
}
