//! Small self-contained utilities: a JSON codec (persistence, manifests,
//! reports), a deterministic PRNG (workload generation, property tests)
//! and simple summary statistics (benchmark harnesses).
//!
//! All hand-rolled: the build is fully offline, so the crate depends on
//! nothing beyond `xla` + `anyhow` — in the spirit of the paper's
//! low-software-complexity argument (Table 1).

pub mod json;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
pub use stats::Summary;
