//! Minimal SIGINT/SIGTERM hook, so `oar serve` can run the clean-shutdown
//! checkpoint (WAL compaction) on Ctrl-C instead of only on normal
//! return.
//!
//! The build is offline/zero-dep, so no `signal-hook`/`libc` crates: on
//! unix the `signal(2)` symbol is reached directly over FFI (std already
//! links libc on every unix target). The handler body is
//! async-signal-safe — a single atomic store — and serving loops poll
//! [`shutdown_requested`]. Elsewhere [`install`] is a no-op and shutdown
//! is driven by [`request_shutdown`] (also the test hook).

use std::sync::atomic::{AtomicBool, Ordering};

static REQUESTED: AtomicBool = AtomicBool::new(false);

/// Has a shutdown signal (SIGINT/SIGTERM) been delivered — or
/// [`request_shutdown`] been called?
pub fn shutdown_requested() -> bool {
    REQUESTED.load(Ordering::SeqCst)
}

/// Programmatic shutdown request: what the signal handler does, callable
/// in-process (tests, embedding).
pub fn request_shutdown() {
    REQUESTED.store(true, Ordering::SeqCst);
}

/// Install the SIGINT + SIGTERM handlers (idempotent).
#[cfg(unix)]
pub fn install() {
    extern "C" fn handler(_signum: i32) {
        REQUESTED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, handler);
        signal(SIGTERM, handler);
    }
}

/// No signals to hook on non-unix targets; use [`request_shutdown`].
#[cfg(not(unix))]
pub fn install() {}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may panic on broken expectations
mod tests {
    use super::*;

    #[test]
    fn request_flag_roundtrip() {
        // `install` must at least not crash; the flag path is what the
        // serve loop actually polls.
        install();
        request_shutdown();
        assert!(shutdown_requested());
    }
}
