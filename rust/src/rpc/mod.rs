//! The network RPC front-end (§2.1–§2.2): the paper's user commands
//! (`oarsub`, `oarstat`, `oardel`, `oarnodes`) are separate client
//! programs that talk to the always-running server over TCP sockets —
//! "the automaton ... listens for external notifications" — and this
//! module gives the reproduction that client/server boundary.
//!
//! Layers, bottom-up:
//!
//! * [`wire`] — length-framed JSON frames (8-hex-char length prefix,
//!   16 MiB cap), the transport unit of the protocol.
//! * [`proto`] — versioned request/response envelopes with request ids,
//!   stable error codes, and the typed codecs for jobs, specs and queues.
//! * [`server`] — [`RpcServer`]: a threaded TCP front-end over a shared
//!   [`crate::server::Server`] (which is `Sync`: all state sits behind
//!   the database lock and the central automaton's event buffer) with a
//!   bounded worker pool, acceptor backpressure and graceful drain.
//! * [`client`] — [`RpcClient`]: the typed synchronous client library the
//!   CLI subcommands (`oar sub|stat|del|nodes|queues`) are built on.
//! * [`signal`] — SIGINT/SIGTERM → clean-shutdown flag for `oar serve`.
//!
//! Command flow is identical to in-process use: `sub` runs the admission
//! rules and then [`crate::central::NotificationHub::notify`], exactly
//! like [`crate::server::Server::submit`]; `del` is routed through the
//! automaton's job-event buffer ([`crate::central::JobEvent::Cancel`]) so
//! cancellation serializes with scheduling rounds. The wire format and
//! error codes are specified in `docs/PROTOCOL.md`.
//!
//! Panic-freedom: a panicking worker silently shrinks the pool, so
//! `unwrap()` is denied module-wide (request paths are additionally
//! checked by `oarlint` rule R5 — see `docs/LINTS.md`); test modules
//! opt back in locally.
#![deny(clippy::unwrap_used)]

pub mod client;
pub mod proto;
pub mod server;
pub mod signal;
pub mod wire;

pub use client::{CallResult, RpcClient, RpcError};
pub use proto::PROTOCOL_VERSION;
pub use server::{RpcConfig, RpcServer, DEFAULT_ADDR};

/// The front-end shares one [`crate::server::Server`] across its worker
/// threads; this assertion fails to compile if a refactor ever makes the
/// server non-shareable.
#[allow(dead_code)]
fn assert_server_is_shareable() {
    fn requires_send_sync<T: Send + Sync>() {}
    requires_send_sync::<std::sync::Arc<crate::server::Server>>();
}
