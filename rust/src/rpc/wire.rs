//! The wire format: length-framed JSON (specified in `docs/PROTOCOL.md`).
//!
//! A frame is `LLLLLLLL payload` — 8 ASCII lower-case hex characters
//! giving the payload byte length, immediately followed by that many
//! bytes of UTF-8 JSON. The same framing discipline as the WAL
//! (`db/wal.rs`), minus the checksum: TCP already guarantees integrity,
//! the length prefix only has to delimit messages. A frame is hard-capped
//! at [`MAX_FRAME`] bytes so a corrupt or malicious header cannot make
//! the server allocate unbounded memory.

use std::io::{Read, Write};

use crate::util::Json;
use crate::Result;

/// Hard cap on a frame payload (16 MiB). It binds in *both* directions:
/// `read_frame` rejects headers announcing more, and `write_frame`
/// refuses to start an oversized frame (`ErrorKind::InvalidData`, with
/// nothing written — the stream stays in sync, so the server can answer
/// with an error envelope instead). A `stat` over a large enough jobs
/// table can exceed this: narrow the filter.
pub const MAX_FRAME: usize = 16 << 20;

/// Bytes of the hex length header.
pub const HEADER_LEN: usize = 8;

/// Serialize `doc` and write it as one frame. The header and payload go
/// out in a single `write_all` so a frame is never interleaved with
/// another writer's bytes on the same stream. An over-[`MAX_FRAME`]
/// document fails with `ErrorKind::InvalidData` before any byte is
/// written.
pub fn write_frame(w: &mut impl Write, doc: &Json) -> std::io::Result<()> {
    let payload = doc.dump();
    let bytes = payload.as_bytes();
    if bytes.len() > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!(
                "frame of {} bytes exceeds the {MAX_FRAME}-byte cap",
                bytes.len()
            ),
        ));
    }
    let mut buf = Vec::with_capacity(HEADER_LEN + bytes.len());
    buf.extend_from_slice(format!("{:08x}", bytes.len()).as_bytes());
    buf.extend_from_slice(bytes);
    w.write_all(&buf)?;
    w.flush()
}

/// Read one frame. `Ok(None)` means the peer closed the connection
/// cleanly at a frame boundary; EOF anywhere inside a frame is an error
/// (a torn frame — the connection died mid-message).
pub fn read_frame(r: &mut impl Read) -> Result<Option<Json>> {
    let mut header = [0u8; HEADER_LEN];
    // Read the first byte separately: zero bytes here is a clean close,
    // not a protocol violation.
    let mut first = [0u8; 1];
    loop {
        match r.read(&mut first) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    header[0] = first[0];
    r.read_exact(&mut header[1..])?;
    let text = std::str::from_utf8(&header)
        .map_err(|_| anyhow::anyhow!("non-UTF8 frame header"))?;
    let len = usize::from_str_radix(text, 16)
        .map_err(|_| anyhow::anyhow!("bad frame header {text:?}"))?;
    anyhow::ensure!(
        len <= MAX_FRAME,
        "frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"
    );
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let text = std::str::from_utf8(&payload)?;
    Ok(Some(Json::parse(text)?))
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may panic on broken expectations
mod tests {
    use super::*;

    #[test]
    fn roundtrip_over_a_buffer() {
        let doc = Json::obj(vec![
            ("v", Json::Num(1.0)),
            ("method", Json::Str("ping".into())),
        ]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &doc).unwrap();
        write_frame(&mut buf, &Json::Null).unwrap();
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap(), Some(doc));
        assert_eq!(read_frame(&mut r).unwrap(), Some(Json::Null));
        assert_eq!(read_frame(&mut r).unwrap(), None, "clean EOF");
    }

    #[test]
    fn header_is_fixed_width_hex() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Json::Bool(true)).unwrap();
        assert_eq!(&buf[..HEADER_LEN], b"00000004");
        assert_eq!(&buf[HEADER_LEN..], b"true");
    }

    #[test]
    fn torn_frames_are_errors_not_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Json::Str("hello world".into())).unwrap();
        // cut inside the header
        let mut r = &buf[..4];
        assert!(read_frame(&mut r).is_err());
        // cut inside the payload
        let mut r = &buf[..HEADER_LEN + 3];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn bad_header_and_oversized_frames_are_rejected() {
        let mut r = &b"zzzzzzzz{}"[..];
        assert!(read_frame(&mut r).is_err());
        let mut r = &b"ffffffff"[..]; // 4 GiB claim, no payload
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn oversized_documents_are_refused_before_any_byte_is_written() {
        let doc = Json::Str("x".repeat(MAX_FRAME + 1));
        let mut buf = Vec::new();
        let err = write_frame(&mut buf, &doc).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(buf.is_empty(), "stream must stay in sync");
    }

    #[test]
    fn garbage_payload_is_an_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"00000003not");
        let mut r = &buf[..];
        assert!(read_frame(&mut r).is_err());
    }
}
