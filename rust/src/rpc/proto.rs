//! Request/response envelopes and the typed JSON codecs of the protocol
//! (`docs/PROTOCOL.md`).
//!
//! Every request is `{"v": 1, "id": N, "method": "...", "params": {...}}`
//! and every response echoes the id: `{"v": 1, "id": N, "ok": ...}` on
//! success, `{"v": 1, "id": N, "err": {"code": "...", "message": "..."}}`
//! on failure. `v` is the protocol version: a server answers a request
//! whose version it does not speak with `unsupported_version` (and its
//! own version in the message), so clients can fail with a clear
//! diagnostic instead of a decode error.

use crate::types::{
    Job, JobId, JobKind, JobSpec, JobState, Queue, QueuePolicyKind, ReservationField, Time,
};
use crate::util::Json;
use crate::Result;

/// The protocol version this build speaks.
pub const PROTOCOL_VERSION: i64 = 1;

/// Stable error codes (`err.code`). Messages are human-readable and may
/// change; codes are the machine contract.
pub mod code {
    /// Envelope or params malformed (missing method, bad field type,
    /// unknown field...).
    pub const BAD_REQUEST: &str = "bad_request";
    /// `v` is not a version this server speaks.
    pub const UNSUPPORTED_VERSION: &str = "unsupported_version";
    /// `method` is not part of the protocol.
    pub const UNKNOWN_METHOD: &str = "unknown_method";
    /// An admission rule fired `REJECT '<message>'`; the message travels
    /// verbatim in `err.message`.
    pub const ADMISSION_REJECTED: &str = "admission_rejected";
    /// The `stat` filter expression failed to parse.
    pub const BAD_FILTER: &str = "bad_filter";
    /// `del`/`hold`/`resume` named a job id the database does not know.
    pub const NO_SUCH_JOB: &str = "no_such_job";
    /// `hold`/`resume` targeted a job whose current state forbids the
    /// transition (fig. 1: only Waiting ⇄ Hold are legal).
    pub const ILLEGAL_STATE: &str = "illegal_state";
    /// The server is draining for shutdown and takes no new work.
    pub const SHUTTING_DOWN: &str = "shutting_down";
    /// Anything else (e.g. a stored admission rule that fails to parse).
    pub const INTERNAL: &str = "internal";

    /// Every stable code, for exhaustiveness checks (the per-code obs
    /// counters assert they cover this list).
    pub const ALL: &[&str] = &[
        BAD_REQUEST,
        UNSUPPORTED_VERSION,
        UNKNOWN_METHOD,
        ADMISSION_REJECTED,
        BAD_FILTER,
        NO_SUCH_JOB,
        ILLEGAL_STATE,
        SHUTTING_DOWN,
        INTERNAL,
    ];
}

/// Build a request envelope.
pub fn request(id: u64, method: &str, params: Json) -> Json {
    Json::obj(vec![
        ("v", Json::Num(PROTOCOL_VERSION as f64)),
        ("id", Json::Num(id as f64)),
        ("method", Json::Str(method.to_string())),
        ("params", params),
    ])
}

/// Build a success response.
pub fn ok_response(id: u64, result: Json) -> Json {
    Json::obj(vec![
        ("v", Json::Num(PROTOCOL_VERSION as f64)),
        ("id", Json::Num(id as f64)),
        ("ok", result),
    ])
}

/// Build an error response.
pub fn err_response(id: u64, code: &str, message: &str) -> Json {
    Json::obj(vec![
        ("v", Json::Num(PROTOCOL_VERSION as f64)),
        ("id", Json::Num(id as f64)),
        (
            "err",
            Json::obj(vec![
                ("code", Json::Str(code.to_string())),
                ("message", Json::Str(message.to_string())),
            ]),
        ),
    ])
}

/// Decode outcome: `(id, method, params)` on success, or the
/// best-effort request id (0 when unreadable) plus code/message for the
/// error response.
pub type DecodedRequest = std::result::Result<(u64, String, Json), (u64, &'static str, String)>;

/// Decode a request envelope.
pub fn decode_request(doc: &Json) -> DecodedRequest {
    // The id echoes verbatim, so it gets the same strict-integer
    // discipline as everything else: truncating 7.9 to 7 would hand an
    // id-checking client an opaque mismatch instead of a typed error.
    let id = match doc.get("id") {
        None | Some(Json::Null) => 0,
        Some(Json::Num(n)) if n.fract() == 0.0 && *n >= 0.0 => *n as u64,
        Some(other) => {
            return Err((
                0,
                code::BAD_REQUEST,
                format!("request id must be a non-negative integer, got {other:?}"),
            ))
        }
    };
    // A missing or non-integer `v` is a malformed envelope
    // (`bad_request`); `unsupported_version` is reserved for a
    // well-formed version this server does not speak. Strict integer
    // match: 1.5 is not version 1.
    let v = match doc.get("v") {
        Some(Json::Num(n)) if n.fract() == 0.0 => *n as i64,
        None | Some(Json::Null) => {
            return Err((id, code::BAD_REQUEST, "missing protocol version `v`".into()))
        }
        Some(other) => {
            return Err((
                id,
                code::BAD_REQUEST,
                format!("protocol version `v` must be an integer, got {other:?}"),
            ))
        }
    };
    if v != PROTOCOL_VERSION {
        return Err((
            id,
            code::UNSUPPORTED_VERSION,
            format!("request version {v}; this server speaks version {PROTOCOL_VERSION}"),
        ));
    }
    let Some(method) = doc.get("method").and_then(Json::as_str) else {
        return Err((id, code::BAD_REQUEST, "missing request method".into()));
    };
    let params = doc.get("params").cloned().unwrap_or(Json::Null);
    Ok((id, method.to_string(), params))
}

/// Strict integer read of an optional numeric field — the one validator
/// for every integer in `sub` params (spec fields and the `array`
/// campaign count): fractional values are rejected, never truncated.
pub fn int_param(doc: &Json, k: &str) -> Result<Option<i64>> {
    match doc.get(k) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Num(n)) if n.fract() == 0.0 => Ok(Some(*n as i64)),
        Some(other) => anyhow::bail!("field {k:?} must be an integer, got {other:?}"),
    }
}

fn opt_str(v: &Option<String>) -> Json {
    v.clone().map(Json::Str).unwrap_or(Json::Null)
}

fn opt_num(v: Option<i64>) -> Json {
    v.map(|n| Json::Num(n as f64)).unwrap_or(Json::Null)
}

// ----------------------------------------------------------- JobSpec ----

/// Fields the `sub` params object accepts. `array` is the campaign count
/// handled by the server, not a spec field.
const SPEC_FIELDS: &[&str] = &[
    "user",
    "command",
    "nbNodes",
    "weight",
    "maxTime",
    "properties",
    "queue",
    "interactive",
    "reservation",
    "launchingDirectory",
    "bestEffort",
    "array",
    "resources",
];

/// Encode a submission as `sub` params (field names follow fig. 2, as the
/// rest of the system does).
pub fn spec_to_json(spec: &JobSpec) -> Json {
    Json::obj(vec![
        ("user", Json::Str(spec.user.clone())),
        ("command", Json::Str(spec.command.clone())),
        ("nbNodes", Json::Num(spec.nb_nodes as f64)),
        ("weight", Json::Num(spec.weight as f64)),
        ("maxTime", opt_num(spec.max_time)),
        ("properties", opt_str(&spec.properties)),
        ("queue", opt_str(&spec.queue)),
        ("interactive", Json::Bool(spec.kind == JobKind::Interactive)),
        ("reservation", opt_num(spec.reservation_start)),
        (
            "launchingDirectory",
            Json::Str(spec.launching_directory.clone()),
        ),
        ("bestEffort", Json::Bool(spec.best_effort)),
        ("resources", opt_str(&spec.resources)),
    ])
}

/// Decode `sub` params into a [`JobSpec`]. Unknown fields are rejected
/// (a typo'd field silently ignored would submit a different job than
/// the user asked for). Absent fields keep [`JobSpec::default`] values so
/// the admission rules fill them, exactly as in-process submission does.
pub fn spec_from_json(doc: &Json) -> Result<JobSpec> {
    let Json::Obj(map) = doc else {
        anyhow::bail!("sub params must be an object");
    };
    for key in map.keys() {
        anyhow::ensure!(
            SPEC_FIELDS.contains(&key.as_str()),
            "unknown submission field {key:?}"
        );
    }
    let str_field = |k: &str| -> Result<Option<String>> {
        match doc.get(k) {
            None | Some(Json::Null) => Ok(None),
            Some(Json::Str(s)) => Ok(Some(s.clone())),
            Some(other) => anyhow::bail!("field {k:?} must be a string, got {other:?}"),
        }
    };
    let int_field = |k: &str| int_param(doc, k);
    let bool_field = |k: &str| -> Result<Option<bool>> {
        match doc.get(k) {
            None | Some(Json::Null) => Ok(None),
            Some(Json::Bool(b)) => Ok(Some(*b)),
            Some(other) => anyhow::bail!("field {k:?} must be a boolean, got {other:?}"),
        }
    };

    let mut spec = JobSpec::default();
    if let Some(u) = str_field("user")? {
        spec.user = u;
    }
    if let Some(c) = str_field("command")? {
        spec.command = c;
    }
    if let Some(n) = int_field("nbNodes")? {
        anyhow::ensure!((0..=u32::MAX as i64).contains(&n), "nbNodes out of range");
        spec.nb_nodes = n as u32;
    }
    if let Some(w) = int_field("weight")? {
        anyhow::ensure!((0..=u32::MAX as i64).contains(&w), "weight out of range");
        spec.weight = w as u32;
    }
    spec.max_time = int_field("maxTime")?;
    spec.properties = str_field("properties")?;
    spec.queue = str_field("queue")?;
    if bool_field("interactive")?.unwrap_or(false) {
        spec.kind = JobKind::Interactive;
    }
    spec.reservation_start = int_field("reservation")?;
    if let Some(d) = str_field("launchingDirectory")? {
        spec.launching_directory = d;
    }
    spec.best_effort = bool_field("bestEffort")?.unwrap_or(false);
    if let Some(r) = str_field("resources")? {
        // Validate with the total grammar here, so a malformed tree
        // request is a typed `bad_request` at the protocol edge — the
        // same field on an older server is rejected as an unknown
        // submission field (see PROTOCOL.md).
        crate::resources::parse_request(&r)
            .map_err(|e| anyhow::anyhow!("bad resources request: {e}"))?;
        spec.resources = Some(r);
    }
    Ok(spec)
}

// --------------------------------------------------------------- Job ----

/// Encode a full job row (`stat` results).
pub fn job_to_json(job: &Job) -> Json {
    Json::obj(vec![
        ("id", Json::Num(job.id as f64)),
        ("kind", Json::Str(job.kind.as_str().to_string())),
        ("infoType", opt_str(&job.info_type)),
        ("state", Json::Str(job.state.as_str().to_string())),
        (
            "reservation",
            Json::Str(job.reservation.as_str().to_string()),
        ),
        ("message", Json::Str(job.message.clone())),
        ("user", Json::Str(job.user.clone())),
        ("nbNodes", Json::Num(job.nb_nodes as f64)),
        ("weight", Json::Num(job.weight as f64)),
        ("command", Json::Str(job.command.clone())),
        ("bpid", opt_num(job.bpid.map(|b| b as i64))),
        ("queue", Json::Str(job.queue_name.clone())),
        ("maxTime", Json::Num(job.max_time as f64)),
        ("properties", Json::Str(job.properties.clone())),
        (
            "launchingDirectory",
            Json::Str(job.launching_directory.clone()),
        ),
        ("submissionTime", Json::Num(job.submission_time as f64)),
        ("startTime", opt_num(job.start_time)),
        ("stopTime", opt_num(job.stop_time)),
        ("bestEffort", Json::Bool(job.best_effort)),
        ("reservationStart", opt_num(job.reservation_start)),
        ("resources", opt_str(&job.resources)),
    ])
}

/// Decode a job row (client side of `stat`).
pub fn job_from_json(doc: &Json) -> Result<Job> {
    let req_str = |k: &str| -> Result<String> {
        doc.get(k)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("job record missing string field {k:?}"))
    };
    let req_num = |k: &str| -> Result<i64> {
        doc.get(k)
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow::anyhow!("job record missing numeric field {k:?}"))
    };
    let opt_num_field = |k: &str| doc.get(k).and_then(Json::as_i64);
    let opt_str_field = |k: &str| doc.get(k).and_then(Json::as_str).map(str::to_string);

    let state_s = req_str("state")?;
    let state = JobState::parse(&state_s)
        .ok_or_else(|| anyhow::anyhow!("unknown job state {state_s:?}"))?;
    let kind = match req_str("kind")?.as_str() {
        "INTERACTIVE" => JobKind::Interactive,
        "PASSIVE" => JobKind::Passive,
        other => anyhow::bail!("unknown job kind {other:?}"),
    };
    let reservation = match req_str("reservation")?.as_str() {
        "None" => ReservationField::None,
        "toSchedule" => ReservationField::ToSchedule,
        "Scheduled" => ReservationField::Scheduled,
        other => anyhow::bail!("unknown reservation field {other:?}"),
    };
    Ok(Job {
        id: req_num("id")?.max(0) as JobId,
        kind,
        info_type: opt_str_field("infoType"),
        state,
        reservation,
        message: req_str("message")?,
        user: req_str("user")?,
        nb_nodes: req_num("nbNodes")?.max(0) as u32,
        weight: req_num("weight")?.max(0) as u32,
        command: req_str("command")?,
        bpid: opt_num_field("bpid").map(|b| b.max(0) as u32),
        queue_name: req_str("queue")?,
        max_time: req_num("maxTime")? as Time,
        properties: req_str("properties")?,
        launching_directory: req_str("launchingDirectory")?,
        submission_time: req_num("submissionTime")? as Time,
        start_time: opt_num_field("startTime"),
        stop_time: opt_num_field("stopTime"),
        best_effort: doc.get("bestEffort").and_then(Json::as_bool).unwrap_or(false),
        reservation_start: opt_num_field("reservationStart"),
        resources: opt_str_field("resources"),
    })
}

// ------------------------------------------------------------- Queue ----

/// Encode a queue row (`queues` results).
pub fn queue_to_json(q: &Queue) -> Json {
    Json::obj(vec![
        ("name", Json::Str(q.name.clone())),
        ("priority", Json::Num(q.priority as f64)),
        ("policy", Json::Str(q.policy.as_str().to_string())),
        ("defaultMaxTime", Json::Num(q.default_max_time as f64)),
        ("maxProcsPerJob", Json::Num(q.max_procs_per_job as f64)),
        ("active", Json::Bool(q.active)),
    ])
}

/// Decode a queue row (client side of `queues`).
pub fn queue_from_json(doc: &Json) -> Result<Queue> {
    let name = doc
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("queue record missing name"))?;
    let policy_s = doc
        .get("policy")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("queue record missing policy"))?;
    let policy = QueuePolicyKind::parse(policy_s)
        .ok_or_else(|| anyhow::anyhow!("unknown queue policy {policy_s:?}"))?;
    Ok(Queue {
        name: name.to_string(),
        priority: doc.get("priority").and_then(Json::as_i64).unwrap_or(0) as i32,
        policy,
        default_max_time: doc
            .get("defaultMaxTime")
            .and_then(Json::as_i64)
            .unwrap_or(3600),
        max_procs_per_job: doc
            .get("maxProcsPerJob")
            .and_then(Json::as_i64)
            .map(|n| n.clamp(0, u32::MAX as i64) as u32)
            .unwrap_or(u32::MAX),
        active: doc.get("active").and_then(Json::as_bool).unwrap_or(true),
    })
}

// -------------------------------------------------------------- load ----

/// Encode a cluster occupancy probe (`load` result).
pub fn load_to_json(info: &crate::server::LoadInfo) -> Json {
    Json::obj(vec![
        ("nodesTotal", Json::Num(info.nodes_total as f64)),
        ("nodesAlive", Json::Num(info.nodes_alive as f64)),
        ("procsTotal", Json::Num(info.procs_total as f64)),
        ("procsAlive", Json::Num(info.procs_alive as f64)),
        ("procsBusy", Json::Num(info.procs_busy as f64)),
        ("procsFree", Json::Num(info.procs_free as f64)),
        ("waitingJobs", Json::Num(info.waiting_jobs as f64)),
        ("runningJobs", Json::Num(info.running_jobs as f64)),
    ])
}

/// Decode a cluster occupancy probe (client side of `load`).
pub fn load_from_json(doc: &Json) -> Result<crate::server::LoadInfo> {
    let field = |k: &str| -> Result<u32> {
        doc.get(k)
            .and_then(Json::as_i64)
            .filter(|v| (0..=u32::MAX as i64).contains(v))
            .map(|v| v as u32)
            .ok_or_else(|| anyhow::anyhow!("load result missing numeric field {k:?}"))
    };
    Ok(crate::server::LoadInfo {
        nodes_total: field("nodesTotal")?,
        nodes_alive: field("nodesAlive")?,
        procs_total: field("procsTotal")?,
        procs_alive: field("procsAlive")?,
        procs_busy: field("procsBusy")?,
        procs_free: field("procsFree")?,
        waiting_jobs: field("waitingJobs")?,
        running_jobs: field("runningJobs")?,
    })
}

// ----------------------------------------------------------- metrics ----

/// Encode a metrics registry snapshot (`metrics` result). Delegates to
/// the snapshot's own encoding: the `v` field *inside* the object is
/// the snapshot schema version ([`crate::obs::SNAPSHOT_VERSION`]),
/// versioned independently of the protocol envelope.
pub fn metrics_to_json(snap: &crate::obs::MetricsSnapshot) -> Json {
    snap.to_json()
}

/// Decode a metrics snapshot (client side of `metrics`).
pub fn metrics_from_json(doc: &Json) -> Result<crate::obs::MetricsSnapshot> {
    crate::obs::MetricsSnapshot::from_json(doc)
        .ok_or_else(|| anyhow::anyhow!("malformed metrics snapshot"))
}

// ------------------------------------------------------------ events ----

/// Encode an `events` result: the tail window (oldest first) plus the
/// total number of live records that matched the filter — so a client
/// showing the last N knows how many more it could have asked for.
pub fn events_to_json(records: &[crate::db::EventRecord], total: usize) -> Json {
    Json::obj(vec![
        (
            "events",
            Json::Arr(
                records
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("time", Json::Num(r.time as f64)),
                            ("kind", Json::Str(r.kind.clone())),
                            (
                                "job",
                                r.job.map(|j| Json::Num(j as f64)).unwrap_or(Json::Null),
                            ),
                            ("detail", Json::Str(r.detail.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("total", Json::Num(total as f64)),
    ])
}

/// Decode the client side of `events`.
pub fn events_from_json(doc: &Json) -> Result<(Vec<crate::db::EventRecord>, usize)> {
    let arr = doc
        .get("events")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("events result missing events array"))?;
    let records = arr
        .iter()
        .map(|item| -> Result<crate::db::EventRecord> {
            Ok(crate::db::EventRecord {
                time: item
                    .get("time")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| anyhow::anyhow!("event record missing time"))?,
                kind: item
                    .get("kind")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("event record missing kind"))?
                    .to_string(),
                job: item.get("job").and_then(Json::as_i64).map(|j| j.max(0) as JobId),
                detail: item
                    .get("detail")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string(),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let total = doc
        .get("total")
        .and_then(Json::as_i64)
        .filter(|t| *t >= 0)
        .ok_or_else(|| anyhow::anyhow!("events result missing total"))?;
    Ok((records, total as usize))
}

/// Encode submission ids (`sub` result).
pub fn ids_to_json(ids: &[JobId]) -> Json {
    Json::obj(vec![(
        "ids",
        Json::Arr(ids.iter().map(|i| Json::Num(*i as f64)).collect()),
    )])
}

/// Decode submission ids (client side of `sub`).
pub fn ids_from_json(doc: &Json) -> Result<Vec<JobId>> {
    let arr = doc
        .get("ids")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("sub result missing ids"))?;
    arr.iter()
        .map(|v| {
            v.as_i64()
                .filter(|i| *i >= 0)
                .map(|i| i as JobId)
                .ok_or_else(|| anyhow::anyhow!("non-numeric job id in sub result"))
        })
        .collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)] // tests may panic on broken expectations
mod tests {
    use super::*;

    #[test]
    fn envelope_roundtrip() {
        let req = request(7, "stat", Json::obj(vec![("filter", Json::Null)]));
        let (id, method, params) = decode_request(&req).unwrap();
        assert_eq!(id, 7);
        assert_eq!(method, "stat");
        assert_eq!(params.get("filter"), Some(&Json::Null));
    }

    #[test]
    fn version_mismatch_is_flagged_with_the_id() {
        let mut req = request(9, "ping", Json::Null);
        if let Json::Obj(m) = &mut req {
            m.insert("v".into(), Json::Num(99.0));
        }
        let (id, code, msg) = decode_request(&req).unwrap_err();
        assert_eq!(id, 9);
        assert_eq!(code, code::UNSUPPORTED_VERSION);
        assert!(msg.contains("99"), "{msg}");
    }

    #[test]
    fn missing_method_is_bad_request() {
        let doc = Json::obj(vec![("v", Json::Num(1.0)), ("id", Json::Num(1.0))]);
        let (_, code, _) = decode_request(&doc).unwrap_err();
        assert_eq!(code, code::BAD_REQUEST);
    }

    #[test]
    fn spec_roundtrip_preserves_every_field() {
        let spec = JobSpec {
            user: "alice".into(),
            command: "sleep 5".into(),
            nb_nodes: 3,
            weight: 2,
            max_time: Some(120),
            properties: Some("mem >= 512".into()),
            queue: Some("default".into()),
            kind: JobKind::Interactive,
            reservation_start: Some(4242),
            launching_directory: "/home/alice".into(),
            best_effort: true,
            resources: Some("/host=3/core=2".into()),
        };
        let back = spec_from_json(&spec_to_json(&spec)).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn spec_defaults_survive_an_empty_object() {
        let spec = spec_from_json(&Json::obj(vec![])).unwrap();
        assert_eq!(spec, JobSpec::default());
    }

    #[test]
    fn unknown_and_mistyped_spec_fields_are_rejected() {
        let doc = Json::obj(vec![("nbNodez", Json::Num(4.0))]);
        assert!(spec_from_json(&doc).is_err());
        let doc = Json::obj(vec![("nbNodes", Json::Str("four".into()))]);
        assert!(spec_from_json(&doc).is_err());
        // Fractional integers are rejected, never truncated.
        let doc = Json::obj(vec![("nbNodes", Json::Num(2.9))]);
        assert!(spec_from_json(&doc).is_err());
        let doc = Json::obj(vec![("maxTime", Json::Num(0.5))]);
        assert!(spec_from_json(&doc).is_err());
        assert!(spec_from_json(&Json::Null).is_err());
        // A malformed tree request is bad_request at the edge, with the
        // grammar's typed error in the message.
        let doc = Json::obj(vec![("resources", Json::Str("/rack=2".into()))]);
        let err = spec_from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("unknown resource level"), "{err}");
    }

    #[test]
    fn missing_or_noninteger_version_is_a_malformed_envelope() {
        // No `v` at all: bad_request, not a bogus "version -1" claim.
        let doc = Json::obj(vec![
            ("id", Json::Num(3.0)),
            ("method", Json::Str("ping".into())),
        ]);
        let (id, code, _) = decode_request(&doc).unwrap_err();
        assert_eq!(id, 3);
        assert_eq!(code, code::BAD_REQUEST);
        // Fractional `v`: malformed too (1.5 is not version 1).
        let mut req = request(3, "ping", Json::Null);
        if let Json::Obj(m) = &mut req {
            m.insert("v".into(), Json::Num(1.5));
        }
        let (_, code, _) = decode_request(&req).unwrap_err();
        assert_eq!(code, code::BAD_REQUEST);
    }

    #[test]
    fn job_roundtrip() {
        let spec = JobSpec::batch("bob", "date", 2, 60);
        let mut job = Job::from_spec(&spec, 1234);
        job.id = 17;
        job.state = JobState::Waiting;
        job.bpid = Some(99);
        let back = job_from_json(&job_to_json(&job)).unwrap();
        assert_eq!(back.id, 17);
        assert_eq!(back.user, "bob");
        assert_eq!(back.state, JobState::Waiting);
        assert_eq!(back.bpid, Some(99));
        assert_eq!(back.submission_time, 1234);
        assert_eq!(back.max_time, job.max_time);
    }

    #[test]
    fn queue_roundtrip() {
        for q in Queue::standard_set() {
            let back = queue_from_json(&queue_to_json(&q)).unwrap();
            assert_eq!(back.name, q.name);
            assert_eq!(back.priority, q.priority);
            assert_eq!(back.policy, q.policy);
            assert_eq!(back.max_procs_per_job, q.max_procs_per_job);
            assert_eq!(back.active, q.active);
        }
    }

    #[test]
    fn load_roundtrip() {
        let info = crate::server::LoadInfo {
            nodes_total: 17,
            nodes_alive: 16,
            procs_total: 34,
            procs_alive: 32,
            procs_busy: 10,
            procs_free: 22,
            waiting_jobs: 3,
            running_jobs: 5,
        };
        let back = load_from_json(&load_to_json(&info)).unwrap();
        assert_eq!(back, info);
        assert!(load_from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn ids_roundtrip() {
        let ids = vec![1u64, 5, 42];
        assert_eq!(ids_from_json(&ids_to_json(&ids)).unwrap(), ids);
        assert!(ids_from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn events_roundtrip() {
        let records = vec![
            crate::db::EventRecord {
                time: 10,
                kind: "SUBMISSION".into(),
                job: Some(3),
                detail: "alice".into(),
            },
            crate::db::EventRecord {
                time: 11,
                kind: "SCHEDULER_ROUND".into(),
                job: None,
                detail: String::new(),
            },
        ];
        let (back, total) = events_from_json(&events_to_json(&records, 57)).unwrap();
        assert_eq!(total, 57);
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].time, 10);
        assert_eq!(back[0].kind, "SUBMISSION");
        assert_eq!(back[0].job, Some(3));
        assert_eq!(back[0].detail, "alice");
        assert_eq!(back[1].job, None);
        assert!(events_from_json(&Json::obj(vec![])).is_err());
    }

    #[test]
    fn metrics_codec_delegates_to_the_snapshot_encoding() {
        let snap = crate::obs::snapshot(None);
        let back = metrics_from_json(&metrics_to_json(&snap)).unwrap();
        assert_eq!(back.version, crate::obs::SNAPSHOT_VERSION);
        assert_eq!(back.counters.len(), snap.counters.len());
        assert_eq!(back.hists.len(), snap.hists.len());
    }
}
