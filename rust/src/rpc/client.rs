//! The typed client library: what `oarsub`/`oarstat`/`oardel`/`oarnodes`
//! are to the paper's server, [`RpcClient`] is to ours — a thin
//! synchronous connection speaking the length-framed JSON protocol.
//!
//! One connection, strictly request/response: each call writes a frame,
//! blocks for the answer and checks that the echoed request id matches.
//! Server-side failures come back as the typed [`RpcError`] (stable
//! `code` + human message) inside `Ok(Err(..))`, transport failures as
//! the outer `Err` — mirroring how [`crate::server::Server::submit`]
//! separates rejection from breakage.

use std::fmt;
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::time::Duration;

use super::proto;
use super::wire;
use crate::types::{Job, JobId, JobSpec, JobState, Queue, Time};
use crate::util::Json;
use crate::Result;

/// A protocol-level error response from the server.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RpcError {
    /// Stable machine-readable code ([`super::proto::code`]).
    pub code: String,
    /// Human-readable detail (for `admission_rejected`, the rule's
    /// REJECT message verbatim).
    pub message: String,
}

impl fmt::Display for RpcError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

/// Outcome of one call: transport errors outside, protocol errors inside.
pub type CallResult<T> = Result<std::result::Result<T, RpcError>>;

/// A connected RPC client.
pub struct RpcClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl RpcClient {
    /// Connect to a serving front-end (`host:port`).
    pub fn connect(addr: &str) -> Result<RpcClient> {
        Self::from_stream(TcpStream::connect(addr)?)
    }

    /// Like [`RpcClient::connect`] but bounded by `timeout` per resolved
    /// address: a black-holed host (powered off, packets dropped) must
    /// fail within the caller's budget, not the OS connect default of
    /// minutes — the grid probes every cluster with this each round.
    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<RpcClient> {
        use std::net::ToSocketAddrs;
        let mut last: Option<std::io::Error> = None;
        for sa in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(stream) => return Self::from_stream(stream),
                Err(e) => last = Some(e),
            }
        }
        Err(match last {
            Some(e) => e.into(),
            None => anyhow::anyhow!("{addr}: no addresses resolved"),
        })
    }

    fn from_stream(stream: TcpStream) -> Result<RpcClient> {
        let _ = stream.set_nodelay(true);
        let read_half = stream.try_clone()?;
        Ok(RpcClient {
            reader: BufReader::new(read_half),
            writer: BufWriter::new(stream),
            next_id: 1,
        })
    }

    /// Set a read timeout for responses (None = block forever).
    pub fn set_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        Ok(())
    }

    /// Raw call: send `method`/`params`, return the `ok` payload or the
    /// typed error. Public so new methods can be driven before a typed
    /// wrapper exists.
    pub fn call(&mut self, method: &str, params: Json) -> CallResult<Json> {
        let id = self.next_id;
        self.next_id += 1;
        wire::write_frame(&mut self.writer, &proto::request(id, method, params))?;
        let doc = wire::read_frame(&mut self.reader)?
            .ok_or_else(|| anyhow::anyhow!("server closed the connection"))?;
        let rid = doc.get("id").and_then(Json::as_i64).unwrap_or(-1);
        anyhow::ensure!(
            rid == id as i64,
            "response id {rid} does not match request id {id}"
        );
        if let Some(err) = doc.get("err") {
            return Ok(Err(RpcError {
                code: err
                    .get("code")
                    .and_then(Json::as_str)
                    .unwrap_or(proto::code::INTERNAL)
                    .to_string(),
                message: err
                    .get("message")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
            }));
        }
        // Move the payload out of the owned document (a full-table `stat`
        // answer is multi-MB — no reason to deep-clone it).
        let Json::Obj(mut map) = doc else {
            anyhow::bail!("response envelope is not an object");
        };
        match map.remove("ok") {
            Some(ok) => Ok(Ok(ok)),
            None => anyhow::bail!("response carries neither ok nor err"),
        }
    }

    /// `ping`: liveness + clock; returns the server's `now` (ms since its
    /// start).
    pub fn ping(&mut self) -> CallResult<Time> {
        let res = self.call("ping", Json::Null)?;
        Ok(res.map(|ok| ok.get("now").and_then(Json::as_i64).unwrap_or(0)))
    }

    /// `sub`: submit one job; the admission rules run server-side.
    pub fn sub(&mut self, spec: &JobSpec) -> CallResult<JobId> {
        let res = self.call("sub", proto::spec_to_json(spec))?;
        match res {
            Ok(ok) => {
                let ids = proto::ids_from_json(&ok)?;
                anyhow::ensure!(ids.len() == 1, "sub acknowledged {} ids", ids.len());
                Ok(Ok(ids[0]))
            }
            Err(e) => Ok(Err(e)),
        }
    }

    /// `sub` with `array = n`: multi-parametric campaign (`{i}` in the
    /// command is replaced by the task index server-side).
    pub fn sub_array(&mut self, spec: &JobSpec, n: u32) -> CallResult<Vec<JobId>> {
        let mut params = proto::spec_to_json(spec);
        if let Json::Obj(map) = &mut params {
            map.insert("array".into(), Json::Num(n as f64));
        }
        let res = self.call("sub", params)?;
        match res {
            Ok(ok) => Ok(Ok(proto::ids_from_json(&ok)?)),
            Err(e) => Ok(Err(e)),
        }
    }

    /// `stat`: all jobs, optionally filtered by a WHERE clause over the
    /// raw job columns.
    pub fn stat(&mut self, filter: Option<&str>) -> CallResult<Vec<Job>> {
        let params = match filter {
            Some(f) => Json::obj(vec![("filter", Json::Str(f.to_string()))]),
            None => Json::Null,
        };
        let res = self.call("stat", params)?;
        match res {
            Ok(ok) => {
                let arr = ok
                    .get("jobs")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("stat result missing jobs"))?;
                Ok(Ok(arr
                    .iter()
                    .map(proto::job_from_json)
                    .collect::<Result<Vec<_>>>()?))
            }
            Err(e) => Ok(Err(e)),
        }
    }

    /// `del`: request cancellation; returns the state the job was
    /// observed in when the cancellation was enqueued (terminal states
    /// mean there was nothing left to cancel).
    pub fn del(&mut self, job: JobId) -> CallResult<JobState> {
        let res = self.call("del", Json::obj(vec![("id", Json::Num(job as f64))]))?;
        match res {
            Ok(ok) => {
                let s = ok
                    .get("state")
                    .and_then(Json::as_str)
                    .and_then(JobState::parse)
                    .ok_or_else(|| anyhow::anyhow!("del result missing state"))?;
                Ok(Ok(s))
            }
            Err(e) => Ok(Err(e)),
        }
    }

    /// `hold`: suspend a Waiting job (`oarhold`); returns the job's
    /// resulting state.
    pub fn hold(&mut self, job: JobId) -> CallResult<JobState> {
        self.hold_resume("hold", job)
    }

    /// `resume`: release a held job back to Waiting (`oarresume`).
    pub fn resume(&mut self, job: JobId) -> CallResult<JobState> {
        self.hold_resume("resume", job)
    }

    fn hold_resume(&mut self, method: &str, job: JobId) -> CallResult<JobState> {
        let res = self.call(method, Json::obj(vec![("id", Json::Num(job as f64))]))?;
        match res {
            Ok(ok) => {
                let s = ok
                    .get("state")
                    .and_then(Json::as_str)
                    .and_then(JobState::parse)
                    .ok_or_else(|| anyhow::anyhow!("{method} result missing state"))?;
                Ok(Ok(s))
            }
            Err(e) => Ok(Err(e)),
        }
    }

    /// `load`: the cluster occupancy probe the grid meta-scheduler sizes
    /// its dispatch waves with.
    pub fn load(&mut self) -> CallResult<crate::server::LoadInfo> {
        let res = self.call("load", Json::Null)?;
        match res {
            Ok(ok) => Ok(Ok(proto::load_from_json(&ok)?)),
            Err(e) => Ok(Err(e)),
        }
    }

    /// `nodes`: fleet summary as `(hostname, state, nbProcs)` rows.
    pub fn nodes(&mut self) -> CallResult<Vec<(String, String, u32)>> {
        let res = self.call("nodes", Json::Null)?;
        match res {
            Ok(ok) => {
                let arr = ok
                    .get("nodes")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("nodes result missing nodes"))?;
                let mut out = Vec::with_capacity(arr.len());
                for n in arr {
                    out.push((
                        n.get("hostname")
                            .and_then(Json::as_str)
                            .unwrap_or_default()
                            .to_string(),
                        n.get("state")
                            .and_then(Json::as_str)
                            .unwrap_or_default()
                            .to_string(),
                        n.get("nbProcs").and_then(Json::as_i64).unwrap_or(0).max(0) as u32,
                    ));
                }
                Ok(Ok(out))
            }
            Err(e) => Ok(Err(e)),
        }
    }

    /// `metrics`: the server's full observability snapshot — counters,
    /// gauges and latency histograms (see `docs/OBSERVABILITY.md`).
    pub fn metrics(&mut self) -> CallResult<crate::obs::MetricsSnapshot> {
        let res = self.call("metrics", Json::Null)?;
        match res {
            Ok(ok) => Ok(Ok(proto::metrics_from_json(&ok)?)),
            Err(e) => Ok(Err(e)),
        }
    }

    /// `events`: the newest `tail` event-log records (oldest first),
    /// optionally filtered by kind and/or job, plus the total number of
    /// live records matching the filter.
    pub fn events(
        &mut self,
        tail: usize,
        kind: Option<&str>,
        job: Option<JobId>,
    ) -> CallResult<(Vec<crate::db::EventRecord>, usize)> {
        let mut params = vec![("tail", Json::Num(tail as f64))];
        if let Some(k) = kind {
            params.push(("kind", Json::Str(k.to_string())));
        }
        if let Some(j) = job {
            params.push(("job", Json::Num(j as f64)));
        }
        let res = self.call("events", Json::obj(params))?;
        match res {
            Ok(ok) => Ok(Ok(proto::events_from_json(&ok)?)),
            Err(e) => Ok(Err(e)),
        }
    }

    /// `queues`: the queue table, by decreasing priority.
    pub fn queues(&mut self) -> CallResult<Vec<Queue>> {
        let res = self.call("queues", Json::Null)?;
        match res {
            Ok(ok) => {
                let arr = ok
                    .get("queues")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow::anyhow!("queues result missing queues"))?;
                Ok(Ok(arr
                    .iter()
                    .map(proto::queue_from_json)
                    .collect::<Result<Vec<_>>>()?))
            }
            Err(e) => Ok(Err(e)),
        }
    }
}
